"""Device-path evidence: slow-axis collective bytes, TAM vs two-phase.

Lowers both SPMD collective-write schedules for an 8-device
(2 nodes x 2 lagg x 2 lmem) mesh and parses the compiled HLO for
wire bytes per collective kind. derived = TAM/two-phase byte ratio on
the slow ('node') axis proxy (all_to_all + node-axis gathers).

Run in a subprocess (needs its own XLA device count).
"""
from __future__ import annotations

import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import IOConfig, contiguous_layout, make_tam_write, make_twophase_write
from repro.launch.hlo_analysis import HloCostModel

mesh = jax.make_mesh((2, 2, 2), ("node", "lagg", "lmem"))
layout = contiguous_layout(4096, 2)
# the paper's regime: request METADATA dominates payload (E3SM-F: 1.4e9
# tiny requests for 14 GiB). 256 adjacent 1-element requests per rank
# coalesce to ~1 run at the local aggregator, so TAM's inter-node
# metadata capacity is 16 pairs vs two-phase's 256.
cfg_tam = IOConfig(req_cap=256, data_cap=64, coalesce_cap=16)
cfg_2ph = IOConfig(req_cap=256, data_cap=64, coalesce_cap=256)

O = np.full((8, 256), 2**31 - 1, np.int32)
L = np.ones((8, 256), np.int32)
C = np.full(8, 256, np.int32)
D = np.ones((8, 64), np.int32)
for p in range(8):
    O[p] = np.arange(256, dtype=np.int32) + p * 256
    L[p] = 1

out = {}
for name, mk, cfg in (("tam", make_tam_write, cfg_tam),
                      ("twophase", make_twophase_write, cfg_2ph)):
    c = jax.jit(mk(mesh, layout, cfg)).lower(O, L, C, D).compile()
    t = HloCostModel(c.as_text()).total()
    out[name] = {k: v for k, v in t.coll_bytes.items()}
print(json.dumps(out))
"""


def collective_bytes():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [("spmd_bytes/ERROR", 0.0, proc.stderr.strip()[-120:])]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for name, kinds in data.items():
        for kind, v in sorted(kinds.items()):
            rows.append((f"spmd_bytes/{name}/{kind}", 0.0, int(v)))
    tot_tam = sum(data["tam"].values())
    tot_2ph = sum(data["twophase"].values())
    rows.append(("spmd_bytes/tam_over_twophase_total", 0.0,
                 round(tot_tam / max(tot_2ph, 1), 3)))
    return rows
