"""Benchmarks reproducing the paper's tables/figures.

Each function returns a list of (name, us_per_call, derived) rows.
Two layers of evidence per figure:
  * measured: the host-level collective I/O actually executed on scaled
    patterns (real byte movement, exact message/request counts);
  * modeled: the calibrated alpha-beta congestion model at the paper's
    full scale (P = 16384, 256 nodes, 56 OSTs).
"""
from __future__ import annotations

import time

import numpy as np

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import cost_model as cm

from benchmarks.workloads import HOST_PATTERNS, MODEL_WORKLOADS

PATTERNS = {
    "e3sm_g": (HOST_PATTERNS["e3sm_g"], MODEL_WORKLOADS["e3sm_g"]),
    "e3sm_f": (HOST_PATTERNS["e3sm_f"], MODEL_WORKLOADS["e3sm_f"]),
    # this suite runs btio at the paper's 64-block figure setting
    "btio": (lambda P: HOST_PATTERNS["btio"](P, n=64),
             MODEL_WORKLOADS["btio"]),
    "s3d": (HOST_PATTERNS["s3d"], MODEL_WORKLOADS["s3d"]),
}


def fig3_bandwidth():
    """Fig. 3: write bandwidth, TAM vs two-phase, strong scaling.

    Measured at laptop scale (16..64 ranks) + modeled at paper scale.
    derived = TAM/two-phase bandwidth ratio (speedup).
    """
    rows = []
    for pname, (gen, wl) in sorted(PATTERNS.items()):
        for P in (16, 64):
            reqs = gen(P)
            io = HostCollectiveIO(n_ranks=P, n_nodes=max(P // 8, 2),
                                  stripe_size=4096, stripe_count=4)
            t0 = time.perf_counter()
            t_tam = io.write(reqs, f"/tmp/bench_{pname}", method="tam",
                             local_aggregators=max(P // 4, 4))
            wall_tam = time.perf_counter() - t0
            t0 = time.perf_counter()
            t_2ph = io.write(reqs, f"/tmp/bench_{pname}",
                             method="twophase")
            wall_2ph = time.perf_counter() - t0
            rows.append((f"fig3/{pname}/P{P}/measured_tam",
                         wall_tam * 1e6,
                         round(t_2ph.total / max(t_tam.total, 1e-12), 2)))
        # paper scale (modeled)
        for P, nodes in ((4096, 64), (16384, 256)):
            w = wl(P, nodes)
            s = cm.speedup(w, 256)
            bw = w.total_bytes / cm.tam_cost(w, 256).total / 2**30
            rows.append((f"fig3/{pname}/P{P}/modeled",
                         cm.tam_cost(w, 256).total * 1e6,
                         round(s, 2)))
            rows.append((f"fig3/{pname}/P{P}/tam_GiBps", bw * 0 + bw,
                         round(bw, 2)))
    return rows


def fig4_7_breakdown():
    """Figs. 4-7: timing breakdown vs P_L (intra falls, inter grows).

    derived = fraction of end-to-end time in communication.
    """
    rows = []
    P = 64
    for pname, (gen, wl) in sorted(PATTERNS.items()):
        reqs = gen(P)
        io = HostCollectiveIO(n_ranks=P, n_nodes=8, stripe_size=4096,
                              stripe_count=4)
        for pl in (8, 16, 32, 64):
            t = io.write(reqs, f"/tmp/bench_bd_{pname}", method="tam",
                         local_aggregators=pl)
            rows.append((f"fig4_7/{pname}/PL{pl}/intra",
                         (t.intra_comm + t.intra_sort + t.intra_memcpy)
                         * 1e6, round(t.coalesce_ratio, 4)))
            rows.append((f"fig4_7/{pname}/PL{pl}/inter",
                         (t.inter_comm + t.inter_sort) * 1e6,
                         t.messages_at_ga))
    return rows


def fig2_congestion():
    """Fig. 2: receives at the hottest global aggregator vs P."""
    rows = []
    for P in (1024, 4096, 16384):
        w = cm.e3sm_f(P, max(P // 64, 1))
        rows.append((f"fig2/receives_per_ga/2ph/P{P}", 0.0,
                     cm.receives_per_global_aggregator(w, None)))
        rows.append((f"fig2/receives_per_ga/tam/P{P}", 0.0,
                     cm.receives_per_global_aggregator(w, 256)))
    return rows


def table1_coalesce():
    """Table I + SV-B: request counts and coalesce ratios (measured)."""
    rows = []
    P = 64
    io = HostCollectiveIO(n_ranks=P, n_nodes=8, stripe_size=1 << 16,
                          stripe_count=2)
    for pname, (gen, _) in sorted(PATTERNS.items()):
        t = io.write(gen(P), f"/tmp/bench_t1_{pname}", method="tam",
                     local_aggregators=16)
        rows.append((f"table1/{pname}/requests_before", 0.0,
                     t.requests_before))
        rows.append((f"table1/{pname}/coalesce_ratio", 0.0,
                     round(t.coalesce_ratio, 4)))
    return rows


def optimal_pl_sweep():
    """SV-A: the P_L balance point (paper: 256 on Theta)."""
    rows = []
    for pname, (_, wl) in sorted(PATTERNS.items()):
        w = wl(16384, 256)
        best, cost = cm.optimal_PL(w)
        rows.append((f"optimal_pl/{pname}", cost.total * 1e6, best))
    return rows
