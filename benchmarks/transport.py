"""Transport benchmark: real wire bytes, aggregated vs flat.

The paper's claim, measured on real processes instead of the
alpha-beta model: intra-node request aggregation (TAM with one local
aggregator per node) puts strictly fewer bytes on the inter-node wire
than flat two-phase, and the gap widens with ranks per node. Both
variants run on the mp transport backend (``checkpoint/mp_exec.py``)
— forked workers, shared-memory fast hop, localhost-socket slow hop —
so ``slow_hop_slow_bytes`` is counted at the RECEIVING socket, not
modeled.

The workload is the checkpoint-shard shape: every rank owns an
interleaved stride of fixed-size chunks, so each cb window holds data
from all co-located ranks — exactly what stage-1 aggregation combines
(coalesced pair metadata + one combined frame per node instead of one
frame per sender). Sweeps ranks-per-node in {2, 4, 8} on 2 nodes.

Each point also compiles and runs the SAME config on the in-process
host executor, giving (a) the byte-identity oracle and (b) the
MODELED total the cost model predicts; the gate checks that the
model's ranking of points agrees with the measured wall-clock ranking
(concordance), so the planner's auto-resolution keeps steering the
real backend correctly.

Emits ``BENCH_transport.json`` for ``check_regression.py
--transport``, which enforces:

* every point byte-identical to the host oracle;
* aggregated slow-hop wire bytes STRICTLY below flat two-phase at
  >= 4 ranks per node (and never above it at 2);
* modeled-vs-measured ordering concordance >= 0.6 over point pairs
  whose modeled totals differ by more than 10%.

Wall times are real (min over ``REPEATS``), so the committed baseline
(``benchmarks/baselines/BENCH_transport_baseline.json``) pins point
coverage only; every timing bound is a within-artifact comparison.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.plan import IOConfig

NODES = 2
RPNS = (2, 4, 8)
REPEATS = 3
CHUNK = 64                 # bytes per request chunk
CHUNKS_PER_RANK = 64       # 4 KiB per rank -> 64 KiB file at rpn=8
CB = 2048                  # window bytes: 32 chunks, all ranks present
VARIANTS = ("flat", "aggregated")


def _reqs(n_ranks: int):
    """Interleaved per-rank chunks: rank r owns chunks r, r+P, ..."""
    out = []
    for r in range(n_ranks):
        offs = (np.arange(CHUNKS_PER_RANK, dtype=np.int64) * n_ranks
                + r) * CHUNK
        lens = np.full(CHUNKS_PER_RANK, CHUNK, np.int64)
        pay = ((offs[:, None] + np.arange(CHUNK)) % 251) \
            .astype(np.uint8).ravel()
        out.append((offs, lens, pay))
    return out


def _cfg(transport=None):
    return IOConfig(req_cap=0, data_cap=0, cb_buffer_size=CB,
                    transport=transport)


def _write_kw(variant: str):
    if variant == "aggregated":
        return dict(method="tam", local_aggregators=NODES)
    return dict(method="twophase")


def _segs(path: str) -> list[bytes]:
    return [p.read_bytes() for p in sorted(Path(path).parent.glob(
        Path(path).name + ".seg*"))]


def _point(rpn: int, variant: str, d: str) -> dict:
    io = HostCollectiveIO(n_ranks=NODES * rpn, n_nodes=NODES,
                          stripe_size=4096, stripe_count=2)
    rr = _reqs(io.n_ranks)
    kw = _write_kw(variant)
    th = io.write(rr, f"{d}/host", config=_cfg(), **kw)
    walls, tm = [], None
    for rep in range(REPEATS):
        t = io.write(rr, f"{d}/mp{rep}", config=_cfg("mp"), **kw)
        walls.append(t.total)          # measured wall-clock rounds
        if tm is None or t.total == min(walls):
            tm = t
    return {
        "rpn": rpn, "variant": variant, "ranks": io.n_ranks,
        "wall_s": min(walls), "walls_s": sorted(walls),
        "modeled_s": th.total,
        "wire_slow_bytes": tm.slow_hop_slow_bytes,
        "wire_fast_bytes": tm.slow_hop_fast_bytes,
        "messages_at_ga": tm.messages_at_ga,
        "byte_identical": all(
            _segs(f"{d}/host") == _segs(f"{d}/mp{rep}")
            for rep in range(REPEATS)) and len(_segs(f"{d}/host")) > 0,
    }


def wire_sweep():
    """benchmarks.run suite: rpn x {flat, aggregated} on the mp
    backend, plus the host oracle per point."""
    blob = {"config": {"nodes": NODES, "rpns": list(RPNS),
                       "repeats": REPEATS, "chunk": CHUNK,
                       "chunks_per_rank": CHUNKS_PER_RANK,
                       "cb_bytes": CB},
            "points": []}
    d = tempfile.mkdtemp(prefix="bench_transport_")
    try:
        for rpn in RPNS:
            for variant in VARIANTS:
                blob["points"].append(_point(rpn, variant, d))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    out = os.environ.get("BENCH_TRANSPORT_OUT", "BENCH_transport.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    rows = []
    for p in blob["points"]:
        rows.append((
            f"transport_rpn{p['rpn']}_{p['variant']}",
            p["wall_s"] * 1e6,
            f"slow_wire={p['wire_slow_bytes']}"
            f" msgs_at_ga={p['messages_at_ga']}"
            f" bytes_ok={p['byte_identical']}"))
    return rows
