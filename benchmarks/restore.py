"""Restore-path sweep: replica fan-out x workload, cold vs warm cache.

The serving scenario the node-level read cache exists for: q model
replicas per node all pull the same checkpoint at startup. Without the
cache every co-located reader pays the slow hop itself, so restore time
scales with the replica count; with it each node's elected fetcher pays
the slow hop ONCE per window and fans out intra-node, so the curve goes
flat. This suite measures that curve and emits ``BENCH_restore.json``
for the CI gate (``check_regression.py --restore``):

* **replica sweep** — for each gated workload, the file is written once
  and then read back by 2 / 4 / 8 replicas per node (every reader wants
  the whole file), cache on and off. Gated: cache-on total stays flat
  within ``RESTORE_FLAT_X`` (1.3x) from 2 -> 8 replicas; cache-on never
  models slower than cache-off at any point; every read is
  byte-identical to the single-reader ``read_file`` oracle; cache-on
  ``hits + misses`` equals cache-off ``misses`` (same deliveries,
  different transport).
* **cold vs warm** — the same restore driven through an ``IOSession``
  with every knob ``"auto"``: the first read compiles + sweeps
  (``cold_s``), repeats hit the cached read plan (``warm_s``,
  ``plan_source="session-hit"``). Gated: warm never models worse than
  cold (the read arbiter keeps the best measured plan).
* **subset** — a pytree checkpoint restored with a half-tree
  ``subset=``: ranged segment reads must fetch only the selected
  leaves' bytes. Gated: ``read_bytes < 50%`` of ``file_len``.

Timings are MODELED seconds (deterministic), so the gate's bounds are
stable; the committed baseline
(``benchmarks/baselines/BENCH_restore_baseline.json``) pins workload
COVERAGE only, never wall times, and only ever grows additively.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from benchmarks.workloads import HOST_PATTERNS
from repro.checkpoint.checkpoint import (manifest_fingerprint,
                                         restore_checkpoint,
                                         save_checkpoint)
from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.cost_model import Machine
from repro.core.plan import IOConfig
from repro.core.session import IOSession

NODES, STRIPE, STRIPE_COUNT = 2, 1024, 4
WRITER_RANKS = 16    # btio needs a square rank count
REPLICAS = (2, 4, 8)            # readers per node
WORKLOADS = ("btio", "e3sm_f", "sparse_ckpt")
CB = 4096                        # fixed cb for the replica sweep: the
# flatness bound compares totals ACROSS reader counts, so the plan must
# not re-pick cb per point
AUTO = IOConfig(req_cap=0, data_cap=0, cb_buffer_size="auto",
                pipeline=True, pipeline_depth="auto", placement="auto",
                slow_hop_codec="auto")


def _machine() -> Machine:
    return Machine(io_bw=5e7)


def _io(n_ranks, session=None) -> HostCollectiveIO:
    return HostCollectiveIO(n_ranks=n_ranks, n_nodes=NODES,
                            stripe_size=STRIPE, stripe_count=STRIPE_COUNT,
                            machine=_machine(), session=session)


def _write_file(wl: str, d: str) -> tuple[str, int]:
    """Write the workload's pattern once; return (path, file_len)."""
    reqs = HOST_PATTERNS[wl](WRITER_RANKS)
    extent = max(int((o + ln).max()) for o, ln, _ in reqs if o.size)
    path = f"{d}/{wl}"
    _io(WRITER_RANKS).write(reqs, path, method="tam",
                            config=IOConfig(req_cap=0, data_cap=0))
    return path, extent


def _read_stats(t) -> dict:
    return {"total_s": float(t.total),
            "hit_ratio": float(t.cache_hit_ratio),
            "cache_hits": int(t.cache_hits),
            "cache_misses": int(t.cache_misses),
            "read_bytes": int(t.read_bytes),
            "slow_bytes": int(t.slow_hop_slow_bytes)}


def _replica_sweep(wl: str, path: str, file_len: int) -> dict:
    oracle = _io(WRITER_RANKS).read_file(path, file_len)
    out = {}
    for q in REPLICAS:
        io = _io(q * NODES)
        reqs = [(np.asarray([0], np.int64),
                 np.asarray([file_len], np.int64))] * io.n_ranks
        point = {}
        for nc in (True, False):
            outs, t = io.read(reqs, path,
                              config=IOConfig(req_cap=0, data_cap=0,
                                              cb_buffer_size=CB),
                              node_cache=nc)
            point["cache_on" if nc else "cache_off"] = _read_stats(t)
            point.setdefault("byte_identical", True)
            point["byte_identical"] &= all(
                np.array_equal(o, oracle) for o in outs)
        point["delivery_conserved"] = (
            point["cache_on"]["cache_hits"]
            + point["cache_on"]["cache_misses"]
            == point["cache_off"]["cache_misses"])
        out[str(q)] = point
    return out


def _cold_warm(wl: str, path: str, file_len: int) -> dict:
    """Session-driven restore with every knob auto: first read compiles
    (cold), repeats hit the cached read plan (warm)."""
    sess = IOSession(machine=_machine())
    io = _io(REPLICAS[0] * NODES, session=sess)
    reqs = [(np.asarray([0], np.int64),
             np.asarray([file_len], np.int64))] * io.n_ranks
    totals, sources = [], []
    for _ in range(4):
        _, t = io.read(reqs, path, config=AUTO)
        totals.append(float(t.total))
        sources.append(t.plan_source)
    return {"cold_s": totals[0], "warm_s": totals[-1],
            "sources": sources, "plan_reused": sources[-1] == "session-hit"}


def _subset(d: str) -> dict:
    """Half-tree partial restore: ranged reads fetch only the selected
    leaves' bytes."""
    rng = np.random.default_rng(0)
    tree = {"w": rng.standard_normal((64, 64)).astype(np.float32),
            "b": rng.standard_normal(64).astype(np.float32),
            "opt": {"m": np.zeros((64, 64), np.float32),
                    "v": np.zeros((64, 64), np.float32)}}
    io = _io(WRITER_RANKS)
    man, _ = save_checkpoint(tree, f"{d}/ck", io=io, method="twophase")
    sub = [e["path"] for e in man["leaves"] if "opt" not in e["path"]]
    like = {"w": np.zeros_like(tree["w"]), "b": np.zeros_like(tree["b"]),
            "opt": {"m": np.zeros_like(tree["opt"]["m"]),
                    "v": np.zeros_like(tree["opt"]["v"])}}
    got, _, t = restore_checkpoint(f"{d}/ck", like, io=io, subset=sub,
                                   with_timings=True)
    ok = (np.array_equal(got["w"], tree["w"])
          and np.array_equal(got["b"], tree["b"]))
    return {"read_bytes": int(t.read_bytes),
            "file_len": int(man["file_len"]),
            "frac": t.read_bytes / man["file_len"],
            "subset_leaves": sub,
            "fingerprint": manifest_fingerprint(man),
            "byte_identical": bool(ok)}


def replica_cache_sweep():
    """benchmarks.run suite: the full replica x workload restore sweep."""
    blob = {"config": {"nodes": NODES, "writer_ranks": WRITER_RANKS,
                       "replicas": list(REPLICAS), "cb_bytes": CB,
                       "stripe_size": STRIPE,
                       "stripe_count": STRIPE_COUNT, "io_bw": 5e7},
            "workloads": {}}
    rows = []
    for wl in WORKLOADS:
        with tempfile.TemporaryDirectory() as d:
            path, file_len = _write_file(wl, d)
            entry = {"file_len": file_len,
                     "replicas": _replica_sweep(wl, path, file_len),
                     "session": _cold_warm(wl, path, file_len)}
        blob["workloads"][wl] = entry
        for q, p in entry["replicas"].items():
            rows.append((
                f"restore_{wl}_q{q}", p["cache_on"]["total_s"] * 1e6,
                f"off={p['cache_off']['total_s'] * 1e6:.1f}us "
                f"hit_ratio={p['cache_on']['hit_ratio']:.2f} "
                f"bytes_ok={p['byte_identical']}"))
        rows.append((
            f"restore_{wl}_warm", entry["session"]["warm_s"] * 1e6,
            f"cold={entry['session']['cold_s'] * 1e6:.1f}us "
            f"reused={entry['session']['plan_reused']}"))
    with tempfile.TemporaryDirectory() as d:
        blob["subset"] = _subset(d)
    rows.append((
        "restore_subset_half_tree", 0.0,
        f"frac={blob['subset']['frac']:.2f} "
        f"bytes={blob['subset']['read_bytes']}/"
        f"{blob['subset']['file_len']}"))
    out = os.environ.get("BENCH_RESTORE_OUT", "BENCH_restore.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    return rows
