"""Degraded-mode scenario matrix: the fault layer, end to end.

Sweeps the fault scenarios the paper's scale makes routine — a slow
node, a dead aggregator mid-round, a resize event mid write-loop —
across the gated host workloads (btio, e3sm_f, sparse_ckpt), each with
a healthy control, and emits ``BENCH_degraded.json`` for the CI gate
(``check_regression.py --degraded``):

* every write of every scenario is checked byte-identical against the
  healthy oracle (recovery must never cost correctness);
* **slow_node**: 3 healthy writes, then a 6x straggler on node 1. The
  session's measured ``node_slowdown`` feedback must move every served
  domain off the straggler within ONE write of the fault appearing
  (``adaptation_writes``), the steady degraded total must stay within
  1.5x of healthy (the straggler keeps only its un-evictable stage-1
  share), and the straggler's served-domain share must drop
  (``slow_share_before`` -> ``slow_share_after``);
* **dead_aggregator**: slot 2's node dies entering round 1. The write
  must COMPLETE (repair re-route + round replay + torn-segment
  rewrite), with the recovery cost reported and bounded
  (``recovery_s``);
* **resize**: node 3 is lost between writes; the loop replans through
  ``core.faults.apply_resize`` / ``runtime.elastic.plan_remesh`` onto
  the shrunken writer and keeps writing byte-identical files instead
  of wedging.

The machine is io-dominant (``io_bw=5e7``) so the per-node service-rate
signal is clean and the evacuated steady state is close to healthy —
same setup as tests/test_faults.py, at benchmark write counts.
Scenario timings are MODELED seconds (deterministic), so the gate's
bounds are stable; the committed baseline
(``benchmarks/baselines/BENCH_degraded_baseline.json``) pins scenario
COVERAGE only, never wall times.
"""
from __future__ import annotations

import json
import os
import warnings

import numpy as np

from benchmarks.workloads import HOST_PATTERNS
from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.cost_model import Machine
from repro.core.faults import FaultSpec, apply_resize
from repro.core.placement import node_of_slot
from repro.core.plan import IOConfig
from repro.core.session import IOSession
from repro.runtime.heartbeat import HeartbeatMonitor

P, NODES, STRIPE, STRIPE_COUNT = 16, 4, 1024, 8
SLOW_NODE, SLOW_FACTOR = 1, 6.0
HEALTHY_WRITES = 3          # writes before the fault in every scenario
WORKLOADS = ("btio", "e3sm_f", "sparse_ckpt")
CONFIG = IOConfig(req_cap=0, data_cap=0, cb_buffer_size="auto",
                  pipeline=True, pipeline_depth="auto", placement="auto")


def _machine() -> Machine:
    return Machine(io_bw=5e7)


def _writer(session=None, machine=None) -> HostCollectiveIO:
    return HostCollectiveIO(n_ranks=P, n_nodes=NODES, stripe_size=STRIPE,
                            stripe_count=STRIPE_COUNT,
                            machine=machine or _machine(),
                            session=session)


def _reference(reqs) -> np.ndarray:
    n = max(int((o + ln).max()) for o, ln, _ in reqs if o.size)
    out = np.zeros(n, np.uint8)
    for offs, lens, data in reqs:
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]]) \
            if offs.size else []
        for o, ln, s in zip(offs, lens, starts):
            out[o:o + ln] = data[s:s + ln]
    return out


def _identical(io, path: str, ref: np.ndarray) -> bool:
    return bool(np.array_equal(io.read_file(path, ref.size), ref))


def _write(io, reqs, path, faults=None, heartbeat=None):
    return io.write(reqs, path, method="tam", local_aggregators=8,
                    config=CONFIG, faults=faults, heartbeat=heartbeat)


def _slow_share(t, n_agg: int, n_nodes: int, node: int) -> float:
    """Fraction of served domains the node carries under the write's
    effective serve map (the plan's bijection when no serve map ran)."""
    serve = t.serve_map if t.serve_map is not None else \
        (t.placement if t.placement is not None else range(n_agg))
    served = [node_of_slot(int(s), n_agg, n_nodes) for s in serve]
    return served.count(node) / float(n_agg)


def _row(t, io, path, ref) -> dict:
    return {"total_s": float(t.total), "source": t.plan_source,
            "recovery_s": float(t.recovery_seconds),
            "retries": int(t.retries),
            "torn_repaired": int(t.torn_writes_detected),
            "evacuated": t.serve_map is not None,
            "slow_share": _slow_share(t, STRIPE_COUNT, io.n_nodes,
                                      SLOW_NODE),
            "byte_identical": _identical(io, path, ref)}


def _steady(writes: list[dict]) -> float:
    return min(w["total_s"] for w in writes[-2:])


def _scenario_healthy(wl, reqs, ref, outdir) -> dict:
    io = _writer(IOSession(machine=_machine()))
    writes = [_row(_write(io, reqs, f"{outdir}/h{i}"), io,
                   f"{outdir}/h{i}", ref) for i in range(6)]
    return {"workload": wl, "scenario": "healthy", "completed": True,
            "byte_identical": all(w["byte_identical"] for w in writes),
            "healthy_steady_s": _steady(writes),
            "degraded_steady_s": _steady(writes),
            "recovery_s": 0.0, "writes": writes}


def _scenario_slow_node(wl, reqs, ref, outdir) -> dict:
    io = _writer(IOSession(machine=_machine()))
    writes = [_row(_write(io, reqs, f"{outdir}/s{i}"), io,
                   f"{outdir}/s{i}", ref)
              for i in range(HEALTHY_WRITES)]
    healthy = _steady(writes)
    fault = FaultSpec(slow_nodes={SLOW_NODE: SLOW_FACTOR})
    degraded = []
    for i in range(7):
        p = f"{outdir}/sd{i}"
        degraded.append(_row(_write(io, reqs, p, faults=fault), io, p, ref))
    # writes-to-adapt: first degraded write whose serve map carries
    # nothing on the straggler, counted from the fault's onset (the
    # onset write itself MEASURES the straggler, so 1 == the session
    # evacuated on the very next write)
    adapt = next((i for i, w in enumerate(degraded)
                  if w["evacuated"] and w["slow_share"] == 0.0), -1)
    return {"workload": wl, "scenario": "slow_node", "completed": True,
            "byte_identical": all(w["byte_identical"]
                                  for w in writes + degraded),
            "healthy_steady_s": healthy,
            "degraded_steady_s": _steady(degraded),
            "recovery_s": 0.0,
            "adaptation_writes": adapt,
            "slow_share_before": degraded[0]["slow_share"],
            "slow_share_after": degraded[-1]["slow_share"],
            "writes": writes + degraded}


def _scenario_dead_aggregator(wl, reqs, ref, outdir) -> dict:
    io = _writer(IOSession(machine=_machine()))
    hb = HeartbeatMonitor(n_hosts=NODES, timeout_s=1e-4,
                          clock=lambda: 0.0)
    writes = [_row(_write(io, reqs, f"{outdir}/d{i}"), io,
                   f"{outdir}/d{i}", ref)
              for i in range(HEALTHY_WRITES)]
    healthy = _steady(writes)
    fault = FaultSpec(dead_aggregator=(2, 1))
    t = _write(io, reqs, f"{outdir}/dead", faults=fault, heartbeat=hb)
    dead_row = _row(t, io, f"{outdir}/dead", ref)
    after = [_row(_write(io, reqs, f"{outdir}/da{i}"), io,
                  f"{outdir}/da{i}", ref) for i in range(2)]
    return {"workload": wl, "scenario": "dead_aggregator",
            "completed": True,
            "byte_identical": all(w["byte_identical"]
                                  for w in writes + [dead_row] + after),
            "healthy_steady_s": healthy,
            "degraded_steady_s": dead_row["total_s"],
            "recovery_s": dead_row["recovery_s"],
            "torn_repaired": dead_row["torn_repaired"],
            "repair_map": list(t.repair_map) if t.repair_map else None,
            "detected_dead_nodes": hb.dead_hosts(),
            "writes": writes + [dead_row] + after}


def _scenario_resize(wl, reqs, ref, outdir) -> dict:
    io = _writer(IOSession(machine=_machine()))
    writes = [_row(_write(io, reqs, f"{outdir}/r{i}"), io,
                   f"{outdir}/r{i}", ref)
              for i in range(HEALTHY_WRITES)]
    healthy = _steady(writes)
    fault = FaultSpec(resize_at_write=HEALTHY_WRITES,
                      resize_dead_nodes=(3,))
    with warnings.catch_warnings():
        # plan_remesh warns about stranded survivors — reported in the
        # artifact instead (unused_devices)
        warnings.simplefilter("ignore", RuntimeWarning)
        io2, reqs2, eplan = apply_resize(io, reqs,
                                         fault.resize_dead_nodes)
    after = [_row(_write(io2, reqs2, f"{outdir}/ra{i}"), io2,
                  f"{outdir}/ra{i}", ref) for i in range(3)]
    return {"workload": wl, "scenario": "resize", "completed": True,
            "byte_identical": all(w["byte_identical"]
                                  for w in writes + after),
            "healthy_steady_s": healthy,
            "degraded_steady_s": _steady(after),
            "recovery_s": 0.0,
            "post_resize_ranks": io2.n_ranks,
            "post_resize_nodes": io2.n_nodes,
            "unused_devices": eplan.unused_devices,
            "writes": writes + after}


SCENARIOS = {
    "healthy": _scenario_healthy,
    "slow_node": _scenario_slow_node,
    "dead_aggregator": _scenario_dead_aggregator,
    "resize": _scenario_resize,
}


def scenario_matrix():
    """benchmarks.run suite: the full workload x scenario sweep."""
    import tempfile
    blob = {"config": {"P": P, "nodes": NODES, "stripe_size": STRIPE,
                       "stripe_count": STRIPE_COUNT, "io_bw": 5e7,
                       "slow_node": SLOW_NODE,
                       "slow_factor": SLOW_FACTOR},
            "scenarios": {}}
    rows = []
    for wl in WORKLOADS:
        reqs = HOST_PATTERNS[wl](P)
        ref = _reference(reqs)
        for sname, fn in SCENARIOS.items():
            with tempfile.TemporaryDirectory() as d:
                entry = fn(wl, reqs, ref, d)
            blob["scenarios"][f"{wl}/{sname}"] = entry
            rows.append((
                f"degraded_{wl}_{sname}",
                entry["degraded_steady_s"] * 1e6,
                f"healthy={entry['healthy_steady_s'] * 1e6:.1f}us "
                f"recovery={entry['recovery_s'] * 1e6:.1f}us "
                f"bytes_ok={entry['byte_identical']}"))
    out = os.environ.get("BENCH_DEGRADED_OUT", "BENCH_degraded.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    return rows
