"""Serial-vs-pipelined round engine benchmark (+ depth-k ring sweep).

Two levels, mirroring the repo's split between the literal host-path
reproduction and the paper-scale analytical model:

* **model sweep** — for each paper workload (``benchmarks.workloads``
  registry) at P=16384 / 256 nodes, sweep the collective-buffer size
  and compare the serial round total against the pipelined total
  (``Workload.overlap`` refinement: each steady-state round pays
  ``max(comm, io)`` instead of the sum), for both schedules. Also
  reports ``optimal_cb``'s autotuned pick and the modeled depth sweep
  (uniform rounds: every depth >= 2 ties — the model's honest answer).
* **host measurement** — run the host-level path (real byte movement)
  at small scale with ring depths k in {1, 2, 3, 4}, report the
  measured totals, the brute-force best depth, and the
  ``pipeline_depth="auto"`` pick (``cost_model.optimal_depth`` over
  the MEASURED per-round arrays) — the two must agree, which
  ``benchmarks/check_regression.py`` gates in CI.

* **codec columns** — slow-hop codec on/off host totals for the gated
  btio/e3sm_f pair (bounding the lossless codec's overhead on
  incompressible payloads), the sparse-checkpoint wire ratio (modeled
  vs measured, 2x agreement CI-gated), and the paper-scale modeled
  discount rows.

* **session sweep** — repeated writes of the gated pair through an
  ``IOSession`` with every knob ``"auto"``: first-write vs steady-state
  cost (modeled write total + REAL planning wall time — the part a
  session amortizes), whether the steady state reused a cached plan,
  and the placement on/off comparison (modeled ``placement_cost`` of
  every named policy vs ``"auto"`` over the MEASURED per-(domain,
  sender-node) byte matrix). ``check_regression.py`` gates: steady
  cost < first cost, steady modeled total <= first, plan reused, and
  auto-placement never worse than spread/packed/off by > 5%.

Emits ``BENCH_pipeline.json`` (env ``BENCH_PIPELINE_OUT`` overrides the
path) so CI can archive the perf trajectory and diff it against the
committed baseline, and returns the usual ``(name, us, derived)`` rows
for ``benchmarks.run``.

derived column: executed rounds (serial rows), pipelined/serial speedup
(pipelined rows), autotuned cb bytes (auto rows), ring depth (depth
rows), overlap fraction (host rows).
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import cost_model as cm
from repro.core import codec as codec_lib
from repro.core import placement as placement_lib
from repro.core.session import IOSession

from benchmarks.workloads import (HOST_PATTERNS, MODEL_WORKLOADS,
                                  PAPER_NODES, PAPER_P, PAPER_P_L)

CB_MIB = (1, 4, 16, 64)
DEPTHS = (1, 2, 3, 4)
HOST_SET = ("e3sm_g", "btio")     # scaled host patterns (registry keys)
CODEC_SET = ("btio", "e3sm_f")    # codec-on/off gated pair (host runs)


def _model_sweep(blob):
    rows = []
    for name, gen in sorted(MODEL_WORKLOADS.items()):
        w = gen(PAPER_P, PAPER_NODES)
        entry = {"cb_sweep": [], "auto": {}, "depth_sweep": {}}
        for mib in CB_MIB:
            cb = mib << 20
            r = cm.rounds_for_cb(w, cb)
            ws = cm.with_measured_rounds(w, r)
            wp = cm.with_overlap(ws, 1.0)
            for method, cost in (("twophase", cm.twophase_cost),
                                 ("tam", lambda x: cm.tam_cost(x, PAPER_P_L))):
                serial = cost(ws).total
                pipe = cost(wp).total
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/serial",
                             serial * 1e6, r))
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/"
                             "pipelined", pipe * 1e6,
                             round(serial / pipe, 4)))
                entry["cb_sweep"].append({
                    "cb_bytes": cb, "method": method, "rounds": r,
                    "serial_s": serial, "pipelined_s": pipe,
                })
        # modeled depth sweep at the 4 MiB cb (uniform per-round phases:
        # depths >= 2 tie; recorded so the artifact shows the model's
        # depth column next to the host-measured one)
        for method, P_L_arg in (("twophase", None), ("tam", PAPER_P_L)):
            wc = cm.with_measured_rounds(w, cm.rounds_for_cb(w, 4 << 20))
            sweep = {}
            for k in DEPTHS:
                _, span = cm.optimal_depth(wc, P_L=P_L_arg, depths=(k,))
                sweep[str(k)] = span
                rows.append((f"pipeline/{name}/{method}/depth{k}/modeled",
                             span * 1e6, k))
            best_k, _ = cm.optimal_depth(wc, P_L=P_L_arg, depths=DEPTHS)
            entry["depth_sweep"][method] = {"span_s": sweep,
                                            "optimal_depth": best_k}
            cb_auto, cost = cm.optimal_cb(cm.with_overlap(w, 1.0),
                                          P_L=P_L_arg)
            rows.append((f"pipeline/{name}/{method}/auto_cb",
                         cost.total * 1e6, cb_auto))
            entry["auto"][method] = {"cb_bytes": cb_auto,
                                     "total_s": cost.total}
        blob["workloads"][name] = entry
    return rows


def _host_measurement(blob):
    rows = []
    n_ranks, cb = 16, 4096
    d = tempfile.mkdtemp()
    for pname in sorted(HOST_SET):
        reqs = HOST_PATTERNS[pname](n_ranks)
        io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=4,
                              stripe_size=1024, stripe_count=4)
        entry = {}
        for method in ("tam", "twophase"):
            la = 8 if method == "tam" else None
            timings = {}
            for k in DEPTHS:
                timings[k] = io.write(reqs, f"{d}/{pname}_{method}_k{k}",
                                      method=method, local_aggregators=la,
                                      cb_bytes=cb, pipeline_depth=k)
                rows.append((f"pipeline/host/{pname}/{method}/depth{k}",
                             timings[k].total * 1e6, k))
            totals = {k: t.total for k, t in timings.items()}
            ts_total = totals[1]
            tp = timings[2]       # pipeline=True == the depth-2 run
            ta = io.write(reqs, f"{d}/{pname}_{method}_a", method=method,
                          local_aggregators=la, cb_bytes=cb,
                          pipeline_depth="auto")
            best = min(DEPTHS, key=lambda k: (round(totals[k], 15), k))
            rows.append((f"pipeline/host/{pname}/{method}/serial",
                         ts_total * 1e6, tp.rounds_executed))
            rows.append((f"pipeline/host/{pname}/{method}/pipelined",
                         tp.total * 1e6, round(tp.overlap_fraction, 4)))
            rows.append((f"pipeline/host/{pname}/{method}/auto_depth",
                         ta.total * 1e6, ta.pipeline_depth))
            entry[method] = {
                "rounds": tp.rounds_executed, "serial_s": ts_total,
                "pipelined_s": tp.total,
                "overlap_saved_s": tp.overlap_saved,
                "overlap_fraction": tp.overlap_fraction,
                "depth_sweep": {str(k): totals[k] for k in DEPTHS},
                "best_depth_measured": best,
                "auto_depth": ta.pipeline_depth,
            }
        blob["host"][pname] = entry
    return rows


def _codec_measurement(blob):
    """Slow-hop codec columns: host codec-on/off pipelined totals for
    the gated pair (btio, e3sm_f — incompressible payloads, so the gate
    bounds the codec's own overhead) and the sparse-checkpoint wire
    ratio, modeled vs measured (the 2x agreement gate). Model rows for
    the paper-scale pair ride along so the artifact shows the modeled
    discount next to the measured one."""
    rows = []
    n_ranks, cb = 16, 4096
    d = tempfile.mkdtemp()
    rle = codec_lib.get_codec("rle")
    for pname in CODEC_SET:
        reqs = HOST_PATTERNS[pname](n_ranks)
        io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=4,
                              stripe_size=1024, stripe_count=4)
        entry = {}
        for method in ("tam", "twophase"):
            la = 8 if method == "tam" else None
            t_off = io.write(reqs, f"{d}/{pname}_{method}_coff",
                             method=method, local_aggregators=la,
                             cb_bytes=cb, pipeline_depth=2)
            t_on = io.write(reqs, f"{d}/{pname}_{method}_con",
                            method=method, local_aggregators=la,
                            cb_bytes=cb, pipeline_depth=2,
                            slow_hop_codec="rle")
            rows.append((f"pipeline/codec/{pname}/{method}/off",
                         t_off.total * 1e6, t_off.rounds_executed))
            rows.append((f"pipeline/codec/{pname}/{method}/on",
                         t_on.total * 1e6,
                         round(t_on.slow_hop_compression_ratio, 4)))
            entry[method] = {
                "off_s": t_off.total, "on_s": t_on.total,
                "measured_ratio": t_on.slow_hop_compression_ratio,
            }
        blob["codec"]["host"][pname] = entry

    # sparse-checkpoint pages: the codec's home workload — modeled vs
    # measured wire ratio must agree within 2x (CI-gated)
    reqs = HOST_PATTERNS["sparse_ckpt"](n_ranks)
    io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=4,
                          stripe_size=1024, stripe_count=4)
    zf = codec_lib.zero_fraction(dd for _, _, dd in reqs)
    total = float(sum(int(ln.sum()) for _, ln, _ in reqs))
    modeled = rle.modeled_ratio(zf, total)
    t_off = io.write(reqs, f"{d}/sparse_coff", method="tam",
                     local_aggregators=8, cb_bytes=cb, pipeline_depth=2)
    t_on = io.write(reqs, f"{d}/sparse_con", method="tam",
                    local_aggregators=8, cb_bytes=cb, pipeline_depth=2,
                    slow_hop_codec="rle")
    rows.append(("pipeline/codec/sparse_ckpt/tam/off",
                 t_off.total * 1e6, t_off.rounds_executed))
    rows.append(("pipeline/codec/sparse_ckpt/tam/on", t_on.total * 1e6,
                 round(t_on.slow_hop_compression_ratio, 4)))
    # ratio rides in the DERIVED column (the us column stays time-only)
    rows.append(("pipeline/codec/sparse_ckpt/modeled_ratio",
                 0.0, round(modeled, 4)))
    blob["codec"]["sparse_ckpt"] = {
        "zero_fraction": zf, "modeled_ratio": modeled,
        "measured_ratio": t_on.slow_hop_compression_ratio,
        "off_s": t_off.total, "on_s": t_on.total,
        "raw_bytes": t_on.slow_hop_raw_bytes,
        "wire_bytes": t_on.slow_hop_wire_bytes,
    }

    # paper-scale model rows: the beta discount / encode cost the plan
    # auto-resolution weighs (ratio ~1 for the incompressible pair)
    for name in CODEC_SET:
        w = MODEL_WORKLOADS[name](PAPER_P, PAPER_NODES)
        ws = cm.with_overlap(
            cm.with_measured_rounds(w, cm.rounds_for_cb(w, 4 << 20)), 1.0)
        for method, cost in (("twophase", cm.twophase_cost),
                             ("tam", lambda x: cm.tam_cost(x, PAPER_P_L))):
            off = cost(ws).total
            on = cost(cm.with_codec(ws, 4.0)).total   # ef-int8-like 4x
            rows.append((f"pipeline/codec/model/{name}/{method}/ratio4",
                         on * 1e6, round(off / on, 4)))
            blob["codec"]["model"].setdefault(name, {})[method] = {
                "off_s": off, "on_ratio4_s": on}
    return rows


def _session_measurement(blob):
    """Repeated-write session sweep on the gated pair: every knob
    "auto", 4 writes each. The first write pays the measurement + the
    autotune sweeps; the steady state must hit the plan cache (cost =
    modeled total + ~0 planning) and never execute a plan that measured
    worse than the first (the session reverts losing trials). The
    placement columns score every policy's modeled cost over the
    MEASURED per-(domain, sender-node) matrix of the last write —
    "auto" is the argmin, which check_regression.py asserts."""
    rows = []
    n_ranks, n_nodes, n_agg = 16, 4, 8
    d = tempfile.mkdtemp()
    for pname in CODEC_SET:
        reqs = HOST_PATTERNS[pname](n_ranks)
        io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=n_nodes,
                              stripe_size=1024, stripe_count=n_agg,
                              session=IOSession())
        writes = []
        last = None
        for i in range(4):
            last = io.write(reqs, f"{d}/{pname}_{i}", method="tam",
                            local_aggregators=8, cb_bytes="auto",
                            pipeline_depth="auto",
                            slow_hop_codec="auto", placement="auto")
            writes.append({"total_s": last.total,
                           "plan_s": last.plan_seconds,
                           "cost_s": last.total + last.plan_seconds,
                           "source": last.plan_source})
        first, steady = writes[0], dict(writes[-1])
        # steady planning cost: the MIN over the steady-state (cache
        # hit) writes — the gate compares real wall-clock against the
        # first write's, and a single GC pause inside one perf_counter
        # window must not flip a CI-blocking strict inequality
        steady["plan_s"] = min(w["plan_s"] for w in writes[2:])
        steady["cost_s"] = steady["total_s"] + steady["plan_s"]
        rows.append((f"pipeline/session/{pname}/first",
                     first["cost_s"] * 1e6, round(first["plan_s"] * 1e6)))
        rows.append((f"pipeline/session/{pname}/steady",
                     steady["cost_s"] * 1e6, steady["source"]))
        # placement on/off: modeled cost of every policy over the
        # measured matrix (what the session's "auto" re-resolution ran)
        w = cm.with_measured_rounds(
            io.workload_for(reqs, method="tam", cb_bytes="auto",
                            pipeline_depth="auto",
                            slow_hop_codec="auto"),
            last.rounds_executed)
        nb = last.node_bytes
        costs = {"off": cm.placement_cost(w, io.machine, None, n_nodes,
                                          node_bytes=nb)}
        for policy in placement_lib.PLACEMENT_POLICIES + ("auto",):
            perm = placement_lib.resolve_placement(
                policy, n_agg, n_nodes, workload=w, machine=io.machine,
                node_bytes=nb)
            costs[policy] = cm.placement_cost(w, io.machine, perm,
                                              n_nodes, node_bytes=nb)
            rows.append((f"pipeline/session/{pname}/placement_{policy}",
                         costs[policy] * 1e6, ""))
        blob["session"][pname] = {
            "writes": writes,
            "first_total_s": first["total_s"],
            "steady_total_s": steady["total_s"],
            "first_cost_s": first["cost_s"],
            "steady_cost_s": steady["cost_s"],
            "plan_reused": steady["source"] == "session-hit",
            "cache_hits": io.session.hits,
            "replans": io.session.replans,
            "placement": costs,
        }
    return rows


def serial_vs_pipelined():
    blob = {"P": PAPER_P, "nodes": PAPER_NODES, "P_L": PAPER_P_L,
            "workloads": {}, "host": {},
            "codec": {"host": {}, "model": {}, "sparse_ckpt": {}},
            "session": {}}
    rows = (_model_sweep(blob) + _host_measurement(blob)
            + _codec_measurement(blob) + _session_measurement(blob))
    out = os.environ.get("BENCH_PIPELINE_OUT", "BENCH_pipeline.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    return rows
