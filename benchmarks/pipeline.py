"""Serial-vs-pipelined round engine benchmark (+ depth-k ring sweep).

Two levels, mirroring the repo's split between the literal host-path
reproduction and the paper-scale analytical model:

* **model sweep** — for each paper workload (``benchmarks.workloads``
  registry) at P=16384 / 256 nodes, sweep the collective-buffer size
  and compare the serial round total against the pipelined total
  (``Workload.overlap`` refinement: each steady-state round pays
  ``max(comm, io)`` instead of the sum), for both schedules. Also
  reports ``optimal_cb``'s autotuned pick and the modeled depth sweep
  (uniform rounds: every depth >= 2 ties — the model's honest answer).
* **host measurement** — run the host-level path (real byte movement)
  at small scale with ring depths k in {1, 2, 3, 4}, report the
  measured totals, the brute-force best depth, and the
  ``pipeline_depth="auto"`` pick (``cost_model.optimal_depth`` over
  the MEASURED per-round arrays) — the two must agree, which
  ``benchmarks/check_regression.py`` gates in CI.

Emits ``BENCH_pipeline.json`` (env ``BENCH_PIPELINE_OUT`` overrides the
path) so CI can archive the perf trajectory and diff it against the
committed baseline, and returns the usual ``(name, us, derived)`` rows
for ``benchmarks.run``.

derived column: executed rounds (serial rows), pipelined/serial speedup
(pipelined rows), autotuned cb bytes (auto rows), ring depth (depth
rows), overlap fraction (host rows).
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import cost_model as cm

from benchmarks.workloads import (HOST_PATTERNS, MODEL_WORKLOADS,
                                  PAPER_NODES, PAPER_P, PAPER_P_L)

CB_MIB = (1, 4, 16, 64)
DEPTHS = (1, 2, 3, 4)
HOST_SET = ("e3sm_g", "btio")     # scaled host patterns (registry keys)


def _model_sweep(blob):
    rows = []
    for name, gen in sorted(MODEL_WORKLOADS.items()):
        w = gen(PAPER_P, PAPER_NODES)
        entry = {"cb_sweep": [], "auto": {}, "depth_sweep": {}}
        for mib in CB_MIB:
            cb = mib << 20
            r = cm.rounds_for_cb(w, cb)
            ws = cm.with_measured_rounds(w, r)
            wp = cm.with_overlap(ws, 1.0)
            for method, cost in (("twophase", cm.twophase_cost),
                                 ("tam", lambda x: cm.tam_cost(x, PAPER_P_L))):
                serial = cost(ws).total
                pipe = cost(wp).total
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/serial",
                             serial * 1e6, r))
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/"
                             "pipelined", pipe * 1e6,
                             round(serial / pipe, 4)))
                entry["cb_sweep"].append({
                    "cb_bytes": cb, "method": method, "rounds": r,
                    "serial_s": serial, "pipelined_s": pipe,
                })
        # modeled depth sweep at the 4 MiB cb (uniform per-round phases:
        # depths >= 2 tie; recorded so the artifact shows the model's
        # depth column next to the host-measured one)
        for method, P_L_arg in (("twophase", None), ("tam", PAPER_P_L)):
            wc = cm.with_measured_rounds(w, cm.rounds_for_cb(w, 4 << 20))
            sweep = {}
            for k in DEPTHS:
                _, span = cm.optimal_depth(wc, P_L=P_L_arg, depths=(k,))
                sweep[str(k)] = span
                rows.append((f"pipeline/{name}/{method}/depth{k}/modeled",
                             span * 1e6, k))
            best_k, _ = cm.optimal_depth(wc, P_L=P_L_arg, depths=DEPTHS)
            entry["depth_sweep"][method] = {"span_s": sweep,
                                            "optimal_depth": best_k}
            cb_auto, cost = cm.optimal_cb(cm.with_overlap(w, 1.0),
                                          P_L=P_L_arg)
            rows.append((f"pipeline/{name}/{method}/auto_cb",
                         cost.total * 1e6, cb_auto))
            entry["auto"][method] = {"cb_bytes": cb_auto,
                                     "total_s": cost.total}
        blob["workloads"][name] = entry
    return rows


def _host_measurement(blob):
    rows = []
    n_ranks, cb = 16, 4096
    d = tempfile.mkdtemp()
    for pname in sorted(HOST_SET):
        reqs = HOST_PATTERNS[pname](n_ranks)
        io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=4,
                              stripe_size=1024, stripe_count=4)
        entry = {}
        for method in ("tam", "twophase"):
            la = 8 if method == "tam" else None
            timings = {}
            for k in DEPTHS:
                timings[k] = io.write(reqs, f"{d}/{pname}_{method}_k{k}",
                                      method=method, local_aggregators=la,
                                      cb_bytes=cb, pipeline_depth=k)
                rows.append((f"pipeline/host/{pname}/{method}/depth{k}",
                             timings[k].total * 1e6, k))
            totals = {k: t.total for k, t in timings.items()}
            ts_total = totals[1]
            tp = timings[2]       # pipeline=True == the depth-2 run
            ta = io.write(reqs, f"{d}/{pname}_{method}_a", method=method,
                          local_aggregators=la, cb_bytes=cb,
                          pipeline_depth="auto")
            best = min(DEPTHS, key=lambda k: (round(totals[k], 15), k))
            rows.append((f"pipeline/host/{pname}/{method}/serial",
                         ts_total * 1e6, tp.rounds_executed))
            rows.append((f"pipeline/host/{pname}/{method}/pipelined",
                         tp.total * 1e6, round(tp.overlap_fraction, 4)))
            rows.append((f"pipeline/host/{pname}/{method}/auto_depth",
                         ta.total * 1e6, ta.pipeline_depth))
            entry[method] = {
                "rounds": tp.rounds_executed, "serial_s": ts_total,
                "pipelined_s": tp.total,
                "overlap_saved_s": tp.overlap_saved,
                "overlap_fraction": tp.overlap_fraction,
                "depth_sweep": {str(k): totals[k] for k in DEPTHS},
                "best_depth_measured": best,
                "auto_depth": ta.pipeline_depth,
            }
        blob["host"][pname] = entry
    return rows


def serial_vs_pipelined():
    blob = {"P": PAPER_P, "nodes": PAPER_NODES, "P_L": PAPER_P_L,
            "workloads": {}, "host": {}}
    rows = _model_sweep(blob) + _host_measurement(blob)
    out = os.environ.get("BENCH_PIPELINE_OUT", "BENCH_pipeline.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    return rows
