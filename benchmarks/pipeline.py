"""Serial-vs-pipelined round engine benchmark.

Two levels, mirroring the repo's split between the literal host-path
reproduction and the paper-scale analytical model:

* **model sweep** — for each paper workload (e3sm_f/g, btio, s3d) at
  P=16384 / 256 nodes, sweep the collective-buffer size and compare the
  serial round total against the pipelined total (``Workload.overlap``
  refinement: each steady-state round pays ``max(comm, io)`` instead of
  the sum), for both schedules. Also reports ``optimal_cb``'s
  autotuned pick.
* **host measurement** — run the host-level path (real byte movement)
  at small scale with ``pipeline=`` off/on and report the measured
  ``overlap_saved`` / ``overlap_fraction`` from ``IOTimings``.

Emits ``BENCH_pipeline.json`` (env ``BENCH_PIPELINE_OUT`` overrides the
path) so CI can archive the perf trajectory, and returns the usual
``(name, us, derived)`` rows for ``benchmarks.run``.

derived column: executed rounds (serial rows), pipelined/serial speedup
(pipelined rows), autotuned cb bytes (auto rows), overlap fraction
(host rows).
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import cost_model as cm
from repro.io_patterns import btio_pattern, e3sm_g_pattern

WORKLOADS = {
    "e3sm_f": cm.e3sm_f,
    "e3sm_g": cm.e3sm_g,
    "btio": cm.btio,
    "s3d": cm.s3d,
}
CB_MIB = (1, 4, 16, 64)
P, NODES, P_L = 16384, 256, 256

HOST_PATTERNS = {
    "e3sm_g": e3sm_g_pattern,
    "btio": lambda n: btio_pattern(n, n=32),
}


def _model_sweep(blob):
    rows = []
    for name, gen in sorted(WORKLOADS.items()):
        w = gen(P, NODES)
        entry = {"cb_sweep": [], "auto": {}}
        for mib in CB_MIB:
            cb = mib << 20
            r = cm.rounds_for_cb(w, cb)
            ws = cm.with_measured_rounds(w, r)
            wp = cm.with_overlap(ws, 1.0)
            for method, cost in (("twophase", cm.twophase_cost),
                                 ("tam", lambda x: cm.tam_cost(x, P_L))):
                serial = cost(ws).total
                pipe = cost(wp).total
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/serial",
                             serial * 1e6, r))
                rows.append((f"pipeline/{name}/{method}/cb{mib}MiB/"
                             "pipelined", pipe * 1e6,
                             round(serial / pipe, 4)))
                entry["cb_sweep"].append({
                    "cb_bytes": cb, "method": method, "rounds": r,
                    "serial_s": serial, "pipelined_s": pipe,
                })
        for method, P_L_arg in (("twophase", None), ("tam", P_L)):
            cb_auto, cost = cm.optimal_cb(cm.with_overlap(w, 1.0),
                                          P_L=P_L_arg)
            rows.append((f"pipeline/{name}/{method}/auto_cb",
                         cost.total * 1e6, cb_auto))
            entry["auto"][method] = {"cb_bytes": cb_auto,
                                     "total_s": cost.total}
        blob["workloads"][name] = entry
    return rows


def _host_measurement(blob):
    rows = []
    n_ranks, cb = 16, 4096
    d = tempfile.mkdtemp()
    for pname, gen in sorted(HOST_PATTERNS.items()):
        reqs = gen(n_ranks)
        io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=4,
                              stripe_size=1024, stripe_count=4)
        entry = {}
        for method in ("tam", "twophase"):
            la = 8 if method == "tam" else None
            ts = io.write(reqs, f"{d}/{pname}_{method}_s", method=method,
                          local_aggregators=la, cb_bytes=cb)
            tp = io.write(reqs, f"{d}/{pname}_{method}_p", method=method,
                          local_aggregators=la, cb_bytes=cb,
                          pipeline=True)
            rows.append((f"pipeline/host/{pname}/{method}/serial",
                         ts.total * 1e6, ts.rounds_executed))
            rows.append((f"pipeline/host/{pname}/{method}/pipelined",
                         tp.total * 1e6, round(tp.overlap_fraction, 4)))
            entry[method] = {
                "rounds": tp.rounds_executed, "serial_s": ts.total,
                "pipelined_s": tp.total,
                "overlap_saved_s": tp.overlap_saved,
                "overlap_fraction": tp.overlap_fraction,
            }
        blob["host"][pname] = entry
    return rows


def serial_vs_pipelined():
    blob = {"P": P, "nodes": NODES, "P_L": P_L,
            "workloads": {}, "host": {}}
    rows = _model_sweep(blob) + _host_measurement(blob)
    out = os.environ.get("BENCH_PIPELINE_OUT", "BENCH_pipeline.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    return rows
