"""Async checkpoint overlap: step-time overhead + hidden fraction.

The tentpole claim of the async path: checkpoint-every-N costs the
training loop only the SNAPSHOT (a host memcpy), because the collective
write drains behind the following compute steps. This suite runs a
calibrated compute loop (a GIL-releasing ``np.dot`` sized to
~``TARGET_STEP_MS`` per step) under three variants:

* ``none`` — no checkpointing, the step-time floor;
* ``sync`` — ``CheckpointManager.save`` every ``CKPT_EVERY`` steps
  (the loop blocks on every collective write);
* ``async`` — ``CheckpointManager.save_async`` every ``CKPT_EVERY``
  steps (the loop blocks only on the snapshot + the depth-one queue).

The three variants run back-to-back inside each of ``REPEATS`` paired
rounds, and the round with the lowest PAIRED async-vs-none overhead is
kept: CPU-speed drift on a shared runner moves all three variants of a
round together, so a paired ratio is far more stable than comparing a
lucky ``none`` window from one moment against an unlucky ``async``
window from another (noise only ever inflates a run, so the cleanest
round is the closest to the true cost). Emits ``BENCH_async.json`` for
the CI gate (``check_regression.py --async``), which enforces:

* async overhead vs ``none`` < ``ASYNC_OVERHEAD_X`` (5%);
* the final async checkpoint is byte-identical to the sync one (the
  overlap buys no correctness discount);
* max hidden fraction across the async saves > 0 — some of the drain
  actually ran behind compute (``IOTimings.overlap_hidden_seconds``).

Wall times here are REAL (threads can't be modeled), so the gate's
bounds are within-artifact ratios, never absolute times; the committed
baseline (``benchmarks/baselines/BENCH_async_baseline.json``) pins
variant coverage only and only ever grows additively.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.checkpoint import CheckpointManager, HostCollectiveIO
from repro.core.session import IOSession

STEPS = 16
CKPT_EVERY = 4
REPEATS = 5
TARGET_STEP_MS = 40.0
RANKS, NODES, STRIPE, STRIPE_COUNT = 8, 2, 1 << 18, 4
# ~0.5 MiB of state: sized so the WHOLE drain is < 3% of the compute
# between checkpoints even when a single-core runner serializes the
# "background" thread onto the compute CPU (the overhead gate must
# hold without SMP overlap; with it, the drain is nearly free)
TREE_SHAPE = (256, 256)


def _make_tree():
    return {"params": {"w": np.zeros(TREE_SHAPE, np.float32)},
            "opt": {"m": np.zeros(TREE_SHAPE, np.float32)}}


def _calibrate() -> tuple[np.ndarray, np.ndarray, int]:
    """Size the busy-work matmul so one step is ~TARGET_STEP_MS. The
    dot releases the GIL, so the drain thread gets real overlap."""
    a = np.random.default_rng(0).standard_normal((384, 384)) \
        .astype(np.float32)
    t0 = time.perf_counter()
    for _ in range(4):
        a @ a
    per = (time.perf_counter() - t0) / 4
    reps = max(1, int(TARGET_STEP_MS / 1000.0 / per))
    return a, a.copy(), reps


def _mgr(d: str) -> CheckpointManager:
    sess = IOSession()
    io = HostCollectiveIO(n_ranks=RANKS, n_nodes=NODES,
                          stripe_size=STRIPE, stripe_count=STRIPE_COUNT,
                          session=sess)
    return CheckpointManager(d, io, method="tam", local_aggregators=4,
                             session=sess)


def _run(variant: str, d: str, a, b, reps) -> tuple[float, list]:
    """One training run; returns (wall_seconds, pending futures)."""
    tree = _make_tree()
    mgr = _mgr(d) if variant != "none" else None
    pendings = []
    t0 = time.perf_counter()
    for step in range(1, STEPS + 1):
        for _ in range(reps):          # the "train step"
            b = a @ a
        tree["params"]["w"] += 1.0     # deterministic state evolution
        tree["opt"]["m"] += 0.5
        if mgr is not None and step % CKPT_EVERY == 0:
            if variant == "sync":
                mgr.save(tree, step)
            else:
                pendings.append(mgr.save_async(tree, step))
    if mgr is not None and variant == "async":
        mgr.block_until_done()
    wall = time.perf_counter() - t0
    return wall, pendings


def _seg_bytes(d: str, step: int) -> list[bytes]:
    return [p.read_bytes() for p in
            sorted(Path(d).glob(f"ckpt_{step:08d}.seg*"))]


def overlap_bench():
    """benchmarks.run suite: the three-variant overlap comparison."""
    a, b, reps = _calibrate()
    blob = {"config": {"steps": STEPS, "ckpt_every": CKPT_EVERY,
                       "repeats": REPEATS, "matmul_reps": reps,
                       "ranks": RANKS, "nodes": NODES,
                       "tree_bytes": 2 * 4 * TREE_SHAPE[0] * TREE_SHAPE[1],
                       "stripe_size": STRIPE,
                       "stripe_count": STRIPE_COUNT},
            "variants": {}, "saves": []}
    all_dirs = []
    rounds = []
    for rep in range(REPEATS):
        round_data = {}
        for variant in ("none", "sync", "async"):
            d = tempfile.mkdtemp(prefix=f"bench_async_{variant}_")
            all_dirs.append(d)
            wall, pendings = _run(variant, d, a, b, reps)
            round_data[variant] = (wall, d, pendings)
        rounds.append(round_data)
    # the gated number is the async/none ratio, so pick the round where
    # THAT is cleanest — drift within a round cancels in the ratio
    best = min(rounds, key=lambda r: r["async"][0] / r["none"][0])
    best_dirs = {v: best[v][1] for v in best}
    for variant in ("none", "sync", "async"):
        wall, _, pendings = best[variant]
        entry = {"total_s": wall, "step_ms": wall / STEPS * 1e3,
                 "runs_s": sorted(r[variant][0] for r in rounds)}
        if variant == "async":
            saves = []
            for p in pendings:
                _, t = p.result()     # already drained; idempotent
                saves.append({"step": p.step,
                              "snapshot_s": t.snapshot_seconds,
                              "drain_wall_s": t.drain_wall_seconds,
                              "overlap_hidden_s": t.overlap_hidden_seconds,
                              "hidden_fraction": t.hidden_fraction})
            blob["saves"] = saves
            entry["hidden_fraction_max"] = max(
                (s["hidden_fraction"] for s in saves), default=0.0)
            entry["snapshot_s_mean"] = float(np.mean(
                [s["snapshot_s"] for s in saves])) if saves else 0.0
        blob["variants"][variant] = entry
    floor = blob["variants"]["none"]["total_s"]
    for variant in ("sync", "async"):
        e = blob["variants"][variant]
        e["overhead_frac"] = e["total_s"] / floor - 1.0
    blob["variants"]["async"]["paired_overheads"] = sorted(
        r["async"][0] / r["none"][0] - 1.0 for r in rounds)
    blob["byte_identical"] = (
        _seg_bytes(best_dirs["sync"], STEPS)
        == _seg_bytes(best_dirs["async"], STEPS)
        and len(_seg_bytes(best_dirs["sync"], STEPS)) > 0)
    for d in all_dirs:
        shutil.rmtree(d, ignore_errors=True)
    out = os.environ.get("BENCH_ASYNC_OUT", "BENCH_async.json")
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    rows = []
    for variant in ("none", "sync", "async"):
        e = blob["variants"][variant]
        extra = ""
        if variant != "none":
            extra = f"overhead={e['overhead_frac']:+.1%}"
        if variant == "async":
            extra += (f" hidden_max={e['hidden_fraction_max']:.2f}"
                      f" bytes_ok={blob['byte_identical']}")
        rows.append((f"async_ckpt_{variant}", e["step_ms"] * 1e3, extra))
    return rows
