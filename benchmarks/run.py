"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Requires ``repro`` on the
path (``pip install -e .`` or ``PYTHONPATH=src``):

  PYTHONPATH=src python -m benchmarks.run [--only fig3]
"""
from __future__ import annotations

import argparse

from benchmarks import (async_ckpt, degraded, kernel_bench, paper_figures,
                        pipeline, restore, rounds, spmd_bytes, transport)

SUITES = {
    "fig2": paper_figures.fig2_congestion,
    "fig3": paper_figures.fig3_bandwidth,
    "fig4_7": paper_figures.fig4_7_breakdown,
    "table1": paper_figures.table1_coalesce,
    "optimal_pl": paper_figures.optimal_pl_sweep,
    "kernels": kernel_bench.sort_coalesce_pack,
    "kernel_fusion": kernel_bench.fused_vs_unfused,
    "spmd_bytes": spmd_bytes.collective_bytes,
    "rounds": rounds.cb_sweep,
    "pipeline": pipeline.serial_vs_pipelined,
    "degraded": degraded.scenario_matrix,
    "restore": restore.replica_cache_sweep,
    "async_ckpt": async_ckpt.overlap_bench,
    "transport": transport.wire_sweep,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and args.only != name:
            continue
        for row in fn():
            n, us, derived = row
            print(f"{n},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
