"""Shared workload registry for the benchmark suites.

One place for the paper's workload tables — the analytical generators
(Table I shapes for the cost model), the scaled host-path request
patterns, and the paper-scale constants — so ``benchmarks.run``'s
suites (``pipeline``, ``rounds``, ``paper_figures``) stop redefining
the e3sm_f / e3sm_g / btio / s3d parameter tables independently.
"""
from __future__ import annotations

from repro.core import cost_model as cm
from repro.io_patterns import (btio_pattern, e3sm_f_pattern,
                               e3sm_g_pattern, s3d_pattern,
                               sparse_checkpoint_pattern)

# paper scale: P ranks / nodes / local aggregators (SV: 16384 cores,
# 256 Haswell nodes, P_L = one LA per node)
PAPER_P, PAPER_NODES, PAPER_P_L = 16384, 256, 256

# Table I analytical workloads: name -> Workload generator (P, nodes)
MODEL_WORKLOADS = {
    "e3sm_f": cm.e3sm_f,
    "e3sm_g": cm.e3sm_g,
    "btio": cm.btio,
    "s3d": cm.s3d,
}

# scaled host-path request generators: name -> (n_ranks -> rank_requests)
HOST_PATTERNS = {
    "e3sm_g": e3sm_g_pattern,
    "e3sm_f": e3sm_f_pattern,
    "btio": lambda P, n=32: btio_pattern(P, n=n),
    "s3d": lambda P, n=32: s3d_pattern(P, n=n),
    # zero-dominated checkpoint pages — the slow-hop codec's workload
    # (benchmarks/pipeline.py measures its wire ratio, CI gates it)
    "sparse_ckpt": sparse_checkpoint_pattern,
}


def paper_workload(name: str, P: int = PAPER_P,
                   nodes: int = PAPER_NODES) -> cm.Workload:
    """The named Table I workload at (P, nodes) — paper scale default."""
    return MODEL_WORKLOADS[name](P, nodes)
