"""Round-engine benchmark: sweep the collective-buffer size.

For each pattern and both schedules (TAM / two-phase), sweep
``cb_bytes`` on the host-level path (real byte movement, per-round
incast timing) and report the modeled paper-scale cost with the
EXECUTED round count wired into the analytical model
(``Workload.rounds_override`` replacing the one-stripe-per-round
assumption). Also reports the SPMD round path's static peak
aggregator buffering vs the single-shot exchange
(``rounds.peak_aggregator_buffer_elems``) — the round path's is
independent of the participating rank count.

derived column: executed rounds (sweep rows), modeled total seconds
(model rows), buffer elements (peak rows).
"""
from __future__ import annotations

import tempfile

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import cost_model as cm
from repro.core.rounds import peak_aggregator_buffer_elems

from benchmarks.workloads import (HOST_PATTERNS, MODEL_WORKLOADS,
                                  PAPER_NODES, PAPER_P, PAPER_P_L)

PATTERNS = {name: (HOST_PATTERNS[name], MODEL_WORKLOADS[name])
            for name in ("e3sm_g", "btio")}
CB_SWEEP = (1024, 4096, 16384)


def cb_sweep():
    rows = []
    P = 16
    d = tempfile.mkdtemp()
    for pname, (gen, wl) in sorted(PATTERNS.items()):
        reqs = gen(P)
        io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                              stripe_count=4)
        for method in ("tam", "twophase"):
            la = 8 if method == "tam" else None
            base = io.write(reqs, f"{d}/{pname}_{method}", method=method,
                            local_aggregators=la)
            rows.append((f"rounds/{pname}/{method}/single_shot",
                         base.inter_comm * 1e6, base.rounds_executed))
            for cb in CB_SWEEP:
                t = io.write(reqs, f"{d}/{pname}_{method}_{cb}",
                             method=method, local_aggregators=la,
                             cb_bytes=cb)
                rows.append((f"rounds/{pname}/{method}/cb{cb}",
                             t.inter_comm * 1e6, t.rounds_executed))
                # paper-scale model with the executed rounds wired in
                wp = wl(PAPER_P, PAPER_NODES)
                w = cm.with_measured_rounds(
                    wp, cm.rounds_for_cb(wp, cb * 1024))
                cost = (cm.tam_cost(w, PAPER_P_L) if method == "tam"
                        else cm.twophase_cost(w))
                rows.append((f"rounds/{pname}/{method}/cb{cb}/modeled",
                             cost.comm * 1e6, round(cost.total, 4)))
    # static peak-buffer accounting of the SPMD paths (elements)
    for rpn in (4, 16, 64):
        peak = peak_aggregator_buffer_elems(
            data_cap=4096, n_nodes=8, ranks_per_node=rpn,
            domain_len=1 << 20, cb_buffer_size=8192)
        rows.append((f"rounds/peak_buf/single_shot/rpn{rpn}", 0.0,
                     peak["single_shot"]))
        rows.append((f"rounds/peak_buf/rounds/rpn{rpn}", 0.0,
                     peak["rounds"]))
    return rows
