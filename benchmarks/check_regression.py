"""CI benchmark regression gate for the pipelined round engine.

Compares a freshly produced ``BENCH_pipeline.json`` against the
committed baseline (``benchmarks/baselines/BENCH_pipeline_baseline.json``)
and exits nonzero when:

* the modeled PIPELINED total regresses by more than the threshold
  (default 20%) on any (cb, method) point of the gated workloads
  (btio, e3sm_f — the paper's acceptance pair);
* pipelining stops beating serial on a multi-round point of a gated
  workload (the PR-2 acceptance, kept);
* the host executor's ``pipeline_depth="auto"`` pick disagrees with
  the brute-force best depth of the measured sweep on EVERY paper
  workload. (The host measurement is itself model-driven, so this is
  an end-to-end plumbing consistency check — auto wiring, depth
  clamping, tie-breaking — not independent validation of
  ``optimal_depth``; the span recurrence itself is property-tested in
  tests/test_plan.py.)
* the slow-hop codec columns fail their bounds (baseline-independent,
  computed within the current artifact): enabling the lossless codec
  regresses a gated workload's pipelined total by more than the
  threshold (the codec seam + scan must stay cheap on incompressible
  payloads); the measured sparse-checkpoint wire ratio drops to <= 2x
  (the acceptance floor for the codec's home workload); or the modeled
  and measured ratios disagree by more than 2x in either direction
  (the ``"auto"`` resolution and ``optimal_cb`` discounts run on the
  modeled ratio — if it drifts from reality the autotuning is lying).
* the session columns fail their bounds (baseline-independent): the
  steady-state write COST (modeled total + real planning time) must be
  strictly below the first write's (plan compile amortized — the whole
  point of a session), the steady-state MODELED total must never
  exceed the first write's (the session reverts trials that measured
  worse, so feedback can only help), the steady state must actually
  reuse a cached plan, and ``placement="auto"`` must never be
  modeled-worse than ``spread``/``packed``/placement-off by more than
  5% on any gated workload (auto is an argmin over the measured
  node-byte matrix — if it loses, the wiring broke).

With ``--kernels`` (the ``BENCH_kernels.json`` artifact from the
``kernel_fusion`` suite) the gate also enforces the fused-round
contract:

* fused and unfused drains are byte-identical on every workload;
* per workload, fused wall time <= unfused x (1 + 25% jitter
  headroom), and SUMMED over the registry fused is strictly <=
  unfused — the one-kernel drain must actually pay for itself;
* every workload named in the kernels baseline
  (``benchmarks/baselines/BENCH_kernels_baseline.json``) is present —
  the baseline records COVERAGE, never wall times (those are
  machine-dependent; the fused-vs-unfused bound is within-artifact),
  so it only ever grows additively when workloads are added.

The model is deterministic, so the comparison is stable; the threshold
exists to absorb intentional re-calibrations of ``cost_model.Machine``
(regenerate the baseline alongside such a change:
``BENCH_PIPELINE_OUT=benchmarks/baselines/BENCH_pipeline_baseline.json
PYTHONPATH=src python -m benchmarks.run --only pipeline``).

With ``--degraded`` (the ``BENCH_degraded.json`` artifact from the
``degraded`` suite) the gate also enforces the fault layer's
acceptance contract — byte identity of every recovered write,
one-write straggler evacuation, bounded steady degraded cost and
dead-aggregator recovery, resize-without-wedging — see
:func:`check_degraded`; its baseline
(``benchmarks/baselines/BENCH_degraded_baseline.json``) pins scenario
coverage only.

With ``--restore`` (the ``BENCH_restore.json`` artifact from the
``restore`` suite) the gate also enforces the read path's acceptance
contract — byte identity of every replicated read, the node cache
flattening same-node restores (within ``RESTORE_FLAT_X`` from 2 -> 8
replicas/node), cache-on never slower than cache-off, warm session
restores never worse than cold, half-tree subset restores reading
< 50% of the file — see :func:`check_restore`; its baseline
(``benchmarks/baselines/BENCH_restore_baseline.json``) pins workload
coverage only.

With ``--async`` (the ``BENCH_async.json`` artifact from the
``async_ckpt`` suite) the gate also enforces the async checkpoint
contract — checkpoint-every-N step-time overhead vs no-checkpoint
under ``ASYNC_OVERHEAD_X`` (5%), the final async checkpoint
byte-identical to the synchronous one, and a positive hidden fraction
(some of the drain genuinely ran behind compute) — see
:func:`check_async`; its baseline
(``benchmarks/baselines/BENCH_async_baseline.json``) pins variant
coverage only (the artifact's times are REAL wall clock, the one suite
where they have to be — threads cannot be modeled — so every bound is
a within-artifact ratio).

Usage: python benchmarks/check_regression.py CURRENT BASELINE
           [--threshold 0.2] [--kernels BENCH_kernels.json]
           [--kernels-baseline benchmarks/baselines/BENCH_kernels_baseline.json]
           [--degraded BENCH_degraded.json]
           [--degraded-baseline benchmarks/baselines/BENCH_degraded_baseline.json]
           [--restore BENCH_restore.json]
           [--restore-baseline benchmarks/baselines/BENCH_restore_baseline.json]
           [--async BENCH_async.json]
           [--async-baseline benchmarks/baselines/BENCH_async_baseline.json]
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_WORKLOADS = ("btio", "e3sm_f")


def check(current: dict, baseline: dict,
          threshold: float) -> tuple[list[str], int]:
    errors = []
    matched = 0

    # ---- modeled pipelined totals vs the committed baseline ----------
    for wl in GATED_WORKLOADS:
        base_rows = {(r["cb_bytes"], r["method"]): r
                     for r in baseline["workloads"][wl]["cb_sweep"]}
        wl_matched = 0
        for row in current["workloads"][wl]["cb_sweep"]:
            # baseline-independent PR-2 acceptance: overlap must win on
            # every multi-round point, including ones the baseline has
            # not been regenerated for yet
            if row["rounds"] > 1 and row["pipelined_s"] >= row["serial_s"]:
                errors.append(
                    f"{wl}/{row['method']}/cb{row['cb_bytes']}: pipelined "
                    f"({row['pipelined_s']:.4g}s) no longer beats serial "
                    f"({row['serial_s']:.4g}s)")
            key = (row["cb_bytes"], row["method"])
            if key not in base_rows:
                continue
            wl_matched += 1
            base = base_rows[key]["pipelined_s"]
            ratio = row["pipelined_s"] / base if base > 0 else 1.0
            if ratio > 1.0 + threshold:
                errors.append(
                    f"{wl}/{row['method']}/cb{row['cb_bytes']}: pipelined "
                    f"total regressed {ratio:.3f}x vs baseline "
                    f"({row['pipelined_s']:.4g}s vs {base:.4g}s)")
        if wl_matched == 0:
            errors.append(
                f"{wl}: no current sweep point matches the baseline — "
                "the cb sweep changed; regenerate "
                "benchmarks/baselines/BENCH_pipeline_baseline.json")
        matched += wl_matched

    # ---- slow-hop codec bounds (within the current artifact) ---------
    codec = current.get("codec", {})
    host_codec = codec.get("host", {})
    if not host_codec:
        errors.append("no codec on/off host entries found in the artifact")
    for wl, entry in host_codec.items():
        for method, e in entry.items():
            if e["off_s"] > 0 and e["on_s"] > (1.0 + threshold) * e["off_s"]:
                errors.append(
                    f"codec/{wl}/{method}: lossless codec regressed the "
                    f"pipelined total {e['on_s'] / e['off_s']:.3f}x "
                    f"(on {e['on_s']:.4g}s vs off {e['off_s']:.4g}s)")
    sparse = codec.get("sparse_ckpt", {})
    if not sparse:
        errors.append("no sparse_ckpt codec entry found in the artifact")
    else:
        measured, modeled = sparse["measured_ratio"], sparse["modeled_ratio"]
        if measured <= 2.0:
            errors.append(
                f"codec/sparse_ckpt: measured slow-hop compression ratio "
                f"{measured:.3f}x <= the 2x acceptance floor")
        if not (0.5 <= modeled / max(measured, 1e-12) <= 2.0):
            errors.append(
                f"codec/sparse_ckpt: modeled ratio {modeled:.3f}x and "
                f"measured ratio {measured:.3f}x disagree by more than 2x")

    # ---- session bounds (within the current artifact) ----------------
    session = current.get("session", {})
    if not session:
        errors.append("no session entries found in the artifact")
    for wl, e in session.items():
        if e["steady_cost_s"] >= e["first_cost_s"]:
            errors.append(
                f"session/{wl}: steady-state cost {e['steady_cost_s']:.4g}s "
                f"does not beat the first write's {e['first_cost_s']:.4g}s "
                "(plan compile no longer amortized)")
        if e["steady_total_s"] > e["first_total_s"] * (1 + 1e-9):
            errors.append(
                f"session/{wl}: steady-state modeled total "
                f"{e['steady_total_s']:.4g}s exceeds the first write's "
                f"{e['first_total_s']:.4g}s — measured feedback made it "
                "WORSE (the revert-losing-trials arbiter broke)")
        if not e.get("plan_reused"):
            errors.append(
                f"session/{wl}: steady-state write did not reuse a "
                f"cached plan (source {e['writes'][-1]['source']!r})")
        pc = e.get("placement", {})
        if pc:
            bound = min(pc["spread"], pc["packed"], pc["off"]) * 1.05
            if pc["auto"] > bound:
                errors.append(
                    f"session/{wl}: placement='auto' "
                    f"({pc['auto']:.4g}s) is worse than the best of "
                    f"spread/packed/off ({bound / 1.05:.4g}s) by > 5%")
        else:
            errors.append(f"session/{wl}: no placement columns")

    # ---- auto depth agrees with the measured best somewhere ----------
    agreements, checked = [], []
    for pname, entry in current.get("host", {}).items():
        for method, e in entry.items():
            if "auto_depth" not in e:
                continue
            expect = min(e["best_depth_measured"], e["rounds"])
            checked.append(f"{pname}/{method}")
            agreements.append(e["auto_depth"] == expect)
    if not checked:
        errors.append("no host depth-sweep entries found in the artifact")
    elif not any(agreements):
        errors.append(
            "pipeline_depth='auto' disagreed with the measured best depth "
            f"on every workload checked: {checked}")
    return errors, matched


DEGRADED_STEADY_X = 1.5   # steady degraded total vs healthy steady
DEGRADED_RECOVERY_X = 2.0  # dead-agg recovery cost vs one healthy write


def check_degraded(degraded: dict, baseline: dict | None) -> list[str]:
    """Gate on the ``degraded`` suite's artifact (``BENCH_degraded.json``,
    benchmarks/degraded.py). The bounds are the fault layer's acceptance
    contract, enforced WITHIN the artifact (timings are modeled and
    deterministic); the baseline pins scenario COVERAGE only:

    * every scenario completes with every write byte-identical to the
      healthy oracle — recovery never costs correctness;
    * slow_node: the session evacuates the straggler within ONE write
      of the fault appearing, the straggler's served share drops, and
      the steady degraded total stays within ``DEGRADED_STEADY_X`` of
      healthy;
    * dead_aggregator: recovery happened (detection + replay + torn
      rewrite reported) and cost at most ``DEGRADED_RECOVERY_X`` healthy
      writes;
    * resize: the loop actually shrank the writer and kept going.
    """
    errors = []
    scenarios = degraded.get("scenarios", {})
    if not scenarios:
        errors.append("degraded: no scenarios in the artifact")
        return errors
    for key in (baseline or {}).get("scenarios", []):
        if key not in scenarios:
            errors.append(
                f"degraded/{key}: scenario in the baseline but missing "
                "from the artifact — coverage shrank")
    for key, e in sorted(scenarios.items()):
        if not e.get("completed"):
            errors.append(f"degraded/{key}: scenario did not complete "
                          "(the write loop wedged)")
            continue
        if not e.get("byte_identical"):
            errors.append(
                f"degraded/{key}: a recovered write is NOT byte-identical "
                "to the healthy oracle")
        healthy, steady = e["healthy_steady_s"], e["degraded_steady_s"]
        scen = e.get("scenario")
        if scen in ("healthy", "slow_node", "resize") \
                and steady > DEGRADED_STEADY_X * healthy:
            errors.append(
                f"degraded/{key}: steady degraded total {steady:.4g}s "
                f"exceeds {DEGRADED_STEADY_X}x healthy ({healthy:.4g}s)")
        if scen == "slow_node":
            adapt = e.get("adaptation_writes", -1)
            if not 0 <= adapt <= 1:
                errors.append(
                    f"degraded/{key}: straggler evacuation took "
                    f"{adapt} writes (must land within ONE write of the "
                    "fault appearing)")
            if not e.get("slow_share_after", 1.0) \
                    < e.get("slow_share_before", 0.0):
                errors.append(
                    f"degraded/{key}: straggler's served share did not "
                    f"drop ({e.get('slow_share_before')} -> "
                    f"{e.get('slow_share_after')})")
        if scen == "dead_aggregator":
            rec = e.get("recovery_s", 0.0)
            if not rec > 0:
                errors.append(
                    f"degraded/{key}: dead aggregator reported no "
                    "recovery cost — detection/replay not charged")
            if rec > DEGRADED_RECOVERY_X * healthy:
                errors.append(
                    f"degraded/{key}: recovery cost {rec:.4g}s exceeds "
                    f"{DEGRADED_RECOVERY_X}x a healthy write "
                    f"({healthy:.4g}s) — recovery is unbounded")
            if e.get("torn_repaired", 0) < 1:
                errors.append(
                    f"degraded/{key}: the victim's torn segment was "
                    "never detected + rewritten")
            if not e.get("repair_map"):
                errors.append(f"degraded/{key}: no repair map reported")
        if scen == "resize":
            if not e.get("post_resize_ranks", 1 << 30) \
                    < degraded["config"]["P"]:
                errors.append(
                    f"degraded/{key}: resize did not shrink the writer "
                    f"(ranks {e.get('post_resize_ranks')})")
    return errors


RESTORE_FLAT_X = 1.3      # cache-on restore total, 2 -> 8 replicas/node


def check_restore(restore: dict, baseline: dict | None) -> list[str]:
    """Gate on the ``restore`` suite's artifact (``BENCH_restore.json``,
    benchmarks/restore.py). The bounds are the read path's acceptance
    contract, enforced WITHIN the artifact (timings are modeled and
    deterministic); the baseline pins workload COVERAGE only:

    * every replica point reads byte-identical to the single-reader
      ``read_file`` oracle, cache on and off;
    * the node cache makes same-node restore FLAT: the cache-on total
      at the highest replica count stays within ``RESTORE_FLAT_X`` of
      the lowest's (each node pays the slow hop once per window, not
      once per reader);
    * cache-on never models slower than cache-off at any point, and
      conserves deliveries (``hits + misses`` == cache-off misses);
    * the warm (session-hit) restore never models worse than the cold
      compile+sweep one;
    * the half-tree subset restore reads < 50% of the file's bytes
      (ranged segment reads, not whole-file).
    """
    errors = []
    wls = restore.get("workloads", {})
    if not wls:
        errors.append("restore: no workloads in the artifact")
        return errors
    for wl in (baseline or {}).get("workloads", []):
        if wl not in wls:
            errors.append(
                f"restore/{wl}: workload in the restore baseline but "
                "missing from the artifact — coverage shrank")
    for wl, e in sorted(wls.items()):
        pts = e.get("replicas", {})
        if not pts:
            errors.append(f"restore/{wl}: no replica points")
            continue
        for q, p in sorted(pts.items(), key=lambda kv: int(kv[0])):
            if not p.get("byte_identical"):
                errors.append(
                    f"restore/{wl}/q{q}: replicated read is NOT "
                    "byte-identical to the single-reader oracle")
            on, off = p["cache_on"], p["cache_off"]
            if on["total_s"] > off["total_s"] * (1 + 1e-9):
                errors.append(
                    f"restore/{wl}/q{q}: cache-on restore "
                    f"({on['total_s']:.4g}s) models SLOWER than "
                    f"cache-off ({off['total_s']:.4g}s)")
            if not p.get("delivery_conserved"):
                errors.append(
                    f"restore/{wl}/q{q}: cache-on hits+misses "
                    f"({on['cache_hits']}+{on['cache_misses']}) != "
                    f"cache-off misses ({off['cache_misses']}) — "
                    "deliveries lost or duplicated")
            if "hit_ratio" not in on:
                errors.append(f"restore/{wl}/q{q}: no cache hit ratio")
        lo = min(pts, key=int)
        hi = max(pts, key=int)
        t_lo = pts[lo]["cache_on"]["total_s"]
        t_hi = pts[hi]["cache_on"]["total_s"]
        if t_hi > RESTORE_FLAT_X * t_lo:
            errors.append(
                f"restore/{wl}: cache-on total grew {t_hi / t_lo:.3f}x "
                f"from {lo} to {hi} replicas/node (bound "
                f"{RESTORE_FLAT_X}x) — the node cache stopped "
                "flattening same-node restores")
        sess = e.get("session", {})
        if not sess:
            errors.append(f"restore/{wl}: no cold/warm session columns")
        else:
            if sess["warm_s"] > sess["cold_s"] * (1 + 1e-9):
                errors.append(
                    f"restore/{wl}: warm restore {sess['warm_s']:.4g}s "
                    f"models worse than cold {sess['cold_s']:.4g}s — "
                    "the read arbiter kept a losing plan")
            if not sess.get("plan_reused"):
                errors.append(
                    f"restore/{wl}: steady-state restore did not reuse "
                    f"a cached read plan (sources {sess.get('sources')})")
    sub = restore.get("subset", {})
    if not sub:
        errors.append("restore: no subset entry in the artifact")
    else:
        if not sub.get("byte_identical"):
            errors.append("restore/subset: restored leaves are NOT "
                          "byte-identical to the saved tree")
        if sub.get("frac", 1.0) >= 0.5:
            errors.append(
                f"restore/subset: half-tree restore read "
                f"{sub.get('read_bytes')}/{sub.get('file_len')} bytes "
                f"({sub.get('frac', 1.0):.0%}) — ranged reads must stay "
                "under 50% of the file")
    return errors


ASYNC_OVERHEAD_X = 0.05   # checkpoint-every-N step-time overhead bound


def check_async(blob: dict, baseline: dict | None) -> list[str]:
    """Gate on the ``async_ckpt`` suite's artifact (``BENCH_async.json``,
    benchmarks/async_ckpt.py). Times are real wall clock (the suite
    measures thread overlap), so every bound is a within-artifact
    ratio — the suite runs its variants in paired rounds and keeps the
    round with the cleanest paired ratio to absorb runner jitter; the
    baseline pins variant coverage only:

    * async checkpoint-every-N overhead vs the no-checkpoint floor
      stays under ``ASYNC_OVERHEAD_X`` — the loop pays the snapshot,
      not the collective write;
    * the final async checkpoint is byte-identical to the synchronous
      variant's (snapshot isolation costs no correctness);
    * the max hidden fraction across the async saves is > 0 — part of
      the drain demonstrably ran before the caller blocked on it.
    """
    errors = []
    variants = blob.get("variants", {})
    for v in (baseline or {}).get("variants", ("none", "sync", "async")):
        if v not in variants:
            errors.append(
                f"async/{v}: variant in the baseline but missing from "
                "the artifact — coverage shrank")
    if not all(v in variants for v in ("none", "sync", "async")):
        return errors or ["async: artifact missing variants"]
    overhead = variants["async"].get("overhead_frac", 1.0)
    if overhead >= ASYNC_OVERHEAD_X:
        errors.append(
            f"async: checkpoint-every-N step-time overhead "
            f"{overhead:.1%} >= the {ASYNC_OVERHEAD_X:.0%} bound "
            "(the loop is paying for the collective write again)")
    if not blob.get("byte_identical"):
        errors.append(
            "async: final async checkpoint is NOT byte-identical to "
            "the synchronous write")
    hidden = variants["async"].get("hidden_fraction_max", 0.0)
    if not hidden > 0.0:
        errors.append(
            f"async: max hidden fraction {hidden} — none of the drain "
            "overlapped the compute steps")
    if not blob.get("saves"):
        errors.append("async: no per-save drain accounting in the "
                      "artifact")
    return errors


TRANSPORT_CONCORDANCE = 0.6   # modeled-vs-measured ordering agreement


def check_transport(blob: dict, baseline: dict | None) -> list[str]:
    """Gate on the ``transport`` suite's artifact
    (``BENCH_transport.json``, benchmarks/transport.py). Wire bytes
    are counted at the receiving socket and wall times are real, so
    every bound is within-artifact; the baseline pins point coverage
    only:

    * every point byte-identical to the in-process host oracle;
    * aggregated (TAM, one LA per node) slow-hop wire bytes STRICTLY
      below flat two-phase at >= 4 ranks per node, and never above it
      at 2 — the paper's intra-node-aggregation claim on a real wire;
    * the cost model's ranking of points agrees with the measured
      wall-clock ranking on >= ``TRANSPORT_CONCORDANCE`` of the pairs
      whose modeled totals differ by more than 10% — the planner's
      auto-resolution still steers the real backend.
    """
    errors = []
    points = blob.get("points", [])
    have = {(p["rpn"], p["variant"]) for p in points}
    for bp in (baseline or {}).get("points", []):
        if (bp["rpn"], bp["variant"]) not in have:
            errors.append(
                f"transport/rpn{bp['rpn']}/{bp['variant']}: point in "
                "the baseline but missing from the artifact — coverage "
                "shrank")
    if not points:
        return errors or ["transport: artifact has no points"]
    for p in points:
        if not p.get("byte_identical"):
            errors.append(
                f"transport/rpn{p['rpn']}/{p['variant']}: mp executor "
                "output is NOT byte-identical to the host oracle")
    by_rpn = {}
    for p in points:
        by_rpn.setdefault(p["rpn"], {})[p["variant"]] = p
    for rpn, d in sorted(by_rpn.items()):
        if not {"flat", "aggregated"} <= set(d):
            errors.append(f"transport/rpn{rpn}: missing a variant — "
                          "cannot compare aggregated vs flat")
            continue
        agg = d["aggregated"]["wire_slow_bytes"]
        flat = d["flat"]["wire_slow_bytes"]
        if rpn >= 4 and not agg < flat:
            errors.append(
                f"transport/rpn{rpn}: aggregated slow-hop wire "
                f"{agg}B is not strictly below flat two-phase "
                f"{flat}B — intra-node aggregation stopped paying on "
                "the real wire")
        elif agg > flat:
            errors.append(
                f"transport/rpn{rpn}: aggregated slow-hop wire {agg}B "
                f"exceeds flat two-phase {flat}B")
    agree = eligible = 0
    for i, a in enumerate(points):
        for b in points[i + 1:]:
            hi = max(a["modeled_s"], b["modeled_s"])
            if hi <= 0 or abs(a["modeled_s"] - b["modeled_s"]) <= 0.1 * hi:
                continue
            eligible += 1
            if ((a["modeled_s"] - b["modeled_s"])
                    * (a["wall_s"] - b["wall_s"]) > 0):
                agree += 1
    if eligible == 0:
        errors.append("transport: no point pair has modeled totals "
                      "differing by >10% — concordance is unmeasurable")
    elif agree / eligible < TRANSPORT_CONCORDANCE:
        errors.append(
            f"transport: modeled-vs-measured ordering agreement "
            f"{agree}/{eligible} below the "
            f"{TRANSPORT_CONCORDANCE:.0%} concordance bound — the "
            "cost model no longer predicts the real backend")
    return errors


KERNEL_JITTER = 0.25      # per-workload headroom; the SUM is strict


def check_kernels(kernels: dict, baseline: dict | None) -> list[str]:
    """Fused-round gate on the ``kernel_fusion`` suite's artifact.
    Wall times are only ever compared WITHIN the artifact (fused vs
    unfused ran back to back on the same machine); the baseline pins
    workload coverage only."""
    errors = []
    drain = kernels.get("drain", {})
    if not drain:
        errors.append("kernels: no drain entries in the artifact")
        return errors
    for wl in (baseline or {}).get("workloads", []):
        if wl not in drain:
            errors.append(
                f"kernels/{wl}: workload in the kernels baseline but "
                "missing from the artifact — coverage shrank")
    tot_f = tot_u = 0.0
    for wl, e in sorted(drain.items()):
        if not e["byte_identical"]:
            errors.append(
                f"kernels/{wl}: fused drain is NOT byte-identical to "
                "the unfused path")
        tot_f += e["fused_us"]
        tot_u += e["unfused_us"]
        if e["fused_us"] > e["unfused_us"] * (1 + KERNEL_JITTER):
            errors.append(
                f"kernels/{wl}: fused drain {e['fused_us']:.0f}us vs "
                f"unfused {e['unfused_us']:.0f}us — slower by more than "
                f"the {KERNEL_JITTER:.0%} jitter headroom")
    if tot_f > tot_u:
        errors.append(
            f"kernels: fused drain total {tot_f:.0f}us exceeds unfused "
            f"{tot_u:.0f}us over the registry — fusion stopped paying "
            "for itself")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.2)
    ap.add_argument("--kernels", default=None,
                    help="BENCH_kernels.json from the kernel_fusion suite")
    ap.add_argument("--kernels-baseline", default=None,
                    help="coverage baseline for --kernels")
    ap.add_argument("--degraded", default=None,
                    help="BENCH_degraded.json from the degraded suite")
    ap.add_argument("--degraded-baseline", default=None,
                    help="coverage baseline for --degraded")
    ap.add_argument("--restore", default=None,
                    help="BENCH_restore.json from the restore suite")
    ap.add_argument("--restore-baseline", default=None,
                    help="coverage baseline for --restore")
    ap.add_argument("--async", dest="async_bench", default=None,
                    help="BENCH_async.json from the async_ckpt suite")
    ap.add_argument("--async-baseline", dest="async_baseline",
                    default=None, help="coverage baseline for --async")
    ap.add_argument("--transport", default=None,
                    help="BENCH_transport.json from the transport suite")
    ap.add_argument("--transport-baseline", default=None,
                    help="coverage baseline for --transport")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    errors, matched = check(current, baseline, args.threshold)
    kmatched = 0
    if args.kernels:
        with open(args.kernels) as f:
            kernels = json.load(f)
        kbase = None
        if args.kernels_baseline:
            with open(args.kernels_baseline) as f:
                kbase = json.load(f)
        errors += check_kernels(kernels, kbase)
        kmatched = len(kernels.get("drain", {}))
    dmatched = 0
    if args.degraded:
        with open(args.degraded) as f:
            degraded = json.load(f)
        dbase = None
        if args.degraded_baseline:
            with open(args.degraded_baseline) as f:
                dbase = json.load(f)
        errors += check_degraded(degraded, dbase)
        dmatched = len(degraded.get("scenarios", {}))
    rmatched = 0
    if args.restore:
        with open(args.restore) as f:
            restore = json.load(f)
        rbase = None
        if args.restore_baseline:
            with open(args.restore_baseline) as f:
                rbase = json.load(f)
        errors += check_restore(restore, rbase)
        rmatched = sum(len(e.get("replicas", {}))
                       for e in restore.get("workloads", {}).values())
    amatched = 0
    if args.async_bench:
        with open(args.async_bench) as f:
            async_blob = json.load(f)
        abase = None
        if args.async_baseline:
            with open(args.async_baseline) as f:
                abase = json.load(f)
        errors += check_async(async_blob, abase)
        amatched = len(async_blob.get("variants", {}))
    tmatched = 0
    if args.transport:
        with open(args.transport) as f:
            transport_blob = json.load(f)
        tbase = None
        if args.transport_baseline:
            with open(args.transport_baseline) as f:
                tbase = json.load(f)
        errors += check_transport(transport_blob, tbase)
        tmatched = len(transport_blob.get("points", []))
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print(f"benchmark gate OK ({matched} matched points"
              + (f", {kmatched} fused-drain workloads" if kmatched else "")
              + (f", {dmatched} degraded scenarios" if dmatched else "")
              + (f", {rmatched} restore replica points" if rmatched else "")
              + (f", {amatched} async variants" if amatched else "")
              + (f", {tmatched} transport points" if tmatched else "")
              + f", threshold {args.threshold:.0%})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
