"""Micro-benchmarks of the aggregation hot spots.

Interpret-mode Pallas timings are NOT TPU timings — the meaningful
numbers are the pure-jnp path (what a CPU host would run) and the
derived column (ops per call, compare counts), which feed the roofline
sanity checks.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coalesce as co
from repro.core.exchange import sort_with
from repro.core.requests import make_requests


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def sort_coalesce_pack():
    rows = []
    rng = np.random.default_rng(0)
    for n in (1024, 8192, 32768):
        gaps = rng.integers(1, 9, size=n)
        lens = rng.integers(1, 6, size=n).astype(np.int32)
        offs = (np.cumsum(gaps) + np.concatenate(
            [[0], np.cumsum(lens)[:-1]])).astype(np.int32)
        r = make_requests(offs, lens, capacity=n)
        starts = co.request_starts(r)
        perm = rng.permutation(n)
        from repro.core.requests import RequestList
        shuffled = RequestList(r.offsets[perm], r.lengths[perm], r.count)

        f_sort = jax.jit(lambda rr, ss: sort_with(rr, ss)[0].offsets)
        rows.append((f"kernel/sort_jnp/n{n}",
                     _timeit(f_sort, shuffled, starts), n))
        f_coal = jax.jit(lambda rr: co.coalesce_sorted(rr).count)
        rows.append((f"kernel/coalesce_jnp/n{n}",
                     _timeit(f_coal, r), n))
        total = int(lens.sum())
        data = jnp.arange(total, dtype=jnp.int32)
        out_len = int(offs[-1] + lens[-1])
        f_pack = jax.jit(lambda rr, ss, dd: co.pack_data(
            rr, ss, dd, out_len))
        rows.append((f"kernel/pack_jnp/n{n}",
                     _timeit(f_pack, r, starts, data), total))
    return rows
