"""Micro-benchmarks of the aggregation hot spots.

Shapes come from the shared workload registry
(``benchmarks/workloads.py`` ``HOST_PATTERNS``) instead of ad-hoc
random sizes: each pattern's per-rank byte requests are folded into one
drain window, which is exactly the aggregator-view input the round
engine's drain sees per round — so the sort/pack timings move when the
paper workloads move, not when a hardcoded constant does.

Two suites:

* ``sort_coalesce_pack`` — the pure-jnp hot paths (what a CPU host
  runs): argsort-based request sort, coalesce, scatter pack.
* ``fused_vs_unfused`` — the PR's fused-round column: the single
  ``pallas_call`` of ``kernels/fused_round.py`` (sort + dual pack, one
  binary-search sweep) against the unfused kernel path (bitonic sort
  kernel + TWO pack-kernel sweeps) on identical inputs. Emits
  ``BENCH_kernels.json`` (env ``BENCH_KERNELS_OUT`` overrides) with
  the per-workload wall times and a byte-identity bit;
  ``check_regression.py --kernels`` gates fused <= unfused and the
  identity in CI.

Interpret-mode Pallas timings are NOT TPU timings — but fused and
unfused run through the SAME interpreter on the same shapes, so the
comparison isolates the structural saving (one kernel launch and one
search sweep instead of three launches and two sweeps).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import workloads
from repro.core import coalesce as co
from repro.core.exchange import sort_with
from repro.core.requests import make_requests
from repro.kernels import ops as kops

BENCH_P = 16        # ranks the registry patterns generate for
WINDOW = 8192       # one drain window (bytes = two pack tiles)
REQ_CAP = 2048      # aggregator-view requests per window
MAX_REQ_LEN = 64    # bounds the packed payload at REQ_CAP * 64 bytes


def _timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))     # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def window_requests(name: str):
    """One drain window's aggregator-view inputs from the registry
    pattern ``name``: every rank's byte requests folded into a
    WINDOW-sized window (rank order, i.e. UNSORTED — sorting is part
    of what is being timed), payloads derived from the folded offset
    so any overlap is identical-data, the drain contract. Returns
    ``(requests, starts, data, n_requests)``."""
    reqs = workloads.HOST_PATTERNS[name](BENCH_P)
    offs = np.concatenate([o for o, _, _ in reqs]).astype(np.int64)
    lens = np.concatenate([ln for _, ln, _ in reqs]).astype(np.int64)
    offs = offs % WINDOW
    lens = np.minimum(np.minimum(lens, MAX_REQ_LEN), WINDOW - offs)
    keep = lens > 0
    offs = offs[keep][:REQ_CAP].astype(np.int32)
    lens = lens[keep][:REQ_CAP].astype(np.int32)
    n = offs.size
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    data = np.zeros(int(lens.sum()), np.int32)
    for i in range(n):
        data[starts[i]:starts[i] + lens[i]] = \
            (offs[i] + np.arange(lens[i])) % 251 + 1
    r = make_requests(offs, lens, capacity=n)
    return r, jnp.asarray(starts), jnp.asarray(data), n


def sort_coalesce_pack():
    """jnp hot-path timings on the registry shapes."""
    rows = []
    for name in workloads.HOST_PATTERNS:
        r, starts, data, n = window_requests(name)
        f_sort = jax.jit(lambda rr, ss: sort_with(rr, ss)[0].offsets)
        rows.append((f"kernel/sort_jnp/{name}",
                     _timeit(f_sort, r, starts), n))
        sr, ss = sort_with(r, starts)
        f_coal = jax.jit(lambda rr: co.coalesce_sorted(rr).count)
        rows.append((f"kernel/coalesce_jnp/{name}", _timeit(f_coal, sr), n))
        f_pack = jax.jit(lambda rr, s2, dd: co.pack_data(rr, s2, dd,
                                                         WINDOW))
        rows.append((f"kernel/pack_jnp/{name}",
                     _timeit(f_pack, sr, ss, data), int(data.shape[0])))
    return rows


def fused_vs_unfused():
    """The fused-round drain: one ``pallas_call`` vs the unfused
    kernel path (sort kernel + two pack-kernel sweeps), per registry
    workload. Writes the artifact ``check_regression.py --kernels``
    gates (fused <= unfused, byte identity)."""
    rows = []
    blob = {}
    for name in workloads.HOST_PATTERNS:
        r, starts, data, n = window_requests(name)

        def unfused(rr, ss, dd):
            sr, s2 = kops.sort_requests_with(rr, ss)
            win = kops.pack(sr, s2, dd, 0, WINDOW)
            mask = kops.pack(sr, s2, jnp.ones_like(dd), 0, WINDOW)
            return win, mask

        def fused(rr, ss, dd):
            return kops.fused_drain_pack(rr, ss, dd, 0, WINDOW)

        ju, jf = jax.jit(unfused), jax.jit(fused)
        wu, mu = jax.block_until_ready(ju(r, starts, data))
        wf, mf = jax.block_until_ready(jf(r, starts, data))
        identical = bool(np.array_equal(np.asarray(wu), np.asarray(wf))
                         and np.array_equal(np.asarray(mu),
                                            np.asarray(mf)))
        t_u = _timeit(ju, r, starts, data)
        t_f = _timeit(jf, r, starts, data)
        rows.append((f"kernel/drain_unfused/{name}", t_u, n))
        rows.append((f"kernel/drain_fused/{name}", t_f,
                     f"speedup={t_u / t_f:.2f}x"))
        blob[name] = {"unfused_us": t_u, "fused_us": t_f,
                      "n_requests": n, "out_len": WINDOW,
                      "byte_identical": identical}
    out = os.environ.get("BENCH_KERNELS_OUT", "BENCH_kernels.json")
    with open(out, "w") as f:
        json.dump({"drain": blob}, f, indent=1, sort_keys=True)
    return rows
