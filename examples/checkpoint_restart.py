"""Fault tolerance demo: kill-and-recover with elastic re-mesh.

1. Train a small model, checkpointing through TAM every 20 steps.
2. Inject a host failure at step 47 (heartbeat monitor fires).
3. Restore the latest checkpoint (step 40) and finish the run —
   demonstrating that the checkpoint byte-space is mesh-agnostic and
   the deterministic data pipeline replays the exact batch stream.
4. Verify the recovered run converges to the same loss as an
   uninterrupted control run.

Run:  PYTHONPATH=src python examples/checkpoint_restart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager, HostCollectiveIO
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim import adamw
from repro.runtime import (HeartbeatMonitor, TrainLoop, TrainLoopConfig,
                           plan_remesh)

CKPT_DIR = "/tmp/repro_restart_demo"
shutil.rmtree(CKPT_DIR, ignore_errors=True)

cfg = reduced(configs.get("glm4_9b"))
opt = adamw(weight_decay=0.0)
data = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq=32,
                                         global_batch=4))


def train_step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, batch)
    params, opt_state = opt.update(grads, opt_state, params, 1e-3)
    return params, opt_state, loss


train_step = jax.jit(train_step)
io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1 << 16,
                      stripe_count=4)

params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
opt_state = opt.init(params)

# ---- control: uninterrupted 80 steps --------------------------------
ctrl_p, ctrl_o = params, opt_state
for step in range(80):
    ctrl_p, ctrl_o, ctrl_loss = train_step(ctrl_p, ctrl_o,
                                           jax.tree.map(jnp.asarray,
                                                        data.batch_at(step)))
print(f"control final loss: {float(ctrl_loss):.5f}")

# ---- faulty run ------------------------------------------------------
mon = HeartbeatMonitor(n_hosts=4, timeout_s=1e9)
ckpt = CheckpointManager(CKPT_DIR, io, method="tam", local_aggregators=4)
loop = TrainLoop(TrainLoopConfig(total_steps=80, checkpoint_every=20),
                 train_step, data, ckpt, monitor=mon)


def inject(step, loss):
    if step == 47:
        mon.inject_failure(2)


try:
    loop.run(params, opt_state, on_step=inject)
    raise AssertionError("failure was not detected")
except RuntimeError as e:
    print(f"detected: {e} at latest checkpoint step {ckpt.latest_step()}")

# ---- recovery: re-mesh for 3 surviving hosts and resume --------------
plan = plan_remesh(total_devices=3 * 4, model_parallel=4,
                   old_data_parallel=4)
print(f"elastic plan: mesh {plan.mesh_shape}, grad_accum x{plan.grad_accum}")
mon.revive(2)

state, step0 = ckpt.restore({"params": params, "opt": opt_state})
params2, opt2 = state["params"], state["opt"]
loop2 = TrainLoop(TrainLoopConfig(total_steps=80, checkpoint_every=20),
                  train_step, data, ckpt, monitor=mon)
params2, opt2, _ = loop2.run(params2, opt2, start_step=step0)

final = float(T.loss_fn(params2, cfg, jax.tree.map(
    jnp.asarray, data.batch_at(80))))
ctrl_final = float(T.loss_fn(ctrl_p, cfg, jax.tree.map(
    jnp.asarray, data.batch_at(80))))
print(f"recovered loss {final:.5f} vs control {ctrl_final:.5f}")
assert abs(final - ctrl_final) < 0.05, "recovery diverged"
print("OK: kill-and-recover run matches uninterrupted control")
