"""Serving example: prefill + batched greedy decode on a reduced gemma2
(local/global attention + softcaps exercised on the serving path).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma2_9b", "--batch", "4",
                "--prompt-len", "24", "--gen", "12"]
    main()
