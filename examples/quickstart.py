"""Quickstart: TAM collective I/O in five minutes.

1. Build a BTIO-like noncontiguous write pattern for 32 ranks.
2. Write it with classic two-phase I/O and with TAM; verify identical
   files; compare the congestion/timing model.
3. Ask the cost model what the paper's full 16384-process run looks like.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.checkpoint import HostCollectiveIO
from repro.core import cost_model as cm
from repro.io_patterns import btio_pattern

P = 36  # BTIO wants a square process count
reqs = btio_pattern(P, n=36)
io = HostCollectiveIO(n_ranks=P, n_nodes=6, stripe_size=4096,
                      stripe_count=4)

t_2ph = io.write(reqs, "/tmp/quickstart", method="twophase")
t_tam = io.write(reqs, "/tmp/quickstart_tam", method="tam",
                 local_aggregators=12)

file_len = int(max(o[-1] + l[-1] for o, l, _ in reqs))
same = np.array_equal(io.read_file("/tmp/quickstart", file_len),
                      io.read_file("/tmp/quickstart_tam", file_len))
print(f"files identical: {same}")
print(f"two-phase: {t_2ph.messages_at_ga} msgs at hottest aggregator, "
      f"modeled {t_2ph.total*1e3:.2f} ms")
print(f"TAM      : {t_tam.messages_at_ga} msgs at hottest aggregator, "
      f"modeled {t_tam.total*1e3:.2f} ms, "
      f"coalesce {t_tam.requests_before} -> {t_tam.requests_after}")

print("\n--- paper scale (16384 procs, 256 nodes, 56 OSTs) ---")
for name, wl in (("E3SM-F", cm.e3sm_f), ("E3SM-G", cm.e3sm_g),
                 ("BTIO", cm.btio), ("S3D-IO", cm.s3d)):
    w = wl(16384, 256)
    best, cost = cm.optimal_PL(w)
    print(f"{name:7s} two-phase {cm.twophase_cost(w).total:7.1f}s  "
          f"TAM(P_L={best}) {cost.total:6.1f}s  "
          f"speedup {cm.speedup(w, best):5.1f}x")
