"""End-to-end training driver: train a small yi-arch LM with the full
stack — data pipeline, AdamW, TAM checkpoints — for a few hundred steps.

Defaults are CPU-sized (~3M params, 300 steps, a couple of minutes);
``--d-model 768 --n-layers 12`` gives the ~100M-param configuration on
real hardware.

Run:  PYTHONPATH=src python examples/train_small_lm.py [--steps 300]
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "300"]
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "yi_34b",
           "--smoke", "--lr", "3e-3", "--ckpt-every", "100",
           "--ckpt-dir", "/tmp/repro_train_ckpt"] + args
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env})
    raise SystemExit(subprocess.call(cmd, env=env))
