"""Fault-tolerant training loop (checkpoint/restart + heartbeats).

The real-hardware loop in miniature, CPU-runnable: deterministic data
pipeline, jitted train step, rolling TAM checkpoints, heartbeat-driven
failure handling (restore from the last checkpoint, optionally onto a
shrunken elastic mesh). examples/checkpoint_restart.py drives a full
kill-and-recover cycle through this class.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.runtime.heartbeat import HeartbeatMonitor


@dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    log_every: int = 10


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, train_step: Callable,
                 data: SyntheticTokenPipeline,
                 ckpt: CheckpointManager,
                 monitor: HeartbeatMonitor | None = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data
        self.ckpt = ckpt
        self.monitor = monitor or HeartbeatMonitor(1, timeout_s=1e9)
        self.losses: list[float] = []

    def run(self, params, opt_state, start_step: int = 0,
            on_step: Callable | None = None):
        """Run to total_steps; returns (params, opt_state, last_step).

        Raises ``RuntimeError("host failure")`` when the monitor reports
        dead hosts — the caller (see examples/checkpoint_restart.py)
        restores from the last checkpoint and calls ``run`` again,
        possibly with re-sharded state on a smaller mesh.
        """
        step = start_step
        while step < self.cfg.total_steps:
            if not self.monitor.healthy():
                raise RuntimeError(
                    f"host failure: {self.monitor.dead_hosts()}")
            batch = jax.tree.map(lambda x: jax.numpy.asarray(x),
                                 self.data.batch_at(step))
            params, opt_state, loss = self.train_step(
                params, opt_state, batch)
            self.monitor.beat(0)
            step += 1
            if step % self.cfg.log_every == 0:
                self.losses.append(float(loss))
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save({"params": params, "opt": opt_state}, step)
            if on_step is not None:
                on_step(step, float(loss))
        return params, opt_state, step
