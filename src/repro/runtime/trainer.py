"""Fault-tolerant training loop (checkpoint/restart + heartbeats).

The real-hardware loop in miniature, CPU-runnable: deterministic data
pipeline, jitted train step, rolling TAM checkpoints, heartbeat-driven
failure handling (restore from the last checkpoint, optionally onto a
shrunken elastic mesh). examples/checkpoint_restart.py drives a full
kill-and-recover cycle through this class.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.runtime.heartbeat import HeartbeatMonitor


@dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    log_every: int = 10
    # Overlap the collective write with subsequent train steps: the
    # checkpoint boundary snapshots + returns immediately and the drain
    # runs behind compute (CheckpointManager.save_async). The manager's
    # one-in-flight backpressure means a too-slow drain degrades to the
    # sync cadence rather than queueing unboundedly.
    async_checkpoint: bool = False


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, train_step: Callable,
                 data: SyntheticTokenPipeline,
                 ckpt: CheckpointManager,
                 monitor: HeartbeatMonitor | None = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data
        self.ckpt = ckpt
        self.monitor = monitor or HeartbeatMonitor(1, timeout_s=1e9)
        self.losses: list[float] = []

    def run(self, params, opt_state, start_step: int = 0,
            on_step: Callable | None = None):
        """Run to total_steps; returns (params, opt_state, last_step).

        Raises ``RuntimeError("host failure")`` when the monitor reports
        dead hosts — the caller (see examples/checkpoint_restart.py)
        restores from the last checkpoint and calls ``run`` again,
        possibly with re-sharded state on a smaller mesh. A host
        failure deliberately does NOT drain an in-flight async write:
        the restart discovers the latest COMMITTED manifest
        (elastic.find_restart_step), and an abandoned half-drained
        write is invisible to it by the commit-last layout.

        With ``cfg.async_checkpoint`` the checkpoint boundary calls
        :meth:`CheckpointManager.save_async` — the write drains behind
        the following steps — and normal completion blocks on the last
        pending write so a finished ``run`` never leaves a checkpoint
        in flight.
        """
        step = start_step
        while step < self.cfg.total_steps:
            if not self.monitor.healthy():
                raise RuntimeError(
                    f"host failure: {self.monitor.dead_hosts()}")
            batch = jax.tree.map(lambda x: jax.numpy.asarray(x),
                                 self.data.batch_at(step))
            params, opt_state, loss = self.train_step(
                params, opt_state, batch)
            self.monitor.beat(0)
            step += 1
            if step % self.cfg.log_every == 0:
                self.losses.append(float(loss))
            if step % self.cfg.checkpoint_every == 0:
                state = {"params": params, "opt": opt_state}
                if self.cfg.async_checkpoint:
                    self.ckpt.save_async(state, step)
                else:
                    self.ckpt.save(state, step)
            if on_step is not None:
                on_step(step, float(loss))
        if self.cfg.async_checkpoint:
            self.ckpt.block_until_done()
        return params, opt_state, step
