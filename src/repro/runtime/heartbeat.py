"""Failure detection for the training controller.

On a real fleet each host posts a heartbeat to the coordinator (or the
coordinator observes barrier timeouts). Here the monitor abstracts that:
workers call ``beat(host_id)``; the controller polls ``dead_hosts()``.
Failure injection (``inject_failure``) drives the fault-tolerance tests
and the checkpoint-restart example without real hardware deaths.
"""
from __future__ import annotations

import threading
import time


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 10.0,
                 clock=time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._clock = clock
        self._last = {h: clock() for h in range(n_hosts)}
        self._failed: set[int] = set()
        self._lock = threading.Lock()

    def beat(self, host_id: int):
        with self._lock:
            if host_id not in self._failed:
                self._last[host_id] = self._clock()

    def inject_failure(self, host_id: int):
        with self._lock:
            self._failed.add(host_id)

    def revive(self, host_id: int):
        with self._lock:
            self._failed.discard(host_id)
            self._last[host_id] = self._clock()

    def dead_hosts(self) -> list[int]:
        now = self._clock()
        with self._lock:
            return sorted(
                h for h in range(self.n_hosts)
                if h in self._failed
                or now - self._last[h] > self.timeout_s)

    def healthy(self) -> bool:
        return not self.dead_hosts()
