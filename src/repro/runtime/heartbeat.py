"""Failure detection for the training controller.

On a real fleet each host posts a heartbeat to the coordinator (or the
coordinator observes barrier timeouts). Here the monitor abstracts that:
workers call ``beat(host_id)``; the controller polls ``dead_hosts()``.
Failure injection (``inject_failure``) drives the fault-tolerance tests,
the degraded-mode benchmark scenarios, and the checkpoint-restart
example without real hardware deaths.

Recovery semantics (one path): death LATCHES. A host counts as dead the
moment it is injected or the first time a ``dead_hosts()`` poll sees its
heartbeat past ``timeout_s`` — and from then on stays dead regardless of
later beats, until an explicit ``revive(host_id)``. Previously a
timed-out host could silently rejoin via ``beat`` while an injected one
could not; that asymmetry meant a controller could observe a host dead,
re-route its work, and then see it alive again with its work running
twice. ``revive`` is the single, deliberate re-admission point.
"""
from __future__ import annotations

import threading
import time


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 10.0,
                 clock=time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._clock = clock
        self._last = {h: clock() for h in range(n_hosts)}
        self._failed: set[int] = set()
        self._lock = threading.Lock()

    def beat(self, host_id: int):
        """Record liveness. A latched-dead host's beats are ignored —
        it must be re-admitted via :meth:`revive`."""
        with self._lock:
            if host_id not in self._failed:
                self._last[host_id] = self._clock()

    def inject_failure(self, host_id: int):
        with self._lock:
            self._failed.add(host_id)

    def revive(self, host_id: int):
        """The ONLY way back from dead — for injected and timed-out
        hosts alike. Clears the latch and refreshes the heartbeat."""
        with self._lock:
            self._failed.discard(host_id)
            self._last[host_id] = self._clock()

    def dead_hosts(self) -> list[int]:
        """Poll for dead hosts; a timed-out host observed here is
        latched into the failed set (it cannot rejoin via ``beat``)."""
        now = self._clock()
        with self._lock:
            for h in range(self.n_hosts):
                if now - self._last[h] > self.timeout_s:
                    self._failed.add(h)
            return sorted(self._failed)

    def healthy(self) -> bool:
        return not self.dead_hosts()
