"""Elastic re-meshing after node loss.

Policy: keep the model axis intact (TP/EP shards are load-bearing —
losing one breaks every layer) and shrink the DATA axis to the largest
size the surviving hosts support; the global batch is preserved by
raising per-replica accumulation. Restoring onto the shrunken mesh is
just ``restore_checkpoint(..., shardings=new)`` — the checkpoint byte
space is mesh-agnostic by construction (checkpoint.py).

Restart discovery (:func:`find_restart_step`) is the other half of a
kill-and-resume: it trusts only COMMITTED checkpoints. The async save
path writes the manifest last (checkpoint._commit_write), so a process
killed mid-drain leaves segment files with no manifest — invisible
here — and a drain torn mid-segment leaves ``.partial`` markers
(core.faults.partial_marker) that disqualify the step.
"""
from __future__ import annotations

import json
import warnings
from dataclasses import dataclass
from pathlib import Path

import jax

from repro.core.faults import partial_marker


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    grad_accum: int        # microbatch multiplier preserving global batch
    #: survivors stranded by rounding the data axis down to a power of
    #: two — they sit idle until the next resize; never silently zero'd
    unused_devices: int = 0

    def make_mesh(self):
        return jax.make_mesh(self.mesh_shape, self.axis_names)


def plan_remesh(total_devices: int, model_parallel: int,
                old_data_parallel: int, *,
                pods: int = 1) -> ElasticPlan:
    """Largest power-of-two data axis that fits the surviving devices.

    Rounding down can strand survivors (e.g. 24 hosts -> data axis 16,
    8 hosts idle). The plan reports the stranded count as
    ``unused_devices`` and warns, so the controller can choose to fold
    them back in (spares, eval, a later grow event) instead of the
    capacity silently vanishing.
    """
    if total_devices < model_parallel:
        raise ValueError(
            f"cannot keep model axis: {total_devices} devices < "
            f"TP {model_parallel}")
    pods = max(pods, 1)
    avail = total_devices // model_parallel // pods
    data = 1
    while data * 2 <= avail:
        data *= 2
    accum = max(1, old_data_parallel // data)
    unused = total_devices - data * model_parallel * pods
    if unused > 0:
        warnings.warn(
            f"plan_remesh strands {unused} of {total_devices} surviving "
            f"devices (data axis rounded down to {data}); they are idle "
            "until the next resize", RuntimeWarning, stacklevel=2)
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"), accum, unused)
    return ElasticPlan((data, model_parallel), ("data", "model"), accum,
                       unused)


def find_restart_step(directory: str | Path) -> int | None:
    """The newest step a restart may restore: the highest committed
    manifest whose segments are intact. Skips (never raises on):

    * orphan ``.seg*`` files with no manifest — an async drain killed
      before its commit point (commit-last: manifest written only
      after every segment landed);
    * a step with a ``.partial`` marker on any segment — a drain torn
      mid-segment (core.faults);
    * a non-empty checkpoint with no segment files at all — a manifest
      that outlived its segments (e.g. manual deletion);
    * a non-empty checkpoint whose segment files are ALL zero-length —
      created-but-never-written segments (a drain killed between
      ``open()`` and the first write, or a truncation) hold none of the
      manifest's bytes, exactly like the no-segments case above.

    Returns ``None`` when no restorable checkpoint exists. This is the
    restart-side counterpart of ``CheckpointManager.latest_step`` with
    the integrity checks a post-crash directory needs.
    """
    d = Path(directory)
    for mpath in sorted(d.glob("ckpt_*.manifest.json"), reverse=True):
        stem = mpath.name.replace(".manifest.json", "")
        segs = [p for p in d.glob(stem + ".seg*")
                if not p.name.endswith(".partial")]
        if any(Path(partial_marker(str(p))).exists() for p in segs):
            continue
        if any(p.name.endswith(".partial") for p in d.glob(stem + ".seg*")):
            continue
        try:
            manifest = json.loads(mpath.read_text())
        except (ValueError, OSError):
            continue
        if manifest.get("file_len", 0) > 0:
            try:
                sizes = [p.stat().st_size for p in segs]
            except OSError:
                continue       # a segment vanished under us: not this one
            if not segs or all(sz == 0 for sz in sizes):
                continue
        return int(manifest["step"])
    return None
