"""Elastic re-meshing after node loss.

Policy: keep the model axis intact (TP/EP shards are load-bearing —
losing one breaks every layer) and shrink the DATA axis to the largest
size the surviving hosts support; the global batch is preserved by
raising per-replica accumulation. Restoring onto the shrunken mesh is
just ``restore_checkpoint(..., shardings=new)`` — the checkpoint byte
space is mesh-agnostic by construction (checkpoint.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    grad_accum: int        # microbatch multiplier preserving global batch

    def make_mesh(self):
        return jax.make_mesh(self.mesh_shape, self.axis_names)


def plan_remesh(total_devices: int, model_parallel: int,
                old_data_parallel: int, *,
                pods: int = 1) -> ElasticPlan:
    """Largest power-of-two data axis that fits the surviving devices."""
    if total_devices < model_parallel:
        raise ValueError(
            f"cannot keep model axis: {total_devices} devices < "
            f"TP {model_parallel}")
    avail = total_devices // model_parallel // max(pods, 1)
    data = 1
    while data * 2 <= avail:
        data *= 2
    accum = max(1, old_data_parallel // data)
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"), accum)
    return ElasticPlan((data, model_parallel), ("data", "model"), accum)
