from repro.runtime.elastic import ElasticPlan, plan_remesh  # noqa: F401
from repro.runtime.heartbeat import HeartbeatMonitor  # noqa: F401
from repro.runtime.trainer import TrainLoop, TrainLoopConfig  # noqa: F401
