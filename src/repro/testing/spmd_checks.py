import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""SPMD correctness checks (run as a subprocess with 8 host devices).

Covers: device-path TAM & two-phase collective write vs oracle; TAM
coalescing stats; hierarchical two-layer psum / compressed psum /
two-layer all_to_all; moe_sharded vs dense-path equivalence; sharded
decode attention vs flash reference. Exits nonzero on any failure.
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

FAILURES = []


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        FAILURES.append(name)


def main():
    from repro.core import (IOConfig, contiguous_layout, make_tam_write,
                            make_twophase_write)
    from repro.core.tam import make_tam_read
    from repro.core.twophase import make_twophase_read, write_reference
    from repro.core.hierarchical import (compressed_psum,
                                         two_layer_all_to_all,
                                         two_layer_psum)

    mesh = jax.make_mesh((2, 2, 2), ("node", "lagg", "lmem"))
    P_ranks, REQ_CAP, DATA_CAP, FILE_LEN = 8, 8, 64, 256
    layout = contiguous_layout(FILE_LEN, 2)
    rng = np.random.default_rng(0)
    slots = rng.permutation(FILE_LEN // 8)
    spr = len(slots) // P_ranks
    O = np.full((P_ranks, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_ranks, REQ_CAP), np.int32)
    C = np.zeros(P_ranks, np.int32)
    D = np.zeros((P_ranks, DATA_CAP), np.int32)
    for p in range(P_ranks):
        mine = np.sort(slots[p * spr:(p + 1) * spr])
        offs = (mine * 8).astype(np.int32)
        lens = rng.integers(1, 9, size=len(mine)).astype(np.int32)
        O[p, :len(offs)], L[p, :len(lens)], C[p] = offs, lens, len(offs)
        D[p, :lens.sum()] = rng.integers(1, 999, size=lens.sum())
    ref = write_reference(layout, O, L, C, D)
    cfg = IOConfig(req_cap=32, data_cap=DATA_CAP, coalesce_cap=32)

    f, s = jax.jit(make_twophase_write(mesh, layout, cfg))(O, L, C, D)
    check("twophase_write", np.array_equal(np.asarray(f).reshape(-1), ref))
    f, s = jax.jit(make_tam_write(mesh, layout, cfg))(O, L, C, D)
    check("tam_write", np.array_equal(np.asarray(f).reshape(-1), ref))
    check("tam_no_drops", int(s["dropped_requests"]) == 0
          and int(s["dropped_elems"]) == 0)
    f, s = jax.jit(make_tam_write(mesh, layout, cfg, use_kernels=True))(
        O, L, C, D)
    check("tam_write_kernels", np.array_equal(np.asarray(f).reshape(-1),
                                              ref))

    rd = jax.jit(make_tam_read(mesh, layout, cfg))
    got = rd(O, L, C, jnp.asarray(ref).reshape(2, -1))
    ok = all(np.array_equal(np.asarray(got)[p][:L[p].sum()],
                            D[p][:L[p].sum()]) for p in range(P_ranks))
    check("tam_read", ok)
    rd2 = jax.jit(make_twophase_read(mesh, layout, cfg))
    got = rd2(O, L, C, jnp.asarray(ref).reshape(2, -1))
    ok = all(np.array_equal(np.asarray(got)[p][:L[p].sum()],
                            D[p][:L[p].sum()]) for p in range(P_ranks))
    check("twophase_read", ok)

    # block pattern: coalescing fires
    Ob = np.full((8, 8), 2**31 - 1, np.int32)
    Lb = np.zeros((8, 8), np.int32)
    for p in range(8):
        Ob[p, :4] = np.arange(4, dtype=np.int32) * 8 + p * 32
        Lb[p, :4] = 8
    Cb = np.full(8, 4, np.int32)
    Db = (np.arange(8 * DATA_CAP, dtype=np.int32).reshape(8, -1) % 97) + 1
    Db[:, 32:] = 0
    refb = write_reference(layout, Ob, Lb, Cb, Db)
    f, s = jax.jit(make_tam_write(mesh, layout, cfg))(Ob, Lb, Cb, Db)
    check("tam_block_write", np.array_equal(np.asarray(f).reshape(-1), refb))
    check("tam_block_coalesce",
          int(s["requests_after_coalesce"]) * 4
          <= int(s["requests_before_coalesce"]))

    # ---- hierarchical collectives ------------------------------------
    mesh2 = jax.make_mesh((2, 4), ("pod", "ici"))
    x = jnp.asarray(rng.normal(size=(8, 33)).astype(np.float32))
    r2 = jax.jit(shard_map(
        lambda xs: two_layer_psum(xs.reshape(33), "ici", "pod"),
        mesh=mesh2, in_specs=P(("pod", "ici")), out_specs=P(),
        check_vma=False))(x)
    check("two_layer_psum",
          np.allclose(np.asarray(r2), np.asarray(x.sum(0)), atol=1e-4))

    outc, nres = jax.jit(shard_map(
        lambda xs, res: compressed_psum(xs.reshape(33), res.reshape(33),
                                        "ici", "pod"),
        mesh=mesh2, in_specs=(P(("pod", "ici")), P(("pod", "ici"))),
        out_specs=(P(), P(("pod", "ici"))), check_vma=False))(
            x, jnp.zeros_like(x))
    rel = (np.abs(np.asarray(outc) - np.asarray(x.sum(0))).max()
           / np.abs(np.asarray(x.sum(0))).max())
    check("compressed_psum_int8", rel < 5e-2)
    check("compressed_psum_residual_nonzero",
          float(jnp.abs(nres).sum()) > 0)

    xa = jnp.arange(8 * 8 * 5, dtype=jnp.int32).reshape(8, 8 * 5)
    ra = jax.jit(shard_map(
        lambda xs: two_layer_all_to_all(xs.reshape(8, 5), "ici", "pod"),
        mesh=mesh2, in_specs=P(("pod", "ici")), out_specs=P(("pod", "ici")),
        check_vma=False))(xa)
    ref_a = np.transpose(np.asarray(xa).reshape(8, 8, 5),
                         (1, 0, 2)).reshape(8, 8 * 5)
    check("two_layer_all_to_all",
          np.array_equal(np.asarray(ra).reshape(8, -1), ref_a))

    # ---- moe_sharded vs dense ----------------------------------------
    from dataclasses import replace as dreplace
    from repro import configs
    from repro.models import layers as ML
    from repro.models import transformer as MT
    from repro.models.config import reduced
    from repro.models.sharding import ShardingPlan, unsharded

    mesh3 = jax.make_mesh((2, 4), ("data", "model"))
    cfg_m = reduced(configs.get("llama4_maverick"))
    cfg_m = dreplace(cfg_m, moe=dreplace(cfg_m.moe, capacity_factor=4.0),
                     d_model=32, vocab=256)
    key = jax.random.PRNGKey(0)
    moe_p = ML.init_moe(key, cfg_m, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
    dense_out, dense_aux = ML.moe(moe_p, x, cfg_m, unsharded())
    plan3 = ShardingPlan(mesh=mesh3, data_axes=("data",),
                         model_axis="model", shard_seq=True)
    sh_out, sh_aux = jax.jit(
        lambda p, xx: ML.moe(p, xx, cfg_m, plan3))(moe_p, x)
    check("moe_sharded_matches_dense",
          np.allclose(np.asarray(sh_out), np.asarray(dense_out),
                      rtol=2e-4, atol=2e-4))
    # per-shard aux is an E[me_loc*ce_loc] approximation of the global
    # E[me]*E[ce] product (standard distributed-MoE practice); they agree
    # in expectation, not exactly.
    check("moe_aux_close",
          abs(float(sh_aux) - float(dense_aux)) < 0.25 * float(dense_aux)
          + 0.05)

    plan3d = ShardingPlan(mesh=mesh3, data_axes=("data",),
                          model_axis="model", shard_seq=False)
    sh_out2, _ = jax.jit(
        lambda p, xx: ML.moe(p, xx, cfg_m, plan3d))(moe_p, x[:, :1])
    dense2, _ = ML.moe(moe_p, x[:, :1], cfg_m, unsharded())
    check("moe_decode_path_matches_dense",
          np.allclose(np.asarray(sh_out2), np.asarray(dense2),
                      rtol=2e-4, atol=2e-4))

    # ---- sharded decode attention vs flash ---------------------------
    B, S, HQ, HKV, HD = 4, 64, 8, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, HQ, HD))
    kc = jax.random.normal(jax.random.PRNGKey(3), (B, S, HKV, HD))
    vc = jax.random.normal(jax.random.PRNGKey(4), (B, S, HKV, HD))
    pos = jnp.int32(37)
    ref_o = ML.flash_attention(q, kc, vc, causal=False, window=None,
                               logit_cap=None, q_offset=pos,
                               kv_len=pos + 1)
    got = jax.jit(lambda q, k, v: ML.decode_attention_sharded(
        q, k, v, cache_pos=pos, window=None, logit_cap=None,
        plan=plan3d))(q, kc, vc)
    check("decode_attention_sharded",
          np.allclose(np.asarray(got).reshape(B, 1, HQ, HD),
                      np.asarray(ref_o), rtol=2e-3, atol=2e-3))

    # full train step under the production mesh partitioning (2x4)
    cfg_t = reduced(configs.get("glm4_9b"))
    plan_t = ShardingPlan(mesh=mesh3, data_axes=("data",),
                          model_axis="model", shard_seq=True)
    params = MT.init_params(jax.random.PRNGKey(5), cfg_t,
                            dtype=jnp.float32)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg_t.vocab),
             "labels": jax.random.randint(key, (4, 16), 0, cfg_t.vocab)}
    loss_sharded = jax.jit(
        lambda p: MT.loss_fn(p, cfg_t, batch, plan_t))(params)
    loss_local = MT.loss_fn(params, cfg_t, batch, unsharded())
    check("sharded_loss_matches_local",
          abs(float(loss_sharded) - float(loss_local)) < 2e-3)

    print(f"{len(FAILURES)} failures", flush=True)
    raise SystemExit(1 if FAILURES else 0)


if __name__ == "__main__":
    main()
