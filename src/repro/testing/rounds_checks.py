import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Round-engine SPMD checks (run as a subprocess with 8 host devices).

Property: for round counts {1, 2, 5} (cb_buffer_size in {160, 80, 32}
on a 160-element domain) and mixed / strided / overlapping / spanning
request patterns, the multi-round two-phase and TAM collective writes
are byte-identical to BOTH the single-shot path and the
``write_reference`` oracle, with identical (zero) drop stats; the
PIPELINED round loop (``IOConfig.pipeline``, prologue → steady state →
epilogue) is byte-identical to the serial round loop and the oracle at
every round count AND at every ring depth — the depth-k window ring
(``IOConfig.pipeline_depth``) is swept over k in {3, 4} x all three
round counts for two-phase and at the 5-round cb for TAM (k in {1, 2}
are the serial/pipelined rows above; depth clamps to the round count,
so the 1-round sweep also exercises the clamp); the round-scheduled
reads (serial, pipelined, and depth-k) return every rank's payload;
and a deliberately overflowed round bucket reports nonzero
``dropped_elems`` instead of failing silently. The spanning pattern
crosses the file-domain boundary, exercising the split-at-domain
handling (those requests were silently truncated before PR 2).

Slow-hop codec: with ``slow_hop_codec="rle"`` (the lossless zero-run
wire transform wrapped around the slow-axis ``all_to_all`` inside the
round engine) the SAME byte-identity must hold — swept over ring
depths {1, 2, 4} x round counts {1, 2, 5} for two-phase, at the
5-round cb for TAM, plus an rle read — because a lossless codec may
change the wire, never the file. Exits nonzero on any failure.

Placement: an aggregator placement is a pure permutation of which slot
serves which domain (``core.placement``), so byte identity must hold
under it too: the handcrafted patterns run the two-phase and TAM
writers (and a read) with the swapped placement ``(1, 0)`` at the
5-round cb, and the FUZZ section below sweeps it properly.

Cross-executor fuzz: seeded random patterns (disjoint random extents
with offset-derived payloads, occasional deterministic identical-data
overlaps, and natural domain-/window-boundary spanners) are run
through BOTH executors — the SPMD writers under placement {identity,
swapped} x codec {None, rle} x depth {1, 2}, and the host executor
(byte units, same striping) under placement {off, spread, swapped} x
codec {None, rle} x depth {1, 2} — and every single run must
reproduce the ``write_reference`` oracle bytes exactly, so the two
backends are compared on inputs nobody hand-picked.

Transport (PR 10): seed 0's pattern additionally runs through the MP
transport executor (``checkpoint.mp_exec`` — real worker processes,
shared-memory fast hop, localhost-socket slow hop) under placement
{off, swapped} x codec {None, rle} x depth {1, 2} for two-phase
writes, a combined-frame TAM write, and node-cache on/off reads — all
byte-identical to the same oracle, so all THREE byte movers agree on
inputs nobody hand-picked.

Read direction (PR 8): the planner no longer nulls ``kernel_fusion``
for reads, so every (codec x depth) reader also runs FUSED
(``zero_skip_decode`` replacing the rle decode scatter inside the read
ring) against its unfused twin — byte-identical, and identical to the
requested payloads. On the host side, every fuzz pattern's files are
read BACK through the planned collective read
(``HostCollectiveIO.read``: ``compile_plan(direction="read")``, the
node-level window cache) across placement x codec x depth x cache
on/off — per-rank payloads must equal the write oracle's byte spans,
the cache must never model slower than the per-rank fetch baseline,
and both modes must account the same delivery count.

Kernel fusion: every SPMD fuzz configuration runs a second time with
``IOConfig.kernel_fusion="fused_round"`` (the planner's
``lower_kernels`` pass selects the single-``pallas_call`` sort +
dual-pack drain of ``kernels/fused_round.py``, plus the fused rle
zero-skip encode when the codec is on) — the fused writes must be
byte-identical to BOTH the unfused writes and the oracle across the
whole placement x codec x depth cross, on both schedules. The host
executor accepts the same unified config (``write(config=...)``) and
ignores the fusion (numpy backend) — its bytes must match the oracle
too, closing the both-executors contract.
"""
import numpy as np
import jax
import jax.numpy as jnp

from dataclasses import replace

FAILURES = []

P_RANKS, REQ_CAP, DATA_CAP, FILE_LEN = 8, 8, 64, 320
CBS = (160, 80, 32)   # domain_len=160 -> 1, 2, 5 rounds
DEPTHS = (3, 4)       # ring depths beyond the serial/pipelined rows


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        FAILURES.append(name)


def mixed_pattern(rng):
    """Random disjoint extents, random lengths, shuffled ownership."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    slots = rng.permutation(FILE_LEN // 8)
    spr = len(slots) // P_RANKS
    for p in range(P_RANKS):
        mine = np.sort(slots[p * spr:(p + 1) * spr])[:6]
        lens = rng.integers(1, 9, size=len(mine)).astype(np.int32)
        O[p, :len(mine)], L[p, :len(lens)] = (mine * 8).astype(np.int32), lens
        C[p] = len(mine)
        D[p, :lens.sum()] = rng.integers(1, 999, size=lens.sum())
    return O, L, C, D


def strided_pattern(rng):
    """E3SM-style round-robin interleave: rank r owns slots r, r+P, ..."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.full(P_RANKS, REQ_CAP, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    unit = FILE_LEN // (P_RANKS * REQ_CAP)  # 5 elements per request
    for p in range(P_RANKS):
        idx = np.arange(REQ_CAP, dtype=np.int32)
        O[p] = (idx * P_RANKS + p) * unit
        L[p] = unit
        D[p, :REQ_CAP * unit] = O[p].repeat(unit) % 97 + 1
    return O, L, C, D


def overlapping_pattern(rng):
    """Ranks 0 and 1 write IDENTICAL data to the same two regions (the
    only deterministic overlap; MPI leaves diverging overlaps
    undefined); ranks 2..7 write disjoint extents elsewhere. The spans
    are sized so TAM's duplicated stage-1 payload (2 x span at one
    local aggregator) still fits the smallest round bucket."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    span, regions = 12, (8, 280)
    for p in (0, 1):
        for i, o in enumerate(regions):
            O[p, i], L[p, i] = o, span
            D[p, i * span:(i + 1) * span] = np.arange(o, o + span) % 97 + 1
        C[p] = 2
    for p in range(2, P_RANKS):
        # disjoint extents clear of both regions and the domain boundary
        o = 40 + (p - 2) * 24 if p <= 4 else 170 + (p - 5) * 24
        O[p, 0], L[p, 0], C[p] = o, 20, 1
        D[p, :20] = rng.integers(1, 999, size=20)
    return O, L, C, D


def spanning_pattern(rng):
    """Requests crossing the file-domain boundary at 160 (and window
    boundaries): both paths must split them — the single-shot exchange
    truncated the spanning tail silently before the domain-split fix."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    # rank 0 straddles the domain boundary: [150, 174)
    O[0, 0], L[0, 0], C[0] = 150, 24, 1
    D[0, :24] = np.arange(150, 174) % 97 + 1
    # rank 1 straddles a cb=32 window boundary inside domain 1:
    # [250, 262) is domain-local [90, 102), crossing 96
    O[1, 0], L[1, 0], C[1] = 250, 12, 1
    D[1, :12] = np.arange(250, 262) % 97 + 1
    for p in range(2, P_RANKS):
        o = 8 + (p - 2) * 16
        O[p, 0], L[p, 0], C[p] = o, 12, 1
        D[p, :12] = rng.integers(1, 999, size=12)
    return O, L, C, D


def _fill_sorted(O, L, C, D, p, segs):
    """Install rank p's segments sorted by offset, payload derived from
    the absolute offset (so any overlap is identical-data, the only
    deterministic kind)."""
    segs = sorted(segs)
    pos = 0
    for i, (o, ln) in enumerate(segs):
        O[p, i], L[p, i] = o, ln
        D[p, pos:pos + ln] = (np.arange(o, o + ln) * 7 + 3) % 251 + 1
        pos += ln
    C[p] = len(segs)


def random_pattern(rng):
    """Seeded random request pattern: the file is cut at random points
    and the pieces are dealt to random ranks (bounded by the caps),
    with offset-derived payloads; ~1 in 4 patterns duplicates one
    piece onto a second rank (identical bytes — the deterministic
    overlap), and pieces freely straddle domain and window boundaries
    (the spanning case)."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    cuts = np.unique(rng.integers(1, FILE_LEN, size=rng.integers(8, 28)))
    bounds = np.concatenate([[0], cuts, [FILE_LEN]])
    per_rank: list[list] = [[] for _ in range(P_RANKS)]
    budget = np.zeros(P_RANKS, np.int64)
    dup = rng.random() < 0.25
    for a, b in zip(bounds[:-1], bounds[1:]):
        ln = min(int(b - a), int(rng.integers(1, 17)))
        if rng.random() < 0.3:
            continue                      # leave a hole
        targets = [int(rng.integers(0, P_RANKS))]
        if dup and rng.random() < 0.2:
            targets.append(int(rng.integers(0, P_RANKS)))
        for p in set(targets):
            if len(per_rank[p]) >= 6 or budget[p] + ln > DATA_CAP - 8:
                continue
            per_rank[p].append((int(a), ln))
            budget[p] += ln
    for p in range(P_RANKS):
        _fill_sorted(O, L, C, D, p, per_rank[p])
    return O, L, C, D


def _byte_requests(O, L, C, D):
    """The same pattern in the host executor's units: byte offsets and
    the int32 payloads' little-endian bytes."""
    reqs = []
    for p in range(P_RANKS):
        n = int(C[p])
        o = O[p, :n].astype(np.int64) * 4
        ln = L[p, :n].astype(np.int64) * 4
        total = int(L[p, :n].sum())
        payload = D[p, :total].astype("<i4").view(np.uint8).copy()
        reqs.append((o, ln, payload))
    return reqs


def main():
    from repro.core import IOConfig, contiguous_layout
    from repro.core.tam import make_tam_read, make_tam_write
    from repro.core.twophase import (make_twophase_read,
                                     make_twophase_write, write_reference)
    from repro.checkpoint.host_io import HostCollectiveIO

    mesh = jax.make_mesh((2, 2, 2), ("node", "lagg", "lmem"))
    layout = contiguous_layout(FILE_LEN, 2)
    base = IOConfig(req_cap=32, data_cap=DATA_CAP, coalesce_cap=32)

    writers = {None: (jax.jit(make_twophase_write(mesh, layout, base)),
                      jax.jit(make_tam_write(mesh, layout, base)))}
    pipelined = {}
    readers = {}
    readers_p = {}
    for cb in CBS:
        cfg = replace(base, cb_buffer_size=cb)
        cfgp = replace(base, cb_buffer_size=cb, pipeline=True)
        writers[cb] = (jax.jit(make_twophase_write(mesh, layout, cfg)),
                       jax.jit(make_tam_write(mesh, layout, cfg)))
        pipelined[cb] = (jax.jit(make_twophase_write(mesh, layout, cfgp)),
                         jax.jit(make_tam_write(mesh, layout, cfgp)))
        readers[cb] = (jax.jit(make_twophase_read(mesh, layout, cfg)),
                       jax.jit(make_tam_read(mesh, layout, cfg)))
    # pipelined reads: 5-round config exercises prologue + steady state
    # + epilogue (1-round = prologue/epilogue only, covered by writes)
    cfgp32 = replace(base, cb_buffer_size=32, pipeline=True)
    readers_p[32] = (jax.jit(make_twophase_read(mesh, layout, cfgp32)),
                     jax.jit(make_tam_read(mesh, layout, cfgp32)))
    # depth-k ring sweep: two-phase at every round count (the 1-round
    # config exercises the depth clamp), TAM at the 5-round cb, and a
    # depth-k read; byte-identity is checked on the mixed + spanning
    # patterns (the other patterns cover k in {1, 2} above)
    deep = {}
    for cb in CBS:
        for k in DEPTHS:
            cfgk = replace(base, cb_buffer_size=cb, pipeline=True,
                           pipeline_depth=k)
            deep[("twophase", cb, k)] = jax.jit(
                make_twophase_write(mesh, layout, cfgk))
    for k in DEPTHS:
        cfgk = replace(base, cb_buffer_size=32, pipeline=True,
                       pipeline_depth=k)
        deep[("tam", 32, k)] = jax.jit(make_tam_write(mesh, layout, cfgk))
    readers_k = {k: jax.jit(make_twophase_read(
        mesh, layout, replace(base, cb_buffer_size=32, pipeline=True,
                              pipeline_depth=k))) for k in DEPTHS}
    # slow-hop codec sweep: rle across depths {1, 2, 4} x all three
    # round counts for two-phase, TAM at the 5-round cb, one rle read
    CODEC_DEPTHS = (1, 2, 4)
    coded = {}
    for cb in CBS:
        for k in CODEC_DEPTHS:
            cfgc = replace(base, cb_buffer_size=cb, pipeline=k > 1,
                           pipeline_depth=k, slow_hop_codec="rle")
            coded[("twophase", cb, k)] = jax.jit(
                make_twophase_write(mesh, layout, cfgc))
    for k in CODEC_DEPTHS:
        cfgc = replace(base, cb_buffer_size=32, pipeline=k > 1,
                       pipeline_depth=k, slow_hop_codec="rle")
        coded[("tam", 32, k)] = jax.jit(make_tam_write(mesh, layout, cfgc))
    reader_rle = jax.jit(make_twophase_read(
        mesh, layout, replace(base, cb_buffer_size=32, pipeline=True,
                              pipeline_depth=2, slow_hop_codec="rle")))
    # placement: the swapped permutation at the 5-round cb for both
    # schedules plus a placement read — byte identity must hold because
    # a placement only moves WHERE the aggregation runs (the shards
    # ppermute back into domain order)
    SWAP = (1, 0)
    placed = {
        "twophase": jax.jit(make_twophase_write(mesh, layout, replace(
            base, cb_buffer_size=32, placement=SWAP))),
        "tam": jax.jit(make_tam_write(mesh, layout, replace(
            base, cb_buffer_size=32, placement=SWAP))),
    }
    reader_placed = jax.jit(make_twophase_read(mesh, layout, replace(
        base, cb_buffer_size=32, placement=SWAP)))
    # fused READ rows (PR 8): since lower_kernels stopped nulling the
    # fusion for reads, kernel_fusion="fused_round" swaps the rle
    # decode scatter for kernels/fused_round.zero_skip_decode inside
    # the read ring — every (codec x depth) pair runs fused and
    # unfused under the swapped placement and must agree byte-for-byte
    # with each other and with the requested payloads
    read_pairs = {}
    for codec in (None, "rle"):
        for k in (1, 2):
            for fused in (False, True):
                cfgr = replace(base, cb_buffer_size=32, pipeline=k > 1,
                               pipeline_depth=k, slow_hop_codec=codec,
                               placement=SWAP,
                               kernel_fusion=("fused_round" if fused
                                              else None))
                read_pairs[(codec, k, fused)] = jax.jit(
                    make_twophase_read(mesh, layout, cfgr))
    # cross-executor fuzz writers: placement x codec x depth (two-phase
    # full cross, TAM corners to bound compile time)
    fuzz_fns = {}
    for pl in (None, SWAP):
        for codec in (None, "rle"):
            for k in (1, 2):
                cfgf = replace(base, cb_buffer_size=32, pipeline=k > 1,
                               pipeline_depth=k, slow_hop_codec=codec,
                               placement=pl)
                fuzz_fns[("twophase", pl is not None, codec, k)] = \
                    jax.jit(make_twophase_write(mesh, layout, cfgf))
    for codec, k in ((None, 1), ("rle", 2)):
        cfgf = replace(base, cb_buffer_size=32, pipeline=k > 1,
                       pipeline_depth=k, slow_hop_codec=codec,
                       placement=SWAP)
        fuzz_fns[("tam", True, codec, k)] = jax.jit(
            make_tam_write(mesh, layout, cfgf))
    # the SAME cross with the fused round kernel selected — every fuzz
    # run is also a fused-vs-unfused byte-identity check
    fused_fns = {}
    for (mname, swapped, codec, k) in fuzz_fns:
        cfgf = replace(base, cb_buffer_size=32, pipeline=k > 1,
                       pipeline_depth=k, slow_hop_codec=codec,
                       placement=SWAP if swapped else None,
                       kernel_fusion="fused_round")
        mk = make_twophase_write if mname == "twophase" else make_tam_write
        fused_fns[(mname, swapped, codec, k)] = jax.jit(
            mk(mesh, layout, cfgf))

    rng = np.random.default_rng(0)
    patterns = {"mixed": mixed_pattern(rng),
                "strided": strided_pattern(rng),
                "overlapping": overlapping_pattern(rng),
                "spanning": spanning_pattern(rng)}

    for pname, (O, L, C, D) in patterns.items():
        ref = write_reference(layout, O, L, C, D)
        singles = {}
        for mi, mname in ((0, "twophase"), (1, "tam")):
            f, s = writers[None][mi](O, L, C, D)
            singles[mname] = np.asarray(f).reshape(-1)
            check(f"{pname}/{mname}/single_shot_vs_ref",
                  np.array_equal(singles[mname], ref))
        for cb in CBS:
            n_rounds = 160 // cb
            for mi, mname in ((0, "twophase"), (1, "tam")):
                f, s = writers[cb][mi](O, L, C, D)
                got = np.asarray(f).reshape(-1)
                tag = f"{pname}/{mname}/rounds{n_rounds}"
                check(f"{tag}_vs_ref", np.array_equal(got, ref))
                check(f"{tag}_vs_single_shot",
                      np.array_equal(got, singles[mname]))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
                fp, sp = pipelined[cb][mi](O, L, C, D)
                gotp = np.asarray(fp).reshape(-1)
                check(f"{tag}_pipelined_vs_serial",
                      np.array_equal(gotp, got))
                check(f"{tag}_pipelined_vs_ref",
                      np.array_equal(gotp, ref))
                check(f"{tag}_pipelined_no_drops",
                      int(sp["dropped_requests"]) == 0
                      and int(sp["dropped_elems"]) == 0)
            rd2, rdt = readers[cb]
            for rd, mname in ((rd2, "twophase"), (rdt, "tam")):
                got = np.asarray(rd(O, L, C,
                                    jnp.asarray(ref).reshape(2, -1)))
                ok = all(np.array_equal(got[p][:L[p].sum()],
                                        D[p][:L[p].sum()])
                         for p in range(P_RANKS))
                check(f"{pname}/{mname}/read_rounds{n_rounds}", ok)
        for rd, mname in zip(readers_p[32], ("twophase", "tam")):
            got = np.asarray(rd(O, L, C, jnp.asarray(ref).reshape(2, -1)))
            ok = all(np.array_equal(got[p][:L[p].sum()],
                                    D[p][:L[p].sum()])
                     for p in range(P_RANKS))
            check(f"{pname}/{mname}/read_pipelined_rounds5", ok)
        if pname in ("mixed", "spanning"):
            for (mname, cb, k), fn in deep.items():
                f, s = fn(O, L, C, D)
                tag = f"{pname}/{mname}/depth{k}_rounds{160 // cb}"
                check(f"{tag}_vs_ref",
                      np.array_equal(np.asarray(f).reshape(-1), ref))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
            for k, rd in readers_k.items():
                got = np.asarray(rd(O, L, C,
                                    jnp.asarray(ref).reshape(2, -1)))
                ok = all(np.array_equal(got[p][:L[p].sum()],
                                        D[p][:L[p].sum()])
                         for p in range(P_RANKS))
                check(f"{pname}/twophase/read_depth{k}_rounds5", ok)
            for (mname, cb, k), fn in coded.items():
                f, s = fn(O, L, C, D)
                tag = f"{pname}/{mname}/rle_depth{k}_rounds{160 // cb}"
                check(f"{tag}_vs_ref",
                      np.array_equal(np.asarray(f).reshape(-1), ref))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
            got = np.asarray(reader_rle(O, L, C,
                                        jnp.asarray(ref).reshape(2, -1)))
            ok = all(np.array_equal(got[p][:L[p].sum()],
                                    D[p][:L[p].sum()])
                     for p in range(P_RANKS))
            check(f"{pname}/twophase/read_rle_rounds5", ok)
            for mname, fn in placed.items():
                f, s = fn(O, L, C, D)
                check(f"{pname}/{mname}/placement_swap_rounds5_vs_ref",
                      np.array_equal(np.asarray(f).reshape(-1), ref))
                check(f"{pname}/{mname}/placement_swap_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
            got = np.asarray(reader_placed(
                O, L, C, jnp.asarray(ref).reshape(2, -1)))
            ok = all(np.array_equal(got[p][:L[p].sum()],
                                    D[p][:L[p].sum()])
                     for p in range(P_RANKS))
            check(f"{pname}/twophase/read_placement_swap_rounds5", ok)
            for codec in (None, "rle"):
                for k in (1, 2):
                    outs = {}
                    for fused in (False, True):
                        rd = read_pairs[(codec, k, fused)]
                        outs[fused] = np.asarray(
                            rd(O, L, C, jnp.asarray(ref).reshape(2, -1)))
                    tag = (f"{pname}/twophase/read_"
                           f"{codec or 'raw'}_k{k}")
                    check(f"{tag}_fused_vs_unfused",
                          np.array_equal(outs[True], outs[False]))
                    ok = all(np.array_equal(outs[True][p][:L[p].sum()],
                                            D[p][:L[p].sum()])
                             for p in range(P_RANKS))
                    check(f"{tag}_fused_vs_payload", ok)

    # ---- cross-executor fuzz: seeded random patterns through BOTH
    # backends, every run against the oracle (so SPMD == host too) ----
    import tempfile
    for seed in range(4):
        O, L, C, D = random_pattern(np.random.default_rng(7000 + seed))
        ref = write_reference(layout, O, L, C, D)
        for (mname, swapped, codec, k), fn in fuzz_fns.items():
            f, s = fn(O, L, C, D)
            got = np.asarray(f).reshape(-1)
            tag = (f"fuzz{seed}/{mname}/pl{int(swapped)}_"
                   f"{codec or 'raw'}_k{k}")
            check(f"{tag}_vs_ref", np.array_equal(got, ref))
            check(f"{tag}_no_drops",
                  int(s["dropped_requests"]) == 0
                  and int(s["dropped_elems"]) == 0)
            ff, sf = fused_fns[(mname, swapped, codec, k)](O, L, C, D)
            gotf = np.asarray(ff).reshape(-1)
            check(f"{tag}_fused_vs_unfused", np.array_equal(gotf, got))
            check(f"{tag}_fused_vs_ref", np.array_equal(gotf, ref))
            check(f"{tag}_fused_no_drops",
                  int(sf["dropped_requests"]) == 0
                  and int(sf["dropped_elems"]) == 0)
        # the host executor moves the same pattern in byte units; its
        # files must reassemble to the same oracle bytes under the
        # placement x codec x depth cross
        breqs = _byte_requests(O, L, C, D)
        ref_bytes = ref.astype("<i4").view(np.uint8)
        hio = HostCollectiveIO(n_ranks=P_RANKS, n_nodes=2,
                               stripe_size=640, stripe_count=2)
        hd = tempfile.mkdtemp()
        for pi, pl in enumerate((None, "spread", (1, 0))):
            ptag = ("off", "spread", "swap")[pi]
            for codec in (None, "rle"):
                for k in (1, 2):
                    path = f"{hd}/{ptag}_{codec or 'raw'}_{k}"
                    hio.write(breqs, path, method="twophase",
                              cb_bytes=128, pipeline_depth=k,
                              slow_hop_codec=codec, placement=pl)
                    got = hio.read_file(path, FILE_LEN * 4)
                    check(f"fuzz{seed}/host/{ptag}_{codec or 'raw'}"
                          f"_k{k}_vs_spmd",
                          np.array_equal(got, ref_bytes))
        path = f"{hd}/tam"
        hio.write(breqs, path, method="tam", local_aggregators=2,
                  cb_bytes=128, pipeline_depth=2, slow_hop_codec="rle",
                  placement=(1, 0))
        check(f"fuzz{seed}/host/tam_swap_rle_k2_vs_spmd",
              np.array_equal(hio.read_file(path, FILE_LEN * 4),
                             ref_bytes))
        # unified-config host write with the fusion selected: the plan
        # carries kernel_fusion (shared field with the SPMD backend)
        # but the numpy executor has no Pallas hot path — bytes must
        # still match the oracle exactly
        cfg_host = IOConfig(req_cap=32, data_cap=DATA_CAP,
                            coalesce_cap=32, cb_buffer_size=128,
                            pipeline=True, pipeline_depth=2,
                            slow_hop_codec="rle", placement="spread",
                            kernel_fusion="fused_round")
        path = f"{hd}/fusedcfg"
        hio.write(breqs, path, method="twophase", config=cfg_host)
        check(f"fuzz{seed}/host/config_fused_vs_spmd",
              np.array_equal(hio.read_file(path, FILE_LEN * 4),
                             ref_bytes))
        # planned collective reads back through the same striping
        # (PR 8): read x placement x codec x depth x cache on/off,
        # every row's per-rank payloads byte-identical to the write
        # oracle's spans; the node cache must never model slower than
        # the per-rank baseline it replaces, and the two modes must
        # account for the SAME delivery count (hits+misses on == the
        # per-rank misses off)
        rreqs = [(o, ln) for o, ln, _ in breqs]
        exp = [(np.concatenate([ref_bytes[o:o + l]
                                for o, l in zip(oo, ll)])
                if oo.size else np.zeros(0, np.uint8))
               for oo, ll in rreqs]
        for ptag, pl in (("off", None), ("spread", "spread")):
            for codec in (None, "rle"):
                for k in (1, 2):
                    src = f"{hd}/{ptag}_{codec or 'raw'}_{k}"
                    tr = {}
                    for nc in (True, False):
                        outs, tr[nc] = hio.read(
                            rreqs, src, cb_bytes=128, pipeline_depth=k,
                            slow_hop_codec=codec, placement=pl,
                            node_cache=nc)
                        ok = all(np.array_equal(a, b)
                                 for a, b in zip(outs, exp))
                        check(f"fuzz{seed}/host_read/{ptag}_"
                              f"{codec or 'raw'}_k{k}_cache{int(nc)}"
                              f"_vs_oracle", ok)
                    check(f"fuzz{seed}/host_read/{ptag}_"
                          f"{codec or 'raw'}_k{k}_cache_not_slower",
                          tr[True].total <= tr[False].total + 1e-12)
                    check(f"fuzz{seed}/host_read/{ptag}_"
                          f"{codec or 'raw'}_k{k}_delivery_conserved",
                          tr[True].cache_hits + tr[True].cache_misses
                          == tr[False].cache_misses)
        # the mp transport runs the SAME plans on real worker
        # processes (arena fast hop, socket slow hop) — byte identity
        # against the oracle across placement x codec x depth, both
        # directions. One seed only: each run forks a process fleet,
        # and the per-combination coverage above already rotates
        # patterns across seeds.
        if seed == 0:
            for pi2, pl in enumerate((None, (1, 0))):
                ptag = ("off", "swap")[pi2]
                for codec in (None, "rle"):
                    for k in (1, 2):
                        path = f"{hd}/mp_{ptag}_{codec or 'raw'}_{k}"
                        hio.write(breqs, path, method="twophase",
                                  cb_bytes=128, pipeline_depth=k,
                                  slow_hop_codec=codec, placement=pl,
                                  transport="mp")
                        check(f"fuzz{seed}/mp/{ptag}_{codec or 'raw'}"
                              f"_k{k}_vs_oracle",
                              np.array_equal(
                                  hio.read_file(path, FILE_LEN * 4),
                                  ref_bytes))
            path = f"{hd}/mp_tam"
            hio.write(breqs, path, method="tam", local_aggregators=2,
                      cb_bytes=128, pipeline_depth=2,
                      slow_hop_codec="rle", placement=(1, 0),
                      transport="mp")
            check(f"fuzz{seed}/mp/tam_swap_rle_k2_vs_oracle",
                  np.array_equal(hio.read_file(path, FILE_LEN * 4),
                                 ref_bytes))
            for nc in (True, False):
                outs, tmp_t = hio.read(
                    rreqs, f"{hd}/mp_off_rle_2", cb_bytes=128,
                    pipeline_depth=2, slow_hop_codec="rle",
                    node_cache=nc, transport="mp")
                check(f"fuzz{seed}/mp_read/rle_k2_cache{int(nc)}"
                      f"_vs_oracle",
                      all(np.array_equal(a, b)
                          for a, b in zip(outs, exp)))

    # overflow observability: one rank pushes 2x identical 32-element
    # requests into one 32-element window -> 64 elems > the round
    # bucket's min(data_cap, cb)=32 -> dropped_elems must be reported.
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    O[0, 0] = O[0, 1] = 0
    L[0, 0] = L[0, 1] = 32
    C[0] = 2
    D[0, :64] = np.tile(np.arange(32) % 97 + 1, 2)
    _, s = writers[32][0](O, L, C, D)
    check("overflow/dropped_elems_reported", int(s["dropped_elems"]) > 0)

    print(f"{len(FAILURES)} failures", flush=True)
    raise SystemExit(1 if FAILURES else 0)


if __name__ == "__main__":
    main()
