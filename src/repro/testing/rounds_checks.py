import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Round-engine SPMD checks (run as a subprocess with 8 host devices).

Property: for round counts {1, 2, 5} (cb_buffer_size in {160, 80, 32}
on a 160-element domain) and mixed / strided / overlapping / spanning
request patterns, the multi-round two-phase and TAM collective writes
are byte-identical to BOTH the single-shot path and the
``write_reference`` oracle, with identical (zero) drop stats; the
PIPELINED round loop (``IOConfig.pipeline``, prologue → steady state →
epilogue) is byte-identical to the serial round loop and the oracle at
every round count AND at every ring depth — the depth-k window ring
(``IOConfig.pipeline_depth``) is swept over k in {3, 4} x all three
round counts for two-phase and at the 5-round cb for TAM (k in {1, 2}
are the serial/pipelined rows above; depth clamps to the round count,
so the 1-round sweep also exercises the clamp); the round-scheduled
reads (serial, pipelined, and depth-k) return every rank's payload;
and a deliberately overflowed round bucket reports nonzero
``dropped_elems`` instead of failing silently. The spanning pattern
crosses the file-domain boundary, exercising the split-at-domain
handling (those requests were silently truncated before PR 2).

Slow-hop codec: with ``slow_hop_codec="rle"`` (the lossless zero-run
wire transform wrapped around the slow-axis ``all_to_all`` inside the
round engine) the SAME byte-identity must hold — swept over ring
depths {1, 2, 4} x round counts {1, 2, 5} for two-phase, at the
5-round cb for TAM, plus an rle read — because a lossless codec may
change the wire, never the file. Exits nonzero on any failure.
"""
import numpy as np
import jax
import jax.numpy as jnp

from dataclasses import replace

FAILURES = []

P_RANKS, REQ_CAP, DATA_CAP, FILE_LEN = 8, 8, 64, 320
CBS = (160, 80, 32)   # domain_len=160 -> 1, 2, 5 rounds
DEPTHS = (3, 4)       # ring depths beyond the serial/pipelined rows


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        FAILURES.append(name)


def mixed_pattern(rng):
    """Random disjoint extents, random lengths, shuffled ownership."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    slots = rng.permutation(FILE_LEN // 8)
    spr = len(slots) // P_RANKS
    for p in range(P_RANKS):
        mine = np.sort(slots[p * spr:(p + 1) * spr])[:6]
        lens = rng.integers(1, 9, size=len(mine)).astype(np.int32)
        O[p, :len(mine)], L[p, :len(lens)] = (mine * 8).astype(np.int32), lens
        C[p] = len(mine)
        D[p, :lens.sum()] = rng.integers(1, 999, size=lens.sum())
    return O, L, C, D


def strided_pattern(rng):
    """E3SM-style round-robin interleave: rank r owns slots r, r+P, ..."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.full(P_RANKS, REQ_CAP, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    unit = FILE_LEN // (P_RANKS * REQ_CAP)  # 5 elements per request
    for p in range(P_RANKS):
        idx = np.arange(REQ_CAP, dtype=np.int32)
        O[p] = (idx * P_RANKS + p) * unit
        L[p] = unit
        D[p, :REQ_CAP * unit] = O[p].repeat(unit) % 97 + 1
    return O, L, C, D


def overlapping_pattern(rng):
    """Ranks 0 and 1 write IDENTICAL data to the same two regions (the
    only deterministic overlap; MPI leaves diverging overlaps
    undefined); ranks 2..7 write disjoint extents elsewhere. The spans
    are sized so TAM's duplicated stage-1 payload (2 x span at one
    local aggregator) still fits the smallest round bucket."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    span, regions = 12, (8, 280)
    for p in (0, 1):
        for i, o in enumerate(regions):
            O[p, i], L[p, i] = o, span
            D[p, i * span:(i + 1) * span] = np.arange(o, o + span) % 97 + 1
        C[p] = 2
    for p in range(2, P_RANKS):
        # disjoint extents clear of both regions and the domain boundary
        o = 40 + (p - 2) * 24 if p <= 4 else 170 + (p - 5) * 24
        O[p, 0], L[p, 0], C[p] = o, 20, 1
        D[p, :20] = rng.integers(1, 999, size=20)
    return O, L, C, D


def spanning_pattern(rng):
    """Requests crossing the file-domain boundary at 160 (and window
    boundaries): both paths must split them — the single-shot exchange
    truncated the spanning tail silently before the domain-split fix."""
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    # rank 0 straddles the domain boundary: [150, 174)
    O[0, 0], L[0, 0], C[0] = 150, 24, 1
    D[0, :24] = np.arange(150, 174) % 97 + 1
    # rank 1 straddles a cb=32 window boundary inside domain 1:
    # [250, 262) is domain-local [90, 102), crossing 96
    O[1, 0], L[1, 0], C[1] = 250, 12, 1
    D[1, :12] = np.arange(250, 262) % 97 + 1
    for p in range(2, P_RANKS):
        o = 8 + (p - 2) * 16
        O[p, 0], L[p, 0], C[p] = o, 12, 1
        D[p, :12] = rng.integers(1, 999, size=12)
    return O, L, C, D


def main():
    from repro.core import IOConfig, contiguous_layout
    from repro.core.tam import make_tam_read, make_tam_write
    from repro.core.twophase import (make_twophase_read,
                                     make_twophase_write, write_reference)

    mesh = jax.make_mesh((2, 2, 2), ("node", "lagg", "lmem"))
    layout = contiguous_layout(FILE_LEN, 2)
    base = IOConfig(req_cap=32, data_cap=DATA_CAP, coalesce_cap=32)

    writers = {None: (jax.jit(make_twophase_write(mesh, layout, base)),
                      jax.jit(make_tam_write(mesh, layout, base)))}
    pipelined = {}
    readers = {}
    readers_p = {}
    for cb in CBS:
        cfg = replace(base, cb_buffer_size=cb)
        cfgp = replace(base, cb_buffer_size=cb, pipeline=True)
        writers[cb] = (jax.jit(make_twophase_write(mesh, layout, cfg)),
                       jax.jit(make_tam_write(mesh, layout, cfg)))
        pipelined[cb] = (jax.jit(make_twophase_write(mesh, layout, cfgp)),
                         jax.jit(make_tam_write(mesh, layout, cfgp)))
        readers[cb] = (jax.jit(make_twophase_read(mesh, layout, cfg)),
                       jax.jit(make_tam_read(mesh, layout, cfg)))
    # pipelined reads: 5-round config exercises prologue + steady state
    # + epilogue (1-round = prologue/epilogue only, covered by writes)
    cfgp32 = replace(base, cb_buffer_size=32, pipeline=True)
    readers_p[32] = (jax.jit(make_twophase_read(mesh, layout, cfgp32)),
                     jax.jit(make_tam_read(mesh, layout, cfgp32)))
    # depth-k ring sweep: two-phase at every round count (the 1-round
    # config exercises the depth clamp), TAM at the 5-round cb, and a
    # depth-k read; byte-identity is checked on the mixed + spanning
    # patterns (the other patterns cover k in {1, 2} above)
    deep = {}
    for cb in CBS:
        for k in DEPTHS:
            cfgk = replace(base, cb_buffer_size=cb, pipeline=True,
                           pipeline_depth=k)
            deep[("twophase", cb, k)] = jax.jit(
                make_twophase_write(mesh, layout, cfgk))
    for k in DEPTHS:
        cfgk = replace(base, cb_buffer_size=32, pipeline=True,
                       pipeline_depth=k)
        deep[("tam", 32, k)] = jax.jit(make_tam_write(mesh, layout, cfgk))
    readers_k = {k: jax.jit(make_twophase_read(
        mesh, layout, replace(base, cb_buffer_size=32, pipeline=True,
                              pipeline_depth=k))) for k in DEPTHS}
    # slow-hop codec sweep: rle across depths {1, 2, 4} x all three
    # round counts for two-phase, TAM at the 5-round cb, one rle read
    CODEC_DEPTHS = (1, 2, 4)
    coded = {}
    for cb in CBS:
        for k in CODEC_DEPTHS:
            cfgc = replace(base, cb_buffer_size=cb, pipeline=k > 1,
                           pipeline_depth=k, slow_hop_codec="rle")
            coded[("twophase", cb, k)] = jax.jit(
                make_twophase_write(mesh, layout, cfgc))
    for k in CODEC_DEPTHS:
        cfgc = replace(base, cb_buffer_size=32, pipeline=k > 1,
                       pipeline_depth=k, slow_hop_codec="rle")
        coded[("tam", 32, k)] = jax.jit(make_tam_write(mesh, layout, cfgc))
    reader_rle = jax.jit(make_twophase_read(
        mesh, layout, replace(base, cb_buffer_size=32, pipeline=True,
                              pipeline_depth=2, slow_hop_codec="rle")))

    rng = np.random.default_rng(0)
    patterns = {"mixed": mixed_pattern(rng),
                "strided": strided_pattern(rng),
                "overlapping": overlapping_pattern(rng),
                "spanning": spanning_pattern(rng)}

    for pname, (O, L, C, D) in patterns.items():
        ref = write_reference(layout, O, L, C, D)
        singles = {}
        for mi, mname in ((0, "twophase"), (1, "tam")):
            f, s = writers[None][mi](O, L, C, D)
            singles[mname] = np.asarray(f).reshape(-1)
            check(f"{pname}/{mname}/single_shot_vs_ref",
                  np.array_equal(singles[mname], ref))
        for cb in CBS:
            n_rounds = 160 // cb
            for mi, mname in ((0, "twophase"), (1, "tam")):
                f, s = writers[cb][mi](O, L, C, D)
                got = np.asarray(f).reshape(-1)
                tag = f"{pname}/{mname}/rounds{n_rounds}"
                check(f"{tag}_vs_ref", np.array_equal(got, ref))
                check(f"{tag}_vs_single_shot",
                      np.array_equal(got, singles[mname]))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
                fp, sp = pipelined[cb][mi](O, L, C, D)
                gotp = np.asarray(fp).reshape(-1)
                check(f"{tag}_pipelined_vs_serial",
                      np.array_equal(gotp, got))
                check(f"{tag}_pipelined_vs_ref",
                      np.array_equal(gotp, ref))
                check(f"{tag}_pipelined_no_drops",
                      int(sp["dropped_requests"]) == 0
                      and int(sp["dropped_elems"]) == 0)
            rd2, rdt = readers[cb]
            for rd, mname in ((rd2, "twophase"), (rdt, "tam")):
                got = np.asarray(rd(O, L, C,
                                    jnp.asarray(ref).reshape(2, -1)))
                ok = all(np.array_equal(got[p][:L[p].sum()],
                                        D[p][:L[p].sum()])
                         for p in range(P_RANKS))
                check(f"{pname}/{mname}/read_rounds{n_rounds}", ok)
        for rd, mname in zip(readers_p[32], ("twophase", "tam")):
            got = np.asarray(rd(O, L, C, jnp.asarray(ref).reshape(2, -1)))
            ok = all(np.array_equal(got[p][:L[p].sum()],
                                    D[p][:L[p].sum()])
                     for p in range(P_RANKS))
            check(f"{pname}/{mname}/read_pipelined_rounds5", ok)
        if pname in ("mixed", "spanning"):
            for (mname, cb, k), fn in deep.items():
                f, s = fn(O, L, C, D)
                tag = f"{pname}/{mname}/depth{k}_rounds{160 // cb}"
                check(f"{tag}_vs_ref",
                      np.array_equal(np.asarray(f).reshape(-1), ref))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
            for k, rd in readers_k.items():
                got = np.asarray(rd(O, L, C,
                                    jnp.asarray(ref).reshape(2, -1)))
                ok = all(np.array_equal(got[p][:L[p].sum()],
                                        D[p][:L[p].sum()])
                         for p in range(P_RANKS))
                check(f"{pname}/twophase/read_depth{k}_rounds5", ok)
            for (mname, cb, k), fn in coded.items():
                f, s = fn(O, L, C, D)
                tag = f"{pname}/{mname}/rle_depth{k}_rounds{160 // cb}"
                check(f"{tag}_vs_ref",
                      np.array_equal(np.asarray(f).reshape(-1), ref))
                check(f"{tag}_no_drops",
                      int(s["dropped_requests"]) == 0
                      and int(s["dropped_elems"]) == 0)
            got = np.asarray(reader_rle(O, L, C,
                                        jnp.asarray(ref).reshape(2, -1)))
            ok = all(np.array_equal(got[p][:L[p].sum()],
                                    D[p][:L[p].sum()])
                     for p in range(P_RANKS))
            check(f"{pname}/twophase/read_rle_rounds5", ok)

    # overflow observability: one rank pushes 2x identical 32-element
    # requests into one 32-element window -> 64 elems > the round
    # bucket's min(data_cap, cb)=32 -> dropped_elems must be reported.
    O = np.full((P_RANKS, REQ_CAP), 2**31 - 1, np.int32)
    L = np.zeros((P_RANKS, REQ_CAP), np.int32)
    C = np.zeros(P_RANKS, np.int32)
    D = np.zeros((P_RANKS, DATA_CAP), np.int32)
    O[0, 0] = O[0, 1] = 0
    L[0, 0] = L[0, 1] = 32
    C[0] = 2
    D[0, :64] = np.tile(np.arange(32) % 97 + 1, 2)
    _, s = writers[32][0](O, L, C, D)
    check("overflow/dropped_elems_reported", int(s["dropped_elems"]) > 0)

    print(f"{len(FAILURES)} failures", flush=True)
    raise SystemExit(1 if FAILURES else 0)


if __name__ == "__main__":
    main()
