"""Planner pass pipeline: named, pure ``IOPlan -> IOPlan`` rewrites.

``compile_plan`` used to be one monolithic function interleaving five
"auto" resolutions (method, cb, depth, codec, placement — PRs 3-5).
Following ROMIO's separation of access-pattern analysis from data
movement (Thakur et al.), planning is now a *pipeline*: an initial plan
carrying the knobs exactly as the caller spelled them ("auto" included)
is pushed through an ordered registry of passes, each a named, pure
rewrite of one concern. ``compile_plan(trace=True)`` returns the
per-pass snapshots so adjacent plans are diffable with
:func:`repro.core.plan.plan_diff` — a bad rewrite names the pass and
the field it broke.

Registered order (semantic, not alphabetical — the codec's wire
discount feeds every later auto through the effective workload):

    normalize_layout     validate direction + even domain split
    resolve_codec        "auto" -> cost-model codec pick; typo dies
    resolve_method       "auto" -> twophase|tam; tam_read_fallback
    resolve_placement    policy/"auto" -> permutation; bijection check
    resolve_cb_and_depth joint cb x depth autotune (cost model)
    coalesce_windows     materialize cb (None -> domain) + n_rounds
    validate             RoundScheduler invariants; no "auto" survives
    lower_kernels        pick the fused Pallas round kernel (or none)
    resolve_transport    pick the byte-moving backend (mp or in-proc)

Purity contract: a pass reads ``(plan, ctx)`` and returns a NEW plan —
no hidden state, no mutation of ``ctx``. The workload adjustment the
codec used to apply in-place is now the pure derivation
:func:`effective_workload`, recomputed by every downstream pass from
the plan's resolved codec field. Every pass is idempotent (property-
tested in tests/test_plan_property.py): running the pipeline on its own
output is the identity, which is what makes per-pass snapshots honest
intermediate states of ONE rewrite system.

Adding a pass: see ARCHITECTURE.md ("adding a planner pass") — define
it here with ``@register_pass("name")`` in registry order, keep it pure
and idempotent, and extend the idempotence property test.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

from repro.core.domains import FileLayout


@dataclass(frozen=True)
class PlanContext:
    """Read-only inputs the passes resolve against (everything that is
    not plan state): the requested config, the cost-model workload as
    supplied/derived (UNADJUSTED — passes derive the codec-discounted
    view via :func:`effective_workload`), the machine calibration, and
    the writer shape."""

    cfg: object                  # IOConfig
    workload: object             # cost_model.Workload (pre-codec)
    machine: object              # cost_model.Machine
    n_nodes: int
    n_ranks: int
    unit_bytes: int


@dataclass(frozen=True)
class Pass:
    name: str
    fn: Callable
    doc: str = ""


PASS_REGISTRY: dict[str, Pass] = {}
_ORDER: list[Pass] = []


def register_pass(name: str):
    """Register a pass in pipeline order (declaration order == run
    order). The function must be a pure ``(plan, ctx) -> plan``."""
    def deco(fn):
        p = Pass(name=name, fn=fn, doc=(fn.__doc__ or "").strip())
        PASS_REGISTRY[name] = p
        _ORDER.append(p)
        return fn
    return deco


def effective_workload(w, slow_hop_codec, machine):
    """The workload view downstream autos resolve against, derived
    purely from the resolved codec field (the pre-pipeline planner
    mutated ``w`` in place at codec-resolution time; same semantics):

    * codec ON and the workload has no measured wire ratio and the
      codec is lossy -> charge the codec's modeled ratio;
    * codec OFF but the workload carries a measured ratio -> strip the
      discount (no codec, no saving, no encode cost);
    * otherwise the workload passes through untouched.
    """
    from repro.core import codec as codec_mod
    from repro.core import cost_model as cm
    if slow_hop_codec is not None:
        c = codec_mod.get_codec(slow_hop_codec)
        if w.slow_hop_ratio == 1.0 and not c.lossless:
            return cm.with_codec(w, c.modeled_ratio(0.0, w.total_bytes))
    elif w.slow_hop_ratio != 1.0:
        return cm.with_codec(w, 1.0)
    return w


# ---------------------------------------------------------------------------
# the passes, in registry (== run) order
# ---------------------------------------------------------------------------

@register_pass("normalize_layout")
def normalize_layout(plan, ctx):
    """Validate the schedule's frame: a known direction and a file that
    splits evenly into aggregator domains. Compile time — not run time
    — is where a bad schedule dies."""
    if plan.direction not in ("write", "read"):
        raise ValueError(f"unknown direction {plan.direction!r}")
    if plan.layout.file_len % plan.n_aggregators:
        raise ValueError("file_len must divide evenly among aggregators")
    return plan


@register_pass("resolve_codec")
def resolve_codec(plan, ctx):
    """Resolve the slow-hop wire codec. Runs FIRST among the autos: its
    beta discount / encode cost feed method, placement, cb, and depth
    through :func:`effective_workload`. ``"auto"`` never picks a lossy
    codec (losing bits is a caller decision, not a tuning knob)."""
    from repro.core import codec as codec_mod
    from repro.core.plan import resolve_slow_hop_codec
    codec = plan.slow_hop_codec
    if codec == "auto":
        codec = resolve_slow_hop_codec(ctx.workload, ctx.machine)
    if codec is not None:
        codec_mod.get_codec(codec)               # typo dies here
    return replace(plan, slow_hop_codec=codec)


@register_pass("resolve_method")
def resolve_method_pass(plan, ctx):
    """Resolve the aggregation topology: ``"auto"`` compares the
    modeled totals (``tam_cost`` at the optimal P_L vs
    ``twophase_cost``) for the codec-adjusted workload. Records the
    TAM-read lowering explicitly (``tam_read_fallback``) instead of
    silently aliasing the two-phase read path."""
    from repro.core.plan import resolve_method
    method = plan.method
    if method == "auto":
        w = effective_workload(ctx.workload, plan.slow_hop_codec,
                               ctx.machine)
        method = resolve_method(w, ctx.machine)
    if method not in ("twophase", "tam"):
        raise ValueError(f"unknown method {method!r}")
    fallback = method == "tam" and plan.direction == "read"
    return replace(plan, method=method, tam_read_fallback=fallback)


@register_pass("resolve_placement")
def resolve_placement_pass(plan, ctx):
    """Resolve the aggregator placement from the same workload view the
    other autos see; an explicit permutation is validated here (a
    non-bijection is a bad schedule and dies at compile time like any
    other)."""
    from repro.core import placement as placement_mod
    w = effective_workload(ctx.workload, plan.slow_hop_codec, ctx.machine)
    placement = placement_mod.resolve_placement(
        plan.placement, plan.n_aggregators, ctx.n_nodes, workload=w,
        machine=ctx.machine)
    return replace(plan, placement=placement)


@register_pass("resolve_cb_and_depth")
def resolve_cb_and_depth(plan, ctx):
    """Joint cb x depth resolution over the RoundScheduler-legal cb
    candidates (``optimal_cb_and_depth`` when both are "auto";
    ``optimal_cb`` / ``optimal_depth`` when only one is). A TAM plan
    autotunes at its optimal P_L. Read plans resolve against the read
    cost model (``read_cost`` — fetch + node-cache fan-out phases)
    instead of the write exchange. Leaves ``cb=None`` (single shot) for
    ``coalesce_windows`` to materialize."""
    from repro.core import cost_model as cm
    from repro.core.plan import _legal_cb_candidates
    cb, depth = plan.cb, plan.pipeline_depth
    if cb == "auto" or depth == "auto":
        w = effective_workload(ctx.workload, plan.slow_hop_codec,
                               ctx.machine)
        P_L_arg = None
        if plan.method == "tam":
            P_L_arg, _ = cm.optimal_PL(w, ctx.machine)
        cands = _legal_cb_candidates(plan.domain_len,
                                     plan.layout.stripe_size,
                                     ctx.unit_bytes)
        if plan.direction == "read":
            if cb == "auto" and depth == "auto":
                cb_bytes, depth, _ = cm.optimal_read_cb_and_depth(
                    w, ctx.machine, candidates=cands)
                cb = cb_bytes // ctx.unit_bytes
            elif cb == "auto":
                cb_bytes, _ = cm.optimal_read_cb(w, ctx.machine,
                                                 candidates=cands)
                cb = cb_bytes // ctx.unit_bytes
            else:  # depth == "auto" at a fixed cb
                cb_bytes = (cb if cb is not None
                            else plan.domain_len) * ctx.unit_bytes
                depth, _ = cm.optimal_read_depth(w, ctx.machine,
                                                 cb_bytes=cb_bytes)
        elif cb == "auto" and depth == "auto":
            cb_bytes, depth, _ = cm.optimal_cb_and_depth(
                w, ctx.machine, P_L=P_L_arg, candidates=cands)
            cb = cb_bytes // ctx.unit_bytes
        elif cb == "auto":
            cb_bytes, _ = cm.optimal_cb(w, ctx.machine, P_L=P_L_arg,
                                        candidates=cands)
            cb = cb_bytes // ctx.unit_bytes
        else:  # depth == "auto" at a fixed cb
            wc = cm.with_measured_rounds(
                w, cm.rounds_for_cb(w, (cb if cb is not None
                                        else plan.domain_len)
                                    * ctx.unit_bytes))
            depth, _ = cm.optimal_depth(wc, ctx.machine, P_L=P_L_arg)
    return replace(plan, cb=cb, pipeline_depth=max(1, int(depth)))


@register_pass("coalesce_windows")
def coalesce_windows(plan, ctx):
    """Materialize the round window schedule: ``cb=None`` becomes the
    whole domain (the single-shot schedule IS the 1-round plan) and
    ``n_rounds`` is derived from the final cb."""
    cb = plan.cb if plan.cb is not None else plan.domain_len
    return replace(plan, cb=cb, n_rounds=-(-plan.domain_len // cb))


@register_pass("validate")
def validate(plan, ctx):
    """Terminal schedule check: constructing the RoundScheduler IS the
    round-partition validation (uneven domains, non-aligned cb die
    here), and no ``"auto"`` may survive lowering."""
    from repro.core.plan import RoundScheduler
    sched = RoundScheduler(plan.layout, plan.n_aggregators, plan.cb)
    for f in ("method", "cb", "pipeline_depth", "slow_hop_codec",
              "placement"):
        if getattr(plan, f) == "auto":
            raise ValueError(f"pass pipeline left {f}='auto' unresolved")
    assert sched.cb == plan.cb and sched.n_rounds == plan.n_rounds
    return plan


@register_pass("lower_kernels")
def lower_kernels(plan, ctx):
    """Pick the per-round kernel lowering. ``kernel_fusion="fused_round"``
    selects the single Pallas kernel fusing window sort + coalesce +
    pack + codec zero-skip encode (``kernels.fused_round``) for the
    write drain — one HBM round-trip where the unfused path pays three.
    On reads the same lowering swaps the rle ``jax_decode`` scatter for
    the ``zero_skip_decode`` kernel in the per-round fetch (and has no
    effect without a codec — execution strategy, never routing)."""
    fusion = getattr(ctx.cfg, "kernel_fusion", None)
    if fusion not in (None, "fused_round"):
        raise ValueError(f"unknown kernel_fusion {fusion!r}")
    return replace(plan, kernel_fusion=fusion)


@register_pass("resolve_transport")
def resolve_transport(plan, ctx):
    """Pick the byte-moving backend. ``transport="mp"`` routes the
    executor dispatch in ``checkpoint.host_io`` to the multi-process
    backend (``checkpoint.mp_exec`` — forked workers, shared-memory
    intra-node fast hop, localhost-socket inter-node slow hop, measured
    wall-clock rounds); ``None`` keeps the in-process executors with
    modeled time. Validation lives in the one transport registry
    (``core.transport.resolve_transport``) — an unregistered name dies
    here, at plan time, not mid-write. Execution strategy, never
    routing: the schedule, placement, and bytes are transport-
    invariant (the rounds_checks cross-executor contract)."""
    from repro.core.transport import resolve_transport as _resolve
    return replace(
        plan, transport=_resolve(getattr(ctx.cfg, "transport", None)))


PASSES: tuple[Pass, ...] = tuple(_ORDER)


def initial_plan(layout: FileLayout, cfg, *, n_aggregators: int,
                 method: str = "twophase", direction: str = "write"):
    """The pipeline's input: an IOPlan carrying every knob exactly as
    requested — ``"auto"`` strings, ``cb=None``, a placement policy
    name — with ``n_rounds=0`` as the not-yet-scheduled marker. Only
    the passes turn it into an executable schedule."""
    from repro.core.plan import IOPlan
    return IOPlan(
        layout=layout, n_aggregators=n_aggregators,
        cb=cfg.cb_buffer_size, n_rounds=0, method=method,
        direction=direction,
        pipeline_depth=cfg.pipeline_depth if cfg.pipeline else 1,
        req_cap=cfg.req_cap, data_cap=cfg.data_cap,
        coalesce_cap=cfg.coalesce_cap, axis_names=cfg.axis_names,
        tam_read_fallback=False, slow_hop_codec=cfg.slow_hop_codec,
        placement=cfg.placement,
        kernel_fusion=getattr(cfg, "kernel_fusion", None),
        transport=getattr(cfg, "transport", None))


def run_passes(plan, ctx: PlanContext, passes: tuple = None,
               trace: list | None = None):
    """Run ``plan`` through ``passes`` (default: the full registry).
    When ``trace`` is a list, append one ``(pass_name, plan_snapshot)``
    per pass so callers can diff adjacent snapshots with
    :func:`repro.core.plan.plan_diff`."""
    for p in (PASSES if passes is None else passes):
        plan = p.fn(plan, ctx)
        if trace is not None:
            trace.append((p.name, plan))
    return plan


def trace_report(trace) -> str:
    """Human-readable pipeline trace: for each pass, the fields it
    rewrote (``plan_diff`` of adjacent snapshots)."""
    from repro.core.plan import plan_diff
    lines = []
    prev = None
    for name, snap in trace:
        if prev is None:
            lines.append(f"[{name}]")
        else:
            d = plan_diff(prev, snap)
            lines.append(f"[{name}] " + (d.replace("\n", "; ")
                                         if d else "(no change)"))
        prev = snap
    return "\n".join(lines)
