"""TAM core: two-layer request aggregation for collective I/O in JAX."""
from repro.core.requests import (  # noqa: F401
    ELEM_BYTES, PAD_OFFSET, RequestList, empty_requests, make_requests,
    split_at_stripes,
)
from repro.core.domains import FileLayout, contiguous_layout  # noqa: F401
from repro.core.coalesce import (  # noqa: F401
    aggregate, coalesce_sorted, merge_sorted, sort_requests,
)
from repro.core.plan import (  # noqa: F401
    IOConfig, IOPlan, RoundScheduler, compile_plan, resolve_cb_buffer_size,
    resolve_slow_hop_codec,
)
from repro.core.codec import (  # noqa: F401
    Codec, available_codecs, get_codec, lossless_codecs,
)
from repro.core.placement import (  # noqa: F401
    PLACEMENT_POLICIES, node_of_slot, resolve_placement,
    validate_placement,
)
from repro.core.session import IOSession  # noqa: F401
from repro.core.twophase import make_twophase_write, plan_for  # noqa: F401
from repro.core.tam import make_tam_write  # noqa: F401
from repro.core.spmd_exec import (  # noqa: F401
    make_collective_write, make_spmd_executor,
)
from repro.core.rounds import peak_aggregator_buffer_elems  # noqa: F401
from repro.core.cost_model import (  # noqa: F401
    Machine, Workload, cb_candidates, optimal_PL, optimal_cb,
    optimal_cb_and_depth, optimal_depth, pipeline_span, placement_cost,
    rounds_for_cb, slow_hop_codec_gain, tam_cost, twophase_cost,
    with_codec, with_locality, with_measured_rounds, with_overlap,
)
from repro.core.hierarchical import (  # noqa: F401
    compressed_psum, two_layer_all_to_all, two_layer_psum,
)
