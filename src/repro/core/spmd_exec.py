"""SPMD executor: runs a compiled :class:`repro.core.plan.IOPlan`.

One of the two interchangeable backends of the plan/executor split
(ARCHITECTURE.md); the other is ``repro.checkpoint.host_exec``. This
one lowers the plan to a ``shard_map`` program over the
``(node, lagg, lmem)`` mesh view and drives the depth-k round ring of
``repro.core.rounds``:

* ``method="twophase"`` — every rank routes each window's requests
  straight to the owning global aggregator (slow-axis ``all_to_all``)
  and the window merges with a masked pmax over the intra-node axes.
* ``method="tam"`` — both aggregation layers run inside the window
  loop (``exchange_rounds_write_tam``): the intra-node gather is
  bounded at ``min(data_cap, cb)`` per rank, then only the coalesced
  window crosses the slow axis.
* ``direction="read"`` — aggregators broadcast one cb window per round
  and ranks gather their own elements.

The single-shot exchange that used to live as a separate code path in
``twophase.py`` / ``tam.py`` is gone: a plan with ``cb == domain_len``
is a 1-round schedule and runs through the same ring (the round engine
with one window IS the single shot — asserted byte-identical by
``repro/testing/rounds_checks.py`` long before the paths merged).

The slow-hop codec (``plan.slow_hop_codec``, ``core.codec``) is such a
per-round transform, wrapped around the ``exchange``/``drain`` pair
inside ``core.rounds`` — both schedules and every depth inherit it;
see ARCHITECTURE.md § "The slow-hop codec".
"""
from __future__ import annotations

from functools import partial

import jax
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core import coalesce as co
from repro.core import rounds
from repro.core.plan import IOPlan, compile_plan
from repro.core.requests import RequestList, mask_invalid


def _as_requests(offsets, lengths, count) -> RequestList:
    return mask_invalid(RequestList(offsets.reshape(-1),
                                    lengths.reshape(-1),
                                    count.reshape(())))


def _write_shard_fn(plan: IOPlan, use_kernels: bool,
                    offsets, lengths, count, data):
    node, lagg, lmem = plan.axis_names
    r = _as_requests(offsets, lengths, count)
    data = data.reshape(-1)
    starts = co.request_starts(r)
    sched = plan.scheduler()

    if plan.method == "tam":
        # fused two-layer round loop; post-gather state is replicated
        # across lmem, so the window merge and receive stats run over
        # lagg only (the pmax combine is idempotent under that
        # replication) and replicated stats divide by the lmem size.
        shard, st = rounds.exchange_rounds_write_tam(
            sched, node, lagg, lmem, r, starts, data,
            coalesce_cap=plan.coalesce_cap, use_kernels=use_kernels,
            depth=plan.pipeline_depth,
            slow_hop_codec=plan.slow_hop_codec,
            placement=plan.placement,
            kernel_fusion=plan.kernel_fusion)
        lmem_size = axis_size(lmem)
        all_axes = (node, lagg, lmem)
        stats = {
            "dropped_requests":
                lax.psum(st["dropped_requests_rank"], all_axes)
                + lax.psum(st["dropped_requests_agg"], all_axes)
                // lmem_size,
            "dropped_elems":
                lax.psum(st["dropped_elems_rank"], all_axes)
                + lax.psum(st["dropped_elems_agg"], all_axes)
                // lmem_size,
            "requests_before_coalesce": lax.psum(
                st["requests_before_coalesce"], (node, lagg)) // lmem_size,
            "requests_after_coalesce": lax.psum(
                st["requests_after_coalesce"], (node, lagg)) // lmem_size,
            "requests_at_ga": st["requests_at_ga"][None],
        }
        return shard[None], stats

    shard, st = rounds.exchange_rounds_write(
        sched, node, (lagg, lmem), r, starts, data,
        depth=plan.pipeline_depth,
        slow_hop_codec=plan.slow_hop_codec,
        placement=plan.placement,
        kernel_fusion=plan.kernel_fusion)
    stats = {
        "dropped_requests": lax.psum(st["dropped_requests"],
                                     (node, lagg, lmem)),
        "dropped_elems": lax.psum(st["dropped_elems"],
                                  (node, lagg, lmem)),
        "requests_at_ga": st["requests_at_ga"][None],
    }
    return shard[None], stats


def _read_shard_fn(plan: IOPlan, offsets, lengths, count, file_shard):
    node = plan.axis_names[0]
    r = _as_requests(offsets, lengths, count)
    starts = co.request_starts(r)
    out = rounds.exchange_rounds_read(
        plan.scheduler(), node, r, starts, file_shard.reshape(-1),
        plan.data_cap, depth=plan.pipeline_depth,
        slow_hop_codec=plan.slow_hop_codec,
        placement=plan.placement,
        kernel_fusion=plan.kernel_fusion)
    return out[None]


def make_collective_write(mesh: jax.sharding.Mesh, layout, cfg,
                          method: str = "auto", use_kernels: bool = False,
                          machine=None, workload=None):
    """Plan + execute in one call, with ``method="auto"`` picking
    two-phase vs TAM per workload via the cost model at plan time
    (``tam_cost`` at the optimal P_L vs ``twophase_cost``). The stats
    dict follows the resolved method (TAM adds the coalesce counters).
    Pass a measured ``cost_model.Workload`` to ground the choice in
    observed request counts instead of the static capacities."""
    node = cfg.axis_names[0]
    plan = compile_plan(layout, cfg, n_aggregators=mesh.shape[node],
                        n_nodes=mesh.shape[node], n_ranks=mesh.size,
                        method=method, machine=machine, workload=workload)
    return make_spmd_executor(mesh, plan, use_kernels=use_kernels)


def make_spmd_executor(mesh: jax.sharding.Mesh, plan: IOPlan,
                       use_kernels: bool = False):
    """Lower an :class:`IOPlan` to a jit-able shard_map program.

    Write plans return ``(file [n_aggregators, domain_len] sharded over
    the slow axis, stats dict)``; read plans return per-rank payloads.
    The mesh's slow-axis size must match the plan's aggregator count —
    the plan IS the schedule, the mesh is just where it runs.
    """
    node, lagg, lmem = plan.axis_names
    if mesh.shape[node] != plan.n_aggregators:
        raise ValueError(
            f"plan compiled for {plan.n_aggregators} aggregators but mesh "
            f"axis {node!r} has size {mesh.shape[node]}")
    rank_spec = P((node, lagg, lmem))
    if plan.direction == "read":
        return shard_map(
            partial(_read_shard_fn, plan), mesh=mesh, check_vma=False,
            in_specs=(rank_spec, rank_spec, rank_spec, P(node)),
            out_specs=rank_spec)
    stats_spec = {"dropped_requests": P(), "dropped_elems": P(),
                  "requests_at_ga": P(node)}
    if plan.method == "tam":
        stats_spec.update({"requests_before_coalesce": P(),
                           "requests_after_coalesce": P()})
    return shard_map(
        partial(_write_shard_fn, plan, use_kernels), mesh=mesh,
        check_vma=False,
        in_specs=(rank_spec, rank_spec, rank_spec, rank_spec),
        out_specs=(P(node), stats_spec))
