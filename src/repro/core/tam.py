"""TAM — the two-layer aggregation method (the paper's contribution).

Collective write in three steps:

1. **Intra-node aggregation** (fast axis ``lmem``): ranks within each
   local-aggregator group ship requests + payload to the group's local
   aggregator; the aggregator merge-sorts the offset-length pairs,
   coalesces contiguous runs, and repacks payloads so each coalesced run
   is one contiguous span. All node groups run concurrently; nothing
   crosses the slow axis.
2. **Inter-node aggregation** (slow axis ``node``): only local
   aggregators participate. Coalesced metadata (capacity ``coalesce_cap``
   << lmem * req_cap for patterns that coalesce) + repacked payload are
   routed to the owning global aggregator via all_to_all; ``P_L/P_G``
   incoming buckets per aggregator instead of ``P/P_G``.
3. **I/O step**: identical to two-phase — the global aggregator
   merge-sorts and packs its contiguous file domain.

Two-phase I/O is the degenerate configuration lmem == 1 and
coalesce_cap == req_cap (P_L == P): stage 1 becomes the identity.

Since the plan/executor split (ARCHITECTURE.md) this module is a thin
wrapper: the builders compile an :class:`~repro.core.plan.IOPlan` with
``method="tam"`` and hand it to the SPMD executor, whose fused round
loop (``rounds.exchange_rounds_write_tam``) runs BOTH aggregation
layers inside each cb window — local-aggregator memory is O(cb), and
the single-shot exchange is just the 1-round plan.

SPMD note: every ``lmem`` slot redundantly executes stage 2 on replicated
aggregates (SPMD has no "idle rank"); the HLO slow-axis collective is
still the coalesced size, which is what the roofline reads. The
host-level path models the true per-endpoint congestion.
"""
from __future__ import annotations

import jax

from repro.core.domains import FileLayout
from repro.core.spmd_exec import make_spmd_executor
from repro.core.twophase import IOConfig, plan_for


def make_tam_write(mesh: jax.sharding.Mesh, layout: FileLayout,
                   cfg: IOConfig, use_kernels: bool = False):
    """Build the jit-able TAM collective write.

    Same signature as :func:`repro.core.twophase.make_twophase_write`;
    P_L = mesh.shape[node] * mesh.shape[lagg] local aggregators. Both
    aggregation layers run inside the window loop (local-aggregator
    memory O(cb)); ``cfg.pipeline`` runs the
    depth-``cfg.pipeline_depth`` window ring over each round's
    two-layer exchange; ``"auto"`` resolves the round size (and depth)
    via the cost model at plan time.
    """
    node = cfg.axis_names[0]
    plan = plan_for(layout, cfg, mesh.shape[node], mesh.size,
                    method="tam")
    return make_spmd_executor(mesh, plan, use_kernels=use_kernels)


def make_tam_read(mesh: jax.sharding.Mesh, layout: FileLayout,
                  cfg: IOConfig):
    """TAM collective read — an EXPLICIT alias of the two-phase read
    schedule.

    In MPI, TAM-read reverses the write: global aggregators send domain
    slices to local aggregators (P_L/P_G slow-axis messages instead of
    P/P_G), which redistribute within the node. Under SPMD there is no
    idle rank: every rank participates in every collective hop, so the
    slow-axis transfer lowers to the same one-window-per-round
    broadcast either way and the two schedules are the same program —
    the metadata/congestion saving TAM-read buys on real MPI endpoints
    is modeled by the host path and ``cost_model``, not by HLO. The
    plan records this as ``tam_read_fallback`` (asserted here and in
    tests/test_plan.py) instead of silently falling back.
    """
    node = cfg.axis_names[0]
    plan = plan_for(layout, cfg, mesh.shape[node], mesh.size,
                    method="tam", direction="read")
    assert plan.tam_read_fallback, (
        "TAM read compiles to the two-phase window broadcast under SPMD; "
        "the plan must record the fallback explicitly")
    return make_spmd_executor(mesh, plan)
