"""TAM — the two-layer aggregation method (the paper's contribution).

Collective write in three steps:

1. **Intra-node aggregation** (fast axis ``lmem``): ranks within each
   local-aggregator group ship requests + payload to the group's local
   aggregator; the aggregator merge-sorts the offset-length pairs,
   coalesces contiguous runs, and repacks payloads so each coalesced run
   is one contiguous span. All node groups run concurrently; nothing
   crosses the slow axis.
2. **Inter-node aggregation** (slow axis ``node``): only local
   aggregators participate. Coalesced metadata (capacity ``coalesce_cap``
   << lmem * req_cap for patterns that coalesce) + repacked payload are
   routed to the owning global aggregator via all_to_all; ``P_L/P_G``
   incoming buckets per aggregator instead of ``P/P_G``.
3. **I/O step**: identical to two-phase — the global aggregator
   merge-sorts and packs its contiguous file domain.

Two-phase I/O is the degenerate configuration lmem == 1 and
coalesce_cap == req_cap (P_L == P): stage 1 becomes the identity.

SPMD note: every ``lmem`` slot redundantly executes stage 2 on replicated
aggregates (SPMD has no "idle rank"); the HLO slow-axis collective is
still the coalesced size, which is what the roofline reads. The
host-level path models the true per-endpoint congestion.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core import coalesce as co
from repro.core import rounds
from repro.core.domains import FileLayout
from repro.core.exchange import bucket_by_dest, flatten_buckets, repack_sorted, sort_with
from repro.core.requests import RequestList, mask_invalid, split_at_stripes
from repro.core.twophase import IOConfig, resolve_cb_buffer_size


def _intra_node_aggregate(cfg: IOConfig, r: RequestList, data: jax.Array,
                          use_kernels: bool = False):
    """Stage 1: gather over ``lmem``, merge-sort, coalesce, repack.

    Returns (coalesced requests [coalesce_cap], repacked payload
    [lmem * data_cap], pre/post request counts for stats).
    """
    _, _, lmem = cfg.axis_names
    g = partial(lax.all_gather, axis_name=lmem, axis=0, tiled=False)
    all_off, all_len, all_cnt, all_data = (g(r.offsets), g(r.lengths),
                                           g(r.count), g(data))
    m = all_off.shape[0]
    merged, starts_m, data_flat = flatten_buckets(
        all_off, all_len, all_cnt, all_data)
    if use_kernels:
        from repro.kernels import ops as kops
        sorted_r, starts_s = kops.sort_requests_with(merged, starts_m)
        packed = repack_sorted(sorted_r, starts_s, data_flat,
                               m * cfg.data_cap)
        coalesced = kops.coalesce(sorted_r)
    else:
        sorted_r, starts_s = sort_with(merged, starts_m)
        packed = repack_sorted(sorted_r, starts_s, data_flat,
                               m * cfg.data_cap)
        coalesced = co.coalesce_sorted(sorted_r)
    cap = cfg.coalesce_cap or coalesced.capacity
    out = RequestList(coalesced.offsets[:cap], coalesced.lengths[:cap],
                      jnp.minimum(coalesced.count, cap))
    dropped = jnp.maximum(coalesced.count - cap, 0)
    return out, packed, merged.count, out.count, dropped


def _tam_write_shard_fn(layout: FileLayout, cfg: IOConfig, n_nodes: int,
                        use_kernels: bool,
                        offsets, lengths, count, data):
    node, lagg, lmem = cfg.axis_names
    r = mask_invalid(RequestList(offsets.reshape(-1), lengths.reshape(-1),
                                 count.reshape(())))
    data = data.reshape(-1)

    if cfg.cb_buffer_size is not None:
        # fused round loop: BOTH layers are window-bounded — stage 1
        # gathers only min(data_cap, cb) payload per rank per round, so
        # local-aggregator memory is O(cb) too (see
        # rounds.exchange_rounds_write_tam). Post-gather state is
        # replicated across lmem, so the window merge and receive stats
        # run over lagg only (the pmax combine is idempotent under that
        # replication) and replicated stats divide by the lmem size.
        starts = co.request_starts(r)
        sched = rounds.RoundScheduler(layout, n_nodes, cfg.cb_buffer_size)
        shard, st = rounds.exchange_rounds_write_tam(
            sched, node, lagg, lmem, r, starts, data,
            coalesce_cap=cfg.coalesce_cap, use_kernels=use_kernels,
            pipeline=cfg.pipeline)
        lmem_size = axis_size(lmem)
        all_axes = (node, lagg, lmem)
        stats = {
            "dropped_requests":
                lax.psum(st["dropped_requests_rank"], all_axes)
                + lax.psum(st["dropped_requests_agg"], all_axes)
                // lmem_size,
            "dropped_elems":
                lax.psum(st["dropped_elems_rank"], all_axes)
                + lax.psum(st["dropped_elems_agg"], all_axes)
                // lmem_size,
            "requests_before_coalesce": lax.psum(
                st["requests_before_coalesce"], (node, lagg)) // lmem_size,
            "requests_after_coalesce": lax.psum(
                st["requests_after_coalesce"], (node, lagg)) // lmem_size,
            "requests_at_ga": st["requests_at_ga"][None],
        }
        return shard[None], stats

    # ---- stage 1: intra-node ----------------------------------------
    agg_r, packed, n_before, n_after, drop_coal = _intra_node_aggregate(
        cfg, r, data, use_kernels)

    # ---- stage 2: inter-node (local aggregators only) ----------------
    domain_len = layout.file_len // n_nodes
    # coalescing may fuse runs across file-domain boundaries (and ranks
    # may submit domain-spanning requests): split so each forwarded
    # request has exactly one owning aggregator (they were silently
    # truncated by the domain packing before)
    agg_r = split_at_stripes(agg_r, domain_len,
                             packed.shape[0] // domain_len + 2)
    agg_starts = co.request_starts(agg_r)
    dest = agg_r.offsets // domain_len
    inter_data_cap = packed.shape[0]
    buckets = bucket_by_dest(agg_r, agg_starts, packed, dest, n_nodes,
                             agg_r.capacity, inter_data_cap)
    a2a = partial(lax.all_to_all, axis_name=node, split_axis=0,
                  concat_axis=0, tiled=True)
    rx_off, rx_len, rx_data = (a2a(buckets.offsets), a2a(buckets.lengths),
                               a2a(buckets.data))
    rx_cnt = a2a(buckets.counts)

    # global aggregator also hears the node's other local aggregators
    g = partial(lax.all_gather, axis_name=lagg, axis=0, tiled=False)
    all_off, all_len, all_cnt, all_data = (g(rx_off), g(rx_len), g(rx_cnt),
                                           g(rx_data))

    # ---- I/O step: identical to two-phase ----------------------------
    merged, starts_m, data_flat = flatten_buckets(all_off, all_len,
                                                  all_cnt, all_data)
    sorted_r, starts_s = sort_with(merged, starts_m)
    my_node = lax.axis_index(node)
    shard = co.pack_data(sorted_r, starts_s, data_flat, domain_len,
                         base=my_node * domain_len)
    stats = {
        "dropped_requests": lax.psum(
            buckets.dropped_requests + drop_coal, (node, lagg, lmem)),
        "dropped_elems": lax.psum(buckets.dropped_elems, (node, lagg, lmem)),
        "requests_before_coalesce": lax.psum(n_before, (node, lagg)) //
            axis_size(lmem),
        "requests_after_coalesce": lax.psum(n_after, (node, lagg)) //
            axis_size(lmem),
        "requests_at_ga": sorted_r.count[None],
    }
    return shard[None], stats


def make_tam_write(mesh: jax.sharding.Mesh, layout: FileLayout,
                   cfg: IOConfig, use_kernels: bool = False):
    """Build the jit-able TAM collective write.

    Same signature as :func:`repro.core.twophase.make_twophase_write`;
    P_L = mesh.shape[node] * mesh.shape[lagg] local aggregators. With
    ``cfg.cb_buffer_size`` set, both aggregation layers run inside the
    window loop (local-aggregator memory O(cb)); ``cfg.pipeline``
    overlaps each round's two-layer exchange with the previous round's
    drain; ``"auto"`` resolves the round size via
    ``cost_model.optimal_cb``.
    """
    node, lagg, lmem = cfg.axis_names
    n_nodes = mesh.shape[node]
    if layout.file_len % n_nodes:
        raise ValueError("file_len must divide evenly among aggregators")
    cfg = resolve_cb_buffer_size(layout, n_nodes, mesh.size, cfg)
    if cfg.cb_buffer_size is not None:  # validate the round partition now
        rounds.RoundScheduler(layout, n_nodes, cfg.cb_buffer_size)
    rank_spec = P((node, lagg, lmem))
    fn = partial(_tam_write_shard_fn, layout, cfg, n_nodes, use_kernels)
    return shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(rank_spec, rank_spec, rank_spec, rank_spec),
        out_specs=(P(node), {"dropped_requests": P(),
                             "dropped_elems": P(),
                             "requests_before_coalesce": P(),
                             "requests_after_coalesce": P(),
                             "requests_at_ga": P(node)}),
    )


def make_tam_read(mesh: jax.sharding.Mesh, layout: FileLayout,
                  cfg: IOConfig):
    """TAM collective read: reverse order.

    Global aggregators slice their domains per destination node
    (all_to_all over ``node``), local aggregators reassemble the node's
    span, ranks gather their own requests from the node-local image.
    For simplicity the node-local image is the union span of the node's
    requests bounded by per-node domain windows.
    """
    node, lagg, lmem = cfg.axis_names
    n_nodes = mesh.shape[node]
    cfg = resolve_cb_buffer_size(layout, n_nodes, mesh.size, cfg)
    domain_len = layout.file_len // n_nodes
    rank_spec = P((node, lagg, lmem))

    def fn(offsets, lengths, count, file_shard):
        r = mask_invalid(RequestList(offsets.reshape(-1),
                                     lengths.reshape(-1), count.reshape(())))
        starts = co.request_starts(r)
        if cfg.cb_buffer_size is not None:
            # rounds bound the slow-axis broadcast at one window/round
            sched = rounds.RoundScheduler(layout, n_nodes,
                                          cfg.cb_buffer_size)
            out = rounds.exchange_rounds_read(
                sched, node, r, starts, file_shard.reshape(-1),
                cfg.data_cap, pipeline=cfg.pipeline)
            return out[None]
        # stage 2 reversed: every node obtains the full file image only of
        # the domains it needs; here we conservatively gather the file over
        # the slow axis once per node (one receive per GA pair, P_L/P_G
        # slow-axis messages as in TAM-read).
        whole = lax.all_gather(file_shard.reshape(-1), node, axis=0,
                               tiled=True)
        # stage 1 reversed: node-local distribution from the local image.
        return co.unpack_data(r, starts, whole, cfg.data_cap)[None]

    return shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(rank_spec, rank_spec, rank_spec, P(node)),
        out_specs=rank_spec,
    )
