"""Pluggable slow-hop codec registry (the per-round wire transform).

The paper's 29x win shrinks the NUMBER of endpoints and requests on the
slow (inter-node) hop; the next-order term is the BYTES per hop. This
module is the one place those bytes are transformed: a registry of
codecs with an ``encode -> wire`` / ``decode -> payload`` contract,
consumed by

* the round engine (``core.rounds``): the ``exchange`` closure encodes
  each round's per-destination payload buckets before the slow-axis
  ``all_to_all`` and the ``drain`` closure decodes them — one wrap
  covers both schedules (two-phase + TAM stage 2), both directions,
  every ring depth, and the serial and pipelined loops;
* the host executor (``checkpoint.host_exec``): per-message numpy byte
  encoding, with the encoded size charged against the alpha-beta model
  and the achieved compression ratio reported in ``IOTimings``;
* ``hierarchical.compressed_psum``: the error-feedback int8 slow-hop
  compression that motivated the seam is now a consumer of the same
  ``ef-int8`` codec (the arithmetic moved here from
  ``hierarchical._int8_encode/_decode``).

Two codec families:

* **lossless byte codecs** (``lossless = True``) — ``identity`` and
  ``rle`` (zero-run encoding for sparse checkpoint pages). Byte-exact:
  every byte-identity harness must pass unchanged with these enabled.
  The SPMD realization is static-shape (XLA needs fixed buffers), so
  ``rle`` lowers to a zero-skipping compaction ``(values, positions)``
  of the same capacity — the wire VOLUME saving is modeled (and
  measured on the host path), the transform itself is exact.
* **lossy error-feedback codecs** (``lossless = False``) — ``ef-int8``
  quantizes float payloads to int8 with a per-row scale and feeds the
  quantization error back into the next round's send (EF-SGD,
  Karimireddy et al. 2019). The residual is codec STATE: it rides the
  round engine's pipeline ring exactly like the in-flight ``rx``
  windows do (``jax_encode(data, state) -> (wire, state)``).

Adding a codec: subclass :class:`Codec`, implement the four hooks, and
``register()`` it — the plan IR (``IOPlan.slow_hop_codec``), both
executors, and the cost model pick it up by name.
"""
from __future__ import annotations

import numpy as np

# Wire-format constants of the zero-run byte codec: a u32 raw-length
# header, then (u32 literal_len, u32 zero_len, literal bytes) records.
_HDR = np.dtype("<u4")
RLE_HEADER_BYTES = 4
RLE_RECORD_BYTES = 8
RLE_MIN_RUN = 16      # zero runs shorter than a record header stay literal


class Codec:
    """One slow-hop wire transform.

    name:      registry key (``IOPlan.slow_hop_codec`` value).
    lossless:  byte-exact round trip — the byte-identity harnesses run
               with these enabled; lossy codecs are rejected by the
               host write path (its payloads are raw bytes).
    stateful:  carries residual state through the round loop
               (``state`` argument of :meth:`jax_encode`).

    The numpy hooks (:meth:`encode_bytes` / :meth:`decode_bytes`) move
    REAL bytes on the host executor; the jax hooks
    (:meth:`jax_encode` / :meth:`jax_decode`) transform the static-shape
    per-destination payload buckets around the SPMD ``all_to_all``.
    """

    name: str = "abstract"
    lossless: bool = True
    stateful: bool = False
    # static wire size of one jax-encoded payload element, in UNITS OF
    # the payload element (XLA buffers cannot shrink, so the ring
    # carries this much per element regardless of achieved
    # compression); rounds.peak_aggregator_buffer_elems charges it
    jax_wire_overhead: float = 1.0

    # ---- host (numpy) side: real byte movement -----------------------
    def encode_bytes(self, buf: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def decode_bytes(self, wire: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # ---- SPMD (jax) side: static-shape window transform --------------
    def jax_init_state(self, shape, dtype):
        """Residual state carried through the round loop (stateless
        codecs carry the empty pytree)."""
        return ()

    def jax_encode(self, data, state):
        """``data [..., cap] -> (wire_parts tuple, new_state)``. Every
        wire part keeps the leading (destination) axis so the round
        engine can ``all_to_all`` each part."""
        raise NotImplementedError

    def jax_decode(self, parts):
        """Inverse of :meth:`jax_encode`'s wire tuple."""
        raise NotImplementedError

    # ---- modeling ----------------------------------------------------
    def modeled_ratio(self, zero_fraction: float,
                      total_bytes: float) -> float:
        """Expected raw/wire ratio for a payload with the given zero
        fraction (drives the cost model's slow-hop discount and the
        ``"auto"`` codec resolution)."""
        return 1.0


class IdentityCodec(Codec):
    """Passthrough — the codec seam with zero transform (useful to
    measure the seam's own overhead and as the registry default)."""

    name = "identity"
    lossless = True

    def encode_bytes(self, buf):
        return np.asarray(buf, np.uint8)

    def decode_bytes(self, wire):
        return np.asarray(wire, np.uint8)

    def jax_encode(self, data, state):
        return (data,), state

    def jax_decode(self, parts):
        (data,) = parts
        return data


class RleCodec(Codec):
    """Zero-run byte codec for sparse checkpoint pages.

    Host wire format (byte-exact for ARBITRARY input, including empty
    and all-zero): a little-endian u32 raw length, then records of
    ``(u32 literal_len, u32 zero_len, literal bytes)``. Only zero runs
    of at least ``RLE_MIN_RUN`` bytes are collapsed — shorter runs ride
    inside literals, so incompressible payloads pay only the constant
    header + one record (never a blow-up proportional to content).

    SPMD realization: XLA buffers are static, so the jax hooks perform
    the zero-SKIPPING form of the same codec — per destination row the
    nonzero elements are compacted to the front with their positions
    (``(values, positions)``, both at bucket capacity). The transform
    is exact for every dtype (the byte-identity harnesses assert it at
    every ring depth); the wire-volume saving it stands for is what the
    cost model discounts and the host path measures.
    """

    name = "rle"
    lossless = True
    jax_wire_overhead = 2.0      # (values, int32 positions) per element

    def encode_bytes(self, buf):
        buf = np.ascontiguousarray(np.asarray(buf, np.uint8))
        n = buf.size
        header = np.array([n], _HDR).view(np.uint8)
        if n == 0:
            return header.copy()
        z = buf == 0
        d = np.diff(z.astype(np.int8))
        starts = np.flatnonzero(d == 1) + 1
        ends = np.flatnonzero(d == -1) + 1
        if z[0]:
            starts = np.concatenate([[0], starts])
        if z[-1]:
            ends = np.concatenate([ends, [n]])
        runlen = ends - starts
        keep = runlen >= RLE_MIN_RUN
        gs, ge, gl = starts[keep], ends[keep], runlen[keep]
        lit_starts = np.concatenate([[0], ge])
        lit_ends = np.concatenate([gs, [n]])
        zero_lens = np.concatenate([gl, [0]])
        chunks = [header]
        for ls, le, zl in zip(lit_starts, lit_ends, zero_lens):
            if le == ls and zl == 0:
                continue              # empty trailing record
            chunks.append(np.array([le - ls, zl], _HDR).view(np.uint8))
            chunks.append(buf[ls:le])
        return np.concatenate(chunks)

    def decode_bytes(self, wire):
        wire = np.ascontiguousarray(np.asarray(wire, np.uint8))
        n = int(wire[:4].view(_HDR)[0])
        out = np.zeros(n, np.uint8)
        pos, w = 0, 4
        while pos < n:
            nlit, nzero = (int(v) for v in wire[w:w + 8].view(_HDR))
            w += 8
            out[pos:pos + nlit] = wire[w:w + nlit]
            w += nlit
            pos += nlit + nzero
        return out

    def jax_encode(self, data, state):
        import jax.numpy as jnp
        nz = data != 0
        # stable argsort of (zero-ness) compacts nonzeros to the front
        # in position order
        order = jnp.argsort(jnp.where(nz, 0, 1).astype(jnp.int32),
                            axis=-1, stable=True)
        vals = jnp.take_along_axis(data, order, axis=-1)
        live = jnp.take_along_axis(nz, order, axis=-1)
        pos = jnp.where(live, order, -1).astype(jnp.int32)
        vals = jnp.where(live, vals, jnp.zeros((), data.dtype))
        return (vals, pos), state

    def jax_decode(self, parts):
        import jax.numpy as jnp
        vals, pos = parts
        cap = vals.shape[-1]
        lead = vals.shape[:-1]
        v2 = vals.reshape(-1, cap)
        p2 = pos.reshape(-1, cap)
        rows = jnp.arange(v2.shape[0], dtype=jnp.int32)[:, None]
        idx = jnp.where(p2 >= 0, p2, cap)        # invalid -> pad slot
        out = jnp.zeros((v2.shape[0], cap + 1), vals.dtype)
        out = out.at[rows, idx].set(v2)
        return out[:, :cap].reshape(*lead, cap)

    def modeled_ratio(self, zero_fraction, total_bytes):
        total = max(float(total_bytes), 1.0)
        zf = min(max(float(zero_fraction), 0.0), 1.0)
        wire = (total * (1.0 - zf)
                + RLE_HEADER_BYTES + 2 * RLE_RECORD_BYTES)
        return max(total / wire, 1e-9)


def int8_encode(x):
    """Error-feedback int8 quantization over the LAST axis: per-row
    symmetric scale ``max|x| / 127``. Returns ``(q int8, scale)`` with
    ``scale`` shaped like ``x`` minus its last axis. The flat (1-D)
    form is what ``hierarchical.compressed_psum`` always used — the
    arithmetic moved here so the round engine and the psum share one
    implementation."""
    import jax.numpy as jnp
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decode(q, scale):
    import jax.numpy as jnp
    return q.astype(jnp.float32) * scale[..., None]


class EfInt8Codec(Codec):
    """Error-feedback int8 for float payloads (lossy).

    Each round's send is quantized to int8 with a per-destination-row
    scale; the quantization error ``x - decode(encode(x))`` is the
    codec's STATE, added to the next round's send before quantizing
    (EF-SGD). The round engine carries that residual through its
    pipeline ring exactly like the in-flight ``rx`` windows, so the
    error SUMMED over the stream telescopes to a single round's
    quantization error (tests/test_codec.py asserts the 5e-2 band
    ``spmd_checks`` uses for ``compressed_psum``) instead of growing
    with the round count. 4x fewer slow-hop bytes plus one f32 scale
    per destination row.

    What feedback buys depends on the consumer. For ACCUMULATION
    semantics (``hierarchical.compressed_psum``: the same gradient
    stream is reduced step after step) the telescoping is the
    convergence guarantee. For a pure WRITE (each element lands once,
    rounds cover disjoint windows) nothing downstream sums the stream:
    element-wise the file sees ``x + r_t - r_{t+1}`` — bounded at ~2x
    the residual-free quantization step, never compensated. The
    residual still rides the ring because that is the codec contract
    (state advances in round order at every depth); a lossy write is a
    caller's explicit accuracy trade either way.
    """

    name = "ef-int8"
    lossless = False
    stateful = True
    jax_wire_overhead = 0.3      # int8 codes (1/4 of f32) + per-row
    # scale + the f32 residual rides OUTSIDE the ring count (one copy,
    # not one per in-flight window)

    def encode_bytes(self, buf):   # pragma: no cover - guarded by host
        raise TypeError(
            "ef-int8 is a lossy float codec; the host write path moves "
            "raw bytes — use a lossless codec ('identity', 'rle')")

    decode_bytes = encode_bytes

    def jax_init_state(self, shape, dtype):
        import jax.numpy as jnp
        if not jnp.issubdtype(dtype, jnp.floating):
            raise TypeError(
                f"slow_hop_codec='ef-int8' quantizes float payloads; "
                f"got dtype {np.dtype(dtype)}")
        return jnp.zeros(shape, jnp.float32)

    def jax_encode(self, data, state):
        import jax.numpy as jnp
        x = data.astype(jnp.float32)
        if not isinstance(state, tuple):   # residual rides along
            x = x + state
        q, scale = int8_encode(x)
        decoded = int8_decode(q, scale)
        new_state = state if isinstance(state, tuple) else x - decoded
        return (q, scale), new_state

    def jax_decode(self, parts):
        q, scale = parts
        return int8_decode(q, scale)

    def modeled_ratio(self, zero_fraction, total_bytes):
        return 4.0      # f32 -> int8 (+ one scale per row, amortized)


_REGISTRY: dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    """Add a codec to the registry (last registration of a name wins —
    deliberate, so tests/experiments can shadow a builtin)."""
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    """Look up a codec by name; raises ``ValueError`` with the known
    names so a typo dies at plan time, not mid-exchange."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown slow_hop_codec {name!r}; "
            f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_codecs() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def lossless_codecs() -> tuple[str, ...]:
    return tuple(sorted(n for n, c in _REGISTRY.items() if c.lossless))


register(IdentityCodec())
register(RleCodec())
register(EfInt8Codec())


def zero_fraction(bufs) -> float:
    """Fraction of zero bytes across an iterable of uint8 payloads —
    the measurable statistic behind ``rle``'s modeled ratio (sparse
    checkpoint pages are zero-dominated)."""
    total = zeros = 0
    for b in bufs:
        b = np.asarray(b)
        total += b.size
        zeros += int((b == 0).sum())
    return zeros / total if total else 0.0
