"""Unified collective-I/O plan IR (the schedule, compiled once).

The paper's contribution is a *schedule*: which requests move on which
hop, in which round, bounded by the collective buffer. Before this
module, four entry points (``core.twophase``, ``core.tam``,
``core.rounds``, ``checkpoint.host_io``) each re-derived domain
partitioning, stripe splitting, window math, and round accounting —
every new capability had to be built 2-4 times. Following ROMIO's split
of access-pattern analysis from data movement (Thakur et al.) and the
intra/inter-node layering of the source paper, the schedule is now
compiled ONCE into an explicit, immutable :class:`IOPlan` and executed
by interchangeable backends:

* the **SPMD executor** (``core.spmd_exec``) — shard_map + the
  depth-k round ring of ``core.rounds``;
* the **host executor** (``checkpoint.host_exec``) — numpy data
  movement + modeled alpha-beta timing + drain threads.

``make_twophase_*`` / ``make_tam_*`` / ``HostCollectiveIO`` keep their
signatures as thin wrappers over plan + execute. See ARCHITECTURE.md
for the layer diagram and how to add a backend or a per-round
transform (e.g. the future slow-hop compression hook).

What the IR captures
--------------------
* **File-domain assignment** — ``layout`` + ``n_aggregators``:
  aggregator g owns domain ``[g * domain_len, (g+1) * domain_len)`` of
  the (possibly striped) file.
* **Round schedule** — ``cb`` elements per aggregator per round,
  ``n_rounds = domain_len / cb`` (:class:`RoundScheduler`, which lives
  here now). The single-shot exchange is the degenerate 1-round plan
  (``cb == domain_len``) — there is no separate single-shot code path
  anymore.
* **Aggregation topology** — ``method``: ``"twophase"`` (flat
  all-to-many) or ``"tam"`` (two-stage intra/inter-node); ``"auto"``
  resolves via the cost model at plan time.
* **Direction** — ``"write"`` or ``"read"``.
* **Pipeline depth** — ``pipeline_depth`` in-flight cb windows
  (1 = serial, 2 = double buffer, k = ring); ``"auto"`` resolves
  jointly with cb via ``cost_model.optimal_cb_and_depth``.
* **Static capacities** — per-rank request/payload capacities the SPMD
  backend needs for fixed shapes (the host backend, being numpy, reads
  them as documentation only).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.domains import FileLayout
from repro.core.requests import ELEM_BYTES


@dataclass(frozen=True)
class IOConfig:
    """Static capacities + schedule knobs for the collective-I/O paths.

    req_cap:        per-rank request-list capacity.
    data_cap:       per-rank payload capacity (elements).
    coalesce_cap:   post-coalesce metadata capacity forwarded by a local
                    aggregator (TAM stage 2). Patterns that coalesce well
                    (BTIO/S3D-like) allow coalesce_cap << lmem * req_cap —
                    that is TAM's inter-node metadata saving.
    cb_buffer_size: aggregator collective-buffer elements per round
                    (ROMIO's romio_cb_buffer_size). ``None`` = one round
                    covering the whole domain (the single-shot
                    schedule); ``"auto"`` lets ``cost_model.optimal_cb``
                    pick the size minimizing the modeled (pipelined)
                    total at plan time.
    pipeline:       pipeline the round loop — round t+1's exchange
                    overlaps round t's window drain (byte-identical;
                    see ``repro.core.rounds``).
    pipeline_depth: in-flight cb windows when ``pipeline`` is set
                    (ignored otherwise): 2 = the classic double buffer,
                    k = a depth-k ring holding k windows at k x the
                    window memory; ``"auto"`` picks depth jointly with
                    cb via ``cost_model.optimal_cb_and_depth``.
    axis_names:     (node, lagg, lmem) mesh-axis names.
    slow_hop_codec: per-round wire transform of the slow-axis payload
                    (``core.codec`` registry: "identity", "rle",
                    "ef-int8"). ``None`` = no transform; ``"auto"``
                    enables the lossless byte codec when the modeled
                    slow-hop saving beats the encode cost
                    (``cost_model.slow_hop_codec_gain``).
    placement:      aggregator placement (``core.placement``): which
                    slot serves each file domain, as a policy name
                    ("packed", "spread", "node_balanced"), an explicit
                    permutation tuple, or ``"auto"`` (argmin of
                    ``cost_model.placement_cost``). ``None`` = off —
                    the legacy identity path.
    kernel_fusion:  per-round kernel lowering (``passes.lower_kernels``):
                    ``"fused_round"`` drains each write window through
                    ONE Pallas kernel (sort + coalesce + pack + codec
                    zero-skip encode, ``kernels.fused_round``) instead
                    of three separate kernel launches / HBM round
                    trips; ``None`` = the unfused jnp path. Byte
                    -identical by contract (rounds_checks fuzz).
    transport:      which executor ships the exchange's bytes
                    (``core.transport`` registry, validated by
                    ``passes.resolve_transport``): ``"mp"`` = the real
                    multi-process backend (``checkpoint.mp_exec``) —
                    forked worker processes, shared-memory arenas for
                    the intra-node fast hop, localhost sockets for the
                    inter-node slow hop, wall-clock round timings;
                    ``None`` = the in-process executors with modeled
                    time. Byte-identical by contract (rounds_checks
                    fuzz vs the host oracle).
    """

    req_cap: int
    data_cap: int
    coalesce_cap: int | None = None
    cb_buffer_size: int | str | None = None
    pipeline: bool = False
    pipeline_depth: int | str = 2
    axis_names: tuple[str, str, str] = ("node", "lagg", "lmem")
    slow_hop_codec: str | None = None
    placement: str | tuple[int, ...] | None = None
    kernel_fusion: str | None = None
    transport: str | None = None


@dataclass(frozen=True)
class RoundScheduler:
    """Static partition of each aggregator's file domain into rounds.

    layout:         striped file layout (element units).
    n_aggregators:  global aggregators (== slow-axis size in SPMD).
    cb_buffer_size: collective-buffer elements per aggregator per round;
                    ``None`` = one round == the single-shot behavior.
    """

    layout: FileLayout
    n_aggregators: int
    cb_buffer_size: int | None = None

    def __post_init__(self):
        if self.layout.file_len % self.n_aggregators:
            raise ValueError("file_len must divide evenly among aggregators")
        cb = self.cb
        if self.domain_len % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must divide domain_len "
                f"{self.domain_len} (stripe-aligned rounds)")
        s = self.layout.stripe_size
        if cb % s and s % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must align with stripe_size {s}")

    @property
    def domain_len(self) -> int:
        return self.layout.file_len // self.n_aggregators

    @property
    def cb(self) -> int:
        return (self.cb_buffer_size if self.cb_buffer_size is not None
                else self.domain_len)

    @property
    def n_rounds(self) -> int:
        return -(-self.domain_len // self.cb)

    def max_spans(self, data_cap: int) -> int:
        """Windows one request (length <= data_cap) can straddle."""
        return data_cap // self.cb + 2

    def window_of(self, offsets):
        """Round in which an offset is exchanged (domain-local window)."""
        return (offsets % self.domain_len) // self.cb


@dataclass(frozen=True)
class IOPlan:
    """The compiled schedule of one collective-I/O operation.

    Immutable and hashable: two entry points given the same workload
    must compile the SAME plan (asserted by tests/test_plan.py), which
    is what guarantees the SPMD and host executors run one schedule.

    layout / n_aggregators: file-domain assignment (aggregator g owns
        the contiguous domain-local span of its stripes).
    cb / n_rounds: the round window schedule; ``cb == domain_len`` is
        the single-shot (1-round) schedule.
    method: "twophase" | "tam" (resolved — never "auto" here).
    direction: "write" | "read".
    pipeline_depth: resolved in-flight window count (1 = serial).
    req_cap / data_cap / coalesce_cap: static capacities for the SPMD
        backend; advisory for the host backend (numpy is dynamic).
    tam_read_fallback: True when method == "tam" and direction ==
        "read": under SPMD every rank participates in every collective
        hop, so a TAM read lowers to the same slow-axis window
        broadcast as the two-phase read — the plan records the fallback
        EXPLICITLY instead of silently aliasing (``make_tam_read``
        asserts it; see that docstring for why the paths coincide).
    slow_hop_codec: resolved per-round wire codec (never "auto" here;
        ``None`` = no transform). Both executors read it — the round
        engine wraps the ``exchange``/``drain`` pair, the host
        executor charges encoded bytes — so one plan field governs the
        wire format everywhere (ARCHITECTURE.md § slow-hop codec).
    placement: resolved aggregator placement (never "auto" or a policy
        name here): ``placement[g]`` is the slot serving domain ``g``
        (``core.placement``), or ``None`` when placement is off. Both
        executors read it — the SPMD round engine routes destinations
        through the permutation and permutes the domain shards back,
        the host executor charges the fast-hop/slow-hop split the
        placement induces — so one plan field governs where aggregation
        lands everywhere (ARCHITECTURE.md § sessions and placement).
    kernel_fusion: resolved per-round kernel lowering (the
        ``lower_kernels`` pass): ``"fused_round"`` = the single Pallas
        drain kernel of ``kernels.fused_round`` on the write drain, and
        the ``zero_skip_decode`` kernel replacing the rle decode
        scatter on the read fetch; ``None`` = the unfused jnp path.
        Only the SPMD backend consumes it (the host executor is numpy).
    transport: resolved byte-moving backend (the ``resolve_transport``
        pass; never an unregistered name here): ``"mp"`` dispatches
        ``checkpoint.host_io`` writes/reads to the multi-process
        executor (``checkpoint.mp_exec`` — real processes, shm fast
        hop, socket slow hop, measured wall-clock rounds); ``None`` =
        the in-process executors. Part of the session plan-cache key,
        and ``IOTimings.transport`` records which backend produced a
        measurement so feedback never crosses executors.
    """

    layout: FileLayout
    n_aggregators: int
    cb: int
    n_rounds: int
    method: str
    direction: str
    pipeline_depth: int
    req_cap: int
    data_cap: int
    coalesce_cap: int | None
    axis_names: tuple[str, str, str]
    tam_read_fallback: bool = False
    slow_hop_codec: str | None = None
    placement: tuple[int, ...] | None = None
    kernel_fusion: str | None = None
    transport: str | None = None

    @property
    def domain_len(self) -> int:
        return self.layout.file_len // self.n_aggregators

    @property
    def in_flight_windows(self) -> int:
        """Window buffers live at once (the k x memory price)."""
        return max(1, min(self.pipeline_depth, self.n_rounds))

    def scheduler(self) -> RoundScheduler:
        return RoundScheduler(self.layout, self.n_aggregators, self.cb)

    def describe(self) -> str:
        """One line per field (plus the derived schedule numbers) —
        the human-readable form pass traces and test failure messages
        print. Field order follows the dataclass so two describes line
        up for eyeball comparison; :func:`plan_diff` gives the
        field-level delta."""
        from dataclasses import fields
        lines = ["IOPlan:"]
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name == "layout":
                v = (f"FileLayout(stripe_size={v.stripe_size}, "
                     f"stripe_count={v.stripe_count}, "
                     f"file_len={v.file_len})")
                lines.append(f"  {f.name:<17} = {v}")
            else:
                lines.append(f"  {f.name:<17} = {v!r}")
        if isinstance(self.cb, int) and self.cb > 0:
            lines.append(f"  {'domain_len':<17} = {self.domain_len!r}"
                         " (derived)")
            lines.append(f"  {'in_flight_windows':<17} = "
                         f"{self.in_flight_windows!r} (derived)")
        return "\n".join(lines)


def plan_diff(a: IOPlan, b: IOPlan) -> str:
    """Field-level textual diff of two plans: one ``field: a -> b``
    line per differing field, ``""`` when the plans are equal. Wired
    into pass tracing (``passes.trace_report``) and property-test
    failure messages so a bad rewrite names the field it broke."""
    from dataclasses import fields
    lines = []
    for f in fields(IOPlan):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va != vb:
            lines.append(f"{f.name}: {va!r} -> {vb!r}")
    return "\n".join(lines)


def _default_workload(layout: FileLayout, cfg: IOConfig, n_aggregators: int,
                      n_nodes: int, n_ranks: int, unit_bytes: int):
    """Cost-model Workload for plan-time auto resolution when the caller
    did not supply a measured one (mirrors the PR-2 ``"auto"`` cb
    resolution: byte units, k = req_cap, coalesce ratio from the
    configured coalesce capacity)."""
    from repro.core import cost_model as cm
    s = max(layout.stripe_size, 1)
    coalesce_ratio = 1.0
    if cfg.coalesce_cap and cfg.req_cap:
        # one local aggregator coalesces its whole group's request
        # lists (~n_ranks/n_nodes of them) down to <= coalesce_cap, so
        # the modeled k'/k accounts for the per-LA fan-in, not just one
        # rank's list
        group = max(n_ranks // max(n_nodes, 1), 1)
        coalesce_ratio = min(1.0,
                             cfg.coalesce_cap / (group * cfg.req_cap))
    return cm.Workload(
        P=n_ranks, nodes=n_nodes, P_G=n_aggregators,
        k=float(max(cfg.req_cap, 1)),
        total_bytes=float(max(layout.file_len, 1) * unit_bytes),
        stripe_size=float(s * unit_bytes),
        coalesce_ratio=coalesce_ratio,
        overlap=1.0 if cfg.pipeline else 0.0)


def resolve_method(workload, machine=None) -> str:
    """``method="auto"``: pick two-phase vs TAM for a workload by the
    modeled totals (``tam_cost`` at the optimal P_L vs
    ``twophase_cost``). Shared by :func:`compile_plan` and the host
    planner so the choice cannot drift between entry points."""
    from repro.core import cost_model as cm
    machine = machine or cm.Machine()
    tam_best = cm.optimal_PL(workload, machine)[1]
    return ("tam" if tam_best.total < cm.twophase_cost(workload,
                                                       machine).total
            else "twophase")


def resolve_slow_hop_codec(workload, machine=None) -> str | None:
    """``slow_hop_codec="auto"``: enable the lossless byte codec when
    the modeled slow-hop saving beats the encode cost
    (``cost_model.slow_hop_codec_gain`` at the workload's measured
    ``slow_hop_ratio`` — the host path estimates it from the payload's
    zero fraction). Auto never picks a LOSSY codec: losing bits is a
    caller decision (``slow_hop_codec="ef-int8"`` explicitly), not a
    tuning knob. Shared by :func:`compile_plan` and the host planner."""
    from repro.core import cost_model as cm
    machine = machine or cm.Machine()
    if workload.slow_hop_ratio <= 1.0:
        return None
    gain = cm.slow_hop_codec_gain(workload, machine)
    return "rle" if gain > 0.0 else None


def _legal_cb_candidates(domain_len: int, stripe: int, unit_bytes: int):
    """RoundScheduler-legal cb sizes in BYTES for the autotuner."""
    from repro.core import cost_model as cm
    cands = tuple(c for c in cm.cb_candidates(domain_len, stripe)
                  if domain_len % c == 0 and (c % stripe == 0
                                              or stripe % c == 0))
    cands = cands or (domain_len,)
    return tuple(c * unit_bytes for c in cands)


def compile_plan(layout: FileLayout, cfg: IOConfig, *,
                 n_aggregators: int, n_nodes: int, n_ranks: int,
                 method: str = "twophase", direction: str = "write",
                 machine=None, workload=None,
                 unit_bytes: int = ELEM_BYTES, trace: bool = False):
    """Compile one collective-I/O schedule into an :class:`IOPlan` by
    running the pass pipeline of ``repro.core.passes``.

    This is THE planner: both executors' entry points
    (``twophase.plan_for`` / ``tam`` wrappers and
    ``HostCollectiveIO.plan_for``) route through it, so all domain /
    stripe / window / round derivation lives here and nowhere else.
    Every knob resolution is one named, pure ``IOPlan -> IOPlan`` pass
    (normalize_layout -> resolve_codec -> resolve_method ->
    resolve_placement -> resolve_cb_and_depth -> coalesce_windows ->
    validate -> lower_kernels; see ``core/passes.py`` for why that
    order). The pipeline is deterministic — the session-cache-key
    contract (tests/test_plan_property.py).

    layout:        striped file layout. Units are the caller's (elements
                   on the SPMD side, bytes on the host side) — the plan
                   is unit-agnostic; ``unit_bytes`` converts to bytes
                   only where the cost model needs absolute sizes.
    n_aggregators: global aggregators (slow-axis size for SPMD,
                   stripe_count for the host path).
    method:        "twophase" | "tam" | "auto" — auto compares the
                   modeled totals (``tam_cost`` at the optimal P_L vs
                   ``twophase_cost``) for the workload and picks.
    workload:      optional measured ``cost_model.Workload`` driving
                   the auto resolutions; derived from cfg + layout when
                   absent.
    machine:       optional ``cost_model.Machine`` calibration.
    trace:         when True, return ``(plan, snapshots)`` where
                   ``snapshots`` is one ``(pass_name, plan)`` pair per
                   pass — diff adjacent snapshots with
                   :func:`plan_diff` (or ``passes.trace_report``) to
                   see exactly which pass rewrote which field.

    Raises ``ValueError`` for schedules violating the round-partition
    invariants (uneven domains, non-aligned cb) — compile time, not run
    time, is where a bad schedule should die.
    """
    from repro.core import cost_model as cm
    from repro.core import passes as passes_mod
    machine = machine or cm.Machine()
    w = workload if workload is not None else _default_workload(
        layout, cfg, n_aggregators, n_nodes, n_ranks, unit_bytes)
    ctx = passes_mod.PlanContext(cfg=cfg, workload=w, machine=machine,
                                 n_nodes=n_nodes, n_ranks=n_ranks,
                                 unit_bytes=unit_bytes)
    plan = passes_mod.initial_plan(layout, cfg,
                                   n_aggregators=n_aggregators,
                                   method=method, direction=direction)
    snapshots: list | None = [] if trace else None
    plan = passes_mod.run_passes(plan, ctx, trace=snapshots)
    return (plan, tuple(snapshots)) if trace else plan


def resolve_cb_buffer_size(layout: FileLayout, n_nodes: int, n_ranks: int,
                           cfg: IOConfig, machine=None) -> IOConfig:
    """Resolve ``cb_buffer_size == "auto"`` to concrete elements.

    Kept as the public PR-2 entry point; it is now a thin view over
    :func:`compile_plan`'s cb resolution (one aggregator per node)."""
    if cfg.cb_buffer_size != "auto":
        return cfg
    plan = compile_plan(layout, cfg, n_aggregators=n_nodes,
                        n_nodes=n_nodes, n_ranks=n_ranks,
                        machine=machine)
    return replace(cfg, cb_buffer_size=plan.cb)
