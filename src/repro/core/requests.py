"""Request model for collective I/O.

An I/O request list is the JAX analogue of ROMIO's flattened MPI file
view: a list of (file offset, length) pairs, sorted in monotonically
nondecreasing offset order per rank (required by MPI_File_write_all and
relied upon by the paper's heap merge-sort).

XLA requires static shapes, so request lists are fixed-capacity arrays
with a ``count`` scalar; unused slots are padded with ``PAD_OFFSET``
(which sorts to the end) and zero length.

Units: offsets and lengths are in ELEMENTS (4-byte words), not bytes.
TPU Pallas has no native int64, so offsets are int32 — one "file" (a
serialized checkpoint byte-space) addresses up to 2^31 elements = 8 GiB.
Larger paper-scale patterns (up to 200 GiB) are handled by the analytical
cost model plus scaled empirical runs (see DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

ELEM_BYTES = 4  # element = one 4-byte word
PAD_OFFSET = np.int32(2**31 - 1)


class RequestList(NamedTuple):
    """Fixed-capacity list of (offset, length) pairs, offset-sorted.

    offsets: int32[cap] — element offsets into the file; PAD_OFFSET pad.
    lengths: int32[cap] — element counts; 0 for padding slots.
    count:   int32 scalar — number of valid leading entries.
    """

    offsets: jax.Array
    lengths: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.offsets.shape[-1]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count

    def total_elems(self) -> jax.Array:
        return jnp.sum(self.lengths, dtype=jnp.int32)


def make_requests(offsets, lengths, capacity: int | None = None) -> RequestList:
    """Build a RequestList from (possibly shorter) offset/length arrays."""
    offsets = jnp.asarray(offsets, dtype=jnp.int32)
    lengths = jnp.asarray(lengths, dtype=jnp.int32)
    n = offsets.shape[0]
    cap = capacity if capacity is not None else n
    if cap < n:
        raise ValueError(f"capacity {cap} < number of requests {n}")
    off = jnp.full((cap,), PAD_OFFSET, dtype=jnp.int32).at[:n].set(offsets)
    ln = jnp.zeros((cap,), dtype=jnp.int32).at[:n].set(lengths)
    return RequestList(off, ln, jnp.int32(n))


def empty_requests(capacity: int) -> RequestList:
    return RequestList(
        jnp.full((capacity,), PAD_OFFSET, dtype=jnp.int32),
        jnp.zeros((capacity,), dtype=jnp.int32),
        jnp.int32(0),
    )


def is_sorted(r: RequestList) -> jax.Array:
    """True if valid entries are in nondecreasing offset order."""
    off = jnp.where(r.valid_mask(), r.offsets, PAD_OFFSET)
    return jnp.all(off[:-1] <= off[1:])


def mask_invalid(r: RequestList) -> RequestList:
    """Force padding convention on all slots >= count."""
    m = r.valid_mask()
    return RequestList(
        jnp.where(m, r.offsets, PAD_OFFSET),
        jnp.where(m, r.lengths, 0),
        r.count,
    )


def split_at_stripes(r: RequestList, stripe_size: int, max_spans: int) -> RequestList:
    """Split every request at stripe boundaries.

    After splitting, each request lies entirely within one stripe, which
    is what lets a request be routed to exactly one global aggregator
    (ROMIO splits requests across file-domain boundaries the same way).
    Each input request may span at most ``max_spans`` stripes; output
    capacity is cap * max_spans.
    """
    cap = r.capacity
    o = r.offsets.astype(jnp.int32)
    l = r.lengths
    # span j of request i covers [max(o, (s0+j)*S), min(o+l, (s0+j+1)*S))
    s0 = o // stripe_size
    j = jnp.arange(max_spans, dtype=jnp.int32)[None, :]
    lo = jnp.maximum(o[:, None], (s0[:, None] + j) * stripe_size)
    hi = jnp.minimum((o + l)[:, None], (s0[:, None] + j + 1) * stripe_size)
    ln = jnp.maximum(hi - lo, 0)
    valid = (ln > 0) & r.valid_mask()[:, None]
    off_flat = jnp.where(valid, lo, PAD_OFFSET).reshape(-1)
    len_flat = jnp.where(valid, ln, 0).reshape(-1)
    # compact: stable sort by (invalid, original order) keeps offset order,
    # since spans are generated in nondecreasing offset order already.
    key = jnp.where(len_flat > 0, 0, 1).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    return RequestList(
        off_flat[order],
        len_flat[order],
        jnp.sum(valid, dtype=jnp.int32),
    )


def to_numpy(r: RequestList) -> tuple[np.ndarray, np.ndarray]:
    """Return the valid (offsets, lengths) as host numpy arrays."""
    n = int(r.count)
    return np.asarray(r.offsets[:n]), np.asarray(r.lengths[:n])
