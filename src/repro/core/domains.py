"""File-domain partitioning (Lustre-style striping).

ROMIO on Lustre selects P_G = stripe_count global aggregators and builds
a one-to-one mapping between aggregators and OSTs: aggregator g owns all
stripes s with ``s % P_G == g``. The two-phase I/O runs in rounds; in
round t aggregator g writes stripe ``t * P_G + g``.

Here the "file" is the serialized byte-space of a checkpoint (or any
collective buffer); stripes partition it identically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FileLayout(NamedTuple):
    """Striped layout of a file of ``file_len`` elements.

    stripe_size:  elements per stripe.
    stripe_count: number of OSTs == number of global aggregators P_G.
    file_len:     total elements (padded to a stripe multiple by callers
                  that need an exact partition).
    """

    stripe_size: int
    stripe_count: int
    file_len: int

    @property
    def num_stripes(self) -> int:
        return -(-self.file_len // self.stripe_size)

    @property
    def num_rounds(self) -> int:
        """Rounds of two-phase I/O (each aggregator writes one stripe/round)."""
        return -(-self.num_stripes // self.stripe_count)

    @property
    def domain_len(self) -> int:
        """Elements owned by one aggregator (its file domain), padded."""
        return self.num_rounds * self.stripe_size


def owner_of(layout: FileLayout, offsets: jax.Array) -> jax.Array:
    """Global aggregator owning each (stripe-split) request offset."""
    return (offsets // layout.stripe_size) % layout.stripe_count


def round_of(layout: FileLayout, offsets: jax.Array) -> jax.Array:
    """Two-phase round in which each offset is written."""
    return (offsets // layout.stripe_size) // layout.stripe_count


def to_domain_local(layout: FileLayout, offsets: jax.Array) -> jax.Array:
    """Map file offsets to positions inside the owner's file domain.

    An aggregator's domain is the concatenation of its stripes in round
    order, so the domain-local position of offset o is
    ``round(o) * stripe_size + (o % stripe_size)``.
    """
    within = offsets % layout.stripe_size
    return round_of(layout, offsets) * layout.stripe_size + within


def from_domain_local(layout: FileLayout, agg: int, local: jax.Array) -> jax.Array:
    """Inverse of :func:`to_domain_local` for aggregator ``agg``."""
    rnd = local // layout.stripe_size
    within = local % layout.stripe_size
    return (rnd * layout.stripe_count + agg) * layout.stripe_size + within


def contiguous_layout(file_len: int, num_aggregators: int) -> FileLayout:
    """Non-striped fallback: one contiguous domain per aggregator.

    Equivalent to a stripe size of ceil(file_len / P_G) — used when the
    backing store is not striped (e.g. one file segment per host).
    """
    stripe = -(-file_len // num_aggregators)
    return FileLayout(stripe_size=stripe, stripe_count=num_aggregators,
                      file_len=file_len)
