"""Static-shape request/data routing primitives for SPMD collective I/O.

MPI two-phase I/O routes each request to the global aggregator owning its
file domain with point-to-point sends. Under SPMD every device runs the
same program with static shapes, so routing becomes: bucket requests (and
their payload elements) by destination into fixed-capacity per-destination
buckets, then exchange buckets with ``lax.all_to_all`` over a mesh axis.

Bucketing preserves offset order inside each bucket (stable grouping of an
offset-sorted input), which is what lets downstream aggregators merge-sort
cheaply and coalesce effectively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.requests import PAD_OFFSET, RequestList, mask_invalid


class Buckets(NamedTuple):
    """Per-destination request buckets plus packed payload buckets.

    offsets: int32[n_dest, req_cap]
    lengths: int32[n_dest, req_cap]
    counts:  int32[n_dest]
    data:    dtype[n_dest, data_cap] — payload elements, packed in request
             order within each bucket (receiver recomputes starts from
             lengths).
    dropped_requests / dropped_elems: int32 scalars — overflow accounting
             (capacity misconfiguration is observable, never silent).
    """

    offsets: jax.Array
    lengths: jax.Array
    counts: jax.Array
    data: jax.Array
    dropped_requests: jax.Array
    dropped_elems: jax.Array


def _exclusive_cumsum(x: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def sort_with(r: RequestList, *extras: jax.Array):
    """Sort requests by offset, permuting ``extras`` identically.

    Requires the padding-by-construction convention (invalid slots have
    offset PAD_OFFSET and length 0) but NOT the prefix convention —
    padding may be interspersed (e.g. flattened buckets); sorting
    compacts the valid entries to the front.
    """
    order = jnp.argsort(r.offsets, stable=True)
    sorted_r = RequestList(r.offsets[order], r.lengths[order], r.count)
    return (sorted_r, *[e[order] for e in extras])


def bucket_by_dest(r: RequestList, starts: jax.Array, data: jax.Array,
                   dest: jax.Array, n_dest: int, req_cap: int,
                   data_cap: int) -> Buckets:
    """Group requests + payload elements into per-destination buckets.

    r:      offset-sorted requests (element offsets in the file).
    starts: payload start of each request inside ``data``.
    dest:   int32[cap] destination id in [0, n_dest) per request.
    """
    cap = r.capacity
    in_dcap = data.shape[0]
    valid = r.valid_mask()
    d = jnp.where(valid, dest, n_dest).astype(jnp.int32)  # invalid -> sink

    # --- request-level grouping -------------------------------------
    order = jnp.argsort(d, stable=True)        # groups in offset order
    go, gl, gd = r.offsets[order], r.lengths[order], d[order]
    grp_counts = jax.ops.segment_sum(valid.astype(jnp.int32), d,
                                     num_segments=n_dest + 1)
    grp_start = _exclusive_cumsum(grp_counts)
    pos = jnp.arange(cap, dtype=jnp.int32) - grp_start[gd]
    req_ok = (gd < n_dest) & (pos < req_cap)
    # NB: .at[] wraps negative indices (NumPy semantics); the drop
    # sentinel must be out-of-range POSITIVE.
    scatter_idx = jnp.where(req_ok, gd * req_cap + pos, n_dest * req_cap)
    out_off = jnp.full((n_dest * req_cap,), PAD_OFFSET, jnp.int32)
    out_off = out_off.at[scatter_idx].set(go, mode="drop")
    out_len = jnp.zeros((n_dest * req_cap,), jnp.int32)
    out_len = out_len.at[scatter_idx].set(gl, mode="drop")
    counts = jnp.minimum(grp_counts[:n_dest], req_cap)
    dropped_req = jnp.sum(jnp.maximum(grp_counts[:n_dest] - req_cap, 0))

    # --- element-level routing ---------------------------------------
    # payload start of each request within its destination bucket:
    # prefix of lengths among same-dest requests placed before it.
    gpre = jnp.cumsum(gl) - gl                      # global prefix, grouped
    elem_grp_start = _exclusive_cumsum(
        jax.ops.segment_sum(jnp.where(valid, r.lengths, 0), d,
                            num_segments=n_dest + 1))
    dstart_grouped = gpre - elem_grp_start[gd]      # within-dest start
    req_dstart = jnp.zeros((cap,), jnp.int32).at[order].set(dstart_grouped)

    total = jnp.sum(jnp.where(valid, r.lengths, 0), dtype=jnp.int32)
    eidx = jnp.arange(in_dcap, dtype=jnp.int32)
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32),
                        jnp.where(valid, r.lengths, 0),
                        total_repeat_length=in_dcap)
    e_valid = eidx < total
    e_dest = d[req_of]
    e_pos = req_dstart[req_of] + (eidx - starts[req_of])
    e_ok = e_valid & (e_dest < n_dest) & (e_pos < data_cap) & (e_pos >= 0)
    e_scatter = jnp.where(e_ok, e_dest * data_cap + e_pos, n_dest * data_cap)
    out_data = jnp.zeros((n_dest * data_cap,), data.dtype)
    out_data = out_data.at[e_scatter].set(data, mode="drop")
    dropped_elems = jnp.sum(e_valid & (e_dest < n_dest) & ~e_ok)

    return Buckets(out_off.reshape(n_dest, req_cap),
                   out_len.reshape(n_dest, req_cap),
                   counts, out_data.reshape(n_dest, data_cap),
                   dropped_req.astype(jnp.int32),
                   dropped_elems.astype(jnp.int32))


def flatten_buckets(offsets: jax.Array, lengths: jax.Array,
                    counts: jax.Array, data: jax.Array):
    """Merge a stack of buckets [..., B, cap] into one flat request list
    with payload starts pointing into the flattened data buffer.
    """
    b_off = offsets.reshape(-1, offsets.shape[-1])
    b_len = lengths.reshape(-1, lengths.shape[-1])
    nb, cap = b_off.shape
    dcap = data.shape[-1]
    # starts within each bucket, offset by the bucket's slab in flat data
    per_bucket_starts = (jnp.cumsum(b_len, axis=-1) - b_len).astype(jnp.int32)
    slab = (jnp.arange(nb, dtype=jnp.int32) * dcap)[:, None]
    starts = (per_bucket_starts + slab).reshape(-1)
    # NOTE: padding is interspersed (per-bucket suffixes) — the prefix
    # convention does not hold until the list is sorted. Invalid slots
    # are self-describing (PAD_OFFSET / length 0) by bucket construction.
    r = RequestList(b_off.reshape(-1), b_len.reshape(-1),
                    jnp.sum(counts, dtype=jnp.int32))
    return r, starts, data.reshape(-1)


def repack_sorted(r_sorted: RequestList, starts: jax.Array,
                  data_flat: jax.Array, out_cap: int) -> jax.Array:
    """Pack payloads contiguously in sorted-request order.

    After this, the payload of any coalesced run of contiguous requests
    occupies one contiguous span — which is exactly why TAM's local
    aggregators can forward coalesced metadata with repacked data.
    """
    total = jnp.sum(r_sorted.lengths, dtype=jnp.int32)
    eidx = jnp.arange(out_cap, dtype=jnp.int32)
    req_of = jnp.repeat(jnp.arange(r_sorted.capacity, dtype=jnp.int32),
                        r_sorted.lengths, total_repeat_length=out_cap)
    new_starts = (jnp.cumsum(r_sorted.lengths) - r_sorted.lengths).astype(jnp.int32)
    src = starts[req_of] + (eidx - new_starts[req_of])
    vals = data_flat[jnp.clip(src, 0, data_flat.shape[0] - 1)]
    return jnp.where(eidx < total, vals, jnp.zeros((), data_flat.dtype))
