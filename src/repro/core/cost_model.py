"""Analytical congestion / cost model for two-phase I/O vs TAM.

Implements the paper's SIV-D analysis as a calibrated alpha-beta model
with three refinements the raw alpha-beta form misses but the paper's
measurements exhibit:

1. **Rounds.** ROMIO's Lustre driver writes at most one stripe per
   aggregator per round: rounds = total / (stripe_size * P_G). Each
   round re-runs the request exchange (paper SII).
2. **Incast congestion.** A receiver with S concurrent senders does not
   pay S * alpha linearly: queue processing collapses superlinearly
   (the paper's own MPI_Isend -> MPI_Issend fix is about exactly this
   message-queue overwhelm, SV). Modeled as
   alpha_eff = alpha * (1 + S / incast_knee).
3. **Per-request metadata processing.** ADIOI_Calc_my/others_req +
   derived-datatype construction cost scales with the number of
   offset-length pairs handled at the aggregator (dominant for E3SM-F's
   1.36e9 requests; Figs. 4-6 show it) — TAM shrinks it by the
   coalesce ratio.
4. **Round overlap.** The pipelined round engine (``core.rounds`` with
   ``IOConfig.pipeline``) exchanges round t+1 while draining round t,
   so each steady-state round pays ``max(comm, io)`` instead of the
   sum; ``Workload.overlap`` models the hidden fraction and
   :func:`optimal_cb` picks the collective-buffer size minimizing the
   pipelined total, the way :func:`optimal_PL` picks P_L.
5. **Slow-hop codec.** With ``Workload.slow_hop_ratio > 1`` (the
   ``core.codec`` wire transform enabled at a measured/modeled
   raw/wire ratio) the inter-node beta volume divides by the ratio and
   an encode+decode scan ``bytes * (1 + 1/ratio) / codec_bw`` is
   charged; :func:`slow_hop_codec_gain` is the break-even the planner's
   ``slow_hop_codec="auto"`` resolves against.

Message-count facts (paper SIV-D):
  two-phase:  P/P_G receives per GA per round;
              GA merge-sort O((P*k/P_G) log P).
  TAM intra:  P/P_L receives per LA (node-local);
              LA merge-sort O((P*k/P_L) log(P/P_L)).
  TAM inter:  P_L/P_G receives per GA per round;
              GA merge-sort O((P*k'/P_G) log P_L), k' = coalesced.

Validation anchors (tests/test_cost_model.py): end-to-end speedups in
the paper's 3-29x band at P=16384/256 nodes, and TAM-BTIO absolute time
~40 s at >5 GiB/s bandwidth (paper SV-B).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Machine:
    """Latency/bandwidth constants, default-calibrated to the paper's
    Cray XC40 Aries + Lustre (56 OSTs) setup; TPU preset below."""

    alpha_inter: float = 5.0e-6   # per-message cost across nodes (s)
    alpha_intra: float = 4.0e-7   # per-message cost within a node (s)
    beta_inter: float = 1.0 / 8e9   # s per byte across nodes
    beta_intra: float = 1.0 / 40e9  # s per byte within a node
    sort_per_cmp: float = 4.0e-9  # s per compare-move in merge sort
    req_proc: float = 2.0e-7      # s per offset-length pair at receiver
    incast_knee: float = 2048     # senders beyond which queues collapse
    memcpy_bw: float = 5e9        # B/s local packing
    io_bw: float = 5.5e9          # aggregate file-system bandwidth (B/s)
    codec_bw: float = 50e9        # B/s slow-hop codec throughput (a
    # byte-scan like zero-run RLE or int8 quantization runs at memory
    # bandwidth; charged on raw bytes in + wire bytes out)

    @staticmethod
    def tpu_v5e() -> "Machine":
        # intra = ICI within pod, inter = DCI between pods; hosts do I/O
        return Machine(alpha_inter=5.0e-6, alpha_intra=1.0e-6,
                       beta_inter=1.0 / 25e9, beta_intra=1.0 / 50e9,
                       sort_per_cmp=1.0e-9, req_proc=5.0e-8,
                       incast_knee=512, memcpy_bw=100e9, io_bw=20e9,
                       codec_bw=150e9)

    def alpha_eff(self, senders: float) -> float:
        return self.alpha_inter * (1.0 + senders / self.incast_knee)


@dataclass(frozen=True)
class Workload:
    """One collective write (a checkpoint flush)."""

    P: int            # total processes (ranks/devices)
    nodes: int        # compute nodes (fast domains)
    P_G: int          # global aggregators (= Lustre stripe count)
    k: float          # avg noncontiguous requests per process
    total_bytes: float
    coalesce_ratio: float = 1.0   # k'/k after intra-node coalescing
    pair_bytes: int = 8
    stripe_size: float = 1 << 20  # 1 MiB (paper's setting)
    rounds_override: float | None = None  # executed rounds, when measured
    overlap: float = 0.0          # pipelined round engine: fraction of the
    # smaller of (per-round exchange, per-round drain) hidden in steady
    # state. 0 = serial rounds (sum), 1 = perfect double-buffered overlap
    # (each steady-state round pays max(comm, io) instead of comm + io).
    pipeline_depth: int = 2       # in-flight cb windows of the round engine
    # (1 = serial, 2 = the classic double buffer, k > 2 = a depth-k ring
    # that can absorb multi-round spikes in non-uniform round times; with
    # the model's uniform per-round phases every depth >= 2 hides the
    # same amount, so the depth only matters through pipeline_span /
    # optimal_depth when measured per-round times are supplied).
    slow_hop_ratio: float = 1.0   # slow-hop codec raw/wire ratio: the
    # inter-node beta term is divided by this (volume discount) and an
    # encode+decode term bytes*(1 + 1/ratio)/codec_bw is charged
    # (refinement 5 — core.codec). 1.0 = codec off; set via with_codec
    # (measured zero fraction -> Codec.modeled_ratio on the host path).
    locality: float | None = None  # fraction of each domain's bytes that
    # originate on the domain's HOME node (the node the canonical
    # packed placement serves it from — core.placement.node_of_slot).
    # None = uniform (1/nodes): every node contributes equally to every
    # domain, in which case aggregator placement cannot matter and
    # placement_cost ties for every permutation (refinement 6). Set via
    # with_locality, or superseded entirely by a measured per-(domain,
    # sender-node) byte matrix (the session's feedback loop).

    @property
    def q(self) -> int:
        return self.P // self.nodes

    @property
    def rounds(self) -> float:
        """Exchange rounds. Defaults to ROMIO's one-stripe-per-aggregator
        assumption; a measured executed round count (the round engine's
        ``RoundScheduler.n_rounds`` / host-path ``rounds_executed``)
        replaces the assumption via ``rounds_override``."""
        if self.rounds_override is not None:
            return max(float(self.rounds_override), 1.0)
        return max(self.total_bytes / (self.stripe_size * self.P_G), 1.0)

    @property
    def num_stripes(self) -> float:
        return max(self.total_bytes / self.stripe_size, 1.0)

    def senders_per_stripe(self, endpoints: float,
                           requests: float) -> float:
        """Distinct senders whose requests land in one stripe."""
        density = requests / self.num_stripes
        return min(endpoints, max(density, 1.0))


@dataclass(frozen=True)
class CostBreakdown:
    intra_comm: float = 0.0
    intra_sort: float = 0.0
    intra_memcpy: float = 0.0
    inter_comm: float = 0.0
    inter_req_proc: float = 0.0
    inter_sort: float = 0.0
    io: float = 0.0
    overlap_saved: float = 0.0    # time hidden by pipelining rounds
    codec: float = 0.0            # slow-hop encode+decode time

    @property
    def comm(self) -> float:
        return self.intra_comm + self.inter_comm + self.inter_req_proc

    @property
    def total(self) -> float:
        return (self.intra_comm + self.intra_sort + self.intra_memcpy
                + self.inter_comm + self.inter_req_proc + self.inter_sort
                + self.io + self.codec - self.overlap_saved)


def _log2(x: float) -> float:
    return math.log2(max(x, 2.0))


def _inter_phase(w: Workload, m: Machine, endpoints: float,
                 requests: float) -> tuple[float, float, float, float]:
    """(comm, req_proc, sort, codec) for an exchange from ``endpoints``
    senders holding ``requests`` total offset-length pairs, into P_G
    GAs. ``slow_hop_ratio > 1`` divides the beta byte volume (the codec
    discount, refinement 5) and charges the encode+decode scan."""
    senders = w.senders_per_stripe(endpoints, requests)
    ratio = max(w.slow_hop_ratio, 1e-9)
    bytes_per_ga = w.total_bytes / w.P_G
    comm = (w.rounds * m.alpha_eff(senders) * senders
            + m.beta_inter * bytes_per_ga / ratio)
    req_proc = m.req_proc * (requests / w.P_G)
    sort = m.sort_per_cmp * (requests / w.P_G) * _log2(endpoints)
    codec = (bytes_per_ga * (1.0 + 1.0 / ratio) / m.codec_bw
             if ratio != 1.0 else 0.0)
    return comm, req_proc, sort, codec


def _overlap_saved(w: Workload, inter_comm: float, io: float) -> float:
    """Time hidden by the pipelined round engine (refinement 4).

    A pipelined round loop exchanges round t+1 while draining round t,
    so each of the R-1 steady-state rounds pays ``max(comm_r, io_r)``
    instead of ``comm_r + io_r``; the prologue (first exchange) and
    epilogue (last drain) stay exposed. With per-round uniform phases
    the saving is ``overlap * (R - 1) * min(inter_comm, io) / R`` for
    every depth >= 2 (a deeper ring only helps non-uniform rounds —
    see :func:`pipeline_span`); depth 1 is the serial loop.
    """
    rounds = w.rounds
    if w.overlap <= 0.0 or rounds <= 1.0 or w.pipeline_depth <= 1:
        return 0.0
    return (min(1.0, w.overlap) * (rounds - 1.0)
            * min(inter_comm / rounds, io / rounds))


def twophase_cost(w: Workload, m: Machine = Machine()) -> CostBreakdown:
    """Original two-phase I/O: all P ranks -> P_G aggregators."""
    comm, rp, sort, codec = _inter_phase(w, m, w.P, w.P * w.k)
    io = w.total_bytes / m.io_bw
    return CostBreakdown(inter_comm=comm, inter_req_proc=rp,
                         inter_sort=sort, io=io, codec=codec,
                         overlap_saved=_overlap_saved(w, comm, io))


def tam_cost(w: Workload, P_L: int, m: Machine = Machine()) -> CostBreakdown:
    """TAM with P_L local aggregators (P_L == P degenerates to
    two-phase: the intra layer vanishes, nothing coalesces)."""
    if P_L >= w.P:
        return twophase_cost(w, m)
    senders_per_la = w.P / P_L
    meta_bytes = w.P * w.k * w.pair_bytes
    bytes_per_la = (w.total_bytes + meta_bytes) / P_L
    intra_comm = (m.alpha_intra * senders_per_la
                  + m.beta_intra * bytes_per_la)
    intra_sort = m.sort_per_cmp * (w.P * w.k / P_L) * _log2(w.P / P_L)
    intra_memcpy = bytes_per_la / m.memcpy_bw
    k_prime = w.P * w.k * w.coalesce_ratio
    comm, rp, sort, codec = _inter_phase(w, m, P_L, k_prime)
    # GA sort merges P_L pre-sorted streams: log factor is P_L not P
    sort = m.sort_per_cmp * (k_prime / w.P_G) * _log2(P_L)
    io = w.total_bytes / m.io_bw
    return CostBreakdown(intra_comm, intra_sort, intra_memcpy,
                         comm, rp, sort, io=io, codec=codec,
                         overlap_saved=_overlap_saved(w, comm, io))


def optimal_PL(w: Workload, m: Machine = Machine(),
               candidates: tuple[int, ...] | None = None
               ) -> tuple[int, CostBreakdown]:
    """Pick P_L minimizing f(P_L) + g(P_L) (paper SIV-D balance)."""
    if candidates is None:
        cands, c = [], 1
        while w.nodes * c <= w.P:
            cands.append(w.nodes * c)
            c *= 2
        if w.P not in cands:
            cands.append(w.P)
        candidates = tuple(cands)
    best = min(candidates, key=lambda pl: tam_cost(w, pl, m).total)
    return best, tam_cost(w, best, m)


def rounds_for_cb(w: Workload, cb_bytes: float) -> float:
    """Executed round count for a collective-buffer size: each aggregator
    drains its ``total_bytes / P_G`` domain ``cb_bytes`` per round."""
    return max(math.ceil(w.total_bytes / (cb_bytes * w.P_G)), 1)


def with_measured_rounds(w: Workload, rounds: float) -> Workload:
    """Pin the model's round count to an executed value (e.g. the host
    path's ``IOTimings.rounds_executed`` or ``RoundScheduler.n_rounds``)."""
    import dataclasses
    return dataclasses.replace(w, rounds_override=float(rounds))


def with_overlap(w: Workload, overlap: float = 1.0,
                 depth: int = 2) -> Workload:
    """Model the pipelined round engine: ``overlap`` of the smaller
    per-round phase (exchange vs drain) is hidden in steady state.
    ``depth`` is the number of in-flight cb windows (the ring size):
    1 restores the serial loop, 2 is the classic double buffer, and
    deeper rings matter only through :func:`pipeline_span` when
    per-round times are non-uniform."""
    import dataclasses
    return dataclasses.replace(w, overlap=float(overlap),
                               pipeline_depth=int(depth))


def with_codec(w: Workload, ratio: float) -> Workload:
    """Model the slow-hop codec at a raw/wire ``ratio`` (refinement 5):
    the inter-node beta volume divides by it and the encode+decode scan
    ``bytes * (1 + 1/ratio) / codec_bw`` is charged. ``ratio = 1``
    restores the codec-off model. The measured estimate comes from the
    payload zero fraction (``codec.zero_fraction`` +
    ``Codec.modeled_ratio`` — the host path wires this)."""
    import dataclasses
    return dataclasses.replace(w, slow_hop_ratio=float(ratio))


def with_locality(w: Workload, locality: float) -> Workload:
    """Model sender locality (refinement 6 — core.placement): a
    ``locality`` fraction of every domain's bytes originates on the
    domain's home node; :func:`placement_cost` charges the fast
    (intra-node) rates for the bytes a placement keeps home-matched.
    ``1/nodes`` restores the uniform (placement-indifferent) model."""
    import dataclasses
    return dataclasses.replace(w, locality=float(locality))


def placement_cost(w: Workload, m: Machine = Machine(),
                   placement=None, n_nodes: int | None = None, *,
                   domain_bytes=None, node_bytes=None,
                   node_slowdown=None) -> float:
    """Modeled seconds of the inter phase under an aggregator placement
    (refinement 6): the per-node MAKESPAN of the slow-hop exchange when
    domain ``g`` is served by slot ``placement[g]`` (canonical
    slot->node map, ``core.placement.node_of_slot``).

    Two effects the flat model cannot see:

    * **fast-hop/slow-hop split** — bytes whose sender sits on the
      serving slot's node move at the intra rates (``alpha_intra`` /
      ``beta_intra``); the rest pay the inter rates with the incast
      knee (``alpha_eff``) and the slow-hop codec discount. The split
      comes from ``node_bytes`` (the measured per-(domain, sender-node)
      matrix the session feeds back) or, absent a measurement, from
      ``w.locality`` (``None`` = uniform = placement-indifferent).
    * **per-node load balance** — each domain's exchange cost lands on
      its serving node; the returned value is the max over nodes, so a
      placement that packs the heavy (or the only active) domains onto
      one node is charged for the pileup. ``domain_bytes`` supplies
      measured per-domain loads (default: uniform split).

    ``node_slowdown`` (per-node factors >= 1 — the executor's measured
    ``IOTimings.node_slowdown``, or a ``FaultSpec.slow_nodes`` model)
    scales each serving node's charge: a straggling node is that much
    more expensive per byte it serves, so the makespan argmin steers
    load off it (the degraded half of the session feedback loop).

    ``placement=None`` means the identity (placement off). The
    ``"auto"`` policy resolves by argmin of this function, so auto is
    never modeled-worse than any named policy — the invariant
    ``benchmarks/check_regression.py`` gates.
    """
    nodes = int(n_nodes if n_nodes is not None else w.nodes)
    if placement is None:
        P_G = w.P_G
        placement = tuple(range(P_G))
    else:
        placement = tuple(int(p) for p in placement)
        P_G = len(placement)
    nodes = max(nodes, 1)
    if node_bytes is not None:
        nb = [[float(b) for b in row] for row in node_bytes]
    else:
        if domain_bytes is None:
            domain_bytes = [w.total_bytes / P_G] * P_G
        loc = w.locality if w.locality is not None else 1.0 / nodes
        loc = min(max(float(loc), 0.0), 1.0)
        nb = []
        for g in range(P_G):
            home = g * nodes // P_G
            db = float(domain_bytes[g])
            if nodes == 1:
                nb.append([db])
                continue
            row = [db * (1.0 - loc) / (nodes - 1)] * nodes
            row[home] = db * loc
            nb.append(row)
    ratio = max(w.slow_hop_ratio, 1e-9)
    S = w.senders_per_stripe(w.P, w.P * w.k)
    slow_f = [max(float(s), 1.0) for s in (node_slowdown or ())]
    slow_f += [1.0] * (nodes - len(slow_f))
    node_load = [0.0] * nodes
    for g in range(P_G):
        serving = placement[g] * nodes // P_G      # node_of_slot
        total_g = sum(nb[g])
        if total_g <= 0.0:
            continue
        fast = nb[g][serving]
        slow = total_g - fast
        s_slow = S * slow / total_g
        s_fast = S - s_slow
        comm_g = (w.rounds * (m.alpha_eff(s_slow) * s_slow
                              + m.alpha_intra * s_fast)
                  + m.beta_inter * slow / ratio + m.beta_intra * fast)
        node_load[serving] += comm_g * slow_f[serving]
    return max(node_load)


def slow_hop_codec_gain(w: Workload, m: Machine = Machine(),
                        ratio: float | None = None) -> float:
    """Modeled seconds SAVED per global aggregator by enabling the
    slow-hop codec at ``ratio`` (default: the workload's) — the beta
    volume discount minus the encode+decode cost. Positive means the
    codec pays for itself; ``compile_plan``'s ``slow_hop_codec="auto"``
    enables the codec exactly when this is positive."""
    r = max(float(ratio if ratio is not None else w.slow_hop_ratio), 1e-9)
    bytes_per_ga = w.total_bytes / w.P_G
    saving = m.beta_inter * bytes_per_ga * (1.0 - 1.0 / r)
    cost = bytes_per_ga * (1.0 + 1.0 / r) / m.codec_bw
    return saving - cost


def pipeline_span(comm_rounds, io_rounds, depth: int) -> float:
    """Exact makespan of a depth-k bounded-buffer round pipeline.

    ``comm_rounds[t]`` / ``io_rounds[t]`` are round t's exchange and
    drain times (any non-uniformity is welcome — this is what a deeper
    ring exploits). The ring holds ``depth`` window buffers: the
    exchange of round t reuses the buffer drained in round t - depth,
    so

        finish_ex[t] = max(finish_ex[t-1], finish_dr[t-depth]) + comm[t]
        finish_dr[t] = max(finish_dr[t-1], finish_ex[t]) + io[t]

    ``depth=1`` degenerates to the serial sum; ``depth=2`` reproduces
    the closed form ``c_0 + sum max(c_t, i_{t-1}) + i_{R-1}`` the host
    path measured before depth-k existed.
    """
    comm = [float(c) for c in comm_rounds]
    io = [float(i) for i in io_rounds]
    n = len(comm)
    if n == 0:
        return 0.0
    d = max(1, min(int(depth), n))
    if d == 1:
        return sum(comm) + sum(io)
    fin_ex = [0.0] * n
    fin_dr = [0.0] * n
    for t in range(n):
        start = fin_ex[t - 1] if t else 0.0
        if t - d >= 0:
            start = max(start, fin_dr[t - d])
        fin_ex[t] = start + comm[t]
        fin_dr[t] = max(fin_dr[t - 1] if t else 0.0, fin_ex[t]) + io[t]
    return fin_dr[-1]


def optimal_depth(w: Workload | None = None, m: Machine = Machine(),
                  P_L: int | None = None,
                  cb_bytes: float | None = None,
                  depths: tuple[int, ...] = (1, 2, 3, 4),
                  round_times=None) -> tuple[int, float]:
    """Pick the pipeline-ring depth minimizing the round-loop makespan,
    the way :func:`optimal_cb` picks the collective-buffer size.

    Two modes:

    * **measured** — ``round_times = (comm_rounds, io_rounds)`` from an
      executed run (the host path's per-round arrays): the span is
      computed exactly per candidate depth, so a depth-k ring's ability
      to absorb multi-round spikes is visible.
    * **modeled** — from ``w`` (and ``cb_bytes`` to pin the round
      count): per-round phases are uniform, every depth >= 2 ties and
      the smallest winning depth is returned (deeper rings cost k x
      window memory for no modeled gain — see
      ``rounds.peak_aggregator_buffer_elems``).

    Returns ``(depth, span_seconds)``. Ties go to the smallest depth.
    """
    if round_times is not None:
        comm_rounds, io_rounds = round_times
        comm_rounds = [float(c) for c in comm_rounds]
        io_rounds = [float(i) for i in io_rounds]
        spans = {d: pipeline_span(comm_rounds, io_rounds, d)
                 for d in depths}
    else:
        if w is None:
            raise ValueError("need a Workload or measured round_times")
        wc = w if cb_bytes is None else \
            with_measured_rounds(w, rounds_for_cb(w, cb_bytes))
        cost = tam_cost(wc, P_L, m) if P_L is not None else \
            twophase_cost(wc, m)
        # uniform per-round phases: the span has a closed form (every
        # depth >= 2 ties), so no per-round array is materialized even
        # for million-round schedules
        n = max(float(wc.rounds), 1.0)
        c_r, i_r = cost.inter_comm / n, cost.io / n
        spans = {d: (n * (c_r + i_r) if min(d, n) <= 1
                     else c_r + (n - 1.0) * max(c_r, i_r) + i_r)
                 for d in depths}
    best_d, best_s = None, None
    for d in depths:
        if best_s is None or spans[d] < best_s - 1e-15:
            best_d, best_s = d, spans[d]
    return best_d, best_s


def cb_candidates(domain_bytes: float, stripe_bytes: float, *,
                  min_cb_bytes: int = 1,
                  max_cb_bytes: int | None = None) -> tuple[int, ...]:
    """Collective-buffer sizes satisfying the round-partition invariants.

    Every candidate ``c`` is stripe-aligned (``c % stripe == 0`` or
    ``stripe % c == 0`` — ``RoundScheduler``'s validation) and, when
    ``domain_bytes`` is an exact stripe multiple, divides it evenly (the
    ``domain_len % cb`` invariant the SPMD round partition enforces).
    Non-stripe-divisible domains (paper workloads whose total does not
    divide by P_G, handled with a ceil round count) relax divisibility
    and keep alignment only. Candidates are power-of-two spaced:
    sub-stripe divisors of the stripe, then stripe multiples up to the
    whole domain (``max_cb_bytes`` bounds aggregator memory).
    """
    domain_bytes = max(int(round(domain_bytes)), 1)
    stripe_bytes = max(int(round(stripe_bytes)), 1)
    exact = domain_bytes % stripe_bytes == 0
    if not exact:   # round the domain up to a whole number of stripes
        domain_bytes = -(-domain_bytes // stripe_bytes) * stripe_bytes
    cands: set[int] = set()
    c = stripe_bytes
    while c >= max(min_cb_bytes, 1):          # sub-stripe divisors
        if not exact or domain_bytes % c == 0:
            cands.add(c)
        if c % 2:
            break
        c //= 2
    c = stripe_bytes
    while c <= domain_bytes:                  # stripe multiples
        if not exact or domain_bytes % c == 0:
            cands.add(c)
        c *= 2
    cands.add(domain_bytes)                   # single round
    cands = {c for c in cands
             if c >= min_cb_bytes
             and (max_cb_bytes is None or c <= max_cb_bytes)}
    if not cands:   # memory bound excludes everything: smallest legal cb
        cands = {max(stripe_bytes, min_cb_bytes)}
    return tuple(sorted(cands))


def optimal_cb(w: Workload, m: Machine = Machine(),
               P_L: int | None = None,
               candidates: tuple[int, ...] | None = None,
               min_cb_bytes: int = 1,
               max_cb_bytes: int | None = None
               ) -> tuple[int, CostBreakdown]:
    """Pick ``cb_buffer_size`` (bytes) minimizing the modeled total, the
    way :func:`optimal_PL` picks P_L.

    The trade-off: a small cb means many rounds — each re-paying the
    incast latency ``alpha_eff(senders)`` — but little aggregator memory
    and (with ``w.overlap > 0``) more steady-state rounds in which the
    pipelined engine hides ``min(comm, io)``; a large cb means few
    rounds but ``O(cb)`` aggregator buffering (bounded by
    ``max_cb_bytes``). Every candidate obeys the round-partition
    invariants (see :func:`cb_candidates`). Returns
    ``(cb_bytes, CostBreakdown at that cb)``.
    """
    if candidates is None:
        candidates = cb_candidates(w.total_bytes / w.P_G, w.stripe_size,
                                   min_cb_bytes=min_cb_bytes,
                                   max_cb_bytes=max_cb_bytes)

    def cost(cb: int) -> CostBreakdown:
        wc = with_measured_rounds(w, rounds_for_cb(w, cb))
        return tam_cost(wc, P_L, m) if P_L is not None else \
            twophase_cost(wc, m)

    best = min(candidates, key=lambda cb: cost(cb).total)
    return best, cost(best)


def optimal_cb_and_depth(w: Workload, m: Machine = Machine(),
                         P_L: int | None = None,
                         candidates: tuple[int, ...] | None = None,
                         depths: tuple[int, ...] = (1, 2, 3, 4),
                         min_cb_bytes: int = 1,
                         max_cb_bytes: int | None = None
                         ) -> tuple[int, int, float]:
    """Jointly pick (cb_bytes, pipeline depth): for every legal cb the
    best ring depth's exact :func:`pipeline_span` replaces the serial
    ``inter_comm + io`` round phases, and the (cb, depth) pair with the
    smallest resulting total wins. This is what ``pipeline_depth="auto"``
    resolves through at plan time. Returns
    ``(cb_bytes, depth, total_seconds)``."""
    if candidates is None:
        candidates = cb_candidates(w.total_bytes / w.P_G, w.stripe_size,
                                   min_cb_bytes=min_cb_bytes,
                                   max_cb_bytes=max_cb_bytes)
    best: tuple[float, int, int] | None = None
    for cb in candidates:
        wc = with_measured_rounds(w, rounds_for_cb(w, cb))
        cost = tam_cost(wc, P_L, m) if P_L is not None else \
            twophase_cost(wc, m)
        fixed = (cost.intra_comm + cost.intra_sort + cost.intra_memcpy
                 + cost.inter_req_proc + cost.inter_sort + cost.codec)
        d, span = optimal_depth(wc, m, P_L=P_L, depths=depths)
        total = fixed + span
        if best is None or total < best[0] - 1e-15:
            best = (total, cb, d)
    return best[1], best[2], best[0]


def read_cost(w: Workload, m: Machine = Machine(), *,
              node_cache: bool = True,
              replicas: float = 1.0) -> CostBreakdown:
    """Modeled cost of a planned collective read (a restore).

    The write model run in reverse: global aggregators read the file
    (``io``), ship each file-domain window over the slow hop, and the
    window fans out to the reader ranks that requested it.

    ``node_cache=True`` models the host executor's node-level read
    cache: one elected fetcher per node pulls each window over the slow
    hop exactly once and co-located readers are served at the intra
    rates, so the slow-hop endpoint count is ``min(nodes, P)`` and the
    slow-hop byte volume is independent of ``replicas`` (co-located
    readers requesting the same bytes — the restore fan-out case).
    ``node_cache=False`` is the PR-3 broadcast: every reader rank
    fetches directly, paying the incast knee at P endpoints and
    re-shipping overlapping bytes ``min(replicas, q)`` times.
    """
    ratio = max(w.slow_hop_ratio, 1e-9)
    bytes_per_ga = w.total_bytes / w.P_G
    io = w.total_bytes / m.io_bw
    codec = (bytes_per_ga * (1.0 + 1.0 / ratio) / m.codec_bw
             if ratio != 1.0 else 0.0)
    if node_cache:
        fetchers = float(max(min(w.nodes, w.P), 1))
        inter = (w.rounds * m.alpha_eff(fetchers) * fetchers
                 + m.beta_inter * bytes_per_ga / ratio)
        q = max(w.q, 1)
        node_share = w.total_bytes / max(w.nodes, 1)
        intra = w.rounds * m.alpha_intra * q + m.beta_intra * node_share
        memcpy = node_share / m.memcpy_bw
        return CostBreakdown(intra_comm=intra, intra_memcpy=memcpy,
                             inter_comm=inter, io=io, codec=codec,
                             overlap_saved=_overlap_saved(w, inter, io))
    dup = max(min(float(replicas), float(max(w.q, 1))), 1.0)
    senders = w.senders_per_stripe(w.P, w.P * max(w.k, 1.0))
    inter = (w.rounds * m.alpha_eff(senders) * senders
             + m.beta_inter * bytes_per_ga * dup / ratio)
    return CostBreakdown(inter_comm=inter, io=io, codec=codec,
                         overlap_saved=_overlap_saved(w, inter, io))


def optimal_read_cb(w: Workload, m: Machine = Machine(),
                    candidates: tuple[int, ...] | None = None, *,
                    node_cache: bool = True,
                    min_cb_bytes: int = 1,
                    max_cb_bytes: int | None = None
                    ) -> tuple[int, CostBreakdown]:
    """Read-direction :func:`optimal_cb`: pick the collective-buffer
    size minimizing the modeled :func:`read_cost` total. The trade-off
    mirrors the write side — small cb = many rounds, each re-paying the
    per-round fetch latency; large cb = O(cb) node-cache memory."""
    if candidates is None:
        candidates = cb_candidates(w.total_bytes / w.P_G, w.stripe_size,
                                   min_cb_bytes=min_cb_bytes,
                                   max_cb_bytes=max_cb_bytes)

    def cost(cb: int) -> CostBreakdown:
        wc = with_measured_rounds(w, rounds_for_cb(w, cb))
        return read_cost(wc, m, node_cache=node_cache)

    best = min(candidates, key=lambda cb: cost(cb).total)
    return best, cost(best)


def optimal_read_depth(w: Workload | None = None,
                       m: Machine = Machine(), *,
                       cb_bytes: float | None = None,
                       node_cache: bool = True,
                       depths: tuple[int, ...] = (1, 2, 3, 4),
                       round_times=None) -> tuple[int, float]:
    """Read-direction :func:`optimal_depth`. Measured mode (per-round
    ``(comm_rounds, io_rounds)`` from an executed read) delegates to
    the exact :func:`pipeline_span`; modeled mode uses
    :func:`read_cost`'s uniform per-round phases (every depth >= 2
    ties, smallest wins)."""
    if round_times is not None:
        return optimal_depth(m=m, depths=depths, round_times=round_times)
    if w is None:
        raise ValueError("need a Workload or measured round_times")
    wc = w if cb_bytes is None else \
        with_measured_rounds(w, rounds_for_cb(w, cb_bytes))
    cost = read_cost(wc, m, node_cache=node_cache)
    n = max(float(wc.rounds), 1.0)
    c_r, i_r = cost.inter_comm / n, cost.io / n
    spans = {d: (n * (c_r + i_r) if min(d, n) <= 1
                 else c_r + (n - 1.0) * max(c_r, i_r) + i_r)
             for d in depths}
    best_d, best_s = None, None
    for d in depths:
        if best_s is None or spans[d] < best_s - 1e-15:
            best_d, best_s = d, spans[d]
    return best_d, best_s


def optimal_read_cb_and_depth(w: Workload, m: Machine = Machine(),
                              candidates: tuple[int, ...] | None = None,
                              depths: tuple[int, ...] = (1, 2, 3, 4), *,
                              node_cache: bool = True,
                              min_cb_bytes: int = 1,
                              max_cb_bytes: int | None = None
                              ) -> tuple[int, int, float]:
    """Jointly pick (cb_bytes, pipeline depth) for a read, the way
    :func:`optimal_cb_and_depth` does for writes: per candidate cb the
    best ring depth's span replaces the serial fetch + fan-out round
    phases. This is what read-direction ``pipeline_depth="auto"``
    resolves through. Returns ``(cb_bytes, depth, total_seconds)``."""
    if candidates is None:
        candidates = cb_candidates(w.total_bytes / w.P_G, w.stripe_size,
                                   min_cb_bytes=min_cb_bytes,
                                   max_cb_bytes=max_cb_bytes)
    best: tuple[float, int, int] | None = None
    for cb in candidates:
        wc = with_measured_rounds(w, rounds_for_cb(w, cb))
        cost = read_cost(wc, m, node_cache=node_cache)
        fixed = (cost.intra_comm + cost.intra_sort + cost.intra_memcpy
                 + cost.inter_req_proc + cost.inter_sort + cost.codec)
        d, span = optimal_read_depth(wc, m, node_cache=node_cache,
                                     depths=depths)
        total = fixed + span
        if best is None or total < best[0] - 1e-15:
            best = (total, cb, d)
    return best[1], best[2], best[0]


def receives_per_global_aggregator(w: Workload, P_L: int | None) -> float:
    """The paper's congestion metric (Fig. 2), per round."""
    return (w.P if P_L is None or P_L >= w.P else P_L) / w.P_G


def sort_complexity(w: Workload, P_L: int | None) -> float:
    """Compare-count of the offset merge-sorts (paper SIV-D)."""
    if P_L is None or P_L >= w.P:
        return (w.P * w.k / w.P_G) * _log2(w.P)
    k_prime = w.k * w.coalesce_ratio
    return ((w.P * k_prime / w.P_G) * _log2(P_L)
            + (w.P * w.k / P_L) * _log2(w.P / P_L))


def speedup(w: Workload, P_L: int, m: Machine = Machine()) -> float:
    """End-to-end two-phase / TAM time ratio."""
    return twophase_cost(w, m).total / tam_cost(w, P_L, m).total


# ---------------------------------------------------------------------------
# Paper workloads (Table I).
# ---------------------------------------------------------------------------

def e3sm_g(P: int, nodes: int) -> Workload:
    return Workload(P=P, nodes=nodes, P_G=56, k=1.74e8 / P,
                    total_bytes=85 * 2**30, coalesce_ratio=0.5)


def e3sm_f(P: int, nodes: int) -> Workload:
    return Workload(P=P, nodes=nodes, P_G=56, k=1.36e9 / P,
                    total_bytes=14 * 2**30, coalesce_ratio=0.5)


def btio(P: int, nodes: int) -> Workload:
    n_req = 512**2 * 40 * math.sqrt(P)
    # paper SV-B: 1.34e9 requests coalesce to 2.36e7 at 256 nodes
    return Workload(P=P, nodes=nodes, P_G=56, k=n_req / P,
                    total_bytes=200 * 2**30, coalesce_ratio=0.0176)


def s3d(P: int, nodes: int, y: int | None = None,
        z: int | None = None) -> Workload:
    side = max(round(P ** (1 / 3)), 1)
    y = y or side
    z = z or side
    return Workload(P=P, nodes=nodes, P_G=56, k=800**2 * y * z / P,
                    total_bytes=61 * 2**30, coalesce_ratio=0.05)
