"""Aggregator placement: WHERE each file domain's aggregator sits.

ROMIO's ``cb_config_list`` exists because the cost of a collective
write depends not only on how many aggregators there are but on which
physical ranks they land on relative to the data (Thakur et al.,
"Optimizing Noncontiguous Accesses in MPI-IO"); the hybrid intra-node
literature (Zhou et al.) makes the same point for process grouping.
This module makes that choice an explicit, planner-owned object: a
PERMUTATION ``perm`` of the aggregator slots, where ``perm[g]`` is the
slot that serves file domain ``g``.

Slots vs domains
----------------
A *slot* is a physical aggregator position. The canonical slot->node
map is packed blocks: slot ``s`` lives on node ``s * n_nodes // n_agg``
(:func:`node_of_slot`) — balanced to within one slot per node by
construction. A *domain* is a schedule object: aggregator domain ``g``
owns the domain-local span ``[g * domain_len, (g+1) * domain_len)``.
The placement permutes which slot serves which domain; it never changes
how many slots a node hosts (that is the canonical map's job), so every
placement is a pure bijection on the aggregator set — which is exactly
why every byte-identity harness extends to it: the bytes that land in
domain ``g`` are the same bytes, routed through a different slot.

Policies
--------
* ``"packed"`` — the identity: domain ``g`` on slot ``g``, i.e. every
  domain served on its *home* node (the node the canonical map puts
  slot ``g`` on). Optimal when writers exhibit locality (node n's ranks
  mostly write node n's domains — the fast-hop case the paper's
  intra-node aggregation exploits).
* ``"spread"`` — consecutive domains round-robin across nodes: the
  g-th domain goes to the g-th slot of the node-interleaved slot
  enumeration. Optimal when the *active* file region is a contiguous
  prefix (only some domains carry bytes): packed would concentrate the
  live aggregators on few nodes, spread balances them.
* ``"node_balanced"`` — greedy makespan balancing of MEASURED
  per-domain byte loads: domains in descending-bytes order each take a
  free slot on the currently least-loaded node. Uniform loads reduce it
  to a spread-like interleave; skewed loads are where it earns the
  name. Requires ``domain_bytes`` to differ from ``"spread"``.
* ``"auto"`` — evaluates every named policy with
  :func:`repro.core.cost_model.placement_cost` (the fast-hop/slow-hop
  split plus the per-node makespan the placement induces) and picks the
  argmin — so auto is never modeled-worse than any named policy, and
  ties resolve to ``"packed"`` (the identity, the cheapest to execute).

An explicit tuple is also accepted anywhere a policy name is (the
session's measured re-resolution produces tuples; tests pass arbitrary
permutations). :func:`validate_placement` rejects non-bijections at
plan-compile time.
"""
from __future__ import annotations

PLACEMENT_POLICIES = ("packed", "spread", "node_balanced")


def node_of_slot(slot: int, n_aggregators: int, n_nodes: int) -> int:
    """Canonical slot->node map: packed, balanced to within one slot."""
    return slot * n_nodes // n_aggregators


def validate_placement(perm, n_aggregators: int) -> tuple[int, ...]:
    """Return ``perm`` as a tuple, or raise ``ValueError`` unless it is
    a bijection on ``range(n_aggregators)`` (the property every
    executor relies on: each slot serves exactly one domain)."""
    perm = tuple(int(p) for p in perm)
    if len(perm) != n_aggregators or sorted(perm) != list(
            range(n_aggregators)):
        raise ValueError(
            f"placement {perm!r} is not a permutation of "
            f"range({n_aggregators})")
    return perm


def is_identity(perm) -> bool:
    return perm is None or tuple(perm) == tuple(range(len(perm)))


def inverse_placement(perm) -> tuple[int, ...]:
    """``inv[slot] = domain`` for ``perm[domain] = slot``."""
    inv = [0] * len(perm)
    for g, s in enumerate(perm):
        inv[s] = g
    return tuple(inv)


def packed_placement(n_aggregators: int, n_nodes: int) -> tuple[int, ...]:
    return tuple(range(n_aggregators))


def spread_placement(n_aggregators: int, n_nodes: int) -> tuple[int, ...]:
    """Node-interleaved slot enumeration: consecutive domains land on
    different nodes (first slot of each node, then second of each...)."""
    by_node: list[list[int]] = [[] for _ in range(max(n_nodes, 1))]
    for s in range(n_aggregators):
        by_node[node_of_slot(s, n_aggregators, n_nodes)].append(s)
    order: list[int] = []
    depth = 0
    while len(order) < n_aggregators:
        for slots in by_node:
            if depth < len(slots):
                order.append(slots[depth])
        depth += 1
    return tuple(order)


def node_balanced_placement(n_aggregators: int, n_nodes: int,
                            domain_bytes=None,
                            node_slowdown=None) -> tuple[int, ...]:
    """Greedy per-node makespan balancing of the measured domain loads:
    heaviest domain first, each onto a free slot of the least-loaded
    node (node order breaks ties deterministically). ``node_slowdown``
    (per-node factors >= 1, the executor's measured feedback) scales a
    node's accrued load — a straggler fills up ``factor`` times faster,
    so the greedy argmin naturally steers the heavy domains off it
    while this stays a pure bijection (every slot still serves exactly
    one domain; only the domain->node MATCHING changes)."""
    if domain_bytes is None:
        domain_bytes = [1.0] * n_aggregators
    slow = [max(float(s), 1.0) for s in (node_slowdown or ())]
    slow += [1.0] * (max(n_nodes, 1) - len(slow))
    by_node: list[list[int]] = [[] for _ in range(max(n_nodes, 1))]
    for s in range(n_aggregators):
        by_node[node_of_slot(s, n_aggregators, n_nodes)].append(s)
    load = [0.0] * len(by_node)
    order = sorted(range(n_aggregators),
                   key=lambda g: (-float(domain_bytes[g]), g))
    perm = [0] * n_aggregators
    for g in order:
        db = float(domain_bytes[g])
        n = min((i for i in range(len(by_node)) if by_node[i]),
                key=lambda i: (load[i] + db * slow[i], i))
        perm[g] = by_node[n].pop(0)
        load[n] += db * slow[n]
    return tuple(perm)


_POLICY_FNS = {
    "packed": packed_placement,
    "spread": spread_placement,
    "node_balanced": node_balanced_placement,
}


def resolve_placement(spec, n_aggregators: int, n_nodes: int, *,
                      workload=None, machine=None, domain_bytes=None,
                      node_bytes=None,
                      node_slowdown=None) -> tuple[int, ...] | None:
    """Resolve a placement spec to a concrete permutation (or ``None``).

    spec: ``None`` (placement off — executors keep the legacy
    identity path), a policy name, ``"auto"``, or an explicit
    permutation. ``"auto"`` scores every named policy with
    ``cost_model.placement_cost`` for the (measured or assumed)
    workload — ``node_bytes`` is the session's measured per-(domain,
    sender-node) byte matrix, ``domain_bytes`` the per-domain loads —
    and returns the argmin; with no workload at all it falls back to
    ``"packed"`` (the identity: safe, and modeled-tied with everything
    under the uniform default anyway). ``node_slowdown`` (measured
    per-node factors, ``IOTimings.node_slowdown``) biases both the
    balanced policy's greedy and the auto scoring so a straggling node
    sheds aggregator load — the bijective half of degraded placement
    (the non-bijective half, slot evacuation, lives in
    ``core.faults.evacuation_map`` and stays out of the plan)."""
    if spec is None:
        return None
    if not isinstance(spec, str):
        return validate_placement(spec, n_aggregators)
    if node_bytes is not None and domain_bytes is None:
        # measured matrix implies the per-domain loads — named policies
        # (node_balanced) consume them too, not just "auto"
        domain_bytes = [sum(row) for row in node_bytes]
    if spec in _POLICY_FNS:
        if spec == "node_balanced":
            return validate_placement(
                node_balanced_placement(n_aggregators, n_nodes,
                                        domain_bytes, node_slowdown),
                n_aggregators)
        return validate_placement(_POLICY_FNS[spec](n_aggregators,
                                                    n_nodes),
                                  n_aggregators)
    if spec != "auto":
        raise ValueError(
            f"unknown placement {spec!r} (policies: "
            f"{PLACEMENT_POLICIES + ('auto',)} or an explicit "
            "permutation)")
    if workload is None:
        return packed_placement(n_aggregators, n_nodes)
    from repro.core import cost_model as cm
    machine = machine or cm.Machine()
    best_perm, best_cost = None, None
    for name in PLACEMENT_POLICIES:
        perm = (_POLICY_FNS[name](n_aggregators, n_nodes, domain_bytes,
                                  node_slowdown)
                if name == "node_balanced"
                else _POLICY_FNS[name](n_aggregators, n_nodes))
        cost = cm.placement_cost(workload, machine, perm, n_nodes,
                                 domain_bytes=domain_bytes,
                                 node_bytes=node_bytes,
                                 node_slowdown=node_slowdown)
        if best_cost is None or cost < best_cost - 1e-15:
            best_perm, best_cost = perm, cost
    return validate_placement(best_perm, n_aggregators)
