"""Baseline two-phase collective I/O (ROMIO-style) under SPMD.

This is the paper's comparison baseline: every rank routes its requests
directly to the global aggregator owning the destination file domain
(all-to-many), aggregators merge-sort the received offset-length pairs
and place payloads into their file-domain buffers.

Mesh layout for collective I/O (see DESIGN.md §4): a 3-D view
``(node, lagg, lmem)`` of the device mesh —

* ``node`` — the slow boundary (across compute nodes / pods). One global
  aggregator per node (ROMIO's default), file domains are contiguous
  per-node slices.
* ``lagg`` × ``lmem`` — ranks within a node; ``lagg`` indexes local-
  aggregator slots (used by TAM; the baseline ignores the distinction).

SPMD note (DESIGN.md §7): MPI point-to-point congestion has no literal
XLA analogue; the all-to-many here is an ``all_to_all`` over the slow
axis plus intra-node gathers. Congestion itself is reproduced by the
host-level path (``repro.checkpoint.host_io``) and the analytical model
(``repro.core.cost_model``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import coalesce as co
from repro.core import rounds
from repro.core.domains import FileLayout
from repro.core.exchange import Buckets, bucket_by_dest, flatten_buckets, sort_with
from repro.core.requests import ELEM_BYTES, RequestList, mask_invalid, split_at_stripes


@dataclass(frozen=True)
class IOConfig:
    """Static capacities for the SPMD collective-I/O paths.

    req_cap:        per-rank request-list capacity.
    data_cap:       per-rank payload capacity (elements).
    coalesce_cap:   post-coalesce metadata capacity forwarded by a local
                    aggregator (TAM stage 2). Patterns that coalesce well
                    (BTIO/S3D-like) allow coalesce_cap << lmem * req_cap —
                    that is TAM's inter-node metadata saving.
    cb_buffer_size: aggregator collective-buffer elements per round
                    (ROMIO's romio_cb_buffer_size). ``None`` keeps the
                    single-shot exchange; setting it bounds aggregator
                    buffering at O(cb_buffer_size) independent of the
                    rank count (see ``repro.core.rounds``); ``"auto"``
                    lets ``cost_model.optimal_cb`` pick the size
                    minimizing the modeled (pipelined) total at build
                    time (:func:`resolve_cb_buffer_size`).
    pipeline:       double-buffer the round loop — round t+1's exchange
                    overlaps round t's window drain (byte-identical;
                    see ``repro.core.rounds``). Ignored by the
                    single-shot path.
    axis_names:     (node, lagg, lmem) mesh-axis names.
    """

    req_cap: int
    data_cap: int
    coalesce_cap: int | None = None
    cb_buffer_size: int | str | None = None
    pipeline: bool = False
    axis_names: tuple[str, str, str] = ("node", "lagg", "lmem")


def resolve_cb_buffer_size(layout: FileLayout, n_nodes: int, n_ranks: int,
                           cfg: IOConfig, machine=None) -> IOConfig:
    """Resolve ``cb_buffer_size == "auto"`` to concrete elements.

    Builds the matching ``cost_model.Workload`` (byte units, one GA per
    node) and lets :func:`repro.core.cost_model.optimal_cb` pick the
    candidate minimizing the modeled total — pipelined when
    ``cfg.pipeline`` — from the sizes that satisfy the
    ``RoundScheduler`` invariants (divides ``domain_len``,
    stripe-aligned)."""
    if cfg.cb_buffer_size != "auto":
        return cfg
    from repro.core import cost_model as cm
    dl = layout.file_len // n_nodes
    s = layout.stripe_size
    cands = tuple(c for c in cm.cb_candidates(dl, s)
                  if dl % c == 0 and (c % s == 0 or s % c == 0)) or (dl,)
    w = cm.Workload(
        P=n_ranks, nodes=n_nodes, P_G=n_nodes, k=float(cfg.req_cap),
        total_bytes=float(layout.file_len * ELEM_BYTES),
        stripe_size=float(s * ELEM_BYTES),
        overlap=1.0 if cfg.pipeline else 0.0)
    cb_bytes, _ = cm.optimal_cb(
        w, machine or cm.Machine(),
        candidates=tuple(c * ELEM_BYTES for c in cands))
    return replace(cfg, cb_buffer_size=cb_bytes // ELEM_BYTES)


def _gather_axes(cfg: IOConfig) -> tuple[str, str]:
    return cfg.axis_names[1], cfg.axis_names[2]


def _squeeze(r: RequestList) -> RequestList:
    return RequestList(r.offsets.reshape(-1), r.lengths.reshape(-1),
                       r.count.reshape(()))


def _twophase_shard_fn(layout: FileLayout, cfg: IOConfig, n_nodes: int,
                       offsets, lengths, count, data):
    node, lagg, lmem = cfg.axis_names
    r = mask_invalid(RequestList(offsets.reshape(-1), lengths.reshape(-1),
                                 count.reshape(())))
    data = data.reshape(-1)
    starts = co.request_starts(r)

    if cfg.cb_buffer_size is not None:
        # round-scheduled exchange: aggregator buffers O(cb_buffer_size)
        sched = rounds.RoundScheduler(layout, n_nodes, cfg.cb_buffer_size)
        shard, st = rounds.exchange_rounds_write(
            sched, node, (lagg, lmem), r, starts, data,
            pipeline=cfg.pipeline)
        stats = {
            "dropped_requests": lax.psum(st["dropped_requests"],
                                         (node, lagg, lmem)),
            "dropped_elems": lax.psum(st["dropped_elems"],
                                      (node, lagg, lmem)),
            "requests_at_ga": st["requests_at_ga"][None],
        }
        return shard[None], stats

    # route directly to the owning global aggregator (= node id);
    # domain-spanning requests are split at the boundary so each piece
    # has exactly one owner (they were silently truncated before)
    domain_len = layout.file_len // n_nodes
    r = split_at_stripes(r, domain_len, cfg.data_cap // domain_len + 2)
    starts = co.request_starts(r)
    dest = r.offsets // domain_len
    buckets = bucket_by_dest(r, starts, data, dest, n_nodes,
                             cfg.req_cap, cfg.data_cap)

    a2a = partial(lax.all_to_all, axis_name=node, split_axis=0,
                  concat_axis=0, tiled=True)
    rx_off, rx_len, rx_data = (a2a(buckets.offsets), a2a(buckets.lengths),
                               a2a(buckets.data))
    rx_cnt = a2a(buckets.counts)

    # complete the all-to-many: aggregator sees every intra-node rank's
    # bucket as well.
    g = partial(lax.all_gather, axis_name=_gather_axes(cfg), axis=0,
                tiled=False)
    all_off, all_len, all_cnt, all_data = (g(rx_off), g(rx_len), g(rx_cnt),
                                           g(rx_data))

    merged, starts_m, data_flat = flatten_buckets(all_off, all_len, all_cnt,
                                                  all_data)
    sorted_r, starts_s = sort_with(merged, starts_m)
    my_node = lax.axis_index(node)
    shard = co.pack_data(sorted_r, starts_s, data_flat, domain_len,
                         base=my_node * domain_len)
    stats = {
        "dropped_requests": lax.psum(buckets.dropped_requests,
                                     (node, lagg, lmem)),
        "dropped_elems": lax.psum(buckets.dropped_elems, (node, lagg, lmem)),
        "requests_at_ga": sorted_r.count[None],
    }
    return shard[None], stats


def make_twophase_write(mesh: jax.sharding.Mesh, layout: FileLayout,
                        cfg: IOConfig):
    """Build the jit-able baseline collective write.

    Inputs (global shapes, sharded over all three axes on dim 0):
      offsets/lengths [P, req_cap], count [P], data [P, data_cap]
    Output: file [n_nodes, domain_len] sharded over ``node``; stats.

    Domain-spanning requests are split at file-domain boundaries on
    both paths (the round path additionally splits at window
    boundaries), so each piece has exactly one owning aggregator —
    overflow shows up in ``dropped_requests``/``dropped_elems``, never
    as silent truncation. ``cfg.cb_buffer_size == "auto"`` resolves the
    round size via ``cost_model.optimal_cb`` at build time;
    ``cfg.pipeline`` overlaps each round's exchange with the previous
    round's drain.
    """
    node, lagg, lmem = cfg.axis_names
    n_nodes = mesh.shape[node]
    if layout.file_len % n_nodes:
        raise ValueError("file_len must divide evenly among aggregators")
    cfg = resolve_cb_buffer_size(layout, n_nodes, mesh.size, cfg)
    if cfg.cb_buffer_size is not None:  # validate the round partition now
        rounds.RoundScheduler(layout, n_nodes, cfg.cb_buffer_size)
    rank_spec = P((node, lagg, lmem))
    fn = partial(_twophase_shard_fn, layout, cfg, n_nodes)
    return shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(rank_spec, rank_spec, rank_spec, rank_spec),
        out_specs=(P(node), {"dropped_requests": P(), "dropped_elems": P(),
                             "requests_at_ga": P(node, )}),
    )


def make_twophase_read(mesh: jax.sharding.Mesh, layout: FileLayout,
                       cfg: IOConfig):
    """Baseline collective read: aggregators broadcast their file domains
    (all_gather over the slow axis), every rank gathers its own requests.
    With ``cb_buffer_size`` set, the broadcast is one window per round
    instead of the whole domain.
    """
    node, lagg, lmem = cfg.axis_names
    n_nodes = mesh.shape[node]
    cfg = resolve_cb_buffer_size(layout, n_nodes, mesh.size, cfg)
    domain_len = layout.file_len // n_nodes
    rank_spec = P((node, lagg, lmem))

    def fn(offsets, lengths, count, file_shard):
        r = mask_invalid(RequestList(offsets.reshape(-1),
                                     lengths.reshape(-1), count.reshape(())))
        starts = co.request_starts(r)
        if cfg.cb_buffer_size is not None:
            sched = rounds.RoundScheduler(layout, n_nodes,
                                          cfg.cb_buffer_size)
            out = rounds.exchange_rounds_read(
                sched, node, r, starts, file_shard.reshape(-1),
                cfg.data_cap, pipeline=cfg.pipeline)
            return out[None]
        whole = lax.all_gather(file_shard.reshape(-1), node, axis=0,
                               tiled=True)
        out = co.unpack_data(r, starts, whole, cfg.data_cap)
        return out[None]

    return shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(rank_spec, rank_spec, rank_spec, P(node)),
        out_specs=rank_spec,
    )


def write_reference(layout: FileLayout, offsets, lengths, counts, data):
    """Host-side oracle: scatter every rank's payload into a dense file."""
    import numpy as np

    file = np.zeros((layout.file_len,), dtype=np.asarray(data).dtype)
    offsets, lengths = np.asarray(offsets), np.asarray(lengths)
    counts, data = np.asarray(counts), np.asarray(data)
    for p in range(offsets.shape[0]):
        pos = 0
        for i in range(counts[p]):
            o, l = int(offsets[p, i]), int(lengths[p, i])
            file[o:o + l] = data[p, pos:pos + l]
            pos += l
    return file
