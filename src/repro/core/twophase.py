"""Baseline two-phase collective I/O (ROMIO-style) under SPMD.

This is the paper's comparison baseline: every rank routes its requests
directly to the global aggregator owning the destination file domain
(all-to-many), aggregators merge-sort the received offset-length pairs
and place payloads into their file-domain buffers.

Since the plan/executor split (ARCHITECTURE.md) this module is a thin
wrapper: :func:`make_twophase_write` / :func:`make_twophase_read`
compile the schedule once (``repro.core.plan.compile_plan``) and hand
the resulting :class:`~repro.core.plan.IOPlan` to the SPMD executor
(``repro.core.spmd_exec``). The single-shot exchange that used to live
here is the degenerate 1-round plan (``cb == domain_len``) — one code
path, every capability (rounds, depth-k pipelining, auto-tuned cb)
works identically for both schedules.

Mesh layout for collective I/O (see DESIGN.md §4): a 3-D view
``(node, lagg, lmem)`` of the device mesh —

* ``node`` — the slow boundary (across compute nodes / pods). One global
  aggregator per node (ROMIO's default), file domains are contiguous
  per-node slices.
* ``lagg`` × ``lmem`` — ranks within a node; ``lagg`` indexes local-
  aggregator slots (used by TAM; the baseline ignores the distinction).

SPMD note (DESIGN.md §7): MPI point-to-point congestion has no literal
XLA analogue; the all-to-many here is an ``all_to_all`` over the slow
axis plus intra-node merges. Congestion itself is reproduced by the
host-level path (``repro.checkpoint.host_io``) and the analytical model
(``repro.core.cost_model``).
"""
from __future__ import annotations

import jax

from repro.core.domains import FileLayout
# IOConfig and the "auto" cb resolution moved into the plan IR (PR 3);
# re-exported so existing imports keep working.
from repro.core.plan import (IOConfig, IOPlan, compile_plan,  # noqa: F401
                             resolve_cb_buffer_size)
from repro.core.spmd_exec import make_spmd_executor


def plan_for(layout: FileLayout, cfg: IOConfig, n_nodes: int,
             n_ranks: int, method: str = "twophase",
             direction: str = "write", machine=None,
             workload=None) -> IOPlan:
    """Compile the schedule the SPMD entry points execute: one global
    aggregator per node (contiguous file domains). This is the SPMD
    side of the plan-identity contract — the host entry point
    (``HostCollectiveIO.plan_for``) compiles the same :class:`IOPlan`
    for the same workload (asserted by tests/test_plan.py)."""
    return compile_plan(layout, cfg, n_aggregators=n_nodes,
                        n_nodes=n_nodes, n_ranks=n_ranks, method=method,
                        direction=direction, machine=machine,
                        workload=workload)


def make_twophase_write(mesh: jax.sharding.Mesh, layout: FileLayout,
                        cfg: IOConfig):
    """Build the jit-able baseline collective write.

    Inputs (global shapes, sharded over all three axes on dim 0):
      offsets/lengths [P, req_cap], count [P], data [P, data_cap]
    Output: file [n_nodes, domain_len] sharded over ``node``; stats.

    Domain-spanning requests are split at file-domain and window
    boundaries, so each piece has exactly one owning aggregator —
    overflow shows up in ``dropped_requests``/``dropped_elems``, never
    as silent truncation. ``cfg.cb_buffer_size == "auto"`` resolves the
    round size via ``cost_model.optimal_cb`` at plan time;
    ``cfg.pipeline`` runs the depth-``cfg.pipeline_depth`` window ring
    (byte-identical to serial for every depth).
    """
    node = cfg.axis_names[0]
    plan = plan_for(layout, cfg, mesh.shape[node], mesh.size)
    return make_spmd_executor(mesh, plan)


def make_twophase_read(mesh: jax.sharding.Mesh, layout: FileLayout,
                       cfg: IOConfig):
    """Baseline collective read: aggregators broadcast their file
    domains one ``cb`` window per round (the whole domain when
    ``cb_buffer_size`` is None — the 1-round plan), every rank gathers
    its own requests from the window."""
    node = cfg.axis_names[0]
    plan = plan_for(layout, cfg, mesh.shape[node], mesh.size,
                    direction="read")
    return make_spmd_executor(mesh, plan)


def write_reference(layout: FileLayout, offsets, lengths, counts, data):
    """Host-side oracle: scatter every rank's payload into a dense file."""
    import numpy as np

    file = np.zeros((layout.file_len,), dtype=np.asarray(data).dtype)
    offsets, lengths = np.asarray(offsets), np.asarray(lengths)
    counts, data = np.asarray(counts), np.asarray(data)
    for p in range(offsets.shape[0]):
        pos = 0
        for i in range(counts[p]):
            o, l = int(offsets[p, i]), int(lengths[p, i])
            file[o:o + l] = data[p, pos:pos + l]
            pos += l
    return file
