"""Transport shim: wire framing + the executor-transport registry.

The plan/executor split keeps the planner ignorant of HOW bytes move;
this module is the one place that knowledge lives for the real
multi-process backend (``checkpoint.mp_exec``). It owns

* the **wire framing** of the inter-node slow hop: length-prefixed
  frames over localhost TCP sockets, so every slow-hop message pays
  real serialization + kernel round trips and the frame sizes ARE the
  measured slow-hop byte counts (``IOTimings.slow_hop_slow_bytes`` on
  the mp backend is a sum of ``len(frame)`` values, not a model);
* the **transport registry**: the legal values of the
  ``IOConfig.transport`` knob, resolved by the planner pass
  ``core.passes.resolve_transport`` into ``IOPlan.transport``.

Frame layout (all integers big-endian):

``[u32 length][body]`` where ``body`` starts with a 28-byte header
``(kind, sender, g, round, n_req, raw_len, enc_len)`` (:data:`HDR`).

* ``KIND_BLOCK`` — one sender's (domain g, round r) write block: the
  header, then ``n_req`` interleaved ``(offset, length)`` int64 pairs
  (the request metadata that the alpha-beta model charges at
  ``PAIR_BYTES`` per request moves for real here), then ``enc_len``
  payload bytes (codec-encoded when the plan has a slow-hop codec —
  encode once, on the wire).
* ``KIND_COMBINED`` — a node-combined frame (the TAM path): one header
  per (g, round, sender NODE) with ``n_req`` reused as the subrecord
  count, then per co-located sender a 16-byte :data:`SUB` subheader
  ``(sender, n_req, raw_len, enc_len)`` + its pairs + payload. Flat
  two-phase pays a full frame per sender; the combined frame pays one
  frame plus 16 bytes per extra sender — the message-count collapse of
  intra-node aggregation, measurable on the wire.
* ``KIND_WINDOW`` — read direction: one cb window shipped from the
  serving side; ``sender`` is the destination rank, ``enc_len != 0``
  with ``enc_len != raw_len`` or the ``FLAG_ENCODED`` bit in ``kind``'s
  high byte marks a codec-encoded window the receiver must decode.

Adding a transport: implement ``execute_write``/``execute_read`` with
the :mod:`repro.checkpoint.host_exec` signatures (byte-identical
output is the contract — ``rounds_checks`` cross-checks every backend
against the host oracle), register its name in :data:`TRANSPORTS`, and
dispatch on ``plan.transport`` in ``checkpoint.host_io``.
"""
from __future__ import annotations

import socket
import struct

import numpy as np

# ---- registry --------------------------------------------------------

#: legal non-None values of the ``transport`` knob. ``None`` means the
#: in-process executor pair (SPMD or host) — no real transport.
TRANSPORTS: tuple[str, ...] = ("mp",)


def resolve_transport(name):
    """Validate a requested transport name (the planner-pass hook).

    ``None`` (in-process executors) passes through; anything else must
    be registered in :data:`TRANSPORTS`.
    """
    if name is not None and name not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {name!r}; known: {(None,) + TRANSPORTS}")
    return name


# ---- wire framing ----------------------------------------------------

KIND_BLOCK = 1      # one sender's (g, round) block        (write, flat)
KIND_COMBINED = 2   # node-combined blocks for (g, round)  (write, TAM)
KIND_WINDOW = 3     # one cb window                        (read)

FLAG_ENCODED = 1 << 8   # OR'd into kind: payload is codec-encoded

#: per-frame header: (kind, sender, g, round, n_req, raw_len, enc_len)
HDR = struct.Struct("!IIIIIII")
#: per-subrecord header inside KIND_COMBINED:
#: (sender, n_req, raw_len, enc_len)
SUB = struct.Struct("!IIII")
_LEN = struct.Struct("!I")

#: bytes of frame overhead a flat slow block pays (length prefix +
#: header) and a combined subrecord pays; combined saves
#: ``(FRAME_OVERHEAD - SUB_OVERHEAD)`` per co-located sender beyond the
#: frame's first.
FRAME_OVERHEAD = _LEN.size + HDR.size
SUB_OVERHEAD = SUB.size


def pack_pairs(po: np.ndarray, pl: np.ndarray) -> bytes:
    """Interleave (offset, length) request metadata as big-endian i64."""
    meta = np.empty(2 * int(po.size), dtype=">i8")
    meta[0::2] = po
    meta[1::2] = pl
    return meta.tobytes()


def unpack_pairs(buf: bytes, n_req: int) -> tuple[np.ndarray, np.ndarray]:
    meta = np.frombuffer(buf, dtype=">i8", count=2 * n_req)
    return meta[0::2].astype(np.int64), meta[1::2].astype(np.int64)


def pack_block(kind: int, sender: int, g: int, rnd: int,
               po: np.ndarray, pl: np.ndarray, payload,
               raw_len: int) -> bytes:
    """One KIND_BLOCK / KIND_WINDOW body (header + pairs + payload)."""
    payload = bytes(payload)
    return (HDR.pack(kind, sender, g, rnd, int(po.size), int(raw_len),
                     len(payload))
            + pack_pairs(po, pl) + payload)


def unpack_block(body: bytes):
    """Inverse of :func:`pack_block`; returns
    ``(kind, sender, g, rnd, po, pl, payload, raw_len)``."""
    kind, sender, g, rnd, n_req, raw_len, enc_len = \
        HDR.unpack_from(body, 0)
    pos = HDR.size
    po, pl = unpack_pairs(body[pos:pos + 16 * n_req], n_req)
    pos += 16 * n_req
    return kind, sender, g, rnd, po, pl, body[pos:pos + enc_len], raw_len


def send_msg(sock: socket.socket, body: bytes) -> int:
    """Send one length-prefixed frame; returns the wire bytes moved
    (prefix included) — the unit the mp backend's slow-hop byte
    accounting sums."""
    sock.sendall(_LEN.pack(len(body)) + body)
    return _LEN.size + len(body)


def recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly n bytes; None on a clean EOF at a frame boundary."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise ConnectionError(
                f"socket EOF mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> bytes | None:
    """Receive one frame body (None on orderly EOF between frames)."""
    raw = recv_exact(sock, _LEN.size)
    if raw is None:
        return None
    (n,) = _LEN.unpack(raw)
    body = recv_exact(sock, n)
    if body is None:
        raise ConnectionError("socket EOF after frame length prefix")
    return body
