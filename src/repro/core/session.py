"""Persistent collective-I/O sessions: plan reuse + measured feedback.

Production checkpoint loops repeat the SAME I/O pattern hundreds of
times, yet the planner re-paid the expensive part of every write —
measuring the workload (an O(total_bytes) zero scan when a codec is
weighed), sweeping the cb candidates, re-deriving the topology — on
every call. An :class:`IOSession` is the cross-write memory that
amortizes it:

* **Plan cache.** Compiled :class:`~repro.core.plan.IOPlan`\\ s are
  cached under a key derived from (layout, config): the writer's shape
  (ranks, nodes, striping), the request set's fingerprint (extent,
  total bytes, request count), and every requested knob *as requested*
  (``"auto"`` included). An identical write is a cache hit — the plan
  is reused as-is, planning cost ~0. A changed layout or config is a
  different key and compiles fresh. The cache-key contract is exactly
  plan determinism: ``compile_plan`` is a pure function of its inputs
  (property-tested in tests/test_plan_property.py), so a cached plan
  IS the plan a recompile would produce.

* **Measured feedback.** After each write the session ingests the
  executor's measurements (:class:`IOTimings`): executed rounds, the
  per-round comm/drain arrays, the achieved slow-hop compression
  ratio, and the per-(domain, sender-node) byte matrix. On the next
  write of the same key, every knob the caller left ``"auto"`` is
  re-resolved against the MEASUREMENT instead of the model's
  assumptions — ``rounds_override`` for cb, ``optimal_depth`` over the
  measured round times, ``resolve_slow_hop_codec`` at the measured
  ratio, ``resolve_placement`` over the measured node-byte matrix —
  the ``Workload.rounds_override`` measured-beats-assumed pattern
  promoted to a cross-write loop.

* **Replan only when it pays.** A re-resolution that produces new
  knobs runs ONCE as a trial; from then on every write executes the
  best plan BY MEASURED TOTAL seen so far (ties keep the incumbent).
  The executed total is the final arbiter, so the steady state is
  monotone: it never runs a plan that measured worse than the first
  write's (asserted by tests/test_session.py and gated in
  ``benchmarks/check_regression.py``).

``HostCollectiveIO(session=...)`` / ``write(session=...)`` and
``CheckpointManager(session=...)`` consume this; the SPMD side can use
:meth:`IOSession.compile` as a caching front-end to ``compile_plan``.

Reads drive the same protocol (:meth:`IOSession.begin_read`, an alias
— the state machine is key-generic): ``HostCollectiveIO.read`` keys
its entries on the READER's shape, the manifest fingerprint, the
node-cache flag, and the requested knobs, and feeds the read
executor's measured totals back through the same arbiter. The
steady-state guarantee carries over verbatim: a repeated restore never
executes a plan that measured worse than its first restore's.
"""
from __future__ import annotations

import functools
import threading
from dataclasses import dataclass, field

from repro.core import cost_model as cm
from repro.core import faults as faults_mod
from repro.core import placement as placement_mod
from repro.core.plan import (IOPlan, compile_plan, resolve_method,
                             resolve_slow_hop_codec)


def _knobs_of(plan: IOPlan) -> tuple:
    """The tuning-relevant fingerprint of a compiled plan (what a
    refinement can change; two plans with equal knobs execute — and
    therefore measure — identically, the model being deterministic)."""
    return (plan.method, plan.cb, plan.pipeline_depth,
            plan.slow_hop_codec, plan.placement)


def _arb_key(plan: IOPlan, serve_map) -> tuple:
    """The arbiter key: the plan's knobs PLUS the execution-level serve
    map (a degraded evacuation is a distinct thing-to-measure even when
    the compiled plan is unchanged — core.faults.evacuation_map)."""
    return _knobs_of(plan) + (tuple(serve_map) if serve_map is not None
                              else None,)


def _locked(fn):
    """Serialize a session method on the instance's re-entrant lock —
    the async checkpoint drain thread and the foreground caller share
    one session (see the class docstring's thread-safety note)."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)
    return wrapper


#: "no measurement ingested yet" sentinel for _Entry.executor — None is
#: a real identity (the in-process executors), so it cannot serve
_UNOBSERVED: object = object()


@dataclass
class _Entry:
    plan: IOPlan                      # first-compiled plan
    requested: dict                   # knobs as the caller spelled them
    workload: object | None           # measured cost_model.Workload
    cb_candidates: tuple = ()
    P_L: int | None = None
    n_nodes: int = 1
    n_aggregators: int = 1
    plans: dict = field(default_factory=dict)    # arb key -> IOPlan
    serve_maps: dict = field(default_factory=dict)  # arb key -> serve map
    totals: dict = field(default_factory=dict)   # arb key -> measured total
    best_knobs: tuple | None = None
    feedback: dict = field(default_factory=dict)
    executor: object = _UNOBSERVED    # IOTimings.transport of the totals
    writes: int = 0
    refined: bool = False

    def best_plan(self) -> IOPlan:
        if self.best_knobs is not None and self.best_knobs in self.plans:
            return self.plans[self.best_knobs]
        return self.plan

    def best_serve_map(self):
        if self.best_knobs is not None:
            return self.serve_maps.get(self.best_knobs)
        return None


class IOSession:
    """Cross-write plan cache + measured-feedback tuner (see module
    docstring). One session serves any number of distinct workloads —
    each (layout, config) key gets its own entry — so a single session
    can back a whole checkpoint manager.

    Thread safety: every protocol step (begin/register/observe/abort/
    compile) takes the session's re-entrant lock, so an ASYNC
    checkpoint drain (checkpoint.PendingCheckpoint's daemon thread)
    can feed measured timings back through :meth:`observe` without
    corrupting an entry a foreground caller is reading. Trial
    ORDERING is the caller's contract: ``CheckpointManager`` keeps at
    most one write in flight, so a background drain's feedback never
    interleaves with a foreground trial of the same key mid-protocol.
    """

    def __init__(self, machine=None):
        self.machine = machine or cm.Machine()
        self._entries: dict = {}
        self._compiled: dict = {}     # compile() front-end cache
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.replans = 0

    # ------------------------------------------------------------------
    # generic plan-compile cache (the SPMD-side entry point)
    # ------------------------------------------------------------------
    @_locked
    def compile(self, layout, cfg, **kwargs) -> IOPlan:
        """Caching front-end to :func:`repro.core.plan.compile_plan`:
        identical (layout, cfg, kwargs) return the SAME plan object
        without recompiling — sound because ``compile_plan`` is
        deterministic (the session-cache-key contract,
        tests/test_plan_property.py)."""
        key = (layout, cfg, tuple(sorted(
            (k, v if not isinstance(v, list) else tuple(v))
            for k, v in kwargs.items() if k not in ("machine", "workload"))))
        extra = {k: kwargs[k] for k in ("machine", "workload")
                 if k in kwargs}
        if extra:     # unhashable inputs: compile through, no caching
            return compile_plan(layout, cfg, **kwargs)
        if key in self._compiled:
            self.hits += 1
            return self._compiled[key]
        self.misses += 1
        plan = compile_plan(layout, cfg, **kwargs)
        self._compiled[key] = plan
        return plan

    # ------------------------------------------------------------------
    # the write-path protocol (HostCollectiveIO.write drives this)
    # ------------------------------------------------------------------
    @_locked
    def begin_write(self, key, machine=None) -> tuple[str, object]:
        """Start a write under ``key``. Returns one of:

        * ``("miss", None)`` — no entry: compile a fresh plan and hand
          it back through :meth:`register`;
        * ``("trial", knobs_dict)`` — measured feedback re-resolved the
          ``"auto"`` knobs to something untried: compile a plan with
          these CONCRETE knobs (cheap — nothing left to sweep) and
          register it with :meth:`register_trial`. The dict's
          ``"serve_map"`` entry (usually ``None``) is the degraded
          evacuation map to execute the trial under;
        * ``("hit", (plan, serve_map))`` — reuse the best measured
          (plan, serve map) pair as-is.

        ``machine`` is the WRITER's calibration — refinements must
        resolve under the same machine the first write's autos did, not
        this session's default.

        Refinement normally runs ONCE per entry; :meth:`observe` re-arms
        it when the measured feedback materially changes (a node's
        service rate shifting — a straggler appearing or clearing), so
        a mid-session degradation triggers a fresh trial on the very
        next write instead of being locked out by the one-shot flag.
        """
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return "miss", None
        self.hits += 1
        if entry.feedback and not entry.refined:
            entry.refined = True
            knobs = self._refine(entry, machine or self.machine)
            if knobs is not None:
                tried = set(entry.totals) | {_arb_key(entry.plan, None)}
                serve = knobs.get("serve_map")
                as_tuple = (knobs["method"], knobs["cb_bytes"],
                            knobs["pipeline_depth"],
                            knobs["slow_hop_codec"], knobs["placement"],
                            tuple(serve) if serve is not None else None)
                if as_tuple not in tried:
                    self.replans += 1
                    return "trial", knobs
        return "hit", (entry.best_plan(), entry.best_serve_map())

    # The protocol is key-generic: nothing in begin/register/observe is
    # write-specific, so the read path (HostCollectiveIO.read) drives
    # the SAME state machine under read-marked keys — reads lead their
    # key with a "read" tag plus the node-cache flag, so a read entry
    # never collides with a write of the same shape. ``begin_read`` is
    # the read-path spelling of that reuse.
    begin_read = begin_write

    @_locked
    def register(self, key, plan: IOPlan, *, requested: dict,
                 workload=None, cb_candidates=(), P_L=None,
                 n_nodes: int = 1, n_aggregators: int = 1) -> None:
        """Record the first-compiled plan for ``key`` (the miss path).
        ``workload`` is the measured ``cost_model.Workload`` the autos
        resolved against — stored so refinements never re-pay the
        measurement."""
        self._entries[key] = _Entry(
            plan=plan, requested=dict(requested), workload=workload,
            cb_candidates=tuple(cb_candidates), P_L=P_L,
            n_nodes=n_nodes, n_aggregators=n_aggregators)
        self._entries[key].plans[_arb_key(plan, None)] = plan

    @_locked
    def register_trial(self, key, plan: IOPlan, serve_map=None) -> None:
        entry = self._entries[key]
        ak = _arb_key(plan, serve_map)
        entry.plans[ak] = plan
        if serve_map is not None:
            entry.serve_maps[ak] = tuple(serve_map)

    @_locked
    def abort(self, key, plan: IOPlan | None = None) -> None:
        """A write under ``key`` raised before :meth:`observe` ran.
        Revert the trial bookkeeping so the entry is not poisoned: every
        registered plan with NO measured total (the half-registered
        trial) is dropped, and the one-shot refinement flag is re-armed
        so the next write may re-trial. Without this, an aborted trial
        left the entry holding knobs that would never be measured and
        never retried — silently freezing the tuner."""
        entry = self._entries.get(key)
        if entry is None:
            return
        first = _arb_key(entry.plan, None)
        stale = [ak for ak in entry.plans
                 if ak not in entry.totals and ak != first]
        if plan is not None:
            stale = [ak for ak in stale if entry.plans[ak] is plan
                     or ak[:5] == _knobs_of(plan)]
        for ak in stale:
            entry.plans.pop(ak, None)
            entry.serve_maps.pop(ak, None)
        entry.refined = False

    @_locked
    def observe(self, key, plan: IOPlan, timings, serve_map=None) -> None:
        """Feed one write's measurements back: the executed total
        decides the incumbent (strictly-better wins, ties keep), and
        the per-round arrays / ratio / node-byte matrix / per-node
        slowdown become the next refinement's inputs. A material shift
        in the measured per-node service rates (straggler appearing or
        clearing) re-arms the one-shot refinement flag."""
        entry = self._entries.get(key)
        if entry is None:
            return
        entry.writes += 1
        # measured totals are executor-relative: the in-process
        # executors report MODELED time, the mp transport reports
        # wall-clock. If the backend that produced this measurement
        # differs from the one whose totals the entry holds, the stored
        # numbers are incomparable with the new one — arbitrating
        # across them would crown a plan on the wrong clock. Drop them
        # and start the arbiter fresh on the new executor's scale.
        ident = getattr(timings, "transport", None)
        if entry.executor is not _UNOBSERVED and entry.executor != ident:
            entry.totals.clear()
            entry.best_knobs = None
        entry.executor = ident
        ak = _arb_key(plan, serve_map)
        entry.plans.setdefault(ak, plan)
        if serve_map is not None:
            entry.serve_maps[ak] = tuple(serve_map)
        entry.totals[ak] = float(timings.total)
        if entry.best_knobs is None:
            entry.best_knobs = ak
        else:
            # re-elect the argmin (not just promote strictly-better
            # newcomers): re-measuring the INCUMBENT under a degraded
            # machine overwrites its total in place, and the crown must
            # move to whatever now measures best. Ties keep the
            # earliest-measured plan (insertion order), preserving the
            # healthy-path tie-to-incumbent semantics.
            best = entry.best_knobs
            for k2, v in entry.totals.items():
                if v < entry.totals[best] - 1e-15:
                    best = k2
            entry.best_knobs = best
        fb = entry.feedback
        fb["rounds"] = int(getattr(timings, "rounds_executed", 1))
        if getattr(timings, "comm_rounds", ()):
            fb["round_times"] = (tuple(timings.comm_rounds),
                                 tuple(timings.io_rounds))
        if getattr(timings, "slow_hop_codec", None) is not None:
            fb["ratio"] = float(timings.slow_hop_compression_ratio)
        if getattr(timings, "node_bytes", ()):
            fb["node_bytes"] = tuple(tuple(row)
                                     for row in timings.node_bytes)
        new_sd = tuple(float(s) for s in
                       getattr(timings, "node_slowdown", ()) or ())
        if new_sd:
            old_sd = fb.get("node_slowdown")
            fb["node_slowdown"] = new_sd
            changed = (any(abs(a - b) > 0.25
                           for a, b in zip(new_sd, old_sd))
                       if old_sd is not None
                       else max(new_sd) > 1.25)
            if changed:
                entry.refined = False   # re-arm: the machine moved

    @_locked
    def entry(self, key) -> _Entry | None:
        return self._entries.get(key)

    # ------------------------------------------------------------------
    def _refine(self, entry: _Entry, machine=None) -> dict | None:
        """Re-resolve the requested ``"auto"`` knobs against the
        measurement (measured-beats-assumed, across writes). Returns a
        concrete knob dict, or ``None`` when nothing was auto or no
        measurement informs a change."""
        req = entry.requested
        autos = [k for k in ("method", "cb_bytes", "pipeline_depth",
                             "slow_hop_codec", "placement")
                 if req.get(k) == "auto"]
        if not autos or entry.workload is None:
            return None
        m = machine or self.machine
        fb = entry.feedback
        base = entry.best_plan()
        w = cm.with_measured_rounds(entry.workload,
                                    fb.get("rounds", base.n_rounds))
        if "ratio" in fb and base.slow_hop_codec is not None:
            # the achieved wire ratio replaces the zero-scan estimate
            w = cm.with_codec(w, max(fb["ratio"], 1.0))

        codec = base.slow_hop_codec
        if "slow_hop_codec" in autos:
            codec = resolve_slow_hop_codec(w, m)
        method = base.method
        if "method" in autos:
            method = resolve_method(w, m)
        P_L = entry.P_L if method == "tam" else None
        cb = base.cb
        if "cb_bytes" in autos and entry.cb_candidates:
            cb, _ = cm.optimal_cb(w, m, P_L=P_L,
                                  candidates=entry.cb_candidates)
        depth = base.pipeline_depth
        if "pipeline_depth" in autos and "round_times" in fb:
            depth, _ = cm.optimal_depth(round_times=fb["round_times"])
        placement = base.placement
        sd = fb.get("node_slowdown")
        serve_map = None
        if "placement" in autos and ("node_bytes" in fb
                                     or sd is not None):
            placement = placement_mod.resolve_placement(
                "auto", entry.n_aggregators, entry.n_nodes, workload=w,
                machine=m, node_bytes=fb.get("node_bytes"),
                node_slowdown=sd)
            # degraded half: past the straggler threshold a bijection
            # cannot unload the node (it still serves its slot count),
            # so resolve an execution-level evacuation map on top —
            # overflow domains serialize on healthy slots, the
            # straggler's slots go idle (core.faults; the plan and its
            # SPMD identity stay bijective)
            if sd is not None:
                db = ([sum(row) for row in fb["node_bytes"]]
                      if "node_bytes" in fb else None)
                serve_map = faults_mod.evacuation_map(
                    entry.n_aggregators, entry.n_nodes, sd,
                    domain_bytes=db)
        return {"method": method, "cb_bytes": cb,
                "pipeline_depth": depth, "slow_hop_codec": codec,
                "placement": placement, "serve_map": serve_map}
