"""Round-scheduled bounded-buffer exchange engine (DESIGN).

Why rounds
----------
ROMIO's Lustre driver (paper §II) never materializes a whole file
domain worth of incoming traffic at an aggregator: the two-phase
exchange runs in ROUNDS, each bounded by the aggregator's collective
buffer (``cb_buffer_size``, romio_cb_buffer_size). Our analytical model
already charges for this (``cost_model`` refinement 1: each round
re-runs the request exchange and re-pays the incast latency), but the
single-shot SPMD paths in ``twophase``/``tam`` exchanged everything at
once, so aggregator-side receive buffers grew as
``O(P * data_cap)`` — the per-rank payload capacity times every
participating rank. That caps the file size a fixed mesh can drive.

The protocol
------------
Aggregator ``g`` owns the contiguous file domain
``[g * domain_len, (g+1) * domain_len)``. :class:`RoundScheduler`
partitions every domain into ``domain_len / cb_buffer_size``
stripe-aligned windows; round ``t`` moves exactly the requests whose
offsets fall in window ``t`` of their destination domain:

1. **split** — requests are split at window boundaries once, up front
   (``requests.split_at_stripes``), so each request lives in exactly one
   (destination, round) window;
2. **select** — per round, the active requests are compacted to the
   front of a static-capacity list (offset order preserved);
3. **exchange** — the existing ``bucket_by_dest`` / ``all_to_all`` /
   ``flatten_buckets`` / ``sort_with`` pipeline runs with per-bucket
   payload capacity ``min(data_cap, cb_buffer_size)``;
4. **pack + merge** — each rank packs its received slice into a
   ``cb_buffer_size`` window image and the images are merged across the
   node's other receive streams with a masked max-combine
   (``lax.pmax``), NOT a gather: the merge buffer stays
   ``O(cb_buffer_size)`` instead of ``O(ranks_per_node * data_cap)``;
5. **accumulate** — the window is written into the carried domain
   buffer at ``t * cb_buffer_size`` and the loop (``lax.fori_loop``, so
   compiled size is round-count independent) advances.

Peak aggregator-side buffering is therefore
``n_nodes * min(data_cap, cb) + cb`` elements — independent of the
number of participating ranks (see
:func:`peak_aggregator_buffer_elems`, asserted by tests/test_rounds.py).
The same mesh can drive arbitrarily large files by holding
``cb_buffer_size`` fixed while rounds grow.

The pipeline (``pipeline=True``)
--------------------------------
The serial loop pays ``exchange + drain`` per round. The pipelined loop
is a classic software pipeline over TWO in-flight window buffers:

* **prologue** — round 0 is exchanged into buffer A; nothing drains.
* **steady state** — iteration ``t`` (1..n_rounds-1) exchanges round
  ``t`` into the free buffer while DRAINING the carried buffer from
  round ``t-1`` (flatten → sort → pack → masked pmax merge →
  accumulate). The two halves share no data, so XLA is free to run the
  slow-axis ``all_to_all`` concurrently with the local merge — each
  steady-state round costs ``max(comm, drain)`` instead of their sum
  (the host path's ``IOTimings`` measures exactly this, and
  ``cost_model.Workload.overlap`` models it).
* **epilogue** — the last carried buffer (round n_rounds-1) drains;
  nothing is exchanged.

Buffer ownership: the exchanged-but-undrained window (the ``rx`` tuple
of post-``all_to_all`` buckets) is the loop carry — buffer A; the
buffer being refilled by the current exchange is buffer B. They swap
roles every iteration, so exactly two ``n_nodes * min(data_cap, cb)``
receive images are ever live (``peak_aggregator_buffer_elems`` with
``pipeline=True``).

Byte-identity: the pipeline only re-associates WHEN each round's drain
runs, not WHAT it drains — every round's received buckets pass through
the identical drain (same sort, same pack base ``t * cb``, same pmax
merge) exactly once, and rounds still accumulate into disjoint
``[t*cb, (t+1)*cb)`` slices of the domain buffer, so the result is
bit-identical to the serial loop (asserted by
``repro/testing/rounds_checks.py`` for round counts {1, 2, 5}).

Round-aware TAM stage 1
-----------------------
:func:`exchange_rounds_write_tam` fuses BOTH TAM layers into the same
window loop: per round, ranks ship only the window's requests to their
local aggregator (the ``lmem`` gather is bounded at
``min(data_cap, cb)`` per rank instead of ``data_cap``), the LA
sorts/coalesces that window, and the coalesced window flows through the
same slow-axis exchange + pmax drain. Local-aggregator memory is then
``ranks_per_node * min(data_cap, cb)`` — O(cb) for cb < data_cap —
instead of ``ranks_per_node * data_cap`` (the ``tam_stage1_*`` keys of
:func:`peak_aggregator_buffer_elems`).

Semantics: concurrently written regions must not overlap (the MPI
standard leaves overlapping collective writes undefined); when they do,
the masked max-combine resolves each element deterministically to the
maximum written value, and capacity overflow is reported through the
``dropped_requests`` / ``dropped_elems`` stats, never silent.

Cost-model coupling
-------------------
The executed round count is ``RoundScheduler.n_rounds`` ==
``cost_model.Workload.rounds`` when ``rounds_override`` is wired from a
measured run (``IOTimings.rounds_executed`` on the host path). Each
round pays ``alpha_eff(senders)`` once (incast refinement 2), which is
exactly what ``HostCollectiveIO.write(cb_bytes=...)`` times; with
``pipeline=True`` the steady-state rounds overlap that latency with the
drain (refinement 4), and ``cost_model.optimal_cb`` picks the cb
balancing incast latency, memory, and round count.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import coalesce as co
from repro.core.domains import FileLayout
from repro.core.exchange import (bucket_by_dest, flatten_buckets,
                                 repack_sorted, sort_with)
from repro.core.requests import PAD_OFFSET, RequestList, split_at_stripes


@dataclass(frozen=True)
class RoundScheduler:
    """Static partition of each aggregator's file domain into rounds.

    layout:         striped file layout (element units).
    n_aggregators:  global aggregators (== slow-axis size in SPMD).
    cb_buffer_size: collective-buffer elements per aggregator per round;
                    ``None`` = one round == the single-shot behavior.
    """

    layout: FileLayout
    n_aggregators: int
    cb_buffer_size: int | None = None

    def __post_init__(self):
        if self.layout.file_len % self.n_aggregators:
            raise ValueError("file_len must divide evenly among aggregators")
        cb = self.cb
        if self.domain_len % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must divide domain_len "
                f"{self.domain_len} (stripe-aligned rounds)")
        s = self.layout.stripe_size
        if cb % s and s % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must align with stripe_size {s}")

    @property
    def domain_len(self) -> int:
        return self.layout.file_len // self.n_aggregators

    @property
    def cb(self) -> int:
        return (self.cb_buffer_size if self.cb_buffer_size is not None
                else self.domain_len)

    @property
    def n_rounds(self) -> int:
        return -(-self.domain_len // self.cb)

    def max_spans(self, data_cap: int) -> int:
        """Windows one request (length <= data_cap) can straddle."""
        return data_cap // self.cb + 2

    def window_of(self, offsets: jax.Array) -> jax.Array:
        """Round in which an offset is exchanged (domain-local window)."""
        return (offsets % self.domain_len) // self.cb


def _compact_active(r: RequestList, starts: jax.Array, dest: jax.Array,
                    active: jax.Array):
    """Move the active requests to the front, preserving offset order."""
    off = jnp.where(active, r.offsets, PAD_OFFSET)
    ln = jnp.where(active, r.lengths, 0)
    order = jnp.argsort(jnp.where(active, 0, 1).astype(jnp.int32),
                        stable=True)
    return (RequestList(off[order], ln[order],
                        jnp.sum(active, dtype=jnp.int32)),
            starts[order], dest[order])


def _lowest(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def _make_drain(base0, cb: int, merge_axes: tuple[str, ...], dtype):
    """Drain closure: merge one round's received buckets into the
    carried domain buffer (flatten → sort → pack window → masked pmax
    merge → accumulate at ``t * cb``)."""
    low = _lowest(dtype)

    def drain(t, buf, rx):
        merged, starts_m, data_flat = flatten_buckets(*rx)
        sorted_r, starts_s = sort_with(merged, starts_m)
        base = base0 + t * cb
        win = co.pack_data(sorted_r, starts_s, data_flat, cb, base=base)
        mask = co.pack_data(sorted_r, starts_s,
                            jnp.ones_like(data_flat), cb, base=base)
        comb = lax.pmax(jnp.where(mask != 0, win, low), merge_axes)
        anyw = lax.pmax(mask, merge_axes)
        final = jnp.where(anyw != 0, comb, jnp.zeros((), dtype))
        buf = lax.dynamic_update_slice(buf, final, (t * cb,))
        return buf, (merged.count,)

    return drain


def _run_rounds(n_rounds: int, domain_len: int, dtype, exchange, drain,
                n_ex_stats: int, n_dr_stats: int, pipeline: bool):
    """Drive the round loop, serial or software-pipelined.

    ``exchange(t) -> (rx, ex_stats)`` produces round t's received
    buckets; ``drain(t, buf, rx) -> (buf, dr_stats)`` merges them into
    the domain buffer. Stats tuples are accumulated elementwise.
    Pipelined: prologue exchanges round 0; steady-state iteration t
    exchanges round t while draining round t-1 (the carried ``rx`` is
    the second in-flight window buffer); epilogue drains the last round.
    """
    zeros = tuple(jnp.int32(0) for _ in range(n_ex_stats + n_dr_stats))

    def add(acc, delta, base):
        return tuple(a + d for a, d in zip(acc[base:base + len(delta)],
                                           delta))

    buf0 = jnp.zeros((domain_len,), dtype)
    if not pipeline:
        def body(t, carry):
            buf, acc = carry
            rx, ex = exchange(t)
            buf, dr = drain(t, buf, rx)
            return buf, add(acc, ex, 0) + add(acc, dr, n_ex_stats)

        buf, acc = lax.fori_loop(0, n_rounds, body, (buf0, zeros))
        return buf, acc[:n_ex_stats], acc[n_ex_stats:]

    rx0, ex0 = exchange(0)                       # prologue: fill buffer A

    def body(t, carry):
        buf, rx_prev, acc = carry
        rx_next, ex = exchange(t)                # refill the free buffer …
        buf, dr = drain(t - 1, buf, rx_prev)     # … while draining t-1
        return buf, rx_next, add(acc, ex, 0) + add(acc, dr, n_ex_stats)

    init_acc = ex0 + tuple(jnp.int32(0) for _ in range(n_dr_stats))
    buf, rx_last, acc = lax.fori_loop(1, n_rounds, body,
                                      (buf0, rx0, init_acc))
    buf, dr = drain(n_rounds - 1, buf, rx_last)  # epilogue: last drain
    acc = acc[:n_ex_stats] + tuple(
        a + d for a, d in zip(acc[n_ex_stats:], dr))
    return buf, acc[:n_ex_stats], acc[n_ex_stats:]


def exchange_rounds_write(sched: RoundScheduler, node_axis: str,
                          merge_axes: tuple[str, ...], r: RequestList,
                          starts: jax.Array, data: jax.Array,
                          pipeline: bool = False):
    """Round loop of the collective write (runs inside a shard_map body).

    r/starts/data: this sender's offset-sorted requests, the payload
    start of each request inside ``data``, and the packed payload.
    ``pipeline=True`` double-buffers: round t+1's exchange overlaps
    round t's drain (byte-identical to the serial loop — see the module
    docstring). Returns (domain shard [domain_len], stats dict);
    ``requests_at_ga`` is already summed over ``merge_axes`` (replicated
    at the node).
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    data_cap = data.shape[0]
    split = split_at_stripes(r, cb, sched.max_spans(data_cap))
    s_starts = co.request_starts(split)
    dest = (split.offsets // dl).astype(jnp.int32)
    window = sched.window_of(split.offsets)
    round_req_cap = min(split.capacity, cb)
    round_data_cap = min(data_cap, cb)
    base0 = lax.axis_index(node_axis) * dl
    a2a = partial(lax.all_to_all, axis_name=node_axis, split_axis=0,
                  concat_axis=0, tiled=True)

    def exchange(t):
        active = split.valid_mask() & (window == t)
        act_r, act_starts, act_dest = _compact_active(split, s_starts,
                                                      dest, active)
        act_data = repack_sorted(act_r, act_starts, data, data_cap)
        b = bucket_by_dest(act_r, co.request_starts(act_r), act_data,
                           act_dest, n_dest, round_req_cap, round_data_cap)
        rx = (a2a(b.offsets), a2a(b.lengths), a2a(b.counts), a2a(b.data))
        return rx, (b.dropped_requests, b.dropped_elems)

    drain = _make_drain(base0, cb, merge_axes, data.dtype)
    buf, (drop_r, drop_e), (reqs_rx,) = _run_rounds(
        sched.n_rounds, dl, data.dtype, exchange, drain, 2, 1, pipeline)
    return buf, {
        "dropped_requests": drop_r,
        "dropped_elems": drop_e,
        "requests_at_ga": lax.psum(reqs_rx, merge_axes),
    }


def exchange_rounds_write_tam(sched: RoundScheduler, node_axis: str,
                              lagg_axis: str, lmem_axis: str,
                              r: RequestList, starts: jax.Array,
                              data: jax.Array,
                              coalesce_cap: int | None = None,
                              use_kernels: bool = False,
                              pipeline: bool = False):
    """Fused TAM round loop: BOTH aggregation layers run per window.

    Per round t, stage 1 gathers only the window's requests over
    ``lmem_axis`` (per-rank payload bounded at ``min(data_cap, cb)``),
    the local aggregator sorts/coalesces/repacks that window, and
    stage 2 exchanges the coalesced window over ``node_axis`` with the
    pmax merge over ``lagg_axis`` — so local-aggregator memory is
    O(cb) too, not just the global aggregator's (ROADMAP item).
    ``pipeline=True`` overlaps round t+1's two-layer exchange with
    round t's drain, as in :func:`exchange_rounds_write`.

    Returns (domain shard, stats). ``*_rank`` drop stats are per-rank
    (pre-gather — psum over all axes); ``*_agg`` drops and the
    before/after coalesce counts are replicated across ``lmem_axis``
    (post-gather — divide the psum by the lmem size).
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    data_cap = data.shape[0]
    split = split_at_stripes(r, cb, sched.max_spans(data_cap))
    s_starts = co.request_starts(split)
    dest0 = (split.offsets // dl).astype(jnp.int32)
    window = sched.window_of(split.offsets)
    rcap = min(split.capacity, cb)       # stage-1 requests/rank/round
    rdcap = min(data_cap, cb)            # stage-1 payload/rank/round
    base0 = lax.axis_index(node_axis) * dl
    a2a = partial(lax.all_to_all, axis_name=node_axis, split_axis=0,
                  concat_axis=0, tiled=True)
    g = partial(lax.all_gather, axis_name=lmem_axis, axis=0, tiled=False)
    idx = jnp.arange(split.capacity, dtype=jnp.int32)

    def exchange(t):
        # ---- stage 1: window-bounded intra-node aggregation ---------
        active = split.valid_mask() & (window == t)
        act_r, act_starts, _ = _compact_active(split, s_starts, dest0,
                                               active)
        drop_rank_r = jnp.maximum(act_r.count - rcap, 0)
        drop_rank_e = jnp.sum(jnp.where(idx >= rcap, act_r.lengths, 0),
                              dtype=jnp.int32)
        win_r = RequestList(act_r.offsets[:rcap], act_r.lengths[:rcap],
                            jnp.minimum(act_r.count, rcap))
        drop_rank_e = drop_rank_e + jnp.maximum(
            jnp.sum(win_r.lengths, dtype=jnp.int32) - rdcap, 0)
        win_data = repack_sorted(win_r, act_starts[:rcap], data, rdcap)
        all_off, all_len, all_cnt, all_data = (
            g(win_r.offsets), g(win_r.lengths), g(win_r.count),
            g(win_data))
        m = all_off.shape[0]
        merged, starts_m, data_flat = flatten_buckets(all_off, all_len,
                                                      all_cnt, all_data)
        if use_kernels:
            from repro.kernels import ops as kops
            sorted_r, starts_s = kops.sort_requests_with(merged, starts_m)
            packed = repack_sorted(sorted_r, starts_s, data_flat, m * rdcap)
            coal = kops.coalesce(sorted_r)
        else:
            sorted_r, starts_s = sort_with(merged, starts_m)
            packed = repack_sorted(sorted_r, starts_s, data_flat, m * rdcap)
            coal = co.coalesce_sorted(sorted_r)
        ccap = min(coalesce_cap or coal.capacity, coal.capacity)
        drop_agg_r = jnp.maximum(coal.count - ccap, 0)
        agg = RequestList(coal.offsets[:ccap], coal.lengths[:ccap],
                          jnp.minimum(coal.count, ccap))
        # a coalesced run can escape its window only when cb == dl (the
        # last window of domain d touches window 0 of domain d+1, both
        # live in the single round) — re-split at the domain boundary so
        # each forwarded request has exactly one owner
        agg = split_at_stripes(agg, dl, m * rdcap // dl + 2)
        # ---- stage 2: slow-axis exchange of the coalesced window ----
        dest = (agg.offsets // dl).astype(jnp.int32)
        b = bucket_by_dest(agg, co.request_starts(agg), packed, dest,
                           n_dest, min(agg.capacity, cb),
                           min(m * rdcap, cb))
        rx = (a2a(b.offsets), a2a(b.lengths), a2a(b.counts), a2a(b.data))
        return rx, (drop_rank_r, drop_rank_e,
                    b.dropped_requests + drop_agg_r, b.dropped_elems,
                    merged.count, agg.count)

    drain = _make_drain(base0, cb, (lagg_axis,), data.dtype)
    buf, ex_acc, dr_acc = _run_rounds(
        sched.n_rounds, dl, data.dtype, exchange, drain, 6, 1, pipeline)
    (drop_rank_r, drop_rank_e, drop_agg_r, drop_agg_e,
     n_before, n_after) = ex_acc
    return buf, {
        "dropped_requests_rank": drop_rank_r,
        "dropped_elems_rank": drop_rank_e,
        "dropped_requests_agg": drop_agg_r,
        "dropped_elems_agg": drop_agg_e,
        "requests_before_coalesce": n_before,
        "requests_after_coalesce": n_after,
        "requests_at_ga": lax.psum(dr_acc[0], (lagg_axis,)),
    }


def exchange_rounds_read(sched: RoundScheduler, node_axis: str,
                         r: RequestList, starts: jax.Array,
                         file_shard: jax.Array, data_cap: int,
                         pipeline: bool = False) -> jax.Array:
    """Round loop of the collective read: per round, aggregators
    broadcast one ``cb``-sized window over the slow axis and every rank
    gathers the elements of its requests falling in that window. Peak
    per-rank buffering is ``n_nodes * cb`` instead of ``file_len``.
    ``pipeline=True`` double-buffers: window t+1's broadcast overlaps
    the scatter of window t's elements into the output.
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    cap = r.capacity
    eidx = jnp.arange(data_cap, dtype=jnp.int32)
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32), r.lengths,
                        total_repeat_length=data_cap)
    fpos = r.offsets[req_of] + (eidx - starts[req_of])
    live = eidx < jnp.sum(r.lengths, dtype=jnp.int32)
    fpos = jnp.where(live, fpos, 0)
    dest, wloc = fpos // dl, fpos % dl

    def fetch(t):
        win = lax.dynamic_slice_in_dim(file_shard, t * cb, cb)
        return lax.all_gather(win, node_axis, axis=0, tiled=True)

    def scatter(t, out, allw):
        active = live & (wloc // cb == t)
        src = dest * cb + (wloc - t * cb)
        vals = allw[jnp.clip(src, 0, n_dest * cb - 1)]
        return jnp.where(active, vals, out)

    out0 = jnp.zeros((data_cap,), file_shard.dtype)
    if not pipeline:
        return lax.fori_loop(
            0, sched.n_rounds,
            lambda t, out: scatter(t, out, fetch(t)), out0)

    allw0 = fetch(0)                             # prologue

    def body(t, carry):
        out, prev = carry
        nxt = fetch(t)                           # broadcast window t …
        return scatter(t - 1, out, prev), nxt    # … while placing t-1

    out, last = lax.fori_loop(1, sched.n_rounds, body, (out0, allw0))
    return scatter(sched.n_rounds - 1, out, last)   # epilogue


def peak_aggregator_buffer_elems(data_cap: int, n_nodes: int,
                                 ranks_per_node: int, domain_len: int,
                                 cb_buffer_size: int | None,
                                 pipeline: bool = False) -> dict:
    """Static receive-side buffer sizes (elements) of the write paths.

    ``single_shot`` is the flattened payload stack after the slow-axis
    all_to_all plus the intra-node gather — linear in the participating
    rank count. ``rounds`` is the a2a slice plus one window image —
    independent of ``ranks_per_node`` (the acceptance criterion); with
    ``pipeline=True`` TWO a2a window buffers are in flight (the price of
    the overlap — the loop carry holds the previous round's received
    buckets while the current exchange fills the next).
    ``tam_stage1_*`` are the local aggregator's intra-node gather
    buffers: the fused round loop (:func:`exchange_rounds_write_tam`)
    bounds the per-rank contribution at ``min(data_cap, cb)`` instead
    of ``data_cap``. Stage 1 is NOT doubled by the pipeline: the gather
    is produced and consumed inside one exchange step, so only one is
    ever live — only the post-``all_to_all`` carry doubles.
    """
    single = n_nodes * ranks_per_node * data_cap + domain_len
    cb = cb_buffer_size if cb_buffer_size is not None else domain_len
    in_flight = 2 if pipeline else 1
    rounds = n_nodes * min(data_cap, cb) * in_flight + cb + domain_len
    return {
        "single_shot": single,
        "rounds": rounds,
        "tam_stage1_single_shot": ranks_per_node * data_cap,
        "tam_stage1_rounds": ranks_per_node * min(data_cap, cb),
    }
