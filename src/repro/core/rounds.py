"""Round-scheduled bounded-buffer exchange engine (DESIGN).

Why rounds
----------
ROMIO's Lustre driver (paper §II) never materializes a whole file
domain worth of incoming traffic at an aggregator: the two-phase
exchange runs in ROUNDS, each bounded by the aggregator's collective
buffer (``cb_buffer_size``, romio_cb_buffer_size). Our analytical model
already charges for this (``cost_model`` refinement 1: each round
re-runs the request exchange and re-pays the incast latency), but the
single-shot SPMD paths in ``twophase``/``tam`` exchanged everything at
once, so aggregator-side receive buffers grew as
``O(P * data_cap)`` — the per-rank payload capacity times every
participating rank. That caps the file size a fixed mesh can drive.

The protocol
------------
Aggregator ``g`` owns the contiguous file domain
``[g * domain_len, (g+1) * domain_len)``. :class:`RoundScheduler`
partitions every domain into ``domain_len / cb_buffer_size``
stripe-aligned windows; round ``t`` moves exactly the requests whose
offsets fall in window ``t`` of their destination domain:

1. **split** — requests are split at window boundaries once, up front
   (``requests.split_at_stripes``), so each request lives in exactly one
   (destination, round) window;
2. **select** — per round, the active requests are compacted to the
   front of a static-capacity list (offset order preserved);
3. **exchange** — the existing ``bucket_by_dest`` / ``all_to_all`` /
   ``flatten_buckets`` / ``sort_with`` pipeline runs with per-bucket
   payload capacity ``min(data_cap, cb_buffer_size)``;
4. **pack + merge** — each rank packs its received slice into a
   ``cb_buffer_size`` window image and the images are merged across the
   node's other receive streams with a masked max-combine
   (``lax.pmax``), NOT a gather: the merge buffer stays
   ``O(cb_buffer_size)`` instead of ``O(ranks_per_node * data_cap)``;
5. **accumulate** — the window is written into the carried domain
   buffer at ``t * cb_buffer_size`` and the loop (``lax.fori_loop``, so
   compiled size is round-count independent) advances.

Peak aggregator-side buffering is therefore
``n_nodes * min(data_cap, cb) + cb`` elements — independent of the
number of participating ranks (see
:func:`peak_aggregator_buffer_elems`, asserted by tests/test_rounds.py).
The same mesh can drive arbitrarily large files by holding
``cb_buffer_size`` fixed while rounds grow.

The depth-k pipeline ring (``depth`` / ``pipeline=True``)
---------------------------------------------------------
The serial loop pays ``exchange + drain`` per round. The pipelined loop
is a software pipeline over a RING of ``depth`` in-flight window
buffers (``depth=2`` is the classic double buffer; the ``pipeline``
bool remains as sugar for depth 2):

* **prologue** — rounds ``0..depth-2`` are exchanged into the ring
  (statically unrolled); nothing drains.
* **steady state** — iteration ``t`` (depth-1..n_rounds-1) exchanges
  round ``t`` into the freed buffer while DRAINING the OLDEST carried
  window, round ``t-(depth-1)`` (flatten → sort → pack → masked pmax
  merge → accumulate). The two halves share no data, so XLA is free to
  run the slow-axis ``all_to_all`` concurrently with the local merge —
  each steady-state round costs ``max(comm, drain)`` instead of their
  sum, and with k > 2 the ring absorbs a multi-round incast spike: up
  to k-1 exchanged windows can queue while one slow drain (or k-1
  drains while one slow exchange) catches up
  (``cost_model.pipeline_span`` is the exact makespan recurrence).
* **epilogue** — the last ``depth-1`` carried windows drain; nothing
  is exchanged.

Buffer ownership: the exchanged-but-undrained windows (``rx`` tuples
of post-``all_to_all`` buckets) are the loop carry — a ring of
``depth-1`` tuples rotated each iteration, plus the buffer the current
exchange refills, so exactly ``min(depth, n_rounds)``
``n_nodes * min(data_cap, cb)`` receive images are ever live — the
k x window memory price (``peak_aggregator_buffer_elems`` with
``pipeline_depth=k``). Depth clamps to the round count.

Byte-identity: the ring only re-associates WHEN each round's drain
runs, not WHAT it drains — every round's received buckets pass through
the identical drain (same sort, same pack base ``t * cb``, same pmax
merge) exactly once, and rounds still accumulate into disjoint
``[t*cb, (t+1)*cb)`` slices of the domain buffer, so the result is
bit-identical to the serial loop for EVERY depth (asserted by
``repro/testing/rounds_checks.py`` for depths {1, 2, 3, 4} x round
counts {1, 2, 5}).

Round-aware TAM stage 1
-----------------------
:func:`exchange_rounds_write_tam` fuses BOTH TAM layers into the same
window loop: per round, ranks ship only the window's requests to their
local aggregator (the ``lmem`` gather is bounded at
``min(data_cap, cb)`` per rank instead of ``data_cap``), the LA
sorts/coalesces that window, and the coalesced window flows through the
same slow-axis exchange + pmax drain. Local-aggregator memory is then
``ranks_per_node * min(data_cap, cb)`` — O(cb) for cb < data_cap —
instead of ``ranks_per_node * data_cap`` (the ``tam_stage1_*`` keys of
:func:`peak_aggregator_buffer_elems`).

Semantics: concurrently written regions must not overlap (the MPI
standard leaves overlapping collective writes undefined); when they do,
the masked max-combine resolves each element deterministically to the
maximum written value, and capacity overflow is reported through the
``dropped_requests`` / ``dropped_elems`` stats, never silent.

Cost-model coupling
-------------------
The executed round count is ``RoundScheduler.n_rounds`` ==
``cost_model.Workload.rounds`` when ``rounds_override`` is wired from a
measured run (``IOTimings.rounds_executed`` on the host path). Each
round pays ``alpha_eff(senders)`` once (incast refinement 2), which is
exactly what ``HostCollectiveIO.write(cb_bytes=...)`` times; with
``pipeline=True`` the steady-state rounds overlap that latency with the
drain (refinement 4), and ``cost_model.optimal_cb`` picks the cb
balancing incast latency, memory, and round count.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import coalesce as co
from repro.core import codec as codec_mod
from repro.core import placement as placement_mod
from repro.core.exchange import (bucket_by_dest, flatten_buckets,
                                 repack_sorted, sort_with)
# RoundScheduler folded into the plan IR (PR 3); re-exported here so
# ``from repro.core.rounds import RoundScheduler`` keeps working.
from repro.core.plan import RoundScheduler  # noqa: F401
from repro.core.requests import PAD_OFFSET, RequestList, split_at_stripes


def _codec_hooks(slow_hop_codec: str | None, dtype, state_shape,
                 fused: bool = False):
    """(encode, decode, state0) for the slow-hop wire transform.

    ``encode(data, state) -> (wire_parts, state)`` runs inside the
    ``exchange`` closure BEFORE the slow-axis collective;
    ``decode(wire_parts) -> data`` runs inside the drain. ``state0`` is
    the codec's residual (error feedback) — the empty pytree for
    stateless codecs — and is threaded through the round loop by
    ``_run_rounds`` exactly like the in-flight ``rx`` windows. A lossy
    codec on a non-float payload dies here, at trace time.

    ``fused`` (``IOPlan.kernel_fusion == "fused_round"``) swaps the rle
    codec's stable-argsort compaction for the Pallas zero-skip kernel
    (``kernels.fused_round.zero_skip_encode``) and its staged decode
    scatter for ``zero_skip_decode`` — byte-identical wire and window,
    one VMEM block per bucket instead of an argsort (resp. an HBM
    staging buffer) per round. The decode half serves both directions:
    the write drain and the read fetch.
    """
    if slow_hop_codec is None:
        return (lambda data, st: ((data,), st),
                lambda parts: parts[0], ())
    c = codec_mod.get_codec(slow_hop_codec)
    if not c.lossless and not jnp.issubdtype(dtype, jnp.floating):
        raise TypeError(
            f"slow_hop_codec={c.name!r} is lossy (float payloads only) "
            f"but the payload dtype is {jnp.dtype(dtype)}")
    state0 = c.jax_init_state(state_shape, dtype) if c.stateful else ()
    if fused and c.name == "rle":
        from repro.kernels import ops as kops

        def enc(data, st):
            return kops.rle_zero_skip_encode(data), st

        def dec(parts):
            return kops.rle_zero_skip_decode(parts)

        return enc, dec, state0
    return c.jax_encode, c.jax_decode, state0


def _placement_hooks(placement, n_dest: int, dl: int, node_axis: str):
    """(to_slot, base0, unpermute) for an aggregator placement.

    ``to_slot(domain_idx)`` maps each request's destination DOMAIN to
    the SLOT serving it (``plan.placement``); ``base0`` is this slot's
    served domain's base offset (slot s serves domain ``inv[s]``); and
    ``unpermute(x)`` ppermutes the finished domain shards (and their
    per-aggregator stats) back into domain order — slot s holds domain
    ``inv[s]`` after the rounds, and sending it to slot ``inv[s]``
    leaves every slot holding its own domain, so the OUTPUT is
    byte-identical to the identity placement (the permutation moves
    where the aggregation work happens, never what lands in the file).
    The identity placement compiles the placement machinery away
    entirely.
    """
    if placement_mod.is_identity(placement):
        return (lambda d: d,
                lax.axis_index(node_axis) * dl,
                lambda x: x)
    perm = placement_mod.validate_placement(placement, n_dest)
    inv = placement_mod.inverse_placement(perm)
    perm_arr = jnp.asarray(perm, jnp.int32)
    inv_arr = jnp.asarray(inv, jnp.int32)

    def to_slot(domain_idx):
        return perm_arr[jnp.clip(domain_idx, 0, n_dest - 1)]

    base0 = inv_arr[lax.axis_index(node_axis)] * dl
    pairs = [(s, inv[s]) for s in range(n_dest)]

    def unpermute(x):
        return lax.ppermute(x, node_axis, pairs)

    return to_slot, base0, unpermute


def _effective_depth(pipeline: bool, depth: int | None) -> int:
    """Resolve the (pipeline, depth) sugar: an explicit ``depth`` wins;
    the ``pipeline`` bool alone means the classic double buffer."""
    if depth is not None:
        return max(1, int(depth))
    return 2 if pipeline else 1


def _compact_active(r: RequestList, starts: jax.Array, dest: jax.Array,
                    active: jax.Array):
    """Move the active requests to the front, preserving offset order."""
    off = jnp.where(active, r.offsets, PAD_OFFSET)
    ln = jnp.where(active, r.lengths, 0)
    order = jnp.argsort(jnp.where(active, 0, 1).astype(jnp.int32),
                        stable=True)
    return (RequestList(off[order], ln[order],
                        jnp.sum(active, dtype=jnp.int32)),
            starts[order], dest[order])


def _lowest(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def _make_drain(base0, cb: int, merge_axes: tuple[str, ...], dtype,
                decode=None, fused: bool = False):
    """Drain closure: merge one round's received buckets into the
    carried domain buffer (decode wire → flatten → sort → pack window →
    masked pmax merge → accumulate at ``t * cb``). ``rx`` is
    ``(offsets, lengths, counts, *wire_parts)``; ``decode`` inverts the
    slow-hop codec's encode (identity when no codec is planned).

    ``fused`` (``IOPlan.kernel_fusion == "fused_round"``) runs the sort
    + dual pack as ONE Pallas kernel (``kernels.fused_round``) instead
    of a stable argsort plus two scatter packs — byte-identical by the
    rounds_checks contract, one HBM round-trip instead of three."""
    low = _lowest(dtype)

    def drain(t, buf, rx):
        data = rx[3] if decode is None else decode(rx[3:]).astype(dtype)
        merged, starts_m, data_flat = flatten_buckets(rx[0], rx[1],
                                                      rx[2], data)
        base = base0 + t * cb
        if fused:
            from repro.kernels import ops as kops
            win, mask = kops.fused_drain_pack(merged, starts_m,
                                              data_flat, base, cb)
        else:
            sorted_r, starts_s = sort_with(merged, starts_m)
            win = co.pack_data(sorted_r, starts_s, data_flat, cb,
                               base=base)
            mask = co.pack_data(sorted_r, starts_s,
                                jnp.ones_like(data_flat), cb, base=base)
        comb = lax.pmax(jnp.where(mask != 0, win, low), merge_axes)
        anyw = lax.pmax(mask, merge_axes)
        final = jnp.where(anyw != 0, comb, jnp.zeros((), dtype))
        buf = lax.dynamic_update_slice(buf, final, (t * cb,))
        return buf, (merged.count,)

    return drain


def _run_rounds(n_rounds: int, domain_len: int, dtype, exchange, drain,
                n_ex_stats: int, n_dr_stats: int, depth: int,
                codec_state=()):
    """Drive the round loop: serial (depth 1) or a depth-k window ring.

    ``exchange(t, cstate) -> (rx, ex_stats, cstate)`` produces round
    t's received buckets and the advanced codec state (the slow-hop
    codec's residual — the empty pytree when stateless);
    ``drain(t, buf, rx) -> (buf, dr_stats)`` merges the buckets into
    the domain buffer. Stats tuples are accumulated elementwise.
    Ring schedule (depth k, clamped to the round count): the prologue
    exchanges rounds 0..k-2 into the ring (statically unrolled); the
    steady-state iteration t exchanges round t while draining the
    oldest carried window, round t-(k-1); the epilogue drains the
    remaining k-1 windows. Every round is drained exactly once, in
    order, through the identical drain — byte-identical to serial for
    every k. The codec state rides the same loop carry as the ring:
    exchanges always run in round order, so error feedback sees rounds
    0, 1, 2, ... at every depth.
    """
    zeros = tuple(jnp.int32(0) for _ in range(n_ex_stats + n_dr_stats))

    def add(acc, delta, base):
        return tuple(a + d for a, d in zip(acc[base:base + len(delta)],
                                           delta))

    buf0 = jnp.zeros((domain_len,), dtype)
    d = max(1, min(depth, n_rounds))
    if d == 1:
        def body(t, carry):
            buf, cst, acc = carry
            rx, ex, cst = exchange(t, cst)
            buf, dr = drain(t, buf, rx)
            return buf, cst, add(acc, ex, 0) + add(acc, dr, n_ex_stats)

        buf, _, acc = lax.fori_loop(0, n_rounds, body,
                                    (buf0, codec_state, zeros))
        return buf, acc[:n_ex_stats], acc[n_ex_stats:]

    ring: list = []                              # prologue: fill the ring
    acc = zeros
    cst = codec_state
    for i in range(d - 1):
        rx, ex, cst = exchange(i, cst)
        ring.append(rx)
        acc = add(acc, ex, 0) + acc[n_ex_stats:]

    def body(t, carry):
        buf, ring, cst, acc = carry
        rx_new, ex, cst = exchange(t, cst)       # refill the freed buffer …
        buf, dr = drain(t - (d - 1), buf, ring[0])   # … drain the oldest
        ring = ring[1:] + (rx_new,)
        return (buf, ring, cst,
                add(acc, ex, 0) + add(acc, dr, n_ex_stats))

    buf, ring, _, acc = lax.fori_loop(d - 1, n_rounds, body,
                                      (buf0, tuple(ring), cst, acc))
    for j in range(d - 1):                       # epilogue: drain the ring
        buf, dr = drain(n_rounds - (d - 1) + j, buf, ring[j])
        acc = acc[:n_ex_stats] + add(acc, dr, n_ex_stats)
    return buf, acc[:n_ex_stats], acc[n_ex_stats:]


def exchange_rounds_write(sched: RoundScheduler, node_axis: str,
                          merge_axes: tuple[str, ...], r: RequestList,
                          starts: jax.Array, data: jax.Array,
                          pipeline: bool = False,
                          depth: int | None = None,
                          slow_hop_codec: str | None = None,
                          placement=None,
                          kernel_fusion: str | None = None):
    """Round loop of the collective write (runs inside a shard_map body).

    r/starts/data: this sender's offset-sorted requests, the payload
    start of each request inside ``data``, and the packed payload.
    ``depth=k`` runs the depth-k window ring (k in-flight windows;
    byte-identical to the serial loop for every k — see the module
    docstring); ``pipeline=True`` is sugar for depth 2.
    ``slow_hop_codec`` names a ``core.codec`` transform applied to each
    round's payload buckets around the slow-axis ``all_to_all``
    (lossless codecs keep byte identity; ``ef-int8``'s residual rides
    the loop carry). ``placement`` is the plan's aggregator permutation
    (``core.placement``): requests route to the slot SERVING their
    domain and the finished shards ppermute back into domain order, so
    the output is byte-identical for every placement. Returns
    (domain shard [domain_len], stats dict); ``requests_at_ga`` is
    already summed over ``merge_axes`` (replicated at the node) and
    reported in DOMAIN order whatever the placement.
    ``kernel_fusion="fused_round"`` (``IOPlan.kernel_fusion``, set by
    the planner's ``lower_kernels`` pass) drains each window with the
    single fused Pallas kernel and, when the codec is rle, encodes the
    wire with the fused zero-skip kernel — byte-identical either way.
    """
    fused = kernel_fusion == "fused_round"
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    data_cap = data.shape[0]
    split = split_at_stripes(r, cb, sched.max_spans(data_cap))
    s_starts = co.request_starts(split)
    to_slot, base0, unpermute = _placement_hooks(placement, n_dest, dl,
                                                 node_axis)
    dest = to_slot((split.offsets // dl).astype(jnp.int32))
    window = sched.window_of(split.offsets)
    round_req_cap = min(split.capacity, cb)
    round_data_cap = min(data_cap, cb)
    a2a = partial(lax.all_to_all, axis_name=node_axis, split_axis=0,
                  concat_axis=0, tiled=True)
    enc, dec, cstate0 = _codec_hooks(slow_hop_codec, data.dtype,
                                     (n_dest, round_data_cap),
                                     fused=fused)

    def exchange(t, cst):
        active = split.valid_mask() & (window == t)
        act_r, act_starts, act_dest = _compact_active(split, s_starts,
                                                      dest, active)
        act_data = repack_sorted(act_r, act_starts, data, data_cap)
        b = bucket_by_dest(act_r, co.request_starts(act_r), act_data,
                           act_dest, n_dest, round_req_cap, round_data_cap)
        wire, cst = enc(b.data, cst)
        rx = ((a2a(b.offsets), a2a(b.lengths), a2a(b.counts))
              + tuple(a2a(p) for p in wire))
        return rx, (b.dropped_requests, b.dropped_elems), cst

    drain = _make_drain(base0, cb, merge_axes, data.dtype, decode=dec,
                        fused=fused)
    buf, (drop_r, drop_e), (reqs_rx,) = _run_rounds(
        sched.n_rounds, dl, data.dtype, exchange, drain, 2, 1,
        _effective_depth(pipeline, depth), codec_state=cstate0)
    return unpermute(buf), {
        "dropped_requests": drop_r,
        "dropped_elems": drop_e,
        "requests_at_ga": unpermute(lax.psum(reqs_rx, merge_axes)),
    }


def exchange_rounds_write_tam(sched: RoundScheduler, node_axis: str,
                              lagg_axis: str, lmem_axis: str,
                              r: RequestList, starts: jax.Array,
                              data: jax.Array,
                              coalesce_cap: int | None = None,
                              use_kernels: bool = False,
                              pipeline: bool = False,
                              depth: int | None = None,
                              slow_hop_codec: str | None = None,
                              placement=None,
                              kernel_fusion: str | None = None):
    """Fused TAM round loop: BOTH aggregation layers run per window.

    Per round t, stage 1 gathers only the window's requests over
    ``lmem_axis`` (per-rank payload bounded at ``min(data_cap, cb)``),
    the local aggregator sorts/coalesces/repacks that window, and
    stage 2 exchanges the coalesced window over ``node_axis`` with the
    pmax merge over ``lagg_axis`` — so local-aggregator memory is
    O(cb) too, not just the global aggregator's (ROADMAP item).
    ``depth=k`` / ``pipeline=True`` overlap each round's two-layer
    exchange with older rounds' drains through the depth-k window
    ring, as in :func:`exchange_rounds_write`.

    Returns (domain shard, stats). ``*_rank`` drop stats are per-rank
    (pre-gather — psum over all axes); ``*_agg`` drops and the
    before/after coalesce counts are replicated across ``lmem_axis``
    (post-gather — divide the psum by the lmem size).
    ``kernel_fusion="fused_round"`` fuses the global-aggregator drain
    (and the rle wire encode) exactly as in
    :func:`exchange_rounds_write`.
    """
    fused = kernel_fusion == "fused_round"
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    data_cap = data.shape[0]
    split = split_at_stripes(r, cb, sched.max_spans(data_cap))
    s_starts = co.request_starts(split)
    dest0 = (split.offsets // dl).astype(jnp.int32)
    window = sched.window_of(split.offsets)
    rcap = min(split.capacity, cb)       # stage-1 requests/rank/round
    rdcap = min(data_cap, cb)            # stage-1 payload/rank/round
    # placement routes only the SLOW hop (stage 2): the intra-node
    # gather is placement-blind, mirroring the codec's asymmetry
    to_slot, base0, unpermute = _placement_hooks(placement, n_dest, dl,
                                                 node_axis)
    a2a = partial(lax.all_to_all, axis_name=node_axis, split_axis=0,
                  concat_axis=0, tiled=True)
    g = partial(lax.all_gather, axis_name=lmem_axis, axis=0, tiled=False)
    idx = jnp.arange(split.capacity, dtype=jnp.int32)
    # the codec wraps ONLY the slow-axis hop (stage 2): the intra-node
    # gather stays raw — exactly the paper's asymmetry (compress where
    # the fabric is slow), mirroring hierarchical.compressed_psum
    from repro.compat import axis_size
    lmem_size = axis_size(lmem_axis)
    enc, dec, cstate0 = _codec_hooks(
        slow_hop_codec, data.dtype,
        (n_dest, min(lmem_size * rdcap, cb)), fused=fused)

    def exchange(t, cst):
        # ---- stage 1: window-bounded intra-node aggregation ---------
        active = split.valid_mask() & (window == t)
        act_r, act_starts, _ = _compact_active(split, s_starts, dest0,
                                               active)
        drop_rank_r = jnp.maximum(act_r.count - rcap, 0)
        drop_rank_e = jnp.sum(jnp.where(idx >= rcap, act_r.lengths, 0),
                              dtype=jnp.int32)
        win_r = RequestList(act_r.offsets[:rcap], act_r.lengths[:rcap],
                            jnp.minimum(act_r.count, rcap))
        drop_rank_e = drop_rank_e + jnp.maximum(
            jnp.sum(win_r.lengths, dtype=jnp.int32) - rdcap, 0)
        win_data = repack_sorted(win_r, act_starts[:rcap], data, rdcap)
        all_off, all_len, all_cnt, all_data = (
            g(win_r.offsets), g(win_r.lengths), g(win_r.count),
            g(win_data))
        m = all_off.shape[0]
        merged, starts_m, data_flat = flatten_buckets(all_off, all_len,
                                                      all_cnt, all_data)
        if use_kernels:
            from repro.kernels import ops as kops
            sorted_r, starts_s = kops.sort_requests_with(merged, starts_m)
            packed = repack_sorted(sorted_r, starts_s, data_flat, m * rdcap)
            coal = kops.coalesce(sorted_r)
        else:
            sorted_r, starts_s = sort_with(merged, starts_m)
            packed = repack_sorted(sorted_r, starts_s, data_flat, m * rdcap)
            coal = co.coalesce_sorted(sorted_r)
        ccap = min(coalesce_cap or coal.capacity, coal.capacity)
        drop_agg_r = jnp.maximum(coal.count - ccap, 0)
        agg = RequestList(coal.offsets[:ccap], coal.lengths[:ccap],
                          jnp.minimum(coal.count, ccap))
        # a coalesced run can escape its window only when cb == dl (the
        # last window of domain d touches window 0 of domain d+1, both
        # live in the single round) — re-split at the domain boundary so
        # each forwarded request has exactly one owner
        agg = split_at_stripes(agg, dl, m * rdcap // dl + 2)
        # ---- stage 2: slow-axis exchange of the coalesced window ----
        dest = to_slot((agg.offsets // dl).astype(jnp.int32))
        b = bucket_by_dest(agg, co.request_starts(agg), packed, dest,
                           n_dest, min(agg.capacity, cb),
                           min(m * rdcap, cb))
        wire, cst = enc(b.data, cst)
        rx = ((a2a(b.offsets), a2a(b.lengths), a2a(b.counts))
              + tuple(a2a(p) for p in wire))
        return rx, (drop_rank_r, drop_rank_e,
                    b.dropped_requests + drop_agg_r, b.dropped_elems,
                    merged.count, agg.count), cst

    drain = _make_drain(base0, cb, (lagg_axis,), data.dtype, decode=dec,
                        fused=fused)
    buf, ex_acc, dr_acc = _run_rounds(
        sched.n_rounds, dl, data.dtype, exchange, drain, 6, 1,
        _effective_depth(pipeline, depth), codec_state=cstate0)
    (drop_rank_r, drop_rank_e, drop_agg_r, drop_agg_e,
     n_before, n_after) = ex_acc
    return unpermute(buf), {
        "dropped_requests_rank": drop_rank_r,
        "dropped_elems_rank": drop_rank_e,
        "dropped_requests_agg": drop_agg_r,
        "dropped_elems_agg": drop_agg_e,
        "requests_before_coalesce": n_before,
        "requests_after_coalesce": n_after,
        "requests_at_ga": unpermute(lax.psum(dr_acc[0], (lagg_axis,))),
    }


def exchange_rounds_read(sched: RoundScheduler, node_axis: str,
                         r: RequestList, starts: jax.Array,
                         file_shard: jax.Array, data_cap: int,
                         pipeline: bool = False,
                         depth: int | None = None,
                         slow_hop_codec: str | None = None,
                         placement=None,
                         kernel_fusion: str | None = None) -> jax.Array:
    """Round loop of the collective read: per round, aggregators
    broadcast one ``cb``-sized window over the slow axis and every rank
    gathers the elements of its requests falling in that window. Peak
    per-rank buffering is ``n_nodes * cb`` instead of ``file_len``.
    ``depth=k`` / ``pipeline=True`` run the window ring: the broadcast
    of window t overlaps the scatters of the k-1 carried older windows.
    ``slow_hop_codec`` encodes each aggregator's window before the
    slow-axis broadcast and decodes after (per-window, residual-free:
    a broadcast repeats nothing, so error feedback has nothing to
    correct — ``ef-int8`` here is plain per-window quantization).
    ``placement`` permutes which slot SERVES (broadcasts) each domain:
    the file shards ppermute to their serving slots up front and ranks
    index the gathered windows through the permutation — the returned
    payloads are byte-identical for every placement.
    ``kernel_fusion="fused_round"`` swaps the rle decode scatter for
    the Pallas ``zero_skip_decode`` kernel (byte-identical; execution
    strategy only, never routing).
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    cap = r.capacity
    if not placement_mod.is_identity(placement):
        perm = placement_mod.validate_placement(placement, n_dest)
        # slot perm[g] serves domain g: hand it the domain's shard
        file_shard = lax.ppermute(file_shard, node_axis,
                                  [(s, perm[s]) for s in range(n_dest)])
        slot_of = jnp.asarray(perm, jnp.int32)
    else:
        slot_of = None
    eidx = jnp.arange(data_cap, dtype=jnp.int32)
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32), r.lengths,
                        total_repeat_length=data_cap)
    fpos = r.offsets[req_of] + (eidx - starts[req_of])
    live = eidx < jnp.sum(r.lengths, dtype=jnp.int32)
    fpos = jnp.where(live, fpos, 0)
    dest, wloc = fpos // dl, fpos % dl

    enc, dec, _ = _codec_hooks(slow_hop_codec, file_shard.dtype, (cb,),
                               fused=kernel_fusion == "fused_round")

    def fetch(t):
        win = lax.dynamic_slice_in_dim(file_shard, t * cb, cb)
        if slow_hop_codec is None:
            return lax.all_gather(win, node_axis, axis=0, tiled=True)
        parts, _ = enc(win, ())      # broadcast: no residual to carry
        gathered = tuple(
            lax.all_gather(p, node_axis, axis=0, tiled=False)
            if p.ndim == 0 else
            lax.all_gather(p, node_axis, axis=0,
                           tiled=True).reshape(n_dest, *p.shape)
            for p in parts)
        return (dec(gathered).astype(file_shard.dtype).reshape(-1))

    def scatter(t, out, allw):
        active = live & (wloc // cb == t)
        slot = dest if slot_of is None else slot_of[dest]
        src = slot * cb + (wloc - t * cb)
        vals = allw[jnp.clip(src, 0, n_dest * cb - 1)]
        return jnp.where(active, vals, out)

    out0 = jnp.zeros((data_cap,), file_shard.dtype)
    d = max(1, min(_effective_depth(pipeline, depth), sched.n_rounds))
    if d == 1:
        return lax.fori_loop(
            0, sched.n_rounds,
            lambda t, out: scatter(t, out, fetch(t)), out0)

    ring = tuple(fetch(i) for i in range(d - 1))    # prologue

    def body(t, carry):
        out, ring = carry
        nxt = fetch(t)                           # broadcast window t …
        out = scatter(t - (d - 1), out, ring[0])    # … place the oldest
        return out, ring[1:] + (nxt,)

    out, ring = lax.fori_loop(d - 1, sched.n_rounds, body, (out0, ring))
    for j in range(d - 1):                       # epilogue
        out = scatter(sched.n_rounds - (d - 1) + j, out, ring[j])
    return out


def peak_aggregator_buffer_elems(data_cap: int, n_nodes: int,
                                 ranks_per_node: int, domain_len: int,
                                 cb_buffer_size: int | None,
                                 pipeline: bool = False,
                                 pipeline_depth: int | None = None,
                                 slow_hop_codec: str | None = None) -> dict:
    """Static receive-side buffer sizes (elements) of the write paths.

    ``single_shot`` is the flattened payload stack after the slow-axis
    all_to_all plus the intra-node gather — linear in the participating
    rank count. ``rounds`` is the a2a slice plus one window image —
    independent of ``ranks_per_node`` (the acceptance criterion); with
    ``pipeline_depth=k`` (``pipeline=True`` is sugar for k=2) k a2a
    window buffers are in flight — the k x window memory price of the
    ring: the loop carry holds the k-1 oldest undrained rounds'
    received buckets while the current exchange fills the k-th (the
    depth clamps to the round count at run time; this static bound
    charges the configured k).
    ``tam_stage1_*`` are the local aggregator's intra-node gather
    buffers: the fused round loop (:func:`exchange_rounds_write_tam`)
    bounds the per-rank contribution at ``min(data_cap, cb)`` instead
    of ``data_cap``. Stage 1 is NOT multiplied by the ring depth: the
    gather is produced and consumed inside one exchange step, so only
    one is ever live — only the post-``all_to_all`` carry rings.
    ``slow_hop_codec`` scales the in-flight a2a windows by the codec's
    static wire width (``Codec.jax_wire_overhead`` — e.g. rle rings
    values AND int32 positions, 2x; XLA buffers cannot shrink, so the
    RING memory pays the wire format even though the WIRE volume the
    cost model discounts is smaller).
    """
    wire = (codec_mod.get_codec(slow_hop_codec).jax_wire_overhead
            if slow_hop_codec is not None else 1.0)
    single = n_nodes * ranks_per_node * data_cap + domain_len
    cb = cb_buffer_size if cb_buffer_size is not None else domain_len
    in_flight = _effective_depth(pipeline, pipeline_depth)
    rounds = (math.ceil(n_nodes * min(data_cap, cb) * wire)
              * in_flight + cb + domain_len)
    return {
        "single_shot": single,
        "rounds": rounds,
        "tam_stage1_single_shot": ranks_per_node * data_cap,
        "tam_stage1_rounds": ranks_per_node * min(data_cap, cb),
    }
