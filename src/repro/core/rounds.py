"""Round-scheduled bounded-buffer exchange engine (DESIGN).

Why rounds
----------
ROMIO's Lustre driver (paper §II) never materializes a whole file
domain worth of incoming traffic at an aggregator: the two-phase
exchange runs in ROUNDS, each bounded by the aggregator's collective
buffer (``cb_buffer_size``, romio_cb_buffer_size). Our analytical model
already charges for this (``cost_model`` refinement 1: each round
re-runs the request exchange and re-pays the incast latency), but the
single-shot SPMD paths in ``twophase``/``tam`` exchanged everything at
once, so aggregator-side receive buffers grew as
``O(P * data_cap)`` — the per-rank payload capacity times every
participating rank. That caps the file size a fixed mesh can drive.

The protocol
------------
Aggregator ``g`` owns the contiguous file domain
``[g * domain_len, (g+1) * domain_len)``. :class:`RoundScheduler`
partitions every domain into ``domain_len / cb_buffer_size``
stripe-aligned windows; round ``t`` moves exactly the requests whose
offsets fall in window ``t`` of their destination domain:

1. **split** — requests are split at window boundaries once, up front
   (``requests.split_at_stripes``), so each request lives in exactly one
   (destination, round) window;
2. **select** — per round, the active requests are compacted to the
   front of a static-capacity list (offset order preserved);
3. **exchange** — the existing ``bucket_by_dest`` / ``all_to_all`` /
   ``flatten_buckets`` / ``sort_with`` pipeline runs with per-bucket
   payload capacity ``min(data_cap, cb_buffer_size)``;
4. **pack + merge** — each rank packs its received slice into a
   ``cb_buffer_size`` window image and the images are merged across the
   node's other receive streams with a masked max-combine
   (``lax.pmax``), NOT a gather: the merge buffer stays
   ``O(cb_buffer_size)`` instead of ``O(ranks_per_node * data_cap)``;
5. **accumulate** — the window is written into the carried domain
   buffer at ``t * cb_buffer_size`` and the loop (``lax.fori_loop``, so
   compiled size is round-count independent) advances.

Peak aggregator-side buffering is therefore
``n_nodes * min(data_cap, cb) + cb`` elements — independent of the
number of participating ranks (see
:func:`peak_aggregator_buffer_elems`, asserted by tests/test_rounds.py).
The same mesh can drive arbitrarily large files by holding
``cb_buffer_size`` fixed while rounds grow.

Semantics: concurrently written regions must not overlap (the MPI
standard leaves overlapping collective writes undefined); when they do,
the masked max-combine resolves each element deterministically to the
maximum written value, and capacity overflow is reported through the
``dropped_requests`` / ``dropped_elems`` stats, never silent.

Cost-model coupling
-------------------
The executed round count is ``RoundScheduler.n_rounds`` ==
``cost_model.Workload.rounds`` when ``rounds_override`` is wired from a
measured run (``IOTimings.rounds_executed`` on the host path). Each
round pays ``alpha_eff(senders)`` once (incast refinement 2), which is
exactly what ``HostCollectiveIO.write(cb_bytes=...)`` times.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import coalesce as co
from repro.core.domains import FileLayout
from repro.core.exchange import (bucket_by_dest, flatten_buckets,
                                 repack_sorted, sort_with)
from repro.core.requests import PAD_OFFSET, RequestList, split_at_stripes


@dataclass(frozen=True)
class RoundScheduler:
    """Static partition of each aggregator's file domain into rounds.

    layout:         striped file layout (element units).
    n_aggregators:  global aggregators (== slow-axis size in SPMD).
    cb_buffer_size: collective-buffer elements per aggregator per round;
                    ``None`` = one round == the single-shot behavior.
    """

    layout: FileLayout
    n_aggregators: int
    cb_buffer_size: int | None = None

    def __post_init__(self):
        if self.layout.file_len % self.n_aggregators:
            raise ValueError("file_len must divide evenly among aggregators")
        cb = self.cb
        if self.domain_len % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must divide domain_len "
                f"{self.domain_len} (stripe-aligned rounds)")
        s = self.layout.stripe_size
        if cb % s and s % cb:
            raise ValueError(
                f"cb_buffer_size {cb} must align with stripe_size {s}")

    @property
    def domain_len(self) -> int:
        return self.layout.file_len // self.n_aggregators

    @property
    def cb(self) -> int:
        return (self.cb_buffer_size if self.cb_buffer_size is not None
                else self.domain_len)

    @property
    def n_rounds(self) -> int:
        return -(-self.domain_len // self.cb)

    def max_spans(self, data_cap: int) -> int:
        """Windows one request (length <= data_cap) can straddle."""
        return data_cap // self.cb + 2

    def window_of(self, offsets: jax.Array) -> jax.Array:
        """Round in which an offset is exchanged (domain-local window)."""
        return (offsets % self.domain_len) // self.cb


def _compact_active(r: RequestList, starts: jax.Array, dest: jax.Array,
                    active: jax.Array):
    """Move the active requests to the front, preserving offset order."""
    off = jnp.where(active, r.offsets, PAD_OFFSET)
    ln = jnp.where(active, r.lengths, 0)
    order = jnp.argsort(jnp.where(active, 0, 1).astype(jnp.int32),
                        stable=True)
    return (RequestList(off[order], ln[order],
                        jnp.sum(active, dtype=jnp.int32)),
            starts[order], dest[order])


def _lowest(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def exchange_rounds_write(sched: RoundScheduler, node_axis: str,
                          merge_axes: tuple[str, ...], r: RequestList,
                          starts: jax.Array, data: jax.Array):
    """Round loop of the collective write (runs inside a shard_map body).

    r/starts/data: this sender's offset-sorted requests, the payload
    start of each request inside ``data``, and the packed payload.
    Returns (domain shard [domain_len], stats dict); ``requests_at_ga``
    is already summed over ``merge_axes`` (replicated at the node).
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    data_cap = data.shape[0]
    split = split_at_stripes(r, cb, sched.max_spans(data_cap))
    s_starts = co.request_starts(split)
    dest = (split.offsets // dl).astype(jnp.int32)
    window = sched.window_of(split.offsets)
    round_req_cap = min(split.capacity, cb)
    round_data_cap = min(data_cap, cb)
    base0 = lax.axis_index(node_axis) * dl
    a2a = partial(lax.all_to_all, axis_name=node_axis, split_axis=0,
                  concat_axis=0, tiled=True)
    low = _lowest(data.dtype)

    def body(t, carry):
        buf, drop_r, drop_e, reqs_rx = carry
        active = split.valid_mask() & (window == t)
        act_r, act_starts, act_dest = _compact_active(split, s_starts,
                                                      dest, active)
        act_data = repack_sorted(act_r, act_starts, data, data_cap)
        b = bucket_by_dest(act_r, co.request_starts(act_r), act_data,
                           act_dest, n_dest, round_req_cap, round_data_cap)
        rx_off, rx_len, rx_data = (a2a(b.offsets), a2a(b.lengths),
                                   a2a(b.data))
        rx_cnt = a2a(b.counts)
        merged, starts_m, data_flat = flatten_buckets(rx_off, rx_len,
                                                      rx_cnt, rx_data)
        sorted_r, starts_s = sort_with(merged, starts_m)
        base = base0 + t * cb
        win = co.pack_data(sorted_r, starts_s, data_flat, cb, base=base)
        mask = co.pack_data(sorted_r, starts_s,
                            jnp.ones_like(data_flat), cb, base=base)
        comb = lax.pmax(jnp.where(mask != 0, win, low), merge_axes)
        anyw = lax.pmax(mask, merge_axes)
        final = jnp.where(anyw != 0, comb, jnp.zeros((), data.dtype))
        buf = lax.dynamic_update_slice(buf, final, (t * cb,))
        return (buf, drop_r + b.dropped_requests, drop_e + b.dropped_elems,
                reqs_rx + merged.count)

    init = (jnp.zeros((dl,), data.dtype), jnp.int32(0), jnp.int32(0),
            jnp.int32(0))
    buf, drop_r, drop_e, reqs_rx = lax.fori_loop(0, sched.n_rounds, body,
                                                 init)
    return buf, {
        "dropped_requests": drop_r,
        "dropped_elems": drop_e,
        "requests_at_ga": lax.psum(reqs_rx, merge_axes),
    }


def exchange_rounds_read(sched: RoundScheduler, node_axis: str,
                         r: RequestList, starts: jax.Array,
                         file_shard: jax.Array, data_cap: int) -> jax.Array:
    """Round loop of the collective read: per round, aggregators
    broadcast one ``cb``-sized window over the slow axis and every rank
    gathers the elements of its requests falling in that window. Peak
    per-rank buffering is ``n_nodes * cb`` instead of ``file_len``.
    """
    n_dest, cb, dl = sched.n_aggregators, sched.cb, sched.domain_len
    cap = r.capacity
    eidx = jnp.arange(data_cap, dtype=jnp.int32)
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32), r.lengths,
                        total_repeat_length=data_cap)
    fpos = r.offsets[req_of] + (eidx - starts[req_of])
    live = eidx < jnp.sum(r.lengths, dtype=jnp.int32)
    fpos = jnp.where(live, fpos, 0)
    dest, wloc = fpos // dl, fpos % dl

    def body(t, out):
        win = lax.dynamic_slice_in_dim(file_shard, t * cb, cb)
        allw = lax.all_gather(win, node_axis, axis=0, tiled=True)
        active = live & (wloc // cb == t)
        src = dest * cb + (wloc - t * cb)
        vals = allw[jnp.clip(src, 0, n_dest * cb - 1)]
        return jnp.where(active, vals, out)

    return lax.fori_loop(0, sched.n_rounds, body,
                         jnp.zeros((data_cap,), file_shard.dtype))


def peak_aggregator_buffer_elems(data_cap: int, n_nodes: int,
                                 ranks_per_node: int, domain_len: int,
                                 cb_buffer_size: int | None) -> dict:
    """Static receive-side buffer sizes (elements) of both write paths.

    ``single_shot`` is the flattened payload stack after the slow-axis
    all_to_all plus the intra-node gather — linear in the participating
    rank count. ``rounds`` is the a2a slice plus one window image —
    independent of ``ranks_per_node`` (the acceptance criterion).
    """
    single = n_nodes * ranks_per_node * data_cap + domain_len
    cb = cb_buffer_size if cb_buffer_size is not None else domain_len
    rounds = n_nodes * min(data_cap, cb) + cb + domain_len
    return {"single_shot": single, "rounds": rounds}
