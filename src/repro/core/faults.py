"""Fault injection + degraded-mode recovery for the host executor.

Everything so far assumed a healthy, static machine; at the paper's
scale (16384 ranks) stragglers and node loss are the steady state, and
a single slow node silently poisons every ``"auto"`` knob the planner
and :class:`~repro.core.session.IOSession` resolve. This module makes
faults an explicit, composable INPUT (:class:`FaultSpec`, threaded
through ``HostCollectiveIO.write`` into
``checkpoint.host_exec.execute_write``) and hosts the recovery policy
the executor and session use to survive them:

* **straggler** (``slow_nodes``) — a per-node slowdown factor scales
  everything the node serves (stage-1 aggregation, slow-hop receive,
  segment drain). The executor MEASURES the induced per-node service
  rates (``IOTimings.node_slowdown``) and the session feeds them into
  the next placement resolution, so ``placement="auto"`` visibly moves
  aggregator load off the straggler within one write.
* **dead aggregator** (``dead_aggregator=(slot, round)``) — the slot's
  node stops serving mid-write. Detection is wired to
  ``runtime.heartbeat.HeartbeatMonitor.dead_hosts()`` (the fault
  registers on the monitor; the executor polls); recovery routes the
  victim's file domains through a *repair map* (:func:`repair_map`)
  and replays their unfinished rounds on the repair slot. The victim's
  partially-drained segment is left torn on disk (truncated +
  ``.partial`` marker) exactly as the drain-thread fail-fast path
  leaves it, then detected and rewritten — every recovered write is
  byte-identical to the healthy oracle.
* **lost / delayed slow-hop message** (``lost`` / ``delayed``) — each
  loss charges a per-round retry timeout with exponential backoff and a
  re-send; more than ``max_retries`` losses raises
  :class:`UnrecoverableFaultError` (fail fast, never silently drop
  bytes). Delays push the round's completion out.
* **resize event** (``resize_at_write`` + ``resize_dead_nodes``) — not
  an executor fault: the scenario loop (benchmarks/degraded.py, the
  kill-and-resume tests) consumes it between writes via
  :func:`apply_resize`, which replans the writer shape through
  ``runtime.elastic.plan_remesh`` and redistributes the surviving
  requests — the loop replans instead of wedging.

Degraded placement is deliberately NOT a plan field: ``IOPlan.placement``
stays a bijection (the SPMD executors rely on it). A degraded *serve
map* (:func:`evacuation_map`) is an execution-level override — domain
``g`` served by slot ``serve[g]``, several domains may share a healthy
slot while a straggler's slots serve none — produced by the session's
measured re-resolution and consumed only by the host executor, which
serializes co-located domains per slot in its round timing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

#: a measured per-node slowdown above this is treated as a straggler
#: (the session switches from bijective placement tuning to evacuation)
STRAGGLER_THRESHOLD = 1.5


class FaultError(RuntimeError):
    """Base class for injected-fault failures."""


class UnrecoverableFaultError(FaultError):
    """A fault exhausted its bounded recovery (e.g. a message lost more
    than ``max_retries`` times) — the write must fail, never silently
    drop bytes."""


class TornWriteError(FaultError):
    """The segment drain died mid-write. The file holds a detectable
    partial image: ``windows_written`` cb windows landed on disk and a
    ``<path>.partial`` marker was left next to it."""

    def __init__(self, path: str, windows_enqueued: int,
                 windows_written: int):
        super().__init__(
            f"torn write: {path} drain died after {windows_written} "
            f"windows ({windows_enqueued} enqueued); partial marker left")
        self.path = path
        self.windows_enqueued = windows_enqueued
        self.windows_written = windows_written


@dataclass(frozen=True)
class FaultSpec:
    """One write's injected faults (compose freely; all default off).

    Senders are indexed by their position in the executor's ``per_la``
    list (ranks for two-phase, local aggregators for TAM); slots and
    rounds are the plan's. All times are modeled seconds, consistent
    with the rest of the host executor's timing.
    """

    #: node -> slowdown factor (>= 1): scales the node's stage-1
    #: aggregation, its aggregators' slow-hop receive time, and its
    #: share of the segment drain
    slow_nodes: Mapping[int, float] = field(default_factory=dict)
    #: (aggregator slot, round): the slot's node dies entering that
    #: round; its domains re-route through a repair map and replay
    dead_aggregator: tuple[int, int] | None = None
    #: (sender, round) -> times lost: each loss costs a retry timeout
    #: (with backoff) + a re-send of that sender's round-r messages
    lost: Mapping[tuple[int, int], int] = field(default_factory=dict)
    #: (sender, round) -> seconds: the message arrives late, pushing
    #: the round's completion out by that much
    delayed: Mapping[tuple[int, int], float] = field(default_factory=dict)
    #: (segment, windows): the drain thread of ``<path>.seg<segment>``
    #: dies after that many cb windows (exercises the fail-fast torn
    #: write detection; the executor detects and rewrites)
    torn_window: tuple[int, int] | None = None
    #: scenario-loop event: the write index at which a resize happens
    #: (consumed by the loop via :func:`apply_resize`, not the executor)
    resize_at_write: int | None = None
    #: nodes lost at the resize event
    resize_dead_nodes: tuple[int, ...] = ()
    #: base retry timeout for a lost message (doubles per retry)
    retry_timeout_s: float = 1e-4
    #: bounded retries per message; more losses than this raises
    max_retries: int = 3
    #: dead-aggregator detection latency when no heartbeat monitor is
    #: supplied (a monitor's ``timeout_s`` wins when present)
    detection_s: float = 1e-3

    def slowdown(self, node: int) -> float:
        return max(float(self.slow_nodes.get(node, 1.0)), 1.0)

    @property
    def any_node_faults(self) -> bool:
        return bool(self.slow_nodes) or self.dead_aggregator is not None

    def retry_penalty(self, times_lost: int) -> float:
        """Summed timeout cost of ``times_lost`` consecutive losses
        (exponential backoff: the t-th retry waits 2^t longer)."""
        return self.retry_timeout_s * float(2 ** times_lost - 1)


def measure_node_slowdown(served_time, served_bytes) -> tuple[float, ...]:
    """Per-node slowdown factors from observed service: each node's
    seconds-per-byte rate normalized by the fastest busy node. Nodes
    serving nothing report 1.0 (no evidence). This is what the executor
    reports (``IOTimings.node_slowdown``) and the session's placement
    re-resolution consumes — the measured analogue of
    ``FaultSpec.slow_nodes``."""
    rates = []
    for t, b in zip(served_time, served_bytes):
        rates.append(float(t) / float(b) if b > 0 else None)
    busy = [r for r in rates if r is not None and r > 0]
    if not busy:
        return tuple(1.0 for _ in rates)
    floor = min(busy)
    return tuple(1.0 if r is None or floor <= 0 else max(r / floor, 1.0)
                 for r in rates)


def evacuation_map(n_aggregators: int, n_nodes: int, node_slowdown,
                   domain_bytes=None, *,
                   threshold: float = STRAGGLER_THRESHOLD,
                   dead_nodes=()) -> tuple[int, ...] | None:
    """Degraded serve map: domain -> serving slot, NOT required to be a
    bijection. Greedy effective-makespan assignment over slots whose
    per-slot load is scaled by the serving node's measured slowdown:
    a straggler's slots accrue effective time ``factor`` times faster,
    so they receive only what the healthy slots cannot absorb more
    cheaply (often nothing); dead nodes' slots are excluded outright.
    Domains co-located on one slot serialize — exactly how the host
    executor charges a serve map's round times.

    Returns ``None`` when no node exceeds ``threshold`` and nothing is
    dead — healthy machines keep the plan's bijective placement.
    """
    from repro.core.placement import node_of_slot
    slow = [max(float(s), 1.0) for s in (node_slowdown or ())]
    slow += [1.0] * (n_nodes - len(slow))
    dead = set(int(n) for n in dead_nodes)
    if max(slow, default=1.0) <= threshold and not dead:
        return None
    slots = [s for s in range(n_aggregators)
             if node_of_slot(s, n_aggregators, n_nodes) not in dead]
    if not slots:
        raise UnrecoverableFaultError("no healthy aggregator slot left")
    if domain_bytes is None:
        domain_bytes = [1.0] * n_aggregators
    factor = {s: slow[node_of_slot(s, n_aggregators, n_nodes)]
              for s in slots}
    load = {s: 0.0 for s in slots}
    serve = [0] * n_aggregators
    order = sorted(range(n_aggregators),
                   key=lambda g: (-float(domain_bytes[g]), g))
    for g in order:
        db = max(float(domain_bytes[g]), 0.0)
        s = min(slots, key=lambda s: (load[s] + db * factor[s], s))
        serve[g] = s
        load[s] += db * factor[s]
    return tuple(serve)


def repair_map(serve, dead_slot: int, slot_load, n_aggregators: int,
               n_nodes: int, dead_nodes=()) -> tuple[tuple[int, ...],
                                                     int,
                                                     tuple[int, ...]]:
    """Re-route a dead slot's domains. Returns ``(new_serve,
    repair_slot, victim_domains)``: every domain the dead slot served
    moves to the healthy slot with the lightest current load (ties to
    the lowest slot id). The repair slot then serves several domains —
    serialized, like any degraded serve map."""
    from repro.core.placement import node_of_slot
    dead = set(int(n) for n in dead_nodes)
    dead.add(node_of_slot(dead_slot, n_aggregators, n_nodes))
    healthy = [s for s in range(n_aggregators)
               if s != dead_slot
               and node_of_slot(s, n_aggregators, n_nodes) not in dead]
    if not healthy:
        raise UnrecoverableFaultError(
            f"aggregator slot {dead_slot} died and no healthy slot "
            "remains to repair through")
    repair = min(healthy, key=lambda s: (float(slot_load[s]), s))
    victims = tuple(g for g, s in enumerate(serve) if s == dead_slot)
    new_serve = tuple(repair if s == dead_slot else s for s in serve)
    return new_serve, repair, victims


def partial_marker(seg_path: str) -> str:
    """The torn-write marker next to a segment file: present whenever a
    drain died before the segment's full image landed."""
    return seg_path + ".partial"


def redistribute_requests(rank_requests, new_n_ranks: int):
    """Re-shard a request set onto a smaller writer: requests are
    dealt round-robin onto the surviving ranks. The UNION of requests
    is unchanged, so the written bytes are byte-identical to the
    pre-resize writer's."""
    flat: list[tuple[int, int, np.ndarray]] = []
    for offs, lens, data in rank_requests:
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]]) \
            if offs.size else np.zeros(0, np.int64)
        for o, ln, st in zip(offs, lens, starts):
            flat.append((int(o), int(ln), data[int(st):int(st) + int(ln)]))
    flat.sort(key=lambda r: r[0])
    buckets: list[list] = [[] for _ in range(new_n_ranks)]
    for i, r in enumerate(flat):
        buckets[i % new_n_ranks].append(r)
    out = []
    for b in buckets:
        if b:
            out.append((np.asarray([r[0] for r in b], np.int64),
                        np.asarray([r[1] for r in b], np.int64),
                        np.concatenate([r[2] for r in b])))
        else:
            out.append((np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.uint8)))
    return out


def apply_resize(io, rank_requests, dead_nodes, heartbeat=None):
    """Consume a resize event mid write-loop: replan the writer shape
    through ``runtime.elastic.plan_remesh`` onto the surviving nodes
    and redistribute the request set, instead of wedging on the old
    shape. Returns ``(new_io, new_requests, ElasticPlan)``.

    The file layout (stripe size/count) is storage-side and survives
    the resize, so the shrunken writer produces byte-identical
    segments. The new writer carries the SAME session object — its
    shape is part of every session key, so the first post-resize write
    replans (a fresh entry), which is the point.
    """
    from repro.runtime.elastic import plan_remesh
    dead = set(int(n) for n in dead_nodes)
    if heartbeat is not None:
        for n in dead:
            heartbeat.inject_failure(n)
        dead |= set(heartbeat.dead_hosts())
    survivors = [n for n in range(io.n_nodes) if n not in dead]
    if not survivors:
        raise UnrecoverableFaultError("resize event killed every node")
    q = io.n_ranks // io.n_nodes
    plan = plan_remesh(total_devices=len(survivors) * q,
                       model_parallel=1,
                       old_data_parallel=io.n_ranks)
    new_ranks = plan.mesh_shape[-2] if len(plan.mesh_shape) == 3 \
        else plan.mesh_shape[0]
    # nodes must divide ranks; keep up to one node per q surviving ranks
    new_nodes = 1
    while (new_nodes * 2 <= len(survivors)
           and new_ranks % (new_nodes * 2) == 0):
        new_nodes *= 2
    new_io = io.__class__(
        n_ranks=new_ranks, n_nodes=new_nodes,
        stripe_size=io.stripe_size, stripe_count=io.stripe_count,
        machine=io.machine, session=io.session)
    return new_io, redistribute_requests(rank_requests, new_ranks), plan
