"""Two-layer (TAM-style) collectives for training-time communication.

Beyond-paper: the paper's congestion argument — aggregate inside the fast
domain first so the slow domain sees fewer endpoints and less metadata —
applied to gradient synchronization and MoE dispatch on a multi-pod mesh:

* ``two_layer_psum``    — reduce-scatter over the fast axis, all-reduce
  over the slow axis on the 1/q-size shard only, all-gather back over the
  fast axis. Slow-axis bytes drop from |g| to |g|/q per device.
* ``compressed_psum``   — same schedule with error-feedback int8 (or
  top-k) compression applied ONLY to the slow hop, the direct analogue of
  coalescing before the inter-node phase.
* ``two_layer_all_to_all`` — hierarchical MoE dispatch: tokens are
  exchanged within the pod first, combined per destination pod, then one
  aggregated inter-pod exchange.

These run inside ``shard_map`` bodies (they use axis names).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size
from repro.core import codec as codec_mod


def _pad_to(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def two_layer_psum(x: jax.Array, fast_axis: str, slow_axis: str) -> jax.Array:
    """psum(x) over (fast, slow) with the TAM schedule.

    Mathematically identical to ``lax.psum(x, (fast, slow))``; the
    explicit schedule pins the slow-axis transfer to the scattered shard
    (1/q of the bytes) and exposes the slow hop for compression.
    """
    orig_shape = x.shape
    q = axis_size(fast_axis)
    flat, n = _pad_to(x.reshape(-1), q)
    shard = lax.psum_scatter(flat, fast_axis, scatter_dimension=0,
                             tiled=True)                   # intra: RS
    shard = lax.psum(shard, slow_axis)                     # inter: AR (1/q)
    full = lax.all_gather(shard, fast_axis, axis=0, tiled=True)  # intra: AG
    return full[:n].reshape(orig_shape)


class ErrorFeedbackState:
    """Per-leaf residual for error-feedback compression (EF-SGD style)."""

    @staticmethod
    def init(x: jax.Array) -> jax.Array:
        return jnp.zeros_like(x)


# The int8 arithmetic moved to the shared slow-hop codec subsystem
# (``core.codec``, the "ef-int8" registry entry) so the collective-I/O
# round engine and this module compress the slow hop the same way; the
# old private names stay as aliases for callers that reached in.
def _int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    return codec_mod.int8_encode(x)


def _int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return codec_mod.int8_decode(q, scale)


def compressed_psum(x: jax.Array, residual: jax.Array, fast_axis: str,
                    slow_axis: str) -> tuple[jax.Array, jax.Array]:
    """Two-layer psum with error-feedback int8 on the slow hop only.

    The fast-axis reduce-scatter runs at full precision; the slow-axis
    all-reduce moves int8 (4x fewer slow-axis bytes on top of the 1/q
    from the schedule). The quantization error is fed back into
    ``residual`` and reapplied next step, preserving convergence
    (Karimireddy et al., 2019). Returns (psum_result, new_residual).

    Consumes the registry's ``ef-int8`` codec — the same encode/decode
    (and the same residual-riding contract) the round engine applies to
    the collective-I/O slow hop (``IOPlan.slow_hop_codec``).
    """
    ef = codec_mod.get_codec("ef-int8")
    orig_shape = x.shape
    q = axis_size(fast_axis)
    flat, n = _pad_to(x.reshape(-1), q)
    shard = lax.psum_scatter(flat, fast_axis, scatter_dimension=0,
                             tiled=True)
    res_flat, _ = _pad_to(residual.reshape(-1), q)
    res_shard = lax.dynamic_slice_in_dim(
        res_flat, lax.axis_index(fast_axis) * shard.shape[0], shard.shape[0])
    wire, new_res_shard = ef.jax_encode(shard, res_shard)
    decoded = ef.jax_decode(wire)
    reduced = lax.psum(decoded, slow_axis)
    full = lax.all_gather(shard * 0 + reduced, fast_axis, axis=0, tiled=True)
    new_res = lax.all_gather(new_res_shard, fast_axis, axis=0, tiled=True)
    return (full[:n].reshape(orig_shape),
            new_res[:n].reshape(residual.shape))


def two_layer_all_to_all(x: jax.Array, fast_axis: str, slow_axis: str) -> jax.Array:
    """Hierarchical all-to-all over the flattened (slow, fast) rank space.

    x: [n_slow * n_fast, ...] — chunk d goes to global rank d. Executed as
    an intra-pod exchange that groups chunks by destination pod, then one
    inter-pod exchange of pod-aggregated slabs, then a final intra-pod
    redistribution. Equivalent permutation to a flat all_to_all over both
    axes, but every slow-axis message is a q-chunk aggregate (fewer,
    larger slow-axis transfers — TAM's congestion fix for MoE dispatch).
    """
    ns, nf = axis_size(slow_axis), axis_size(fast_axis)
    assert x.shape[0] == ns * nf, "leading dim must be n_slow * n_fast"
    tail = x.shape[1:]
    # group by (dest pod, dest fast slot): grouped[t, u] -> rank (t, u)
    grouped = x.reshape(ns, nf, *tail)
    # intra-pod: deliver every chunk to its destination FAST SLOT within
    # my pod. After this, device (s, f) holds intra[u', t] = the chunk
    # from fast peer u' destined to (pod t, slot f) — i.e. all chunks
    # that must leave pod s toward slot f, pre-gathered on one device.
    intra = lax.all_to_all(grouped, fast_axis, split_axis=1, concat_axis=0,
                           tiled=False).reshape(nf, ns, *tail)
    # inter-pod: ONE aggregated slow-axis exchange per device moves each
    # pod-slab to its destination pod; chunks are already at the right
    # fast slot, so this completes the permutation. inter[s', u'] = chunk
    # from global rank (s', u') destined to me.
    inter = lax.all_to_all(intra, slow_axis, split_axis=1, concat_axis=0,
                           tiled=False).reshape(ns, nf, *tail)
    return inter.reshape(ns * nf, *tail)


def tree_two_layer_psum(tree, fast_axis: str, slow_axis: str):
    return jax.tree.map(lambda g: two_layer_psum(g, fast_axis, slow_axis),
                        tree)


def tree_compressed_psum(tree, residuals, fast_axis: str, slow_axis: str):
    flat, treedef = jax.tree.flatten(tree)
    rflat = jax.tree.leaves(residuals)
    out, new_res = [], []
    for g, r in zip(flat, rflat):
        o, nr = compressed_psum(g, r, fast_axis, slow_axis)
        out.append(o)
        new_res.append(nr)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_res)
