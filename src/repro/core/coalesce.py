"""Sort + coalesce of offset-length request lists (pure-jnp path).

This is the algorithmic heart of the paper's aggregation layers: each
(local or global) aggregator merge-sorts the offset-length pairs gathered
from its senders and coalesces consecutive contiguous pairs
(``offset[i] + length[i] == offset[i+1]``) into single larger requests.
Block-partitioned patterns (BTIO, S3D-IO) coalesce by up to
``(1/2)^(P/P_L)`` — the coalesce ratio is what makes TAM's inter-node
phase cheap.

The Pallas kernels in ``repro.kernels`` provide the TPU-optimized
implementations of the same operations; this module is the oracle and
the portable fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.requests import PAD_OFFSET, RequestList, mask_invalid


def sort_requests(r: RequestList) -> RequestList:
    """Sort requests by offset (padding sorts to the end).

    The MPI analogue is the heap merge-sort over per-sender pre-sorted
    lists; a single key sort is the TPU-native equivalent (and is what
    the bitonic Pallas kernel implements).
    """
    r = mask_invalid(r)
    order = jnp.argsort(r.offsets, stable=True)
    return RequestList(r.offsets[order], r.lengths[order], r.count)


def merge_sorted(lists: RequestList) -> RequestList:
    """Merge a batch of per-sender sorted lists into one sorted list.

    ``lists`` is a RequestList with leading batch dim [S, cap]; returns a
    flat sorted RequestList of capacity S*cap. This is the aggregator-side
    merge in both aggregation layers.
    """
    off = lists.offsets.reshape(-1)
    ln = lists.lengths.reshape(-1)
    cnt = jnp.sum(lists.count, dtype=jnp.int32)
    return sort_requests(RequestList(off, ln, cnt))


def coalesce_sorted(r: RequestList) -> RequestList:
    """Coalesce adjacent contiguous requests of an offset-sorted list.

    Returns a compacted RequestList (valid entries at the front) with
    the same capacity. Zero-length requests must not appear among the
    valid entries (the padding convention reserves length 0).
    """
    off, ln = r.offsets, r.lengths
    cap = r.capacity
    prev_end = jnp.concatenate([jnp.full((1,), -1, jnp.int32),
                                (off + ln)[:-1]])
    is_pad = off == PAD_OFFSET
    # a new segment starts where the request is not contiguous with the
    # previous one; padding always starts its own (discarded) segment.
    boundary = (off != prev_end) | is_pad
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    seg_off = jax.ops.segment_min(jnp.where(is_pad, PAD_OFFSET, off), seg,
                                  num_segments=cap)
    seg_len = jax.ops.segment_sum(jnp.where(is_pad, 0, ln), seg,
                                  num_segments=cap)
    n_seg = jnp.where(r.count > 0, seg[jnp.maximum(r.count - 1, 0)] + 1, 0)
    idx = jnp.arange(cap, dtype=jnp.int32)
    valid = idx < n_seg
    return RequestList(
        jnp.where(valid, seg_off, PAD_OFFSET),
        jnp.where(valid, seg_len, 0),
        n_seg.astype(jnp.int32),
    )


def aggregate(lists: RequestList) -> RequestList:
    """Full aggregator step: merge-sort per-sender lists, then coalesce."""
    return coalesce_sorted(merge_sorted(lists))


def coalesce_ratio(before: RequestList, after: RequestList) -> jax.Array:
    """Fraction of requests remaining after coalescing (lower = better)."""
    return after.count.astype(jnp.float32) / jnp.maximum(
        before.count.astype(jnp.float32), 1.0)


def pack_data(r: RequestList, starts: jax.Array, data: jax.Array,
              out_len: int, base: jax.Array | int = 0) -> jax.Array:
    """Scatter request payloads into a contiguous buffer.

    This is the "memory operation for moving the request data into a
    contiguous space based on the sorted offsets" (paper §V-A) and the
    aggregator-side placement into its file domain.

    r:      requests (element offsets into the *output* space).
    starts: int32[cap] — start of each request's payload within ``data``.
    data:   the concatenated payload elements for this sender set.
    out_len: length of the output buffer.
    base:   subtracted from offsets (e.g. the file-domain start).

    Elements mapping outside [0, out_len) are dropped — that is how a
    device ignores requests outside its file domain.
    """
    cap = r.capacity
    dcap = data.shape[0]
    # walk a contiguous "element stream": element e belongs to request
    # req_of[e] at index `within` inside that request. Its source lives at
    # starts[req] + within in `data` (slab gaps allowed); its destination
    # is offsets[req] + within - base in the output buffer.
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32), r.lengths,
                        total_repeat_length=dcap)
    eidx = jnp.arange(dcap, dtype=jnp.int32)
    packed_starts = (jnp.cumsum(r.lengths) - r.lengths).astype(jnp.int32)
    within = eidx - packed_starts[req_of]
    src = starts[req_of] + within
    dst = r.offsets[req_of] + within - base
    total = jnp.sum(r.lengths, dtype=jnp.int32)
    live = eidx < total
    vals = data[jnp.clip(src, 0, dcap - 1)]
    # positive OOB sentinel: .at[] wraps negative indices
    dst = jnp.where(live, dst, out_len)
    out = jnp.zeros((out_len,), dtype=data.dtype)
    return out.at[dst].set(vals, mode="drop")


def unpack_data(r: RequestList, starts: jax.Array, buf: jax.Array,
                out_len: int, base: jax.Array | int = 0) -> jax.Array:
    """Gather request payloads out of a contiguous buffer (read path)."""
    cap = r.capacity
    req_of = jnp.repeat(jnp.arange(cap, dtype=jnp.int32), r.lengths,
                        total_repeat_length=out_len)
    within = jnp.arange(out_len, dtype=jnp.int32) - starts[req_of]
    pos = r.offsets[req_of] + within - base
    total = jnp.sum(r.lengths, dtype=jnp.int32)
    pos = jnp.where(jnp.arange(out_len, dtype=jnp.int32) < total, pos, 0)
    vals = buf[jnp.clip(pos, 0, buf.shape[0] - 1)]
    return jnp.where(jnp.arange(out_len, dtype=jnp.int32) < total, vals, 0)


def request_starts(r: RequestList) -> jax.Array:
    """Start position of each request's payload in the packed data buffer."""
    return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(r.lengths)[:-1].astype(jnp.int32)])
