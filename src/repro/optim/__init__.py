from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adafactor, adamw, global_norm,
)
from repro.optim.schedule import warmup_cosine  # noqa: F401
