"""Sharded optimizers (no optax dependency).

AdamW keeps bf16 moments (documented deviation from fp32-master
practice: at 1T params fp32 m/v/master = 14 bytes/param = 14 TB — beyond
any 512-chip v5e fleet; bf16 m/v + bf16 params = 6 bytes/param).
Adafactor (factored second moment, no first moment) is the memory-floor
option used for the ≥400B MoE archs (see configs in launch/shapes.py).

Moment tensors inherit the parameter PartitionSpecs, so optimizer state
is sharded exactly like the model (update math is elementwise — GSPMD
partitions it with zero communication).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, lr)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float | None = 1.0,
          moment_dtype=jnp.bfloat16) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        if clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh, vh = m32 / bc1, v32 / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m32.astype(moment_dtype), v32.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init=init, update=update)


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    State per matrix param: one row vector + one col vector (fp32);
    scalars/vectors keep a full second moment. No first moment.
    """
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)
                                  or hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps))
                u = g32 / jnp.sqrt(jnp.maximum(denom * vc[..., None, :],
                                               eps))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = (p.astype(jnp.float32) - lr * u
                    - lr * weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), ns

        leaves, treedef = jax.tree.flatten(params)
        gl = treedef.flatten_up_to(grads)
        sl = treedef.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(gl, sl, leaves)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_s = treedef.unflatten([o[1] for o in outs])
        return new_p, {"f": new_s, "step": step}

    return Optimizer(init=init, update=update)
