"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr
