"""kimi-k2-1t-a32b [moe] — trillion-param MoE 384e top-8
[arXiv:2501.kimi2; unverified]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=0, vocab=163840, rope_theta=5e4,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048, every_n=1),
)
