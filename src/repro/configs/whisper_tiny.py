"""whisper-tiny [audio] — enc-dec, conv frontend STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, rope_theta=1e4,
    enc_dec=True, n_enc_layers=4, enc_seq=1500, frontend="audio",
)
