"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=0, vocab=202048, rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192, every_n=1),
)
