"""Assigned architecture configs (exact numbers from the assignment table).

``get(name)`` returns the full ModelConfig; ``ARCHS`` lists all ids.
Each arch also defines its shape cells via ``repro.launch.shapes``.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "yi_34b",
    "gemma2_9b",
    "qwen15_32b",
    "glm4_9b",
    "whisper_tiny",
    "jamba_15_large",
    "llama4_maverick",
    "kimi_k2",
    "mamba2_27b",
    "llava_next_34b",
)

ALIASES = {
    "yi-34b": "yi_34b",
    "gemma2-9b": "gemma2_9b",
    "qwen1.5-32b": "qwen15_32b",
    "glm4-9b": "glm4_9b",
    "whisper-tiny": "whisper_tiny",
    "jamba-1.5-large-398b": "jamba_15_large",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "kimi-k2-1t-a32b": "kimi_k2",
    "mamba2-2.7b": "mamba2_27b",
    "llava-next-34b": "llava_next_34b",
}


def get(name: str):
    mod_name = ALIASES.get(name, name).replace("-", "_")
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
