"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887; hf]."""
from repro.models.config import ModelConfig, MoEConfig, MambaConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, rope_theta=1e4,
    attn_every=8,  # layer i%8==0 is attention, 7 mamba layers follow
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, every_n=2),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
)
