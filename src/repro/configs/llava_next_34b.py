"""llava-next-34b [vlm] — yi-34b backbone, anyres tiling; vision
frontend STUB (input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, rope_theta=5e6,
    frontend="vision", num_prefix_embeds=576,  # one anyres tile stub
)
