"""Pallas TPU bitonic sort of offset-length request lists.

The paper's aggregators spend ``O((P*k/P_L) log(P/P_L))`` in a heap
merge-sort of offset-length pairs — the dominant compute hot spot of the
communication phase at scale (SIV-D). A pointer-chasing heap is the wrong
shape for a TPU; the VPU wants a data-parallel network. We therefore sort
with a **bitonic network held entirely in VMEM**: log2(n)*(log2(n)+1)/2
vectorized compare-exchange sweeps, each a full-lane min/max plus masked
select — no scalar control flow, MXU-free, bandwidth-bound on VMEM only.

Hardware adaptation notes (DESIGN.md S7.6):
* one block sorts up to ``MAX_BLOCK`` pairs in VMEM. 32768 pairs x
  (key + 2 carries) x 4 B = 384 KiB << 16 MiB VMEM, leaving room for the
  double-buffered pipeline. Per-round request counts beyond MAX_BLOCK are
  handled by the ops.py wrapper (chunk sort + jnp merge), mirroring
  ROMIO's multi-round bounding of per-round work.
* compare-exchange partners at distance j are materialized with a
  reshape to (n/2j, 2, j) and a middle-axis flip, so every step is a
  contiguous vector op rather than a gather.
* padding (PAD_OFFSET) sorts to the end naturally; ties keep both
  elements' own carries, so the sort is safe for duplicated keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_BLOCK = 32768  # pairs per VMEM block (power of two)


def _cmp_exchange(key: jax.Array, carries: tuple[jax.Array, ...],
                  j: int, k: int):
    """One bitonic compare-exchange sweep at distance j, block size k."""
    n = key.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)
    partner_view = lambda x: x.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
    pkey = partner_view(key)
    take_min = ((i & j) == 0) == ((i & k) == 0)
    new_key = jnp.where(take_min, jnp.minimum(key, pkey),
                        jnp.maximum(key, pkey))
    took_partner = jnp.where(take_min, pkey < key, pkey > key)
    new_carries = tuple(
        jnp.where(took_partner, partner_view(c), c) for c in carries)
    return new_key, new_carries


def _bitonic_sort_body(key, carries):
    n = key.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            key, carries = _cmp_exchange(key, carries, j, k)
            j //= 2
        k *= 2
    return key, carries


def _sort_kernel(off_ref, len_ref, carry_ref, off_out, len_out, carry_out):
    key = off_ref[...]
    carries = (len_ref[...], carry_ref[...])
    key, carries = _bitonic_sort_body(key, carries)
    off_out[...] = key
    len_out[...] = carries[0]
    carry_out[...] = carries[1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(offsets: jax.Array, lengths: jax.Array, carry: jax.Array,
                 *, interpret: bool = True):
    """Sort one batch of request blocks by offset.

    offsets/lengths/carry: int32[b, n] with n a power of two <= MAX_BLOCK.
    Returns the three arrays sorted along the last axis by offset.
    The grid iterates over b — each grid step sorts one block in VMEM.
    """
    b, n = offsets.shape
    if n & (n - 1) or n > MAX_BLOCK:
        raise ValueError(f"block length {n} must be a power of two <= {MAX_BLOCK}")
    block = pl.BlockSpec((1, n), lambda i: (i, 0))
    flat = pl.BlockSpec((1, n), lambda i: (i, 0))

    def kernel(o, l, c, oo, lo, co):
        key = o[0, :]
        carries = (l[0, :], c[0, :])
        key, carries = _bitonic_sort_body(key, carries)
        oo[0, :] = key
        lo[0, :] = carries[0]
        co[0, :] = carries[1]

    out_shape = [jax.ShapeDtypeStruct((b, n), jnp.int32)] * 3
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[block, block, block],
        out_specs=[flat, flat, flat],
        out_shape=out_shape,
        interpret=interpret,
    )(offsets, lengths, carry)
