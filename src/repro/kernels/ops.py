"""Jit'd public wrappers around the Pallas kernels.

Handles: padding to power-of-two block sizes, RequestList integration,
large-list chunking (chunk-sort + merge), and interpret-mode dispatch
(interpret=True on CPU — per the build rules kernels target TPU but are
validated on the CPU interpreter).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.requests import PAD_OFFSET, RequestList
from repro.kernels import coalesce_kernel, pack as pack_mod, sort as sort_mod


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _pad_block(x: jax.Array, n: int, fill) -> jax.Array:
    pad = n - x.shape[-1]
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=fill)


def sort_requests_with(r: RequestList, starts: jax.Array,
                       interpret: bool | None = None):
    """Kernel-backed equivalent of ``exchange.sort_with(r, starts)``.

    Lists longer than one VMEM block are chunk-sorted by the kernel and
    k-way merged with a final jnp argsort of block-sorted runs (the merge
    is cheap relative to the in-block network; on TPU it would be a
    bitonic inter-block merge, see kernels/sort.py docstring).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    cap = r.capacity
    n = _next_pow2(cap)
    if n <= sort_mod.MAX_BLOCK:
        off = _pad_block(r.offsets[None], n, PAD_OFFSET)
        ln = _pad_block(r.lengths[None], n, 0)
        st = _pad_block(starts[None], n, 0)
        so, sl, ss = sort_mod.bitonic_sort(off, ln, st, interpret=interpret)
        return (RequestList(so[0, :cap], sl[0, :cap], r.count), ss[0, :cap])
    # chunked path: sort blocks with the kernel, merge with argsort
    nb = -(-cap // sort_mod.MAX_BLOCK)
    padded = nb * sort_mod.MAX_BLOCK
    off = _pad_block(r.offsets, padded, PAD_OFFSET).reshape(nb, -1)
    ln = _pad_block(r.lengths, padded, 0).reshape(nb, -1)
    st = _pad_block(starts, padded, 0).reshape(nb, -1)
    so, sl, ss = sort_mod.bitonic_sort(off, ln, st, interpret=interpret)
    flat_o, flat_l, flat_s = so.reshape(-1), sl.reshape(-1), ss.reshape(-1)
    order = jnp.argsort(flat_o, stable=True)
    return (RequestList(flat_o[order][:cap], flat_l[order][:cap], r.count),
            flat_s[order][:cap])


def coalesce(r: RequestList, interpret: bool | None = None) -> RequestList:
    """Kernel-backed equivalent of ``coalesce.coalesce_sorted``."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    cap = r.capacity
    n = min(_next_pow2(cap), max(_next_pow2(cap), 8))
    off = _pad_block(r.offsets[None], n, PAD_OFFSET)
    ln = _pad_block(r.lengths[None], n, 0)
    co, cl, cnt = coalesce_kernel.coalesce(off, ln, interpret=interpret)
    return RequestList(co[0, :cap], cl[0, :cap], cnt[0])


def pack(r: RequestList, starts: jax.Array, data: jax.Array, base,
         out_len: int, interpret: bool | None = None) -> jax.Array:
    """Kernel-backed equivalent of ``coalesce.pack_data``.

    Requires offset-sorted, non-overlapping requests (the condition the
    gather formulation exploits). out_len is padded to the tile size
    internally; the caller receives exactly [out_len].
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    cap = _next_pow2(r.capacity)
    off = _pad_block(r.offsets, cap, PAD_OFFSET)
    ln = _pad_block(r.lengths, cap, 0)
    st = _pad_block(starts, cap, 0)
    padded_out = -(-out_len // pack_mod.TILE) * pack_mod.TILE
    out = pack_mod.pack(off, ln, st, data, base, padded_out,
                        interpret=interpret)
    return out[:out_len]


def fused_drain_pack(r: RequestList, starts: jax.Array, data: jax.Array,
                     base, out_len: int, interpret: bool | None = None):
    """Kernel-backed equivalent of the drain's ``sort_with`` + two
    ``pack_data`` calls, in one ``pallas_call``
    (``kernels.fused_round.fused_sort_pack``).

    Takes the UNSORTED merged request list (the fusion absorbs the
    sort); returns ``(window, mask)``, both [out_len] in data.dtype.
    Selected by ``IOPlan.kernel_fusion == "fused_round"``.
    """
    from repro.kernels import fused_round

    interpret = (not _on_tpu()) if interpret is None else interpret
    cap = _next_pow2(r.capacity)
    off = _pad_block(r.offsets, cap, PAD_OFFSET)
    ln = _pad_block(r.lengths, cap, 0)
    st = _pad_block(starts, cap, 0)
    padded_out = -(-out_len // pack_mod.TILE) * pack_mod.TILE
    win, mask = fused_round.fused_sort_pack(off, ln, st, data, base,
                                            padded_out,
                                            interpret=interpret)
    return win[:out_len], mask[:out_len]


def rle_zero_skip_encode(data: jax.Array, interpret: bool | None = None):
    """Kernel-backed equivalent of ``RleCodec.jax_encode``'s zero-skip
    compaction (``kernels.fused_round.zero_skip_encode``): pads rows to
    a power of two, compacts, slices back. Returns ``(vals, pos)`` with
    the codec's exact wire layout (pos == -1 in the padding)."""
    from repro.kernels import fused_round

    interpret = (not _on_tpu()) if interpret is None else interpret
    lead, cap = data.shape[:-1], data.shape[-1]
    n = _next_pow2(cap)
    rows = data.reshape(-1, cap)
    padded = _pad_block(rows, n, 0)
    vals, pos = fused_round.zero_skip_encode(padded, interpret=interpret)
    return (vals[:, :cap].reshape(*lead, cap),
            pos[:, :cap].reshape(*lead, cap))


def rle_zero_skip_decode(parts, interpret: bool | None = None):
    """Kernel-backed equivalent of ``RleCodec.jax_decode``
    (``kernels.fused_round.zero_skip_decode``): pads the compacted
    ``(vals, pos)`` rows to a power of two (pos padding = -1, the drop
    sentinel), scatters in VMEM, slices back to the window shape."""
    from repro.kernels import fused_round

    interpret = (not _on_tpu()) if interpret is None else interpret
    vals, pos = parts
    lead, cap = vals.shape[:-1], vals.shape[-1]
    n = _next_pow2(cap)
    v = _pad_block(vals.reshape(-1, cap), n, 0)
    p = _pad_block(pos.reshape(-1, cap), n, -1)
    out = fused_round.zero_skip_decode(v, p, interpret=interpret)
    return out[:, :cap].reshape(*lead, cap)


def fused_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    logit_cap: float | None = None, q_offset: int = 0,
                    interpret: bool | None = None):
    """Padding wrapper over kernels.flash.flash_attention_fused:
    accepts arbitrary Sq/Skv, pads to block sizes, bounds real keys with
    kv_len (padded keys never enter the softmax), slices back.
    """
    from repro.kernels import flash

    interpret = (not _on_tpu()) if interpret is None else interpret
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    bq = min(flash.BLOCK_Q, max(64, 1 << (sq - 1).bit_length()))
    bkv = min(flash.BLOCK_KV, max(64, 1 << (skv - 1).bit_length()))
    pq = -(-sq // bq) * bq - sq
    pk = -(-skv // bkv) * bkv - skv
    window_eff = window
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # padded keys sit at positions >= skv: mask them with causality when
    # causal (q_offset + sq <= skv pad positions) — for causal callers
    # with q_offset+sq == skv this is automatic.
    out = flash.flash_attention_fused(
        qp, kp, vp, causal=causal, window=window_eff,
        logit_cap=logit_cap, q_offset=q_offset, kv_len=skv,
        interpret=interpret, block_q=bq, block_kv=bkv)
    return out[:, :sq]
