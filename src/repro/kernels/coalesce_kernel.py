"""Pallas TPU kernel: coalesce adjacent contiguous requests.

Given an offset-sorted request block, fuse every run of contiguous
requests (``offset[i] + length[i] == offset[i+1]``) into one request and
compact the results to the front of the block. This is the aggregator
step that lets TAM forward far fewer offset-length pairs across the slow
axis (BTIO coalesces 1.34e9 -> 2.36e7 requests at 256 nodes in the
paper).

TPU shape: boundary detection is an elementwise shift-compare; run ids
and compaction positions are prefix sums (log2(n) doubling sweeps on the
VPU); the head-offset/segment-length reductions become masked selects
plus a segment-sum implemented with the same prefix-sum trick — all on a
VMEM-resident block, no scalar loops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.requests import PAD_OFFSET

MAX_BLOCK = 32768


def _prefix_sum(x: jax.Array) -> jax.Array:
    """Hillis-Steele inclusive scan: log2(n) shifted adds (VPU-friendly)."""
    n = x.shape[0]
    d = 1
    while d < n:
        shifted = jnp.pad(x, (d, 0))[:n]
        x = x + shifted
        d *= 2
    return x


def _coalesce_block(off: jax.Array, ln: jax.Array):
    n = off.shape[0]
    prev_end = jnp.pad(off + ln, (1, 0), constant_values=-1)[:n]
    is_pad = off == PAD_OFFSET
    boundary = (off != prev_end) | is_pad
    # run id of each request (0-based), padding runs included then masked
    run = _prefix_sum(boundary.astype(jnp.int32)) - 1
    # head of each valid run carries the coalesced offset; the coalesced
    # length of a run is the inclusive-scan of lengths at the run's LAST
    # element minus the exclusive prefix before its head.
    csum = _prefix_sum(jnp.where(is_pad, 0, ln))
    is_head = boundary & ~is_pad
    next_boundary = jnp.pad(boundary, (0, 1), constant_values=True)[1:]
    is_last = next_boundary & ~is_pad
    head_excl = csum - jnp.where(is_pad, 0, ln)   # prefix before me
    # scatter head offset / head prefix / last csum into run slots
    sentinel = n  # positive OOB => dropped (never wrap with -1)
    head_idx = jnp.where(is_head, run, sentinel)
    last_idx = jnp.where(is_last, run, sentinel)
    run_off = jnp.full((n,), PAD_OFFSET, jnp.int32).at[head_idx].set(
        off, mode="drop")
    run_start = jnp.zeros((n,), jnp.int32).at[head_idx].set(
        head_excl, mode="drop")
    run_end = jnp.zeros((n,), jnp.int32).at[last_idx].set(csum, mode="drop")
    n_runs = jnp.sum(is_head.astype(jnp.int32))
    i = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)
    valid = i < n_runs
    return (jnp.where(valid, run_off, PAD_OFFSET),
            jnp.where(valid, run_end - run_start, 0),
            n_runs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coalesce(offsets: jax.Array, lengths: jax.Array, *,
             interpret: bool = True):
    """Coalesce a batch of sorted request blocks.

    offsets/lengths: int32[b, n], offset-sorted with PAD_OFFSET padding
    (interspersed padding allowed only at the tail, i.e. post-sort).
    Returns (offsets, lengths, counts): compacted runs per block.
    """
    b, n = offsets.shape
    if n > MAX_BLOCK:
        raise ValueError(f"block length {n} > {MAX_BLOCK}")
    block = pl.BlockSpec((1, n), lambda i: (i, 0))
    cnt_spec = pl.BlockSpec((1,), lambda i: (i,))

    def kernel(o, l, oo, lo, co):
        off, ln, cnt = _coalesce_block(o[0, :], l[0, :])
        oo[0, :] = off
        lo[0, :] = ln
        co[0] = cnt

    out_shape = [jax.ShapeDtypeStruct((b, n), jnp.int32),
                 jax.ShapeDtypeStruct((b, n), jnp.int32),
                 jax.ShapeDtypeStruct((b,), jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[block, block],
        out_specs=[block, block, cnt_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(offsets, lengths)
