"""Pure-jnp oracles for the Pallas kernels (ground truth in tests).

These are thin adapters over ``repro.core.coalesce`` — the portable
algorithm module — exposed in the array-in/array-out signatures of the
kernels so the allclose sweeps compare like with like.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.requests import PAD_OFFSET


def sort_ref(offsets: jax.Array, lengths: jax.Array, carry: jax.Array):
    """Batched sort-by-offset oracle for kernels.sort.bitonic_sort."""
    order = jnp.argsort(offsets, axis=-1, stable=True)
    return (jnp.take_along_axis(offsets, order, -1),
            jnp.take_along_axis(lengths, order, -1),
            jnp.take_along_axis(carry, order, -1))


def coalesce_ref(offsets: jax.Array, lengths: jax.Array):
    """Batched coalesce oracle (numpy, trivially correct)."""
    offsets, lengths = np.asarray(offsets), np.asarray(lengths)
    b, n = offsets.shape
    out_o = np.full((b, n), PAD_OFFSET, np.int32)
    out_l = np.zeros((b, n), np.int32)
    counts = np.zeros((b,), np.int32)
    for i in range(b):
        runs = []
        for o, l in zip(offsets[i], lengths[i]):
            if o == PAD_OFFSET or l == 0:
                continue
            if runs and runs[-1][0] + runs[-1][1] == o:
                runs[-1][1] += int(l)
            else:
                runs.append([int(o), int(l)])
        counts[i] = len(runs)
        for j, (o, l) in enumerate(runs):
            out_o[i, j], out_l[i, j] = o, l
    return jnp.asarray(out_o), jnp.asarray(out_l), jnp.asarray(counts)


def pack_ref(offsets, lengths, starts, data, base, out_len: int):
    """Scatter oracle for kernels.pack.pack."""
    offsets, lengths = np.asarray(offsets), np.asarray(lengths)
    starts, data = np.asarray(starts), np.asarray(data)
    out = np.zeros((out_len,), data.dtype)
    for o, l, s in zip(offsets, lengths, starts):
        if o == PAD_OFFSET or l == 0:
            continue
        dst = int(o) - int(base)
        for e in range(int(l)):
            if 0 <= dst + e < out_len:
                out[dst + e] = data[s + e]
    return jnp.asarray(out)
