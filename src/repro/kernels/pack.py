"""Pallas TPU kernel: pack request payloads into a contiguous buffer.

The third intra-node aggregation component in the paper: "memory
operation for moving the request data into a contiguous space based on
the sorted offsets" (SV-A), and the aggregator-side placement of payload
into the file domain.

GPU/CPU implementations scatter (out[dst[e]] = data[e]); TPUs hate
scatters. We invert it into a GATHER over output tiles: each grid step
produces one aligned output tile; for every output position p it binary-
searches the (VMEM-resident) sorted offset array for the covering
request r — offsets[r] <= p + base < offsets[r] + lengths[r] — and pulls
data[starts[r] + (p + base - offsets[r])], else 0. log2(cap) select
steps, fully vectorized over the tile; the request metadata block stays
pinned in VMEM across tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_REQ_BLOCK = 32768
TILE = 4096


def _searchsorted_right(sorted_keys: jax.Array, queries: jax.Array) -> jax.Array:
    """Vectorized binary search: index of last key <= query (-1 if none)."""
    n = sorted_keys.shape[0]
    lo = jnp.full(queries.shape, -1, jnp.int32)
    hi = jnp.full(queries.shape, n, jnp.int32)
    steps = max(n.bit_length(), 1)
    for _ in range(steps):
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, n - 1)
        take = sorted_keys[mid_c] <= queries
        lo = jnp.where((hi - lo > 1) & take, mid, lo)
        hi = jnp.where((hi - lo > 1) & ~take, mid, hi)
    return lo


def _pack_tile(off, ln, starts, data, base, tile_start, tile):
    p = (jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0).reshape(tile)
         + tile_start + base)
    r = _searchsorted_right(off, p)
    r_c = jnp.clip(r, 0, off.shape[0] - 1)
    within = p - off[r_c]
    covered = (r >= 0) & (within < ln[r_c])
    src = jnp.clip(starts[r_c] + within, 0, data.shape[0] - 1)
    return jnp.where(covered, data[src], jnp.zeros((), data.dtype))


@functools.partial(jax.jit, static_argnames=("out_len", "interpret"))
def pack(offsets: jax.Array, lengths: jax.Array, starts: jax.Array,
         data: jax.Array, base, out_len: int, *, interpret: bool = True):
    """Gather-style pack of payloads into a dense [out_len] buffer.

    offsets/lengths/starts: int32[cap] — offset-SORTED, non-overlapping
    requests (padding at tail). starts[i] locates request i's payload in
    ``data``. base: int32 scalar — file-domain start. Output positions
    not covered by any request are 0.
    """
    cap = offsets.shape[0]
    if cap > MAX_REQ_BLOCK:
        raise ValueError(f"request block {cap} > {MAX_REQ_BLOCK}")
    if out_len % TILE:
        raise ValueError(f"out_len must be a multiple of {TILE}")
    n_tiles = out_len // TILE
    base = jnp.asarray(base, jnp.int32).reshape(1)

    meta = pl.BlockSpec((cap,), lambda i: (0,))
    dspec = pl.BlockSpec(data.shape, lambda i: (0,))
    bspec = pl.BlockSpec((1,), lambda i: (0,))
    out_spec = pl.BlockSpec((TILE,), lambda i: (i,))

    def kernel(o, l, s, d, b, out):
        tile_start = pl.program_id(0) * TILE
        out[...] = _pack_tile(o[...], l[...], s[...], d[...], b[0],
                              tile_start, TILE)

    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[meta, meta, meta, dspec, bspec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((out_len,), data.dtype),
        interpret=interpret,
    )(offsets, lengths, starts, data, base)
