"""Pallas TPU kernel: the fused per-round drain hot path.

Each round, every aggregator drains one cb window: sort the merged
request list by offset, then pack the window payload AND the coverage
mask into the domain buffer. Unfused, that is three kernel launches —
``kernels/sort.py`` (bitonic), then ``kernels/pack.py`` twice (window
payload + mask) — i.e. three HBM round-trips of the request metadata
per round, plus a second binary-search sweep the mask pack repeats
verbatim. At the ranks-per-node the source paper targets (SIV-D: the
aggregator-side sort dominates the communication phase), the metadata
traffic is the hot path.

``fused_sort_pack`` does all of it in ONE ``pallas_call``:

* grid step 0 runs the bitonic network (``kernels.sort``'s compare-
  exchange body, VMEM-resident) and parks the sorted metadata in VMEM
  scratch — TPU grids are sequential, so the scratch persists;
* every grid step then produces one aligned output tile of BOTH the
  window and the mask from a SINGLE binary search per position
  (``kernels.pack``'s gather formulation) — the mask is a byproduct of
  the coverage test the payload gather already performs, so the second
  search sweep of the unfused path disappears entirely.

``zero_skip_encode`` is the codec half of the fusion: the rle codec's
SPMD lowering is a zero-skipping compaction ``(values, positions)``
(``core.codec.RleCodec.jax_encode`` — a stable argsort on zero-ness).
Here it is one VMEM block per destination bucket: a Hillis-Steele
prefix sum ranks the nonzeros in position order and a single in-block
scatter compacts them — byte-identical to the argsort form (asserted
by the rounds_checks fuzz), without materializing the argsort
permutation through HBM.

``zero_skip_decode`` is the read-direction half (the PR 6 "fuse merge
+ codec decode" leftover): the rle ``jax_decode`` expands the
compacted ``(values, positions)`` wire form back into the window by a
row-wise scatter through an HBM-materialized (rows, cap+1) staging
buffer. Here the scatter runs in VMEM, one block per gathered window —
the merge of the fetched window into the reader's shard consumes the
kernel's output directly, so the staging buffer never touches HBM.
Byte-identical to ``jax_decode`` (rounds_checks read fuzz).

Both kernels are selected by the planner's ``lower_kernels`` pass
(``IOPlan.kernel_fusion == "fused_round"``) and consumed by
``core.rounds`` — byte-identity with the unfused jnp path under every
placement x codec x depth is the acceptance contract (rounds_checks).
Validated with interpret=True on CPU per the build rules; blocks obey
the TPU constraints (power-of-two request blocks, aligned output
tiles, >= 2D iota via broadcasted_iota).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pack import MAX_REQ_BLOCK, TILE, _searchsorted_right
from repro.kernels.sort import _bitonic_sort_body


@functools.partial(jax.jit, static_argnames=("out_len", "interpret"))
def fused_sort_pack(offsets: jax.Array, lengths: jax.Array,
                    starts: jax.Array, data: jax.Array, base,
                    out_len: int, *, interpret: bool = True):
    """Sort + dual-pack one drain window in a single kernel.

    offsets/lengths/starts: int32[cap] request metadata, cap a power of
    two <= MAX_REQ_BLOCK, padding at PAD_OFFSET/0 (UNSORTED — the sort
    happens inside). data: the flat payload buffer starts[] points
    into. base: int32 scalar, the window's domain offset. Returns
    ``(window, mask)``, both [out_len]: the packed payload and its
    coverage mask (1 where any request covers the position, else 0),
    in ``data.dtype`` — exactly what the two unfused ``pack_data``
    calls of the drain produce.
    """
    cap = offsets.shape[0]
    if cap & (cap - 1) or cap > MAX_REQ_BLOCK:
        raise ValueError(
            f"request block {cap} must be a power of two <= {MAX_REQ_BLOCK}")
    if out_len % TILE:
        raise ValueError(f"out_len must be a multiple of {TILE}")
    n_tiles = out_len // TILE
    base = jnp.asarray(base, jnp.int32).reshape(1)

    meta = pl.BlockSpec((cap,), lambda i: (0,))
    dspec = pl.BlockSpec(data.shape, lambda i: (0,))
    bspec = pl.BlockSpec((1,), lambda i: (0,))
    tspec = pl.BlockSpec((TILE,), lambda i: (i,))

    def kernel(o, l, s, d, b, win, mask, so, sl, ss):
        # the sort runs once; the sorted metadata rides VMEM scratch
        # across the (sequential) output tiles
        @pl.when(pl.program_id(0) == 0)
        def _sort():
            key, (ln, st) = _bitonic_sort_body(o[...], (l[...], s[...]))
            so[...] = key
            sl[...] = ln
            ss[...] = st

        tile_start = pl.program_id(0) * TILE
        p = (jax.lax.broadcasted_iota(jnp.int32, (TILE, 1), 0)
             .reshape(TILE) + tile_start + b[0])
        off, ln, st = so[...], sl[...], ss[...]
        r = _searchsorted_right(off, p)          # ONE search, two packs
        r_c = jnp.clip(r, 0, cap - 1)
        within = p - off[r_c]
        covered = (r >= 0) & (within < ln[r_c])
        dd = d[...]
        src = jnp.clip(st[r_c] + within, 0, dd.shape[0] - 1)
        zero = jnp.zeros((), dd.dtype)
        win[...] = jnp.where(covered, dd[src], zero)
        mask[...] = jnp.where(covered, jnp.ones((), dd.dtype), zero)

    win, mask = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[meta, meta, meta, dspec, bspec],
        out_specs=[tspec, tspec],
        out_shape=[jax.ShapeDtypeStruct((out_len,), data.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((cap,), jnp.int32)] * 3,
        interpret=interpret,
    )(offsets, lengths, starts, data, base)
    return win, mask


@functools.partial(jax.jit, static_argnames=("interpret",))
def zero_skip_encode(data: jax.Array, *, interpret: bool = True):
    """Zero-skipping compaction of payload rows — the rle codec's SPMD
    wire form, fused into one VMEM block per row.

    data: [rows, n] with n a power of two. Returns ``(vals, pos)``:
    nonzero values compacted to the front in position order, their
    original positions alongside (-1 in the padding) — byte-identical
    to ``RleCodec.jax_encode``'s stable-argsort formulation.
    """
    rows, n = data.shape
    if n & (n - 1):
        raise ValueError(f"row length {n} must be a power of two")
    block = pl.BlockSpec((1, n), lambda i: (i, 0))

    def kernel(d, vals, pos):
        v = d[0, :]
        nz = (v != 0).astype(jnp.int32)
        # inclusive Hillis-Steele prefix sum -> exclusive rank
        run = nz
        shift = 1
        while shift < n:
            shifted = jnp.pad(run, (shift, 0))[:n]
            run = run + shifted
            shift *= 2
        rank = run - nz
        idx = jnp.where(nz == 1, rank, n)        # zeros -> drop sentinel
        i = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)
        vals[0, :] = jnp.zeros((n,), v.dtype).at[idx].set(v, mode="drop")
        pos[0, :] = jnp.full((n,), -1, jnp.int32).at[idx].set(
            i, mode="drop")

    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[block],
        out_specs=[block, block],
        out_shape=[jax.ShapeDtypeStruct((rows, n), data.dtype),
                   jax.ShapeDtypeStruct((rows, n), jnp.int32)],
        interpret=interpret,
    )(data)


@functools.partial(jax.jit, static_argnames=("interpret",))
def zero_skip_decode(vals: jax.Array, pos: jax.Array, *,
                     interpret: bool = True):
    """Expand zero-skip compacted rows back into dense windows — the
    rle codec's decode scatter, fused into one VMEM block per row.

    vals/pos: [rows, n] with n a power of two, ``pos == -1`` in the
    padding (``zero_skip_encode``'s wire layout). Returns [rows, n] in
    ``vals.dtype``, zeros where no position lands — byte-identical to
    ``RleCodec.jax_decode``'s staged-scatter formulation, minus its
    HBM (rows, cap+1) staging buffer.
    """
    rows, n = vals.shape
    if n & (n - 1):
        raise ValueError(f"row length {n} must be a power of two")
    if pos.shape != vals.shape:
        raise ValueError(f"vals {vals.shape} / pos {pos.shape} mismatch")
    block = pl.BlockSpec((1, n), lambda i: (i, 0))

    def kernel(v, p, out):
        vv = v[0, :]
        pp = p[0, :]
        idx = jnp.where(pp >= 0, pp, n)          # padding -> drop sentinel
        out[0, :] = jnp.zeros((n,), vv.dtype).at[idx].set(vv, mode="drop")

    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, n), vals.dtype),
        interpret=interpret,
    )(vals, pos)
