"""Pallas TPU fused flash-attention kernel.

The SPerf analysis (EXPERIMENTS.md, cell 1) showed that once the
collective storm is fixed, the dominant memory term of every train/
prefill cell is the attention probability tensor materializing at XLA
fusion boundaries (~2.5 TB/step on yi-34b). The fix on TPU is the
standard one: a fused kernel that keeps logits/probs in VMEM.

Grid: (batch*kv_heads, q_blocks). Each program instance streams the KV
sequence in VMEM-sized blocks, maintaining the online-softmax state
(m, l, acc) in registers/VMEM — probs NEVER reach HBM. Q blocks of
BLOCK_Q=256 x g*hd and KV blocks of BLOCK_KV=512 x hd keep the working
set << 16 MiB VMEM and the MXU contraction dims at 128-multiples for
hd in {64, 112, 128, 256}.

Supports: causal masking (with q_offset for decode/continuation),
sliding window, logit softcap, GQA (g = Hq/Hkv query heads per program).
Oracle: models.layers.flash_attention (pure jnp). Validated in
interpret mode on CPU per the build rules.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 256
BLOCK_KV = 512
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                 logit_cap, q_offset, sq, skv, block_kv, kv_len):
    # q_ref: [BLOCK_Q, g*hd] for one (batch, kv head); k/v: [skv, hd]
    qb = pl.program_id(1)
    g_hd = q_ref.shape[-1]
    hd = k_ref.shape[-1]
    g = g_hd // hd
    q = q_ref[0].reshape(-1, g, hd).astype(jnp.float32)   # [BQ, g, hd]
    bq = q.shape[0]
    q_pos = (q_offset + qb * bq
             + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0])

    m = jnp.full((bq, g), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, g), jnp.float32)
    acc = jnp.zeros((bq, g, hd), jnp.float32)

    nkv = skv // block_kv

    def body(i, carry):
        m, l, acc = carry
        # NB: dslice(0, 1) + squeeze, not an int indexer — integer dims
        # in pl.load are rejected by older Pallas versions.
        kblk = pl.load(k_ref, (pl.dslice(0, 1),
                               pl.dslice(i * block_kv, block_kv),
                               slice(None)))[0].astype(jnp.float32)
        vblk = pl.load(v_ref, (pl.dslice(0, 1),
                               pl.dslice(i * block_kv, block_kv),
                               slice(None)))[0].astype(jnp.float32)
        kv_pos = (i * block_kv
                  + jax.lax.broadcasted_iota(jnp.int32, (block_kv, 1),
                                             0)[:, 0])
        logits = jnp.einsum("qgd,kd->qgk", q, kblk) * scale
        if logit_cap is not None:
            logits = logit_cap * jnp.tanh(logits / logit_cap)
        mask = jnp.broadcast_to((kv_pos < kv_len)[None, :],
                                (bq, block_kv))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        logits = jnp.where(mask[:, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        probs = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + probs.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "qgk,kd->qgd", probs, vblk)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m, l, acc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    o_ref[0] = out.reshape(bq, g_hd).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_cap", "q_offset",
                              "kv_len", "interpret", "block_q", "block_kv"))
def flash_attention_fused(q, k, v, *, causal: bool = True,
                          window: int | None = None,
                          logit_cap: float | None = None,
                          q_offset: int = 0, kv_len: int | None = None,
                          interpret: bool = True,
                          block_q: int = BLOCK_Q,
                          block_kv: int = BLOCK_KV):
    """Fused attention. q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd].

    Sq must divide by block_q and Skv by block_kv (ops-level callers pad;
    see tests for the sweep).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if sq % block_q or skv % block_kv:
        raise ValueError("pad Sq/Skv to the block sizes")
    scale = 1.0 / math.sqrt(hd)

    # layout: one program per (b * hkv, q block)
    qr = (q.reshape(b, sq, hkv, g, hd).transpose(0, 2, 1, 3, 4)
          .reshape(b * hkv, sq, g * hd))
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, q_offset=q_offset, sq=sq, skv=skv,
        block_kv=block_kv, kv_len=kv_len if kv_len is not None else skv)
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, g * hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g * hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, sq, g * hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return (out.reshape(b, hkv, sq, g, hd).transpose(0, 2, 1, 3, 4)
            .reshape(b, sq, hq, hd))
