"""Model building blocks: norms, RoPE, attention, MLP, MoE, Mamba2 SSD.

Pure functions over explicit parameter dicts (no framework dependency).
Initializers return real arrays for small configs; the dry-run never
calls them (``jax.eval_shape`` turns them into ShapeDtypeStructs).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPlan


def perf_opts_enabled() -> bool:
    """SPerf beyond-paper optimizations (EXPERIMENTS.md): flash chunk
    4096 + bf16 PV product, decode layer-loop unroll. Gated so the
    baseline columns of the roofline table stay reproducible."""
    import os
    return os.environ.get("REPRO_PERF_OPTS", "1") == "1"

# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, head_dim], positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    """Projections stored FLAT ([d, h*hd]) so the TP-sharded dim is the
    product h*hd, which is 16-divisible for every assigned arch even when
    the head count (56, 40, 6, 2...) is not. Head structure is recovered
    by reshape under an (uneven-tolerant) internal sharding constraint.
    """
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, hq * hd), dtype) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv * hd), dtype) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv * hd), dtype) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (hq * hd, d), dtype)
               * (1.0 / math.sqrt(hq * hd))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _proj_heads(x, w, b, n_heads: int, hd: int):
    b_, s_, _ = x.shape
    y = jnp.einsum("bsd,de->bse", x, w)
    if b is not None:
        y = y + b
    return y.reshape(b_, s_, n_heads, hd)


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
         plan: ShardingPlan):
    hd = cfg.head_dim
    q = _proj_heads(x, p["wq"], p.get("bq"), cfg.n_heads, hd)
    k = _proj_heads(x, p["wk"], p.get("bk"), cfg.n_kv_heads, hd)
    v = _proj_heads(x, p["wv"], p.get("bv"), cfg.n_kv_heads, hd)
    q = plan.constrain(q, plan.act_heads())
    if not plan.activation_tp and plan.shard_seq:
        # Ulysses-style: Q stays seq-sharded; K/V replicate over seq so
        # local Q shards attend to the full context without per-chunk
        # resharding inside the flash scan.
        k = plan.constrain(k, plan.kv_full())
        v = plan.constrain(v, plan.kv_full())
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, window: int | None,
                    logit_cap: float | None, q_offset, kv_len=None,
                    chunk: int | None = None):
    if chunk is None:
        chunk = 4096 if perf_opts_enabled() else 1024
    """Chunked (flash-style) GQA attention, O(S * chunk) memory.

    q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd]. q_offset: scalar position
    of q[0] within the kv sequence (for decode/prefill continuation).
    kv_len: optional scalar — valid kv prefix length (decode cache).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    nchunks = -(-skv // chunk)
    pad = nchunks * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, nchunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nchunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc, cidx = carry
        kci, vci = inp
        kvpos = cidx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bskgd,bckd->bskgc", qr, kci) * scale
        logits = softcap(logits, logit_cap)
        # padded keys (skv -> nchunks*chunk) must NEVER enter the
        # softmax — caught by the fused-kernel oracle sweep
        mask = (kvpos[None, :] < skv)
        if causal:
            mask = mask & (kvpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (qpos[:, None] - kvpos[None, :] < window)
        if kv_len is not None:
            mask = mask & (kvpos[None, :] < kv_len)
        logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        probs = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + probs.sum(axis=-1)
        if perf_opts_enabled():
            # probs in bf16 for the PV product: halves the dominant HBM
            # traffic; accumulator stays f32 (SPerf iteration 2)
            pv = jnp.einsum("bskgc,bckd->bskgd",
                            probs.astype(jnp.bfloat16),
                            vci.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bskgc,bckd->bskgd", probs, vci)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new, cidx + 1), None

    m0 = jnp.full((b, sq, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    (m, l, acc, _), _ = lax.scan(
        body, (m0, l0, acc0, jnp.int32(0)),
        (kc.astype(jnp.float32), vc.astype(jnp.float32)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def decode_attention_sharded(q, k_cache, v_cache, *, cache_pos,
                             window: int | None, logit_cap: float | None,
                             plan: ShardingPlan):
    """Decode attention with the KV cache sequence-sharded over the
    model axis — flash-decoding style: each shard computes a partial
    softmax over its local KV slab; partials merge with a log-sum-exp
    psum. Avoids GSPMD's replication fallback when scanning a sharded
    chunk axis (involuntary full remat of the fp32 cache copy).

    q: [B, 1, Hq, hd]; caches: [B, S, Hkv, hd] with S over ``model``.
    Returns [B, 1, Hq*hd].
    """
    from jax.sharding import PartitionSpec as P

    mesh, tp = plan.mesh, plan.tp
    dp = plan.dp
    scale = 1.0 / math.sqrt(q.shape[-1])

    def fn(qb, kc, vc, pos):
        b, _, hq, hd = qb.shape
        s_loc, hkv = kc.shape[1], kc.shape[2]
        g = hq // hkv
        tpi = lax.axis_index(tp)
        kvpos = tpi * s_loc + jnp.arange(s_loc)
        # bf16 operands + f32 accumulation (MXU-style): avoids
        # materializing an f32 copy of the whole cache slab
        qr = qb.reshape(b, hkv, g, hd)
        logits = jnp.einsum("bkgd,bskd->bkgs", qr, kc,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, logit_cap)
        mask = kvpos <= pos
        if window is not None:
            mask = mask & (pos - kvpos < window)
        logits = jnp.where(mask[None, None, None, :], logits, -1e30)
        m = logits.max(axis=-1)
        mg = lax.pmax(m, tp)
        probs = jnp.exp(logits - mg[..., None])
        l = lax.psum(probs.sum(axis=-1), tp)
        acc = lax.psum(jnp.einsum("bkgs,bskd->bkgd",
                                  probs.astype(jnp.bfloat16), vc,
                                  preferred_element_type=jnp.float32), tp)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(b, 1, hq * hd).astype(qb.dtype)

    return shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(P(dp, None, None, None), P(dp, tp, None, None),
                  P(dp, tp, None, None), P()),
        out_specs=P(dp, None, None),
    )(q, k_cache, v_cache, cache_pos)


def attention(p: dict, x: jax.Array, cfg: ModelConfig, positions, plan,
              *, local: bool, cache: tuple | None = None,
              cache_pos=None, xattn_kv: jax.Array | None = None,
              causal: bool = True):
    """Full attention sub-layer.

    Modes:
      train/prefill: cache None -> causal flash attention over x itself.
        Returns (out, (k, v)) so prefill can build the cache.
      decode: cache=(k_cache, v_cache) [B, S_max, Hkv, hd], cache_pos =
        scalar write position. x is [B, 1, d].
      cross-attention (enc-dec): xattn_kv = encoder activations; no
        causal mask, no cache.
    """
    window = cfg.window if local else None
    hd = cfg.head_dim
    if xattn_kv is not None:
        q = _proj_heads(x, p["wq"], p.get("bq"), cfg.n_heads, hd)
        k = _proj_heads(xattn_kv, p["wk"], p.get("bk"), cfg.n_kv_heads, hd)
        v = _proj_heads(xattn_kv, p["wv"], p.get("bv"), cfg.n_kv_heads, hd)
        out = flash_attention(q, k, v, causal=False, window=None,
                              logit_cap=cfg.attn_logit_softcap, q_offset=0)
        out = out.reshape(*out.shape[:2], -1)
        return jnp.einsum("bse,ed->bsd", out, p["wo"]), None

    q, k, v = _qkv(p, x, cfg, positions, plan)
    if cache is None:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cfg.attn_logit_softcap, q_offset=0)
        # constrain so prefill's stacked cache ys accumulate SHARDED
        # (unconstrained ys replicate: 61 layers x 32k seq = fleet-OOM)
        new_cache = (plan.constrain(k, plan.kv_cache()),
                     plan.constrain(v, plan.kv_cache()))
    else:
        k_cache, v_cache = cache
        k_cache = lax.dynamic_update_slice_in_dim(k_cache, k, cache_pos, 1)
        v_cache = lax.dynamic_update_slice_in_dim(v_cache, v, cache_pos, 1)
        k_cache = plan.constrain(k_cache, plan.kv_cache())
        v_cache = plan.constrain(v_cache, plan.kv_cache())
        if plan.mesh is not None and q.shape[1] == 1:
            out = decode_attention_sharded(
                q, k_cache, v_cache, cache_pos=cache_pos, window=window,
                logit_cap=cfg.attn_logit_softcap, plan=plan)
            new_cache = (k_cache, v_cache)
            out = jnp.einsum("bse,ed->bsd", out, p["wo"])
            return plan.constrain(out, plan.act()), new_cache
        out = flash_attention(q, k_cache, v_cache, causal=False,
                              window=window,
                              logit_cap=cfg.attn_logit_softcap,
                              q_offset=cache_pos, kv_len=cache_pos + 1)
        new_cache = (k_cache, v_cache)
    out = out.reshape(*out.shape[:2], -1)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return plan.constrain(out, plan.act()), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": (jax.random.normal(k1, (d, f), dtype) / math.sqrt(d)).astype(dtype),
        "wg": (jax.random.normal(k2, (d, f), dtype) / math.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(k3, (f, d), dtype) / math.sqrt(f)).astype(dtype),
    }


def mlp(p: dict, x: jax.Array, plan: ShardingPlan) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = plan.constrain(jax.nn.silu(g) * h, plan.act_ff())
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return plan.constrain(out, plan.act())


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k1, (d, e), jnp.float32)
                   / math.sqrt(d)).astype(jnp.float32),
        "wi": (jax.random.normal(k2, (e, d, f), dtype) / math.sqrt(d)).astype(dtype),
        "wg": (jax.random.normal(k3, (e, d, f), dtype) / math.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(k4, (e, f, d), dtype) / math.sqrt(f)).astype(dtype),
    }


def moe(p: dict, x: jax.Array, cfg: ModelConfig, plan: ShardingPlan):
    """Top-k MoE. With a mesh: explicitly-partitioned GShard dispatch
    (see moe_sharded.py — GSPMD auto-partitioning of the dispatch scatter
    replicates [N*k, d]); without a mesh: dense sort-based dispatch.
    """
    if plan.mesh is not None:
        from repro.models.moe_sharded import moe_sharded
        return moe_sharded(p, x, cfg, plan)
    return _moe_dense(p, x, cfg, plan)


def _moe_dense(p: dict, x: jax.Array, cfg: ModelConfig, plan: ShardingPlan):
    """Sort-based top-k MoE with capacity dropping (single-device path).

    The dispatch is the same group-by-destination primitive as TAM's
    request bucketing: tokens sorted by expert id, positions within each
    expert computed from prefix sums, overflow dropped. Returns
    (out, aux_loss).
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    n = b * s
    xt = plan.constrain(x.reshape(n, d), plan.flat_tokens())
    logits = plan.constrain(xt.astype(jnp.float32) @ p["router"],
                            plan.flat_tokens())            # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = lax.top_k(probs, k)                  # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(
        1.0 / (n * k))
    aux = e * jnp.sum(me * ce)

    cap = int(math.ceil(n * k / e * m.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)
    flat_e = eids.reshape(-1)                              # [N*k]
    order = jnp.argsort(flat_e, stable=True)
    ranked = flat_e[order]
    # position within expert group (prefix over sorted layout)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[ranked]
    ok = pos < cap
    slot = jnp.where(ok, ranked * cap + pos, e * cap)      # OOB => dropped
    token_of = order // k
    rows = plan.constrain(xt[token_of], plan.flat_tokens())  # [N*k, d]
    disp = jnp.zeros((e * cap, d), x.dtype).at[slot].set(rows, mode="drop")
    disp = plan.constrain(disp.reshape(e, cap, d), plan.moe_dispatch())
    h = jnp.einsum("ecd,edf->ecf", disp, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", disp, p["wg"])
    h = plan.constrain(jax.nn.silu(g) * h,
                       plan.moe_dispatch())  # [E, cap, f]
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    eo = plan.constrain(eo, plan.moe_dispatch()).reshape(e * cap, d)
    # combine: gather each token's k expert outputs, weight by gates
    inv_slot = jnp.full((n * k,), e * cap, jnp.int32).at[order].set(
        jnp.where(ok, slot, e * cap), mode="drop")
    eo_pad = jnp.concatenate([eo, jnp.zeros((1, d), eo.dtype)], axis=0)
    per_tok = plan.constrain(
        eo_pad[jnp.minimum(inv_slot, e * cap)],
        plan.flat_tokens()).reshape(n, k, d)
    out = (per_tok * gate_vals[..., None].astype(per_tok.dtype)).sum(axis=1)
    out = plan.constrain(out.reshape(b, s, d), plan.act())
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    """Split projections (wx/wz TP-sharded on d_inner; B/C/dt tiny and
    replicated) so TP shard boundaries align with the semantic segments —
    a fused in_proj would smear z/x/B/C/dt across shards and force
    reshards after every split.
    """
    mc = cfg.mamba
    d = cfg.d_model
    di, ds, nh = mc.d_inner(d), mc.d_state, mc.n_heads(d)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    sc = 1.0 / math.sqrt(d)
    return {
        "wx": (jax.random.normal(k1, (d, di), dtype) * sc).astype(dtype),
        "wz": (jax.random.normal(k4, (d, di), dtype) * sc).astype(dtype),
        "wbcdt": (jax.random.normal(k5, (d, 2 * ds + nh), dtype)
                  * sc).astype(dtype),
        "conv": (jax.random.normal(k2, (mc.d_conv, di + 2 * ds), dtype)
                 * 0.1).astype(dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": (jax.random.normal(k3, (di, d), dtype)
                     / math.sqrt(di)).astype(dtype),
    }


def _ssd_chunked(xh, dt, A, B_, C_, chunk: int):
    """SSD (state-space duality) forward, chunked.

    xh: [B, S, nh, hd]; dt: [B, S, nh]; A: [nh] (negative);
    B_, C_: [B, S, ds]. Returns y [B, S, nh, hd].
    """
    b, s, nh, hd = xh.shape
    ds = B_.shape[-1]
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B_.reshape(b, nc, chunk, ds)
    Cc = C_.reshape(b, nc, chunk, ds)
    a = dtc * A[None, None, None, :]                     # [b,nc,L,nh] (<=0)
    cum = jnp.cumsum(a, axis=2)                          # within-chunk

    # intra-chunk (masked "attention" in log space)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,Li,Lj,nh]
    il = jnp.arange(chunk)
    causal = (il[:, None] >= il[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)           # [b,nc,Li,Lj]
    m = decay * cb[..., None] * dtc[:, :, None, :, :]    # [b,nc,Li,Lj,nh]
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", m, xc.astype(jnp.float32))

    # chunk states: S_n = sum_j exp(cum_last - cum_j) dt_j B_j x_j
    last = cum[:, :, -1:, :]                             # [b,nc,1,nh]
    w = jnp.exp(last - cum) * dtc                        # [b,nc,L,nh]
    states = jnp.einsum("bnlh,bnls,bnlhd->bnhsd", w, Bc,
                        xc.astype(jnp.float32))          # [b,nc,nh,ds,hd]
    chunk_decay = jnp.exp(last[:, :, 0, :])              # [b,nc,nh]

    def scan_body(st, inp):
        s_n, dec = inp                                   # [b,nh,ds,hd],[b,nh]
        new = st * dec[..., None, None] + s_n
        return new, st                                   # emit PREVIOUS state

    init = jnp.zeros((b, nh, ds, hd), jnp.float32)
    final_state, prev_states = lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [b,nc,nh,ds,hd]

    # inter-chunk: y_i += C_i . (exp(cum_i) * prev_state)
    y_inter = jnp.einsum("bnls,bnlh,bnhsd->bnlhd", Cc, jnp.exp(cum),
                         prev_states)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y, final_state


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig, plan: ShardingPlan,
                state: tuple | None = None):
    """Mamba2 SSD block. state=(ssm_state [B,nh,ds,hd], conv_state
    [B, d_conv-1, di+2ds]) enables single-token decode; None = full seq.
    Returns (out, new_state) — new_state is None in full-seq mode.
    """
    mc = cfg.mamba
    b, s, d = x.shape
    di, ds, nh = mc.d_inner(d), mc.d_state, mc.n_heads(d)
    hd = mc.head_dim
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    bcdt = jnp.einsum("bsd,de->bse", x, p["wbcdt"])
    B_, C_, dt = jnp.split(bcdt, [ds, 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xin, B_, C_], axis=-1)    # [b,s,di+2ds]

    if state is None:
        # causal depthwise conv over seq
        pad = jnp.pad(conv_in, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + s] * p["conv"][i][None, None, :]
                   for i in range(mc.d_conv))
        conv = jax.nn.silu(conv)
        xin, B_, C_ = jnp.split(conv, [di, di + ds], axis=-1)
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        xh = xin.reshape(b, s, nh, hd)
        xh = plan.constrain(xh, plan.act_heads())
        assert s % min(mc.chunk, s) == 0, "seq must divide into SSD chunks"
        y, final_ssm = _ssd_chunked(xh, dt_s, A, B_.astype(jnp.float32),
                                    C_.astype(jnp.float32), min(mc.chunk, s))
        y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        # state handoff for prefill -> decode continuation
        tail = conv_in[:, s - (mc.d_conv - 1):, :] if s >= mc.d_conv - 1 \
            else jnp.pad(conv_in, ((0, 0), (mc.d_conv - 1 - s, 0), (0, 0)))
        new_state = (final_ssm, tail)
    else:
        ssm_state, conv_state = state                    # decode: s == 1
        window = jnp.concatenate([conv_state, conv_in], axis=1)
        conv = sum(window[:, i:i + 1] * p["conv"][i][None, None, :]
                   for i in range(mc.d_conv))
        conv = jax.nn.silu(conv)
        xin, B_, C_ = jnp.split(conv, [di, di + ds], axis=-1)
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        xh = xin.reshape(b, 1, nh, hd).astype(jnp.float32)
        dec = jnp.exp(dt_s[:, 0, :] * A[None, :])        # [b,nh]
        upd = jnp.einsum("bh,bs,bhd->bhsd", dt_s[:, 0, :],
                         B_[:, 0].astype(jnp.float32), xh[:, 0])
        ssm_state = ssm_state * dec[..., None, None] + upd
        y = jnp.einsum("bs,bhsd->bhd", C_[:, 0].astype(jnp.float32),
                       ssm_state)[:, None]
        y = y + p["D"][None, None, :, None] * xh
        new_state = (ssm_state, window[:, 1:])
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return plan.constrain(out, plan.act()), new_state
