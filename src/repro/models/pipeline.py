"""GPipe-style pipeline parallelism as a composable shard_map transform.

Optional fourth parallelism axis ("pipe"): the layer stack is split into
S stages along the scanned n_blocks dimension; microbatches stream
through stages with ``ppermute`` handoffs. S + M - 1 rotations for M
microbatches (classic GPipe bubble = (S-1)/(S+M-1)).

This is deliberately independent of the main GSPMD path: you wrap a
per-stage apply function; weights arrive stage-sharded via in_specs.
Used by tests/test_pipeline.py and available to launch configs that set
``pipeline_stages > 1``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn, mesh, *, axis: str = "pipe",
                   microbatches: int):
    """Build fn(stage_params, x) -> y running the S-stage pipeline.

    stage_fn(params_slice, x_mb) applies ONE stage to ONE microbatch.
    stage_params: pytree with leading dim S (stage-sharded over ``axis``).
    x: [M * mb, ...] global batch, sharded over ``axis`` on dim 0 only
    for transport convenience (microbatches round-robin the stages).
    """
    S = mesh.shape[axis]

    def shard_fn(params, x):
        # params leaves: [1, ...] local stage slice; x local [M*mb/S, ...]
        p_local = jax.tree.map(lambda a: a[0], params)
        stage = lax.axis_index(axis)
        m_total = microbatches
        # gather the full batch once (stage 0 owns input semantics; other
        # stages receive via rotation, but SPMD needs identical shapes)
        x_all = lax.all_gather(x, axis, axis=0, tiled=True)
        mbs = x_all.shape[0] // m_total
        rounds = S + m_total - 1

        def body(carry, t):
            acts, outs = carry
            # stage s works on microbatch (t - s) if 0 <= t - s < M
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < m_total)
            take = jnp.clip(mb_idx, 0, m_total - 1)
            x_in = lax.cond(
                stage == 0,
                lambda: lax.dynamic_slice_in_dim(x_all, take * mbs, mbs, 0),
                lambda: acts)
            y = stage_fn(p_local, x_in)
            y = jnp.where(active, y, acts)
            # hand activations to the next stage
            nxt = lax.ppermute(y, axis,
                               [(i, (i + 1) % S) for i in range(S)])
            # last stage emits completed microbatches
            done_idx = t - (S - 1)
            emit = (stage == S - 1) & (done_idx >= 0) & (done_idx < m_total)
            outs = lax.cond(
                emit,
                lambda o: lax.dynamic_update_slice_in_dim(
                    o, y, jnp.clip(done_idx, 0, m_total - 1) * mbs, 0),
                lambda o: o, outs)
            return (nxt, outs), None

        acts0 = jnp.zeros_like(x_all[:mbs])
        outs0 = jnp.zeros_like(x_all)
        (_, outs), _ = lax.scan(body, (acts0, outs0),
                                jnp.arange(rounds))
        # results live on the last stage; broadcast and reslice
        outs = lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), axis)
        mine = lax.dynamic_slice_in_dim(
            outs, lax.axis_index(axis) * (outs.shape[0] // S),
            outs.shape[0] // S, 0)
        return mine

    return shard_map(
        shard_fn, mesh=mesh, check_vma=False,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis))
