"""Decoder-only LM assembly for all families (dense/moe/ssm/hybrid/vlm),
plus the enc-dec (whisper) variant.

Layer stacking: layers are grouped into super-blocks of ``period`` =
lcm of the structural periods (gemma2 local/global = 2, jamba attn 1:7 =
8, MoE every-2 = 2, ...). Parameters are stacked [n_blocks, ...] per
position-in-period, and the forward is a ``lax.scan`` over blocks — the
compiled HLO contains ONE instance of each distinct layer type
regardless of depth, which keeps 60-layer 512-device lowering tractable.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPlan, unsharded

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, i: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                 "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.is_attn_layer(i):
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = L.init_mamba(ks[1], cfg, dtype)
    if cfg.is_moe_layer(i):
        p["moe"] = L.init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack_layers(key, cfg: ModelConfig, n_layers: int, dtype) -> Params:
    """Stack per-period layer params along a leading n_blocks axis."""
    period = cfg.block_period
    n_blocks = n_layers // period
    keys = jax.random.split(key, n_layers).reshape(n_blocks, period, -1)
    slots = []
    for j in range(period):
        per_block = [_init_layer(keys[b, j], cfg, b * period + j, dtype)
                     for b in range(n_blocks)]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block))
    return {"slots": slots}


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    if cfg.n_layers % cfg.block_period:
        raise ValueError(
            f"{cfg.name}: n_layers {cfg.n_layers} not divisible by "
            f"block period {cfg.block_period}")
    k_emb, k_blocks, k_enc, k_out = jax.random.split(key, 4)
    p: Params = {
        # padded_vocab: TP-shardable tables; loss/sampling mask the pad
        "embed": (jax.random.normal(
            k_emb, (cfg.padded_vocab, cfg.d_model), dtype)
            * 0.02).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "blocks": _stack_layers(k_blocks, cfg, cfg.n_layers, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(
            k_out, (cfg.padded_vocab, cfg.d_model), dtype)
            / math.sqrt(cfg.d_model)).astype(dtype)
    if cfg.enc_dec:
        # encoder stack (self-attn only) + decoder cross-attn params
        enc_cfg = cfg
        p["enc_blocks"] = _stack_layers(k_enc, enc_cfg, cfg.n_enc_layers,
                                        dtype)
        p["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        xkeys = jax.random.split(jax.random.fold_in(k_enc, 1),
                                 cfg.n_layers)
        xattn = [{"xattn": L.init_attention(xkeys[i], cfg, dtype),
                  "lnx": jnp.zeros((cfg.d_model,), jnp.float32)}
                 for i in range(cfg.n_layers)]
        p["xattn"] = jax.tree.map(lambda *xs: jnp.stack(xs), *xattn)
    return p


def param_shardings(cfg: ModelConfig, plan: ShardingPlan):
    """PartitionSpec pytree matching init_params' structure.

    TP over ``model`` on the contraction-friendly dim AND FSDP/ZeRO-3
    over the data axes on the other dim: weights live fully sharded
    (1T params / 512 chips = ~4 GB/chip) and GSPMD all-gathers each
    scanned layer's slice inside the loop at use time. Optimizer moments
    inherit these specs (launch.steps.opt_state_specs).
    """
    from jax.sharding import PartitionSpec as P
    dp, tp = plan.dp, plan.tp

    def attn_spec():
        s = {"wq": _lift(P(dp, tp)), "wk": _lift(P(dp, tp)),
             "wv": _lift(P(dp, tp)), "wo": _lift(P(tp, dp))}
        if cfg.qkv_bias:
            s.update({"bq": _lift(P(tp)), "bk": _lift(P(tp)),
                      "bv": _lift(P(tp))})
        return s

    def mamba_spec():
        return {"wx": _lift(P(dp, tp)),
                "wz": _lift(P(dp, tp)),
                "wbcdt": _lift(P(dp, None)),
                "conv": _lift(P(None, None)),
                "A_log": _lift(P(None)), "D": _lift(P(None)),
                "dt_bias": _lift(P(None)), "norm": _lift(P(tp)),
                "out_proj": _lift(P(tp, dp))}

    def moe_spec():
        return {"router": _lift(P(dp, None)),
                "wi": _lift(P(tp, dp, None)), "wg": _lift(P(tp, dp, None)),
                "wo": _lift(P(tp, None, dp))}

    def mlp_spec():
        return {"wi": _lift(P(dp, tp)), "wg": _lift(P(dp, tp)),
                "wo": _lift(P(tp, dp))}

    def _lift(spec: P) -> P:
        # stacked leading n_blocks axis is unsharded
        return P(None, *spec)

    def layer_spec(i: int):
        s = {"ln1": _lift(P(None)), "ln2": _lift(P(None))}
        if cfg.is_attn_layer(i):
            s["attn"] = attn_spec()
        else:
            s["mamba"] = mamba_spec()
        if cfg.is_moe_layer(i):
            s["moe"] = moe_spec()
        elif cfg.d_ff:
            s["mlp"] = mlp_spec()
        return s

    period = cfg.block_period
    specs: dict = {
        "embed": P(tp, dp),
        "final_norm": P(None),
        "blocks": {"slots": [layer_spec(j) for j in range(period)]},
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(tp, dp)
    if cfg.enc_dec:
        specs["enc_blocks"] = {"slots": [layer_spec(0)]}
        specs["enc_norm"] = P(None)
        specs["xattn"] = {"xattn": attn_spec(), "lnx": _lift(P(None))}
    return specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    """Per-slot caches stacked [n_blocks, ...]."""
    kv: Any           # list per slot: (k, v) or None
    ssm: Any          # list per slot: (ssm_state, conv_state) or None
    pos: jax.Array    # scalar int32 — next write position
    enc_out: Any = None  # enc-dec: encoder activations [B, enc_seq, d]


def _apply_layer(pl_, x, cfg, i_in_period, positions, plan, enc_out=None,
                 cache=None, cache_pos=None, causal=True):
    """One layer (attention-or-mamba + mlp-or-moe). Returns (x, new_cache,
    aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, pl_["ln1"], cfg.norm_eps)
    new_cache = None
    if "attn" in pl_:
        local = cfg.is_local_layer(i_in_period)
        a, new_cache = L.attention(
            pl_["attn"], h, cfg, positions, plan, local=local,
            cache=None if cache is None else cache[0],
            cache_pos=cache_pos, causal=causal)
        x = x + a
    else:
        mstate = None if cache is None else cache[1]
        a, new_m = L.mamba_block(pl_["mamba"], h, cfg, plan, state=mstate)
        x = x + a
        new_cache = (None, new_m)
    if "attn" in pl_ and new_cache is not None:
        new_cache = (new_cache, None)
    if enc_out is not None:
        hx = L.rms_norm(x, pl_["lnx"], cfg.norm_eps)
        xa, _ = L.attention(pl_["xattn"], hx, cfg, positions, plan,
                            local=False, xattn_kv=enc_out)
        x = x + xa
    h2 = L.rms_norm(x, pl_["ln2"], cfg.norm_eps)
    if "moe" in pl_:
        mo, aux = L.moe(pl_["moe"], h2, cfg, plan)
        x = x + mo
    elif "mlp" in pl_:
        x = x + L.mlp(pl_["mlp"], h2, plan)
    return x, new_cache, aux


def _run_blocks(blocks, x, cfg, positions, plan, xattn=None, enc_out=None,
                decode_state: DecodeState | None = None, causal=True,
                collect_caches: bool = False, remat: bool = False):
    """Scan over super-blocks. Returns (x, new_decode_state, aux_sum)."""
    period = len(blocks["slots"])
    slots = blocks["slots"]
    has_xattn = xattn is not None

    def block_fn(carry, scanned):
        xx = carry
        slot_params = scanned["slots"]
        caches = scanned.get("caches")
        xp = scanned.get("xattn")
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for j in range(period):
            pj = slot_params[j]
            if has_xattn:
                pj = dict(pj)
                pj["xattn"] = xp["xattn"]
                pj["lnx"] = xp["lnx"]
            cache_j = None if caches is None else caches[j]
            xx, nc, aux = _apply_layer(
                pj, xx, cfg, j, positions, plan,
                enc_out=enc_out if has_xattn else None,
                cache=cache_j, causal=causal,
                cache_pos=None if decode_state is None else decode_state.pos)
            new_caches.append(nc)
            aux_total = aux_total + aux
        out = {"aux": aux_total}
        if decode_state is not None or collect_caches:
            out["caches"] = new_caches
        return xx, out

    if remat:
        block_fn = jax.checkpoint(block_fn)
    # decode: UNROLL the layer loop. A rolled scan dynamic-slices the
    # stacked KV cache each iteration; GSPMD reshards the whole stack
    # per layer (129 full-cache rewrites/step for qwen decode — SPerf
    # iteration for the decode cells). Unrolled slices are static and
    # the cache update stays in place.
    from repro.models.layers import perf_opts_enabled
    unroll = decode_state is not None and perf_opts_enabled()
    scanned_in = {"slots": slots}
    if decode_state is not None:
        scanned_in["caches"] = [
            (decode_state.kv[j], decode_state.ssm[j])
            for j in range(period)]
    if has_xattn:
        # xattn params are stacked [n_layers] = [n_blocks * period]; for
        # period>1 that would need regrouping — whisper has period 1.
        scanned_in["xattn"] = xattn
    x, outs = lax.scan(block_fn, x, scanned_in,
                       unroll=True if unroll else 1)
    aux = outs["aux"].sum()
    new_state = None
    if decode_state is not None:
        kv = [outs["caches"][j][0] for j in range(period)]
        ssm = [outs["caches"][j][1] for j in range(period)]
        new_state = DecodeState(kv=kv, ssm=ssm, pos=decode_state.pos + 1,
                                enc_out=decode_state.enc_out)
    elif collect_caches:
        kv = [outs["caches"][j][0] for j in range(period)]
        ssm = [outs["caches"][j][1] for j in range(period)]
        new_state = DecodeState(kv=kv, ssm=ssm,
                                pos=jnp.int32(x.shape[1]))
    return x, new_state, aux


def _embed_inputs(params, cfg: ModelConfig, batch: dict, plan):
    """Token and/or frontend-stub embeddings -> [B, S, d]."""
    emb_scale = math.sqrt(cfg.d_model)
    if cfg.frontend == "vision" and "prefix_embeds" in batch:
        tok = params["embed"][batch["tokens"]] * emb_scale
        x = jnp.concatenate(
            [batch["prefix_embeds"].astype(tok.dtype), tok], axis=1)
    elif cfg.frontend == "audio" and not cfg.enc_dec:
        x = batch["frames"]
    else:
        x = params["embed"][batch["tokens"]] * emb_scale
    return plan.constrain(x, plan.act())


def forward(params, cfg: ModelConfig, batch: dict,
            plan: ShardingPlan | None = None, remat: bool = False):
    """Full-sequence forward -> logits [B, S, V] (+ aux loss)."""
    plan = plan or unsharded()
    x = _embed_inputs(params, cfg, batch, plan)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    enc_out = None
    xattn = None
    if cfg.enc_dec:
        enc = batch["frames"]
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)
        enc_x, _, _ = _run_blocks(params["enc_blocks"], enc, cfg, enc_pos,
                                  plan, causal=False)
        enc_out = L.rms_norm(enc_x, params["enc_norm"], cfg.norm_eps)
        xattn = params["xattn"]
    x, _, aux = _run_blocks(params["blocks"], x, cfg, positions, plan,
                            xattn=xattn, enc_out=enc_out, remat=remat)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unemb = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unemb)
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return plan.constrain(logits, plan.logits()), aux


def loss_fn(params, cfg: ModelConfig, batch: dict,
            plan: ShardingPlan | None = None, remat: bool = False):
    """Causal LM cross-entropy (mean over tokens) + MoE aux loss."""
    logits, aux = forward(params, cfg, batch, plan, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "prefix_embeds" in batch:
        npfx = batch["prefix_embeds"].shape[1]
        logits = logits[:, npfx:]
    # mask the padded vocab columns out of the partition function
    if cfg.padded_vocab != cfg.vocab:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                             axis=-1)[..., 0]
    nll = (lse - ll).mean()
    return nll + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch_size: int, max_seq: int,
                      plan: ShardingPlan | None = None,
                      dtype=jnp.bfloat16, enc_out=None) -> DecodeState:
    plan = plan or unsharded()
    period = cfg.block_period
    n_blocks = cfg.n_layers // period
    kv, ssm = [], []
    for j in range(period):
        if cfg.is_attn_layer(j):
            shape = (n_blocks, batch_size, max_seq, cfg.n_kv_heads,
                     cfg.head_dim)
            k = plan.constrain(jnp.zeros(shape, dtype),
                               _stacked(plan.kv_cache()))
            v = plan.constrain(jnp.zeros(shape, dtype),
                               _stacked(plan.kv_cache()))
            kv.append((k, v))
            ssm.append(None)
        else:
            mc = cfg.mamba
            di, ds = mc.d_inner(cfg.d_model), mc.d_state
            nh, hd = mc.n_heads(cfg.d_model), mc.head_dim
            sstate = jnp.zeros((n_blocks, batch_size, nh, ds, hd),
                               jnp.float32)
            cstate = jnp.zeros((n_blocks, batch_size, mc.d_conv - 1,
                                di + 2 * ds), dtype)
            kv.append(None)
            ssm.append((sstate, cstate))
    return DecodeState(kv=kv, ssm=ssm, pos=jnp.int32(0), enc_out=enc_out)


def _stacked(spec):
    from jax.sharding import PartitionSpec as P
    return P(None, *spec)


def decode_step(params, cfg: ModelConfig, state: DecodeState,
                tokens: jax.Array, plan: ShardingPlan | None = None):
    """One decode step. tokens: [B] int32. Returns (logits [B, V], state)."""
    plan = plan or unsharded()
    x = params["embed"][tokens][:, None, :] * math.sqrt(cfg.d_model)
    x = plan.constrain(x, plan.act())
    positions = jnp.full((x.shape[0], 1), state.pos, jnp.int32)
    enc_out, xattn = None, None
    if cfg.enc_dec:
        enc_out = state.enc_out
        xattn = params["xattn"]
    x, new_state, _ = _run_blocks(params["blocks"], x, cfg, positions, plan,
                                  xattn=xattn, enc_out=enc_out,
                                  decode_state=state)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unemb = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unemb)[:, 0]
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits, new_state


def prefill(params, cfg: ModelConfig, batch: dict,
            plan: ShardingPlan | None = None):
    """Full-sequence forward that also builds the decode caches.

    Returns (last-token logits [B, V], DecodeState with kv/ssm caches of
    length S and pos = S) — the serving prefill step.
    """
    plan = plan or unsharded()
    x = _embed_inputs(params, cfg, batch, plan)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    enc_out, xattn = None, None
    if cfg.enc_dec:
        enc = batch["frames"]
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)
        enc_x, _, _ = _run_blocks(params["enc_blocks"], enc, cfg, enc_pos,
                                  plan, causal=False)
        enc_out = L.rms_norm(enc_x, params["enc_norm"], cfg.norm_eps)
        xattn = params["xattn"]
    x, state, _ = _run_blocks(params["blocks"], x, cfg, positions, plan,
                              xattn=xattn, enc_out=enc_out,
                              collect_caches=True)
    state = state._replace(enc_out=enc_out)
    # constrain kv caches for the serving layout (SP over seq)
    kv = [None if c is None else
          (plan.constrain(c[0], _stacked(plan.kv_cache())),
           plan.constrain(c[1], _stacked(plan.kv_cache())))
          for c in state.kv]
    state = state._replace(kv=kv)
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    unemb = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, unemb)[:, 0]
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits, state
