"""Sharding plan: maps model tensors onto the production mesh.

Axes (see launch/mesh.py): single-pod ``(data=16, model=16)``; multi-pod
``(pod=2, data=16, model=16)``. DP over (pod, data); TP/EP/SP over model.

The plan is expressed as PartitionSpecs; model code applies them with
``with_sharding_constraint`` (no-op when no mesh is active, so CPU smoke
tests run the same code unconstrained).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingPlan:
    mesh: jax.sharding.Mesh | None = None
    data_axes: tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    # Megatron-style sequence parallelism: residual-stream activations
    # are sharded over the model axis on the SEQ dim between layers
    # (AG before attn/mlp, RS after — GSPMD inserts them). Off for
    # decode, where seq is 1.
    shard_seq: bool = True
    # Activation-TP vs fully-sequence-sharded compute (SPerf iteration):
    # True  = classic Megatron TP (heads/ffn activations sharded over
    #         model; per-layer ARs; GQA kv=8 pads badly onto tp=16).
    # False = Ulysses/ZeRO-3 style: activations stay SEQ-sharded through
    #         attention and FFN; layer weights are all-gathered at use
    #         (they are FSDP-stored anyway); no activation all-reduce.
    activation_tp: bool = True

    @property
    def dp(self):
        if not self.data_axes:
            return None          # batch too small to shard (e.g. gb=1)
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def tp(self):
        return self.model_axis

    # ---- parameter specs ---------------------------------------------
    def embed(self) -> P:          # [vocab, d]
        return P(self.tp, None)

    def attn_qkv(self) -> P:       # [d, H, head_dim]
        return P(None, self.tp, None)

    def attn_o(self) -> P:         # [H, head_dim, d]
        return P(self.tp, None, None)

    def mlp_in(self) -> P:         # [d, f]
        return P(None, self.tp)

    def mlp_out(self) -> P:        # [f, d]
        return P(self.tp, None)

    def moe_in(self) -> P:         # [E, d, f] — expert parallel
        return P(self.tp, None, None)

    def moe_out(self) -> P:        # [E, f, d]
        return P(self.tp, None, None)

    def vector(self) -> P:         # norms etc.
        return P(None)

    # ---- activation specs --------------------------------------------
    def act(self) -> P:            # [B, S, d] residual stream
        if self.shard_seq:
            return P(self.dp, self.tp, None)
        return P(self.dp, None, None)

    def act_heads(self) -> P:      # [B, S, H, head_dim]
        if not self.activation_tp and self.shard_seq:
            return P(self.dp, self.tp, None, None)   # seq-sharded attn
        return P(self.dp, None, self.tp, None)

    def kv_full(self) -> P:        # [B, S, Hkv, hd] K/V during attention
        # seq-replicated so a seq-sharded Q attends to the whole context
        return P(self.dp, None, None, None)

    def act_ff(self) -> P:         # [B, S, f]
        if not self.activation_tp and self.shard_seq:
            return P(self.dp, self.tp, None)
        return P(self.dp, None, self.tp)

    def logits(self) -> P:         # [B, S, V]
        if not self.activation_tp and self.shard_seq:
            return P(self.dp, self.tp, None)
        return P(self.dp, None, self.tp)

    def tokens(self) -> P:         # [B, S]
        return P(self.dp, None)

    def kv_cache(self) -> P:       # [B, S, Hkv, head_dim] — SP over seq
        return P(self.dp, self.tp, None, None)

    def ssm_state(self) -> P:      # [B, nh, head_dim, d_state]
        return P(self.dp, self.tp, None, None)

    def moe_dispatch(self) -> P:   # [E, cap, d] — EP x DP
        return P(self.tp, self.dp, None)

    def flat_tokens(self) -> P:    # [N(*k), ...] — sharded over EVERYTHING
        axes = tuple(self.data_axes) + (self.model_axis,)
        return P(axes, None)

    def flat_tokens_1d(self) -> P:
        axes = tuple(self.data_axes) + (self.model_axis,)
        return P(axes)

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def unsharded() -> ShardingPlan:
    """Plan with no mesh: every constraint is the identity (smoke tests)."""
    return ShardingPlan(mesh=None)
