"""Explicitly-partitioned MoE dispatch (shard_map), replacing GSPMD's
auto-partition of the dispatch scatter.

Why: the dense-path scatter ``zeros[E*C, d].at[slot].set(rows)`` with
runtime indices makes XLA's SPMD partitioner fall back to replicating the
updates — an all-gather of [N*k, d] (224 GiB/device for kimi-k2
prefill). The fix is the classic GShard schedule, written explicitly:

train/prefill (tokens sharded over dp x tp via sequence parallelism):
  1. local top-k routing + local capacity-C dispatch (tiny local scatter)
  2. all_to_all over the EP axis ("model"): bring each expert's rows to
     its owner — [tp, E_loc, C, d] exchange, no replication anywhere
  3. expert FFN on [E_loc, tp*C, d]
  4. all_to_all back + local gate-weighted combine

decode (few tokens, replicated over the model axis):
  each EP rank computes only its own experts' contributions for the
  (replicated) tokens and the combine is a psum over the model axis —
  cheaper than an a2a round-trip for O(batch) tokens.

The load-balance aux loss is computed per shard and pmean'd — an
expectation-level approximation of the global Switch aux (exact when
shards are identically distributed); documented in tests/spmd.

This mirrors TAM's design point: group-by-destination locally, then one
aggregated exchange on the contended axis (cf. core/exchange.py).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPlan


def _route(xt, router, k):
    """Local routing: returns (gates [n,k] f32, eids [n,k] i32, probs)."""
    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eids.astype(jnp.int32), probs


def _local_dispatch(xt, eids, e, cap):
    """Scatter local tokens into [e, cap, d] expert buckets.

    Returns (disp, slot_of_row [n*k] — destination slot or e*cap when
    dropped). Same group-by-destination primitive as TAM bucketing.
    """
    n, d = xt.shape
    k = eids.shape[-1]
    flat_e = eids.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    ranked = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[ranked]
    ok = pos < cap
    slot_sorted = jnp.where(ok, ranked * cap + pos, e * cap)
    slot_of_row = jnp.zeros((n * k,), jnp.int32).at[order].set(slot_sorted)
    token_of = order // k
    disp = jnp.zeros((e * cap, d), xt.dtype).at[slot_sorted].set(
        xt[token_of], mode="drop")
    return disp.reshape(e, cap, d), slot_of_row


def _expert_ffn(disp, wi, wg, wo):
    h = jnp.einsum("ecd,edf->ecf", disp, wi)
    g = jnp.einsum("ecd,edf->ecf", disp, wg)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)


def _combine(eo_flat, slot_of_row, gates, n, k, d):
    eo_pad = jnp.concatenate(
        [eo_flat, jnp.zeros((1, d), eo_flat.dtype)], axis=0)
    sentinel = eo_flat.shape[0]
    per = eo_pad[jnp.minimum(slot_of_row, sentinel)].reshape(n, k, d)
    return (per * gates[..., None].astype(per.dtype)).sum(axis=1)


def _aux_loss(probs, eids, e, n, k):
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(
        1.0 / (n * k))
    return e * jnp.sum(me * ce)


def moe_sharded(p: dict, x: jax.Array, cfg: ModelConfig,
                plan: ShardingPlan):
    """shard_map MoE for a mesh'd plan. Returns (out, aux)."""
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    mesh = plan.mesh
    tp = plan.tp
    dp_axes = tuple(plan.data_axes)
    all_axes = dp_axes + (tp,)
    ntp = mesh.shape[tp]
    e_loc = e // ntp
    b, s, d = x.shape

    if plan.shard_seq:
        x_spec = P(plan.dp, tp, None)
        n_loc = (b // math.prod(mesh.shape[a] for a in dp_axes)) * (s // ntp)
    else:
        x_spec = P(plan.dp, None, None)
        n_loc = (b // math.prod(mesh.shape[a] for a in dp_axes)) * s
    cap = max(4, -(-int(n_loc * k / e * m.capacity_factor) // 4) * 4)

    w_spec = P(tp, None, None)     # dp (FSDP) shards gathered at entry
    r_spec = P(None, None)

    if plan.shard_seq:
        def fn(xl, router, wi, wg, wo):
            bl, sl, _ = xl.shape
            n = bl * sl
            xt = xl.reshape(n, d)
            gates, eids, probs = _route(xt, router, k)
            disp, slot_of_row = _local_dispatch(xt, eids, e, cap)
            # EP exchange: [tp_dest, e_loc, cap, d] -> rows at owners
            disp = disp.reshape(ntp, e_loc, cap, d)
            rx = lax.all_to_all(disp, tp, split_axis=0, concat_axis=0,
                                tiled=True)              # [tp_src, e_loc, cap, d]
            rows = rx.transpose(1, 0, 2, 3).reshape(e_loc, ntp * cap, d)
            eo = _expert_ffn(rows, wi, wg, wo)
            back = eo.reshape(e_loc, ntp, cap, d).transpose(1, 0, 2, 3)
            tx = lax.all_to_all(back, tp, split_axis=0, concat_axis=0,
                                tiled=True)              # [tp_dest->me]
            eo_flat = tx.reshape(e * cap, d)
            y = _combine(eo_flat, slot_of_row, gates, n, k, d)
            aux = lax.pmean(_aux_loss(probs, eids, e, n, k), all_axes)
            return y.reshape(bl, sl, d), aux
    else:
        def fn(xl, router, wi, wg, wo):
            bl, sl, _ = xl.shape
            n = bl * sl
            xt = xl.reshape(n, d)
            gates, eids, probs = _route(xt, router, k)
            my_tp = lax.axis_index(tp)
            local_eids = eids - my_tp * e_loc
            mine = (local_eids >= 0) & (local_eids < e_loc)
            masked_gates = jnp.where(mine, gates, 0.0)
            safe_eids = jnp.where(mine, local_eids, 0)
            disp, slot_of_row = _local_dispatch(xt, safe_eids, e_loc, cap)
            eo = _expert_ffn(disp, wi, wg, wo)
            y = _combine(eo.reshape(e_loc * cap, d), slot_of_row,
                         masked_gates, n, k, d)
            y = lax.psum(y, tp)
            aux = _aux_loss(probs, eids, e, n, k)
            if dp_axes:
                aux = lax.pmean(aux, dp_axes)
            return y.reshape(bl, sl, d), aux

    out, aux = shard_map(
        fn, mesh=mesh, check_vma=False,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, aux
