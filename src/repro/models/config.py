"""Model configuration for the 10-arch zoo.

One dataclass covers every family (dense / moe / ssm / hybrid / enc-dec /
audio / vlm); family-specific fields are None/0 when unused. All configs
are instantiated in ``repro.configs.<arch>`` with the exact numbers from
the assignment table.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_n: int = 1          # MoE FFN on layers with (i % every_n == every_n-1)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256          # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None        # default d_model // n_heads
    rope_theta: float = 1e4
    qkv_bias: bool = False
    # gemma2-style features
    window: int | None = None          # sliding window for local layers
    local_global_alternate: bool = False
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    # moe / hybrid
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    attn_every: int = 1                # hybrid: attention on layers i%attn_every==0
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                # whisper frame count after conv stub
    # modality frontend stub: inputs are precomputed embeddings
    frontend: Literal["none", "audio", "vision"] = "none"
    num_prefix_embeds: int = 0         # vlm: image patch embeddings prepended
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding tables are
        TP-shardable on any mesh up to 256-way; logits are sliced back to
        ``vocab`` before the loss."""
        return -(-self.vocab // 256) * 256

    @property
    def block_period(self) -> int:
        """Layers per scanned super-block (lcm of structural periods)."""
        p = 1
        if self.local_global_alternate:
            p = 2
        if self.attn_every > 1:
            p = _lcm(p, self.attn_every)
        if self.moe and self.moe.every_n > 1:
            p = _lcm(p, self.moe.every_n)
        return p

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every > 1:
            return i % self.attn_every == 0
        return True

    def is_local_layer(self, i: int) -> bool:
        return bool(self.local_global_alternate) and i % 2 == 0

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe.every_n == self.moe.every_n - 1

    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid; see DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, h = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            if self.is_attn_layer(i):
                total += d * h * (n_q + 2 * n_kv) + n_q * h * d
            elif self.mamba:
                di = self.mamba.d_inner(d)
                nh = self.mamba.n_heads(d)
                ds = self.mamba.d_state
                # in_proj -> [z, x, B, C, dt]; conv over (x, B, C); out_proj
                total += d * (2 * di + 2 * ds + nh)
                total += (di + 2 * ds) * self.mamba.d_conv
                total += di * d
                total += 3 * nh + di                                # A, D, dt_bias, norm
            if self.is_moe_layer(i):
                total += self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                total += d * self.moe.num_experts                   # router
            elif self.d_ff:
                total += 3 * d * self.d_ff
            total += 2 * d                                          # norms
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += d * h * (n_q + 2 * n_kv) + n_q * h * d + 3 * d * self.d_ff
                total += d * h * (n_q + 2 * n_kv) + n_q * h * d     # cross-attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_moe = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        all_exp = n_moe * self.moe.num_experts * 3 * self.d_model * self.moe.d_ff_expert
        act_exp = n_moe * self.moe.top_k * 3 * self.d_model * self.moe.d_ff_expert
        return full - all_exp + act_exp


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=max(cfg.block_period, 2) if cfg.block_period > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        enc_seq=8 if cfg.enc_dec else cfg.enc_seq,
        num_prefix_embeds=4 if cfg.frontend == "vision" else 0,
    )
    if cfg.moe:
        # generous capacity so smoke tests are drop-free (drops make
        # teacher-forced decode legitimately differ from full forward)
        small["moe"] = replace(cfg.moe, num_experts=4,
                               top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
                               capacity_factor=4.0)
    if cfg.mamba:
        small["mamba"] = replace(cfg.mamba, d_state=16, head_dim=16, chunk=8)
    if cfg.enc_dec:
        small["n_enc_layers"] = 2
    small.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **small)
