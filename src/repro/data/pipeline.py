"""Deterministic, host-sharded synthetic token pipeline.

Production shape: each host produces only its shard of the global batch
(by host id), deterministically from (seed, step) — so a restart at step
N regenerates exactly the batch stream from N without data-state
checkpointing, and an elastic re-mesh just changes the host->shard map.

Straggler mitigation: the iterator prefetches ahead with a bounded-wait
deadline; a host that misses the deadline serves the (deterministic)
fallback batch computed synchronously — no global stall (the MPI analogue
of non-exclusive scheduling in [Cha & Maeng 2012], see paper SIII).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    prefetch: int = 2
    deadline_s: float = 30.0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticTokenPipeline:
    """Markov-ish synthetic LM tokens (deterministic per (seed, step))."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        # zipf-flavored unigram + local repetition, enough structure for a
        # loss to fall during the example runs
        base = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq + 1))
        tokens = (base % (cfg.vocab - 2)) + 1
        rep = rng.random((cfg.host_batch, cfg.seq + 1)) < 0.3
        tokens = np.where(rep, np.roll(tokens, 1, axis=1), tokens)
        tokens = tokens.astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def make_batch_iterator(cfg: DataConfig, start_step: int = 0
                        ) -> Iterator[dict]:
    """Prefetching iterator with bounded-wait straggler fallback."""
    pipe = SyntheticTokenPipeline(cfg)
    q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, pipe.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    step = start_step
    try:
        while True:
            try:
                got_step, batch = q.get(timeout=cfg.deadline_s)
                # deterministic stream: producer and consumer agree on
                # step order; a lagging producer is simply skipped past
                while got_step < step:
                    got_step, batch = q.get(timeout=cfg.deadline_s)
            except queue.Empty:
                batch = pipe.batch_at(step)  # bounded-wait fallback
            yield batch
            step += 1
    finally:
        stop.set()
