from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticTokenPipeline, make_batch_iterator,
)
