from repro.io_patterns.generators import (  # noqa: F401
    btio_pattern, e3sm_f_pattern, e3sm_g_pattern, s3d_pattern,
    sparse_checkpoint_pattern,
)
