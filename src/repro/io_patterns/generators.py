"""Scaled reproductions of the paper's I/O request patterns (Table I).

Each generator returns per-rank (offsets[int64], lengths[int64],
payload[uint8]) byte-space requests for ``HostCollectiveIO`` plus the
pattern's analytic Workload for the alpha-beta model. The structures
match the paper:

* E3SM F/G: every rank holds a long list of SMALL noncontiguous
  requests interleaved round-robin across ranks (cubed-sphere / MPAS
  decompositions) — little coalescing, communication-bound.
* BTIO: block-tridiagonal partition of a [N,N,N] array — adjacent ranks
  own adjacent slabs per row, so intra-node aggregation coalesces
  heavily (paper: 1.34e9 -> 2.4e7 requests).
* S3D-IO: block-block-block partition, 4 variables — same coalescing
  structure, fewer requests.

Scale-down: request COUNTS and sizes shrink by ``scale`` while keeping
the per-rank structure; the analytic Workload keeps the full-scale
numbers (cost_model validates the paper's scales; these arrays validate
correctness + measured congestion at laptop scale).
"""
from __future__ import annotations

import numpy as np


def _payload(total: int, seed: int) -> np.ndarray:
    return (np.random.default_rng(seed)
            .integers(1, 255, size=total, dtype=np.uint8))


def e3sm_g_pattern(n_ranks: int, reqs_per_rank: int = 64,
                   req_bytes: int = 64, seed: int = 0):
    """Interleaved small requests: rank r owns slots r, r+P, r+2P, ..."""
    out = []
    for r in range(n_ranks):
        idx = np.arange(reqs_per_rank, dtype=np.int64)
        offs = (idx * n_ranks + r) * req_bytes
        lens = np.full(reqs_per_rank, req_bytes, np.int64)
        out.append((offs, lens, _payload(int(lens.sum()), seed + r)))
    return out


def e3sm_f_pattern(n_ranks: int, reqs_per_rank: int = 256,
                   req_bytes: int = 16, seed: int = 1):
    """F case: ~8x more, ~4x smaller requests than G (14 GiB over 1.4e9)."""
    return e3sm_g_pattern(n_ranks, reqs_per_rank, req_bytes, seed)


def btio_pattern(n_ranks: int, n: int = 64, vars_: int = 4, seed: int = 2):
    """Block-tridiagonal: sqrt(P) x sqrt(P) partition of [N, N] rows of
    length N (the unpartitioned last dims collapse into the row unit).
    Adjacent ranks own adjacent row-blocks -> coalescible at the node.
    """
    side = int(round(np.sqrt(n_ranks)))
    assert side * side == n_ranks, "BTIO needs a square rank count"
    cell = 8  # bytes per element-row unit
    rows_per = n // side
    out = []
    for r in range(n_ranks):
        ri, ci = divmod(r, side)
        offs, lens = [], []
        for v in range(vars_):
            base = v * n * n * cell
            for row in range(ri * rows_per, (ri + 1) * rows_per):
                offs.append(base + (row * n + ci * rows_per) * cell)
                lens.append(rows_per * cell)
        offs = np.asarray(offs, np.int64)
        lens = np.asarray(lens, np.int64)
        order = np.argsort(offs, kind="stable")
        out.append((offs[order], lens[order],
                    _payload(int(lens.sum()), seed + r)))
    return out


def sparse_checkpoint_pattern(n_ranks: int, pages_per_rank: int = 8,
                              page_bytes: int = 2048,
                              zero_page_fraction: float = 0.75,
                              seed: int = 7):
    """Sparse checkpoint pages: each rank owns a contiguous run of
    fixed-size pages of which ``zero_page_fraction`` are ENTIRELY zero
    (pruned weights, zero-initialized optimizer slots, padding) — the
    workload the slow-hop zero-run codec exists for. The zero pages are
    page-aligned runs far longer than ``codec.RLE_MIN_RUN``, so the
    achieved wire ratio tracks ``1 / (1 - zero_page_fraction)`` and the
    modeled-vs-measured agreement is CI-gated
    (``benchmarks/check_regression.py``)."""
    rng0 = np.random.default_rng(seed)
    out = []
    for r in range(n_ranks):
        offs = ((np.arange(pages_per_rank, dtype=np.int64)
                 + r * pages_per_rank) * page_bytes)
        lens = np.full(pages_per_rank, page_bytes, np.int64)
        pages = np.zeros((pages_per_rank, page_bytes), np.uint8)
        live = rng0.random(pages_per_rank) >= zero_page_fraction
        n_live = int(live.sum())
        if n_live:
            pages[live] = rng0.integers(
                1, 255, size=(n_live, page_bytes), dtype=np.uint8)
        out.append((offs, lens, pages.reshape(-1)))
    return out


def s3d_pattern(n_ranks: int, n: int = 32, seed: int = 3):
    """Block-block-block 3D partition; 4 checkpoint variables."""
    side = int(round(n_ranks ** (1 / 3)))
    while side ** 3 > n_ranks:
        side -= 1
    p3 = side ** 3
    cell = 8
    bpr = n // side
    out = []
    var_sizes = [1, 1, 3, 11]
    for r in range(n_ranks):
        if r >= p3:
            out.append((np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.uint8)))
            continue
        zi, rem = divmod(r, side * side)
        yi, xi = divmod(rem, side)
        offs, lens = [], []
        base = 0
        for vs in var_sizes:
            for w in range(vs):
                vbase = base + w * n * n * n * cell
                for z in range(zi * bpr, (zi + 1) * bpr):
                    for y in range(yi * bpr, (yi + 1) * bpr):
                        offs.append(vbase + ((z * n + y) * n + xi * bpr)
                                    * cell)
                        lens.append(bpr * cell)
            base += vs * n * n * n * cell
        offs = np.asarray(offs, np.int64)
        lens = np.asarray(lens, np.int64)
        order = np.argsort(offs, kind="stable")
        out.append((offs[order], lens[order],
                    _payload(int(lens.sum()), seed + r)))
    return out
