"""Serving driver: prefill + batched greedy decode (CPU smoke scale).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b \
      --batch 4 --prompt-len 32 --gen 16

``--restore-dir`` loads the weights from the latest checkpoint in a
directory before serving, through the PLANNED collective read
(``checkpoint.restore_checkpoint``: ``compile_plan(direction="read")``,
node-level window cache, ranged segment reads) — the serving-side
consumer of the read path, with the restore's modeled time and cache
hit ratio printed next to the generation stats.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.sharding import unsharded


def restore_params(restore_dir: str, like_params, *,
                   node_cache: bool = True, n_ranks: int = 8,
                   n_nodes: int = 2):
    """Replace ``like_params`` with the latest checkpoint under
    ``restore_dir`` via the planned collective read. The reader
    topology is the serving host layout (``n_ranks`` readers on
    ``n_nodes`` nodes); the striping comes from the manifest. Returns
    ``(params, step, timings)``."""
    from repro.checkpoint.checkpoint import restore_checkpoint
    from repro.checkpoint.host_io import HostCollectiveIO

    d = Path(restore_dir)
    steps = sorted(int(p.name[5:13])
                   for p in d.glob("ckpt_*.manifest.json"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {restore_dir}")
    path = d / f"ckpt_{steps[-1]:08d}"
    man = json.loads((d / (path.name + ".manifest.json")).read_text())
    io = HostCollectiveIO(n_ranks=n_ranks, n_nodes=n_nodes,
                          stripe_size=man["stripe_size"],
                          stripe_count=man["stripe_count"])
    return restore_checkpoint(path, like_params, io=io,
                              node_cache=node_cache, with_timings=True)


def generate(params, cfg, prompts, gen_len: int, plan):
    """Greedy generation: prefill then ``gen_len`` decode steps."""
    prefill = jax.jit(lambda p, b: T.prefill(p, cfg, b, plan))
    decode = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t, plan))
    b, s = prompts.shape
    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_model),
                                   jnp.float32) * 0.01
    logits, state = prefill(params, batch)
    # pad the caches so decode can extend beyond the prompt
    state = _grow_caches(state, gen_len)
    toks = []
    def pick(lg):
        lg = jnp.where(jnp.arange(lg.shape[-1]) < cfg.vocab, lg, -jnp.inf)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    tok = pick(logits)
    for _ in range(gen_len):
        toks.append(tok)
        logits, state = decode(params, state, tok)
        tok = pick(logits)
    toks.append(tok)
    return jnp.stack(toks, axis=1)


def _grow_caches(state: T.DecodeState, extra: int) -> T.DecodeState:
    def grow(c):
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, extra)  # [n_blocks, B, S, ...] seq dim
        return jnp.pad(c, pad)
    kv = [None if c is None else (grow(c[0]), grow(c[1]))
          for c in state.kv]
    return state._replace(kv=kv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--restore-dir", default=None,
                    help="restore weights from the latest checkpoint in "
                         "this directory through the planned collective "
                         "read before serving")
    ap.add_argument("--no-node-cache", action="store_true",
                    help="disable the node-level read cache on restore "
                         "(per-rank fetch baseline)")
    args = ap.parse_args()

    cfg = reduced(configs.get(args.arch))
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    if args.restore_dir:
        params, step, rt = restore_params(
            args.restore_dir, params,
            node_cache=not args.no_node_cache)
        print(f"restored step {step}: modeled {rt.total * 1e3:.3f}ms, "
              f"cache hit ratio {rt.cache_hit_ratio:.2f}, "
              f"{rt.read_bytes} bytes read")
    plan = unsharded()
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, plan)
    dt = time.time() - t0
    n_new = out.shape[1] * out.shape[0]
    print(f"arch={cfg.name} generated {out.shape} in {dt:.1f}s "
          f"({n_new/dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0][:12]))


if __name__ == "__main__":
    main()
