"""Shape cells: the assigned (arch x input-shape) grid.

LM shapes are seq_len x global_batch. ``decode_*`` / ``long_*`` lower
``serve_step`` (one token against a seq_len KV cache); ``prefill_*``
lowers the cache-building forward; ``train_*`` lowers the full
fwd+bwd+optimizer step. long_500k runs only for sub-quadratic archs
(SSM/hybrid) — see DESIGN.md S5.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro import configs
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

# archs where Adafactor replaces AdamW (>=400B params — bf16 AdamW
# moments alone would exceed the fleet HBM; see optim.optimizers).
ADAFACTOR_ARCHS = frozenset({"kimi_k2", "llama4_maverick",
                             "jamba_15_large"})


def shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    if cell.name == "long_500k":
        return cfg.sub_quadratic()
    return True


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, ShapeCell[, skipped]) for the 40-cell grid."""
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for cell in SHAPES:
            ok = applicable(cfg, cell)
            if include_skipped:
                yield arch, cell, not ok
            elif ok:
                yield arch, cell
