"""Loop-aware HLO cost analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE
(verified: scan-of-10-matmuls reports 1 matmul of flops), which makes
scanned-layer models look 60x cheaper than they are. This module parses
the optimized HLO text instead:

* per-computation FLOPs (dot/convolution, from operand shapes and
  contracting dims), bytes at fusion/op boundaries (the TPU mental
  model: one fused kernel reads operands, writes results), and
  collective wire bytes (ring formulas, group size from
  replica_groups);
* a call-graph walk that multiplies ``while`` bodies by their
  statically-parsed trip counts (condition compared against a
  constant), fusions/calls by 1.

Known approximations (documented in EXPERIMENTS.md):
* the bytes proxy counts each op RESULT once (reads are producers'
  writes); it still includes values a TPU would keep in VMEM across
  fusions and the CPU backend's f32 upcasts of bf16 weights (absent on
  the TPU MXU) — treat the memory term as an upper bound;
* dynamic trip counts (none in these models) fall back to 1.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
               "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
               "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"([\w\-]+)\((.*)$", re.S)
COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(")


def _split_type_op(rest: str):
    """Split '<type> <op>(<tail>' — tuple types may contain
    '/*index=N*/' comments, so parens must be matched, not regexed."""
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str, remainder = rest[:end + 1], rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, remainder = rest[:sp], rest[sp + 1:].strip()
    m = _OPNAME_RE.match(remainder)
    if not m:
        return None
    return type_str, m.group(1), m.group(2)
OPERAND_RE = re.compile(r"%[\w.\-]+")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)   # kind -> wire bytes
    coll_count: dict = field(default_factory=dict)
    coll_detail: list = field(default_factory=list)  # (kind, shape, n, wire)
    bytes_detail: dict = field(default_factory=dict)  # (op, shape) -> bytes
    calls: list = field(default_factory=list)        # (comp_name, mult)


def _ring_bytes(kind: str, size: int, n: int) -> float:
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * size * f
    if kind == "collective-permute":
        return float(size)
    return size * f          # all-gather / reduce-scatter / all-to-all


def _group_size(line: str) -> int:
    # replica_groups=[G,S]<=... (G groups of S) or explicit {{0,1},{2,3}}
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_computations(hlo: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped:
                m = COMP_HDR_RE.match(stripped)
                if m:
                    cur = m.group(1).lstrip("%")
                    comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _analyze_comp(lines: list[str]) -> tuple[CompCost, dict]:
    """Single pass: symbol table + per-op costs + call edges."""
    shapes: dict[str, str] = {}
    cost = CompCost()
    # first pass: symbol table
    for line in lines:
        m = DEF_RE.match(line)
        if not m:
            continue
        om = _split_type_op(m.group(2))
        if om:
            shapes[m.group(1)] = om[0]

    for line in lines:
        m = DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _split_type_op(rest)
        if not om:
            continue
        type_str, op, tail = om
        if op in ("parameter", "constant", "get-tuple-element", "bitcast",
                  "tuple", "iota"):
            continue
        out_bytes = _shape_bytes(type_str)
        operand_names = OPERAND_RE.findall(tail.split(", calls=")[0]
                                           .split(", body=")[0])
        # HBM-traffic proxy: RESULT bytes only — every read is some
        # producer's write (counting both would double); parameters are
        # read once per use-site and dominate nothing here.
        cost.bytes += out_bytes
        if out_bytes > 1 << 20:
            bk = (op, SHAPE_RE.search(type_str).group(0)
                  if SHAPE_RE.search(type_str) else "?")
            cost.bytes_detail[bk] = cost.bytes_detail.get(bk, 0) + out_bytes

        base_op = re.sub(r"-(start|done)$", "", op)
        if base_op in COLLECTIVES:
            if op.endswith("-done"):
                continue
            n = _group_size(line)
            wire = _ring_bytes(base_op, out_bytes, n)
            cost.coll_bytes[base_op] = cost.coll_bytes.get(base_op, 0) + wire
            cost.coll_count[base_op] = cost.coll_count.get(base_op, 0) + 1
            mshape = SHAPE_RE.search(type_str)
            cost.coll_detail.append(
                (base_op, mshape.group(0) if mshape else type_str[:40],
                 n, wire))
        elif op == "dot":
            dims_out = _shape_dims(type_str)
            lhs = operand_names[0] if operand_names else None
            lhs_dims = _shape_dims(shapes.get(lhs, ""))
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if cm and lhs_dims:
                for d in cm.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            n_out = 1
            for d in dims_out:
                n_out *= d
            cost.flops += 2.0 * n_out * k
        elif op == "convolution":
            n_out = 1
            for d in _shape_dims(type_str):
                n_out *= d
            lhs_dims = _shape_dims(shapes.get(operand_names[0], "")) \
                if operand_names else []
            k = lhs_dims[-1] if lhs_dims else 1
            cost.flops += 2.0 * n_out * k
        if op == "while":
            body = re.search(r"body=(%?[\w.\-]+)", line)
            cond = re.search(r"condition=(%?[\w.\-]+)", line)
            # XLA annotates statically-known trip counts on the op
            tc = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', line)
            trip = int(tc.group(1)) if tc else None
            if body:
                cost.calls.append(("WHILE", body.group(1).lstrip("%"),
                                   (cond.group(1).lstrip("%") if cond
                                    else None, trip)))
        elif op == "fusion" or "calls=" in line:
            cm2 = re.search(r"calls=(%?[\w.\-]+)", line)
            if cm2:
                # fused computations execute in registers/VMEM: count
                # their flops & collectives, NOT their internal bytes
                kind_ = "FUSION" if op == "fusion" else "CALL"
                cost.calls.append((kind_, cm2.group(1).lstrip("%"), None))
        elif op == "conditional":
            for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"true_computation=(%?[\w.\-]+)|"
                                 r"false_computation=(%?[\w.\-]+))", line):
                for b in br:
                    for nm in b.split(","):
                        nm = nm.strip().lstrip("%")
                        if nm:
                            cost.calls.append(("CALL", nm, None))
    return cost, shapes


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count from the condition's compare-to-constant."""
    consts = {}
    for line in cond_lines:
        m = re.match(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)",
                     line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line:
            for op in OPERAND_RE.findall(line.split("compare(")[-1]):
                if op in consts:
                    return max(consts[op], 1)
    return 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self._analyzed = {name: _analyze_comp(lines)[0]
                          for name, lines in self.comps.items()}
        self._memo: dict[str, CompCost] = {}
        # entry is the computation named ENTRY in header; fallback:
        # the one not called by others
        called = {c for a in self._analyzed.values()
                  for _, c, _ in a.calls}
        entries = [n for n in self.comps if n not in called]
        self.entry = entries[-1] if entries else next(iter(self.comps))

    def total(self, comp: str | None = None, _depth=0) -> CompCost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        base = self._analyzed.get(comp)
        if base is None or _depth > 64:
            return CompCost()
        out = CompCost(flops=base.flops, bytes=base.bytes,
                       coll_bytes=dict(base.coll_bytes),
                       coll_count=dict(base.coll_count),
                       coll_detail=list(base.coll_detail),
                       bytes_detail=dict(base.bytes_detail))
        for kind, callee, cond in base.calls:
            mult = 1
            if kind == "WHILE":
                cond_name, trip = cond if isinstance(cond, tuple) else (cond, None)
                if trip is not None:
                    mult = trip
                else:
                    mult = _trip_count(self.comps.get(cond_name, [])) \
                        if cond_name else 1
            sub = self.total(callee, _depth + 1)
            out.flops += mult * sub.flops
            if kind != "FUSION":
                out.bytes += mult * sub.bytes
                for bk, v in sub.bytes_detail.items():
                    out.bytes_detail[bk] = out.bytes_detail.get(bk, 0) \
                        + mult * v
            for k, v in sub.coll_bytes.items():
                out.coll_bytes[k] = out.coll_bytes.get(k, 0) + mult * v
            for k, v in sub.coll_count.items():
                out.coll_count[k] = out.coll_count.get(k, 0) + mult * v
            for kind_, shape_, n_, wire_ in sub.coll_detail:
                out.coll_detail.append((kind_, shape_, n_, mult * wire_))
        self._memo[comp] = out
        return out


def top_collectives(cost: CompCost, k: int = 12):
    """Aggregate per-(kind, shape, group) wire bytes, descending."""
    agg: dict = {}
    for kind, shape, n, wire in cost.coll_detail:
        key = (kind, shape, n)
        agg[key] = agg.get(key, 0) + wire
    return sorted(agg.items(), key=lambda kv: -kv[1])[:k]
