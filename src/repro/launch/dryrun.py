import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: GSPMD must
partition every step function onto the production meshes, the compiled
memory analysis reports per-device bytes, cost analysis feeds the
roofline (EXPERIMENTS.md). Collective bytes are parsed from the
optimized HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes).

Usage:
  python -m repro.launch.dryrun --arch yi_34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "c64": 8, "c128": 16}

COLLECTIVE_RE = re.compile(
    r"=\s*(\w[\w:]*)\[?[^=]*?\s(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(")

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO, by kind."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start)?\(", line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        kind = m.group(1)
        # result shape(s) left of '='; use the result shape as proxy for
        # moved bytes (operand tuple shapes appear after the op name too)
        lhs = line.split("=")[0]
        total = 0
        for dt, dims in SHAPE_RE.findall(lhs):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path | None = None) -> dict:
    from repro.launch import shapes as shp
    from repro.launch.mesh import make_plan, make_production_mesh
    from repro.launch.steps import input_specs
    from repro import configs

    cell = shp.shape(shape_name)
    cfg = configs.get(arch)
    if not shp.applicable(cfg, cell):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "full-attention arch; long_500k needs "
                            "sub-quadratic attention (DESIGN.md S5)"}
        if out_dir:
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
                json.dumps(result, indent=2))
        return result
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    from repro.launch.steps import plan_for_cell
    plan = plan_for_cell(mesh, cell)
    t0 = time.time()
    fn, arg_shapes, arg_specs, out_specs = input_specs(arch, cell, plan)

    def shardings(tree_specs, tree_shapes):
        flat_sp, treedef = jax.tree.flatten(
            tree_specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        return treedef.unflatten(
            [NamedSharding(mesh, sp) for sp in flat_sp])

    in_sh = shardings(arg_specs, arg_shapes)
    out_sh = shardings(out_specs, None)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*arg_shapes)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    n_dev = int(np.prod(list(mesh.shape.values())))

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq": cell.seq, "global_batch": cell.global_batch,
        "kind": cell.kind,
    }
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_kind}"
        (out_dir / f"{name}.json").write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.launch import shapes as shp

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, c.name) for a, c in shp.all_cells()]
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    multi_cell = len(cells) * len(meshes) > 1
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape_name}__{mesh_kind}"
            path = out_dir / f"{name}.json"
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip] {name}", flush=True)
                    continue
            if multi_cell:
                # one subprocess per cell: XLA compile caches/constants
                # accumulate across compiles and OOM a single process
                import subprocess
                rc = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape_name,
                     "--mesh", mesh_kind, "--out", str(out_dir)],
                    capture_output=True, text=True)
                tail = [ln for ln in rc.stdout.splitlines()
                        if ln.startswith("[")]
                err1 = (rc.stderr.strip().splitlines()[-1]
                        if rc.stderr.strip() else "")
                print("\n".join(tail) if tail else
                      f"[FAIL] {name}: rc={rc.returncode} {err1}",
                      flush=True)
                failures += rc.returncode != 0
                continue
            try:
                r = run_cell(arch, shape_name, mesh_kind, out_dir)
                if r.get("status") == "skipped":
                    print(f"[skipped] {name}: {r['reason']}", flush=True)
                    continue
                mem_gib = r.get("memory", {}).get("temp_bytes", 0) / 2**30
                print(f"[ok]   {name}: compile={r.get('compile_s')}s "
                      f"flops={r.get('flops', 0):.3e} temp={mem_gib:.2f}GiB",
                      flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {name}: {e}", flush=True)
                traceback.print_exc()
                if out_dir:
                    out_dir.mkdir(parents=True, exist_ok=True)
                    path.write_text(json.dumps(
                        {"arch": arch, "shape": shape_name,
                         "mesh": mesh_kind, "status": "fail",
                         "error": str(e)}, indent=2))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
