import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Roofline analysis per (arch x shape x mesh) cell.

Derives the three roofline terms from the compiled dry-run artifact:

  compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips x 819 GB/s HBM)
  collective = collective wire bytes / (chips x 50 GB/s ICI)

HLO_FLOPs / bytes / collective bytes come from the loop-aware HLO parser
(hlo_analysis.py) — XLA's cost_analysis counts while bodies once, which
underreports scanned-layer models by ~n_layers.

Also reports MODEL_FLOPS (analytic 6*N_active*D + attention terms) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Usage:
  python -m repro.launch.roofline --arch yi_34b --shape train_4k --mesh single
  python -m repro.launch.roofline --all [--out results/roofline]
  python -m repro.launch.roofline --table  # render markdown from results
"""

import argparse
import json
import sys
import time
from pathlib import Path

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS for the cell (global, per step).

    train:   6 * N_active * tokens  + 12 * attn(S) (fwd+bwd, causal)
    prefill: 2 * N_active * tokens  + 4 * attn(S) / 2
    decode:  2 * N_active * batch   + 4 * B * S_ctx * Hq * hd per layer
    SSD state updates are O(S * d_state * d_inner) — folded into the
    linear-projection 6ND term's margin (documented).
    """
    n_act = cfg.active_param_count()
    gb, s = cell.global_batch, cell.seq
    hq, hd = cfg.n_heads, cfg.head_dim or 0
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.n_layers))

    def attn_fwd(seq):
        total = 0.0
        for i in range(cfg.n_layers):
            if not cfg.is_attn_layer(i):
                continue
            if cfg.is_local_layer(i) and cfg.window:
                eff = min(cfg.window, seq)
                total += 4 * gb * seq * eff * hq * hd / 2
            else:
                total += 4 * gb * seq * seq * hq * hd / 2
        return total

    if cell.kind == "train":
        return 6 * n_act * gb * s + 3 * attn_fwd(s)
    if cell.kind == "prefill":
        return 2 * n_act * gb * s + attn_fwd(s)
    # decode: one token against an S-long cache
    per_layer = 4 * gb * s * hq * hd
    return 2 * n_act * gb + n_attn * per_layer


def analyze_cell(arch: str, shape_name: str, mesh_kind: str,
                 out_dir: Path | None) -> dict:
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    from repro import configs
    from repro.launch import shapes as shp
    from repro.launch.hlo_analysis import HloCostModel
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import input_specs, plan_for_cell

    cell = shp.shape(shape_name)
    cfg = configs.get(arch)
    if not shp.applicable(cfg, cell):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = plan_for_cell(mesh, cell)
    fn, arg_shapes, arg_specs, out_specs = input_specs(arch, cell, plan)

    def sh(t):
        f, td = jax.tree.flatten(
            t, is_leaf=lambda x: isinstance(x, PartitionSpec))
        return td.unflatten([NamedSharding(mesh, s) for s in f])

    t0 = time.time()
    compiled = jax.jit(fn, in_shardings=sh(arg_specs),
                       out_shardings=sh(out_specs)).lower(
                           *arg_shapes).compile()
    n_dev = int(np.prod(list(mesh.shape.values())))
    hcm = HloCostModel(compiled.as_text())
    tot = hcm.total()
    mem = compiled.memory_analysis()
    # pod-crossing traffic (multi mesh): replica groups wider than one
    # pod's 16x16 ride the DCI (~25 GB/s effective per device)
    dci_bytes = sum(w for _, _, n, w in tot.coll_detail if n > 256) \
        + sum(w for _, _, n, w in tot.coll_detail if 16 < n <= 32
              and mesh_kind == "multi")

    # per-device HLO numbers (the parsed HLO is the per-device program)
    flops_dev = tot.flops
    bytes_dev = tot.bytes
    coll_dev = sum(tot.coll_bytes.values())
    mf = model_flops(cfg, cell)

    t_compute = flops_dev / PEAK
    t_memory = bytes_dev / HBM
    t_collective = coll_dev / ICI
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "devices": n_dev,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "coll_bytes_per_dev": coll_dev,
        "dci_bytes_per_dev": dci_bytes,
        "t_dci_s": dci_bytes / 25e9,
        "coll_breakdown": tot.coll_bytes,
        "coll_counts": tot.coll_count,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_ratio": (mf / n_dev) / max(flops_dev, 1.0),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "roofline_fraction": (mf / n_dev / PEAK) / max(bound, 1e-30),
        "mem_per_dev": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        },
        "analyze_s": round(time.time() - t0, 1),
    }
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(result, indent=2))
    return result


def render_table(out_dir: Path) -> str:
    rows = []
    for f in sorted(out_dir.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rows.append(r)
    lines = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) "
        "| dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.table:
        print(render_table(out_dir))
        return

    from repro.launch import shapes as shp
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = ([(a, c.name) for a, c in shp.all_cells()] if args.all
             else [(args.arch, args.shape)])
    multi = len(cells) * len(meshes) > 1
    failures = 0
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape_name}__{mesh_kind}"
            if args.skip_existing and (out_dir / f"{name}.json").exists():
                print(f"[skip] {name}", flush=True)
                continue
            if multi:
                import subprocess
                rc = subprocess.run(
                    [sys.executable, "-m", "repro.launch.roofline",
                     "--arch", arch, "--shape", shape_name,
                     "--mesh", mesh_kind, "--out", str(out_dir)],
                    capture_output=True, text=True)
                tail = [ln for ln in rc.stdout.splitlines()
                        if ln.startswith("[")]
                print("\n".join(tail) or f"[FAIL] {name} rc={rc.returncode}",
                      flush=True)
                failures += rc.returncode != 0
                continue
            r = analyze_cell(arch, shape_name, mesh_kind, out_dir)
            if r["status"] == "skipped":
                print(f"[skipped] {name}", flush=True)
            else:
                print(f"[ok] {name}: dom={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f} "
                      f"useful={r['useful_ratio']:.2f}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
