"""Training driver.

Two modes:
  --smoke      reduced config, real training on CPU (examples use this)
  (default)    full config on the production mesh — requires hardware;
               on this CPU container use launch.dryrun instead.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch yi_34b --smoke \
      --steps 200 --ckpt-dir /tmp/ck --io tam
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager, HostCollectiveIO
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import transformer as T
from repro.models.config import reduced
from repro.models.sharding import unsharded
from repro.optim import warmup_cosine
from repro.runtime import HeartbeatMonitor, TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--io", default="tam", choices=["tam", "twophase"])
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param example)")
    ap.add_argument("--n-layers", type=int, default=None)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        head_dim=max(args.d_model // 8, 16), n_heads=8,
                        n_kv_heads=min(
                            4, cfg.n_kv_heads) if cfg.n_kv_heads else 0,
                        d_ff=4 * args.d_model if cfg.d_ff else 0,
                        vocab=8192)
        if args.n_layers:
            per = cfg.block_period
            over["n_layers"] = -(-args.n_layers // per) * per
        cfg = reduced(cfg, **over)
    plan = unsharded()
    opt = make_optimizer(args.arch)

    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt_state = opt.init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps}")

    lr_fn = warmup_cosine(args.lr, warmup=20, total=args.steps)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.loss_fn)(
            params, cfg, batch, plan)
        params, opt_state = opt.update(grads, opt_state, params,
                                       lr_fn(opt_state["step"]))
        return params, opt_state, loss

    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    data = SyntheticTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq=args.seq, global_batch=args.batch))
    io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1 << 20,
                          stripe_count=4)
    ckpt = CheckpointManager(args.ckpt_dir, io, method=args.io)
    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps,
                        checkpoint_every=args.ckpt_every),
        train_step, data, ckpt)

    t0 = time.time()
    first_loss = None

    def on_step(step, loss):
        nonlocal first_loss
        if first_loss is None:
            first_loss = loss
        if step % 20 == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0)/step:.2f}s/step)")

    params, opt_state, step = loop.run(params, opt_state, on_step=on_step)
    print(f"done: loss {first_loss:.4f} -> {loop.losses[-1]:.4f} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
