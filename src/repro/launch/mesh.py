"""Production mesh construction.

Functions (not module constants) so importing never touches jax device
state. TPU v5e targets: 256 chips/pod (16x16), 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

from repro.models.sharding import ShardingPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_plan(mesh, shard_seq: bool = True) -> ShardingPlan:
    axes = mesh.axis_names
    data_axes = ("pod", "data") if "pod" in axes else ("data",)
    return ShardingPlan(mesh=mesh, data_axes=data_axes, model_axis="model",
                        shard_seq=shard_seq)


def make_io_mesh(n_nodes: int, lagg: int, lmem: int):
    """3-D collective-I/O mesh view (node, lagg, lmem) — see core.tam."""
    return jax.make_mesh((n_nodes, lagg, lmem), ("node", "lagg", "lmem"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
