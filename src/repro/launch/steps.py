"""Step builders + input specs for the dry-run and the real drivers.

``input_specs(arch, cell, plan)`` returns (args as ShapeDtypeStructs,
matching PartitionSpec trees, step_fn) — the shannon/kernels pattern:
weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs, optim
from repro.launch.shapes import ADAFACTOR_ARCHS, ShapeCell
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPlan, unsharded

KEY = jax.random.PRNGKey(0)


def plan_for_cell(mesh, cell: ShapeCell,
                  activation_tp: bool | None = None) -> ShardingPlan:
    """Cell-appropriate plan: SP off for decode; batch replicated when
    the global batch does not divide the data axes (long_500k gb=1).

    activation_tp defaults from REPRO_ACTIVATION_TP env (perf A/B knob,
    see EXPERIMENTS.md SPerf)."""
    import dataclasses
    import math as _math
    import os as _os
    from repro.launch.mesh import make_plan
    if activation_tp is None:
        activation_tp = _os.environ.get("REPRO_ACTIVATION_TP", "1") == "1"
    plan = make_plan(mesh, shard_seq=(cell.kind != "decode"))
    plan = dataclasses.replace(plan, activation_tp=activation_tp)
    dp_size = _math.prod(mesh.shape[a] for a in plan.data_axes)
    if cell.global_batch % dp_size:
        plan = dataclasses.replace(plan, data_axes=())
    return plan


def make_optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return optim.adafactor()
    return optim.adamw()


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, plan: ShardingPlan, opt,
                    lr=3e-4, remat: bool = True):
    p_specs = T.param_shardings(cfg, plan) if plan.mesh is not None else None

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.loss_fn)(
            params, cfg, batch, plan, remat=remat)
        if p_specs is not None:
            # land gradients directly in the FSDP layout: turns the
            # backward's weight-grad all-reduces into reduce-scatters
            # (half the wire bytes) and keeps the optimizer local
            # (SPerf iteration 3)
            grads = jax.tree.map(
                lambda g, sp: plan.constrain(g, sp), grads, p_specs,
                is_leaf=lambda x: isinstance(x, jax.Array))
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss
    return train_step


def make_prefill_step(cfg: ModelConfig, plan: ShardingPlan):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, plan)
    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: ShardingPlan):
    def serve_step(params, state, tokens):
        return T.decode_step(params, cfg, state, tokens, plan)
    return serve_step


# ---------------------------------------------------------------------------
# shape/sharding specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, cell: ShapeCell, plan: ShardingPlan):
    gb, s = cell.global_batch, cell.seq
    dp = plan.dp
    tok_s = s - (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
    shapes: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((gb, tok_s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((gb, tok_s), jnp.int32),
    }
    specs: dict[str, Any] = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.frontend == "vision":
        shapes["prefix_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)
        specs["prefix_embeds"] = P(dp, None, None)
    if cfg.enc_dec:
        shapes["frames"] = jax.ShapeDtypeStruct(
            (gb, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P(dp, None, None)
    return shapes, specs


def params_specs(cfg: ModelConfig, plan: ShardingPlan):
    shapes = jax.eval_shape(functools.partial(T.init_params, KEY, cfg))
    specs = T.param_shardings(cfg, plan)
    return shapes, specs


def opt_state_specs(opt, params_shapes, params_specs_tree):
    shapes = jax.eval_shape(opt.init, params_shapes)

    def norm(spec, ndim):
        parts = tuple(spec) if spec is not None else ()
        return parts + (None,) * (ndim - len(parts))

    # adamw: {"m": like params, "v": like params, "step": scalar}
    if set(shapes.keys()) == {"m", "v", "step"}:
        return shapes, {"m": params_specs_tree, "v": params_specs_tree,
                        "step": P()}

    # adafactor: {"f": tree-of {vr, vc} | {v}, "step": scalar}
    def fac_spec(pspec, pshape):
        nd = len(pshape.shape)
        parts = norm(pspec, nd)
        if nd >= 2:
            return {"vr": P(*parts[:-1]),
                    "vc": P(*(parts[:-2] + parts[-1:]))}
        return {"v": P(*parts)}

    flat_specs, treedef = jax.tree.flatten(
        params_specs_tree, is_leaf=lambda x: isinstance(x, P) or x is None)
    flat_shapes = treedef.flatten_up_to(params_shapes)
    fspecs = treedef.unflatten(
        [fac_spec(sp, sh) for sp, sh in zip(flat_specs, flat_shapes)])
    return shapes, {"f": fspecs, "step": P()}


def decode_state_specs(cfg: ModelConfig, cell: ShapeCell,
                       plan: ShardingPlan):
    def mk_state():
        enc = (jnp.zeros((cell.global_batch, cfg.enc_seq, cfg.d_model),
                         jnp.bfloat16) if cfg.enc_dec else None)
        return T.init_decode_state(cfg, cell.global_batch, cell.seq,
                                   None, jnp.bfloat16, enc)

    shapes = jax.eval_shape(mk_state)
    period = cfg.block_period
    kv_specs, ssm_specs = [], []
    for j in range(period):
        if cfg.is_attn_layer(j) and not cfg.attention_free:
            spec = P(None, plan.dp, plan.tp, None, None)
            kv_specs.append((spec, spec))
            ssm_specs.append(None)
        else:
            kv_specs.append(None)
            ssm_specs.append((P(None, plan.dp, plan.tp, None, None),
                              P(None, plan.dp, None, None)))
    specs = T.DecodeState(
        kv=kv_specs, ssm=ssm_specs, pos=P(),
        enc_out=P(plan.dp, None, None) if cfg.enc_dec else None)
    return shapes, specs


def input_specs(arch: str, cell: ShapeCell, plan: ShardingPlan):
    """Returns (step_fn, arg ShapeDtypeStructs, arg PartitionSpec trees,
    out PartitionSpec trees or None)."""
    cfg = configs.get(arch)
    p_shapes, p_specs = params_specs(cfg, plan)
    if cell.kind == "train":
        opt = make_optimizer(arch)
        o_shapes, o_specs = opt_state_specs(opt, p_shapes, p_specs)
        b_shapes, b_specs = batch_specs(cfg, cell, plan)
        fn = make_train_step(cfg, plan, opt)
        return (fn, (p_shapes, o_shapes, b_shapes),
                (p_specs, o_specs, b_specs),
                (p_specs, o_specs, P()))
    if cell.kind == "prefill":
        b_shapes, b_specs = batch_specs(cfg, cell, plan)
        fn = make_prefill_step(cfg, plan)
        _, st_specs = decode_state_specs(cfg, cell, plan)
        return (fn, (p_shapes, b_shapes), (p_specs, b_specs),
                (P(plan.dp, plan.tp), st_specs))
    if cell.kind == "decode":
        st_shapes, st_specs = decode_state_specs(cfg, cell, plan)
        tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
        fn = make_decode_step(cfg, plan)
        return (fn, (p_shapes, st_shapes, tok),
                (p_specs, st_specs, P(plan.dp)),
                (P(plan.dp, plan.tp), st_specs))
    raise ValueError(cell.kind)
