"""Version-compatibility shims for JAX API drift.

``shard_map`` moved twice across the JAX versions this repo must run on:

* old (<= 0.4.x): ``jax.experimental.shard_map.shard_map`` with a
  ``check_rep`` kwarg;
* new (>= 0.5/0.6): top-level ``jax.shard_map`` where ``check_rep``
  was renamed ``check_vma``.

All repro modules import :func:`shard_map` from here and always pass the
NEW kwarg spelling (``check_vma``); the shim renames it when running on
an older JAX. Anything else is forwarded untouched.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on old JAX
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, **kwargs: Any):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename
    papered over (pass ``check_vma``; old JAX receives ``check_rep``)."""
    if check_vma is not None:
        key = "check_vma" if "check_vma" in _PARAMS else "check_rep"
        kwargs[key] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def axis_size(axis_name):
    """``jax.lax.axis_size`` fallback for JAX versions that predate it
    (inside an SPMD context the size is ``psum(1, axis)``)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
