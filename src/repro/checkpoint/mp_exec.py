"""Multi-process transport executor: real processes, real bytes.

The third backend of the plan/executor split (``IOPlan.transport ==
"mp"``, dispatched by ``checkpoint.host_io``). Where the host executor
moves numpy bytes inside one process and CHARGES an alpha-beta model,
this one actually ships them between processes:

* one **worker process per sender** (per ``per_la`` entry — a local
  aggregator under TAM, a rank under two-phase), grouped into "nodes"
  by ``sender_nodes``;
* the **intra-node fast hop** is a per-node
  ``multiprocessing.shared_memory`` arena: a sender co-located with the
  serving aggregator writes its round blocks into its arena region and
  posts only a descriptor — the parent (which maps the same segment)
  consumes the bytes zero-copy;
* the **inter-node slow hop** is a localhost TCP socket per destination
  node (``core.transport`` framing): every cross-node message pays real
  serialization + kernel round trips, so congestion and the
  message-count collapse of intra-node aggregation are measurable as
  wall-clock and wire-byte facts, not model outputs. Under TAM the
  node's elected leader combines all co-located senders' blocks for a
  (domain, round) into ONE frame (subrecords read zero-copy from the
  arena); flat two-phase sends one frame per sender.
* slow-hop codecs run **encode-once on the wire**: the sender encodes,
  the receiver decodes; fast-hop (arena) blocks move raw.

Byte identity is the contract: the parent reassembles the per-domain
inboxes in the host oracle's exact sender order and reuses its
``merge_coalesce``/``domain_image``/``write_segment`` for the drain, so
segments on disk are byte-identical to ``host_exec.execute_write`` for
every placement x codec x depth (cross-checked by
``repro.testing.rounds_checks``). The read direction mirrors
``execute_read``: the parent performs the ranged window reads, one
elected fetcher per (window, node) receives each window over its
socket, stages it into the node arena, and fans it out to co-located
readers through their queues; per-rank outputs return through a result
arena.

TIME here is real wall-clock: ``IOTimings.comm_rounds`` /
``io_rounds`` / ``inter_comm`` / ``io`` are measured, and feed the same
session ``observe`` loop as modeled timings (``IOTimings.transport``
records which executor produced a measurement — the session discards
totals across an executor switch).

Faults: the only injection this backend honors is
``FaultSpec.dead_aggregator = (sender, round)``, reinterpreted at
process level — worker ``sender`` is killed (``os._exit``) entering
``round``. The parent detects the death (exit code + missing blocks),
latches it on the heartbeat monitor, regenerates the victim's
unfinished blocks from the stage-1 data it already holds (the repair
story), and charges ``recovery_seconds`` — the segments stay
byte-identical to the healthy run. Other ``FaultSpec`` fields model
timing, which is not modeled here, and are rejected loudly.

Workers are forked (start method ``"fork"``): they inherit the stage-1
numpy arrays and the arena mappings copy-free, and touch only numpy +
sockets + queues (never JAX) so forking from a JAX-initialized parent
stays safe. Every blocking wait is bounded by ``WAIT_S``
(``REPRO_MP_TIMEOUT_S``) so a hung worker fails the run fast instead of
wedging it.
"""
from __future__ import annotations

import multiprocessing
import os
import queue as queue_mod
import socket
import struct
import threading
import time
from multiprocessing import shared_memory

import numpy as np

from repro.core import placement as placement_mod
from repro.core import transport as tx
from repro.core.codec import get_codec
from repro.core.cost_model import optimal_depth
from repro.core.faults import TornWriteError, partial_marker
from repro.checkpoint.host_exec import (domain_image, merge_coalesce,
                                        to_domain_local, write_segment)

WAIT_S = float(os.environ.get("REPRO_MP_TIMEOUT_S", "60"))

_KILL_EXIT = 23     # exit code of an injected worker kill


def _ctx():
    try:
        return multiprocessing.get_context("fork")
    except ValueError as e:  # pragma: no cover - non-POSIX platforms
        raise RuntimeError(
            "the mp transport needs the 'fork' start method (workers "
            "inherit stage-1 arrays and arena mappings)") from e


def _serve_of(plan, serve_map, stripe_count, n_nodes):
    """The domain->slot map and its node image (host_exec semantics)."""
    perm = (plan.placement if plan.placement is not None
            else tuple(range(stripe_count)))
    if serve_map is not None:
        serve = tuple(int(s) for s in serve_map)
        if len(serve) != stripe_count or not all(
                0 <= s < stripe_count for s in serve):
            raise ValueError(f"serve_map {serve!r} must map each of "
                             f"{stripe_count} domains to a valid slot")
    else:
        serve = tuple(perm)
    serve_nodes = [placement_mod.node_of_slot(serve[g], stripe_count,
                                              n_nodes)
                   for g in range(stripe_count)]
    return serve, serve_nodes


def _sender_schedule(offs, lens, packed, stripe_size, stripe_count, cb):
    """One sender's per-(domain, round) blocks, in the host oracle's
    exact partition: a request belongs to domain ``(off//ss) % sc`` and
    round ``to_domain_local(off) // cb`` (host_exec's per-sender loop).

    Returns ``[(g, po, pl, seg_starts, {round: (in_r, payload)})]``,
    domains ascending, with ``payload`` the round's packed byte slice.
    """
    owner = (offs // stripe_size) % stripe_count
    rnd = to_domain_local(offs, stripe_size, stripe_count) // cb
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    per_g = []
    for g in range(stripe_count):
        sel = owner == g
        if not sel.any():
            continue
        po, pl = offs[sel], lens[sel]
        pd = (np.concatenate([packed[s:s + l]
                              for s, l in zip(starts[sel], pl)])
              if int(pl.sum()) else np.zeros(0, np.uint8))
        seg_starts = np.concatenate([[0], np.cumsum(pl)[:-1]])
        rounds = {}
        for r in np.unique(rnd[sel]):
            in_r = rnd[sel] == r
            payload = (np.concatenate(
                [pd[s:s + l] for s, l in zip(seg_starts[in_r], pl[in_r])])
                if int(pl[in_r].sum()) else np.zeros(0, np.uint8))
            rounds[int(r)] = (in_r, payload)
        per_g.append((int(g), po, pl, seg_starts, rounds))
    return per_g


def _round_walls(arrival: dict, n_rounds: int, t0: float):
    """Per-round wall-clock increments from last-arrival timestamps."""
    dur = [0.0] * n_rounds
    prev = t0
    for r in range(n_rounds):
        end = arrival.get(r)
        if end is not None and end > prev:
            dur[r] = end - prev
            prev = end
    return dur


class _Failed(RuntimeError):
    """A worker process died without fault injection to excuse it."""


def execute_write(plan, machine, per_la, path, t, depth_request=None,
                  sender_nodes=None, n_nodes=None, faults=None,
                  heartbeat=None, serve_map=None):
    """Run a write plan's exchange + I/O on real worker processes.

    Same signature and byte contract as
    :func:`repro.checkpoint.host_exec.execute_write`; see the module
    docstring for what is real here. ``plan.method == "tam"`` selects
    node-combined slow-hop frames (the senders ARE the stage-1 local
    aggregators); two-phase sends per-sender frames.
    """
    m = machine
    stripe_count, cb = plan.n_aggregators, plan.cb
    stripe_size = plan.layout.stripe_size
    n_rounds = plan.n_rounds
    codec = get_codec(plan.slow_hop_codec) if plan.slow_hop_codec else None
    if faults is not None and (
            faults.slow_nodes or faults.lost or faults.delayed
            or faults.torn_window is not None
            or faults.resize_at_write is not None):
        raise ValueError(
            "mp transport: time is wall-clock here, so modeled-timing "
            "faults (slow_nodes/lost/delayed/torn_window/resize) are "
            "not supported — only dead_aggregator (worker kill)")
    if sender_nodes is None:
        sender_nodes = [0] * len(per_la)
    if n_nodes is None:
        n_nodes = int(max(sender_nodes, default=0)) + 1
    serve, serve_nodes = _serve_of(plan, serve_map, stripe_count, n_nodes)
    combined = plan.method == "tam"
    kill = None
    if faults is not None and faults.dead_aggregator is not None:
        kill = (int(faults.dead_aggregator[0]),
                max(0, min(int(faults.dead_aggregator[1]), n_rounds - 1)))
        if not 0 <= kill[0] < len(per_la):
            raise ValueError(f"worker-kill victim {kill[0]} out of range")

    # ---- parent-side schedule (workers inherit it through fork) ------
    sched = {}
    node_bytes = np.zeros((stripe_count, n_nodes), np.int64)
    ga_msgs = np.zeros((stripe_count, n_rounds), np.int64)
    ga_msgs_fast = np.zeros((stripe_count, n_rounds), np.int64)
    combined_seen: set = set()
    senders = []
    for s, (offs, lens, packed) in enumerate(per_la):
        if offs.size == 0:
            continue
        senders.append(s)
        sched[s] = _sender_schedule(offs, lens, packed, stripe_size,
                                    stripe_count, cb)
        for g, po, pl, _, rounds in sched[s]:
            node_bytes[g, sender_nodes[s]] += int(pl.sum())
            fast = serve_nodes[g] == sender_nodes[s]
            for r in rounds:
                if fast:
                    ga_msgs_fast[g, r] += 1
                elif combined:
                    key = (sender_nodes[s], g, r)
                    if key not in combined_seen:
                        combined_seen.add(key)
                        ga_msgs[g, r] += 1
                else:
                    ga_msgs[g, r] += 1
    node_members = {nd: [s for s in senders if sender_nodes[s] == nd]
                    for nd in set(sender_nodes[s] for s in senders)}
    leaders = {nd: min(mem) for nd, mem in node_members.items()}

    # ---- per-node arenas: a region per sender, blocks packed
    # sequentially (payload for fast blocks; pair metadata + encoded
    # payload for TAM slow blocks awaiting the leader's combine) -------
    region_of = {}
    arena_size = {nd: 0 for nd in node_members}
    for s in senders:
        need = 0
        for _, po, pl, _, rounds in sched[s]:
            for _, payload in rounds.values():
                need += int(payload.size) * 2 + 16 * int(po.size) + 128
        nd = sender_nodes[s]
        region_of[s] = arena_size[nd]
        arena_size[nd] += need
    ctx = _ctx()
    shms = {nd: shared_memory.SharedMemory(
        create=True, size=max(sz, 1)) for nd, sz in arena_size.items()}
    arenas = {nd: np.frombuffer(shm.buf, np.uint8)
              for nd, shm in shms.items()}

    # ---- slow-hop listeners: one per destination node ----------------
    listeners = {}
    ports = {}
    for nd in range(n_nodes):
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.bind(("127.0.0.1", 0))
        lst.listen(len(per_la) + 1)
        lst.settimeout(0.2)
        listeners[nd] = lst
        ports[nd] = lst.getsockname()[1]

    ctrl = ctx.Queue()
    node_qs = {nd: ctx.Queue() for nd in node_members} if combined else {}
    stop = threading.Event()
    lock = threading.Lock()
    slow_blocks: dict = {}     # (s, g, r) -> (po, pl, wire, raw_len)
    arrival: dict = {}
    wire_slow = [0]
    recv_errors: list = []

    def _note(r, now):
        if arrival.get(r, 0.0) < now:
            arrival[r] = now

    def _store(kind, s, g, r, po, pl, wire, raw_len):
        with lock:
            slow_blocks[(s, g, r)] = (po, pl, wire, raw_len)
            _note(r, time.perf_counter())

    def _handle_conn(conn):
        try:
            with conn:
                conn.settimeout(WAIT_S)
                while True:
                    body = tx.recv_msg(conn)
                    if body is None:
                        return
                    with lock:
                        wire_slow[0] += 4 + len(body)
                    kind, sender, g, r, n_req, raw_len, enc_len = \
                        tx.HDR.unpack_from(body, 0)
                    if kind == tx.KIND_BLOCK:
                        _, sender, g, r, po, pl, wire, raw_len = \
                            tx.unpack_block(body)
                        _store(kind, sender, g, r, po, pl, wire, raw_len)
                    elif kind == tx.KIND_COMBINED:
                        pos = tx.HDR.size
                        for _ in range(n_req):   # n_req = subrecords
                            s2, nr, rl, el = tx.SUB.unpack_from(body, pos)
                            pos += tx.SUB.size
                            po, pl = tx.unpack_pairs(
                                body[pos:pos + 16 * nr], nr)
                            pos += 16 * nr
                            _store(kind, s2, g, r, po, pl,
                                   body[pos:pos + el], rl)
                            pos += el
                    else:
                        raise ConnectionError(
                            f"unexpected frame kind {kind}")
        except (OSError, ConnectionError) as e:
            if not stop.is_set():
                recv_errors.append(e)

    def _accept_loop(lst):
        handlers = []
        while not stop.is_set():
            try:
                conn, _ = lst.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            th = threading.Thread(target=_handle_conn, args=(conn,))
            th.start()
            handlers.append(th)
        for th in handlers:
            th.join(WAIT_S)

    acceptors = [threading.Thread(target=_accept_loop, args=(lst,))
                 for lst in listeners.values()]
    for th in acceptors:
        th.start()

    # ---- the worker (forked: closes over everything above) -----------
    def _worker(s):
        my_node = sender_nodes[s]
        arena = arenas[my_node]
        pos = region_of[s]
        conns: dict = {}

        def _conn(d):
            if d not in conns:
                sk = socket.create_connection(("127.0.0.1", ports[d]),
                                              timeout=WAIT_S)
                sk.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conns[d] = sk
            return conns[d]

        try:
            for g, po, pl, _, rounds in sched[s]:
                fast = serve_nodes[g] == my_node
                for r in sorted(rounds):
                    if kill is not None and s == kill[0] and r >= kill[1]:
                        os._exit(_KILL_EXIT)
                    in_r, payload = rounds[r]
                    if fast:
                        # fast hop: raw bytes into the arena, descriptor
                        # through the control queue — parent reads the
                        # same mapping zero-copy
                        n = int(payload.size)
                        arena[pos:pos + n] = payload
                        ctrl.put(("fast", s, g, r, pos, n))
                        pos += n
                    else:
                        wire = (np.asarray(codec.encode_bytes(payload),
                                           np.uint8)
                                if codec is not None else payload)
                        if combined:
                            # stage for the node leader's combine
                            meta = np.frombuffer(
                                tx.pack_pairs(po[in_r], pl[in_r]),
                                np.uint8)
                            arena[pos:pos + meta.size] = meta
                            mpos = pos
                            pos += meta.size
                            arena[pos:pos + wire.size] = wire
                            node_qs[my_node].put(
                                ("blk", s, g, r, mpos, int(in_r.sum()),
                                 pos, int(wire.size), int(payload.size)))
                            pos += int(wire.size)
                        else:
                            body = tx.pack_block(
                                tx.KIND_BLOCK, s, g, r, po[in_r],
                                pl[in_r], wire.tobytes(),
                                int(payload.size))
                            tx.send_msg(_conn(serve_nodes[g]), body)
            if combined:
                node_qs[my_node].put(("done", s))
                if s == leaders[my_node]:
                    _leader_combine(s, my_node, conns)
            ctrl.put(("done", s))
        finally:
            for sk in conns.values():
                try:
                    sk.close()
                except OSError:
                    pass

    def _leader_combine(me, my_node, conns):
        """TAM: gather co-located slow blocks from the arena, send one
        combined frame per (domain, round)."""
        arena = arenas[my_node]
        waiting = set(node_members[my_node])
        blocks: dict = {}
        while waiting:
            msg = node_qs[my_node].get(timeout=WAIT_S)
            if msg[0] == "done":
                waiting.discard(msg[1])
            else:
                _, s2, g, r, mpos, n_req, wpos, enc_len, raw_len = msg
                blocks.setdefault((g, r), []).append(
                    (s2, n_req, mpos, wpos, enc_len, raw_len))
        for (g, r), subs in sorted(blocks.items()):
            subs.sort()
            parts = [tx.HDR.pack(tx.KIND_COMBINED, me, g, r, len(subs),
                                 sum(x[5] for x in subs),
                                 sum(x[4] for x in subs))]
            for s2, n_req, mpos, wpos, enc_len, raw_len in subs:
                parts.append(tx.SUB.pack(s2, n_req, raw_len, enc_len))
                parts.append(arena[mpos:mpos + 16 * n_req].tobytes())
                parts.append(arena[wpos:wpos + enc_len].tobytes())
            d = serve_nodes[g]
            if d not in conns:
                sk = socket.create_connection(("127.0.0.1", ports[d]),
                                              timeout=WAIT_S)
                sk.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conns[d] = sk
            tx.send_msg(conns[d], b"".join(parts))

    fast_blocks: dict = {}
    dead: dict = {}
    procs = {}
    t0 = time.perf_counter()
    try:
        for s in senders:
            p = ctx.Process(target=_worker, args=(s,), daemon=True)
            p.start()
            procs[s] = p

        # ---- drain the control queue until every worker reported ----
        pending = set(senders)
        deadline = time.monotonic() + WAIT_S
        while pending:
            try:
                msg = ctrl.get(timeout=0.05)
            except queue_mod.Empty:
                for s in list(pending):
                    p = procs[s]
                    if not p.is_alive() and p.exitcode not in (0, None):
                        p.join()
                        dead[s] = p.exitcode
                        pending.discard(s)
                        if combined:
                            # unblock the leader's member wait
                            node_qs[sender_nodes[s]].put(("done", s))
                if time.monotonic() > deadline:
                    raise _Failed(
                        f"mp transport: workers hung: {sorted(pending)}")
                continue
            if msg[0] == "done":
                pending.discard(msg[1])
            else:
                _, s, g, r, off, nbytes = msg
                fast_blocks[(s, g, r)] = (off, nbytes)
                _note(r, time.perf_counter())
        for s, p in procs.items():
            p.join(WAIT_S)
            if p.is_alive():
                raise _Failed(f"mp transport: worker {s} did not exit")
        comm_wall = time.perf_counter() - t0
        stop.set()
        for lst in listeners.values():
            lst.close()
        for th in acceptors:
            th.join(WAIT_S)
        if recv_errors:
            raise _Failed(f"mp transport: receive failed: {recv_errors}")

        # ---- death audit + repair -----------------------------------
        unexpected = {s: code for s, code in dead.items()
                      if kill is None or s != kill[0]
                      or code != _KILL_EXIT}
        if unexpected:
            raise _Failed(
                f"mp transport: workers died: {unexpected}")
        repaired: set = set()
        if dead:
            t_rec = time.perf_counter()
            victim = next(iter(dead))
            victim_node = sender_nodes[victim]
            if heartbeat is not None:
                heartbeat.inject_failure(victim_node)
                assert victim_node in heartbeat.dead_hosts()
                detect_s = float(heartbeat.timeout_s)
            else:
                detect_s = float(faults.detection_s)
            # blocks whose responsible process died: the victim's own,
            # plus (TAM) everything its node's leader never combined
            for (s, g, r) in _expected_blocks(sched, senders):
                have = (s, g, r) in fast_blocks \
                    or (s, g, r) in slow_blocks
                if have:
                    continue
                leader_dead = combined and \
                    leaders[sender_nodes[s]] in dead
                if s not in dead and not leader_dead:
                    raise _Failed(f"mp transport: block ({s},{g},{r}) "
                                  "missing from a live worker")
                repaired.add((s, g, r))
            t.recovery_seconds += detect_s \
                + (time.perf_counter() - t_rec)
        else:
            missing = [k for k in _expected_blocks(sched, senders)
                       if k not in fast_blocks and k not in slow_blocks]
            if missing:
                raise _Failed(f"mp transport: blocks missing with all "
                              f"workers healthy: {missing[:4]}")

        # ---- reassemble the per-domain inboxes (host sender order) --
        ga_inbox: list[list] = [[] for _ in range(stripe_count)]
        raw_total = wire_total = fast_bytes = 0
        dec_wall = 0.0
        for s in senders:
            for g, po, pl, seg_starts, rounds in sched[s]:
                pd = np.zeros(int(pl.sum()), np.uint8)
                for r in sorted(rounds):
                    in_r, payload = rounds[r]
                    if (s, g, r) in fast_blocks:
                        off, nbytes = fast_blocks[(s, g, r)]
                        src = arenas[sender_nodes[s]][off:off + nbytes]
                        fast_bytes += nbytes
                    elif (s, g, r) in slow_blocks:
                        rpo, rpl, wire, raw_len = slow_blocks[(s, g, r)]
                        if not (np.array_equal(rpo, po[in_r])
                                and np.array_equal(rpl, pl[in_r])):
                            raise _Failed(
                                f"mp transport: pair metadata mismatch "
                                f"for block ({s},{g},{r})")
                        wire_arr = np.frombuffer(wire, np.uint8)
                        if codec is not None:
                            d0 = time.perf_counter()
                            src = np.asarray(
                                codec.decode_bytes(wire_arr), np.uint8)
                            dec_wall += time.perf_counter() - d0
                            raw_total += int(raw_len)
                            wire_total += int(wire_arr.size)
                        else:
                            src = wire_arr
                        if src.size != raw_len:
                            raise _Failed(
                                f"mp transport: block ({s},{g},{r}) "
                                f"decoded to {src.size} != {raw_len}")
                    else:        # repaired from the parent's stage-1 copy
                        assert (s, g, r) in repaired
                        src = payload
                    pos = 0
                    for st, ln in zip(seg_starts[in_r], pl[in_r]):
                        pd[st:st + ln] = src[pos:pos + ln]
                        pos += ln
                ga_inbox[g].append((po, pl, pd))

        # ---- measured timings ---------------------------------------
        t.transport = "mp"
        t.rounds_executed = n_rounds
        comm_rounds = _round_walls(arrival, n_rounds, t0)
        if not arrival:           # everything landed before first stamp
            comm_rounds[-1:] = [comm_wall] if n_rounds else []
        t.comm_rounds = tuple(comm_rounds)
        t.inter_comm = float(sum(comm_rounds))
        t.messages_at_ga = int((ga_msgs + ga_msgs_fast).max(initial=0))
        t.placement = plan.placement
        t.slow_hop_fast_bytes = int(fast_bytes)
        t.slow_hop_slow_bytes = int(wire_slow[0])
        t.node_bytes = tuple(tuple(int(b) for b in row)
                             for row in node_bytes)
        if codec is not None:
            t.slow_hop_codec = codec.name
            t.slow_hop_raw_bytes = int(raw_total)
            t.slow_hop_wire_bytes = int(wire_total)
            t.codec = float(dec_wall)
        t.serve_map = serve if serve_map is not None else None
        t.retries = 0

        # ---- sort + drain (the host oracle's exact byte path) -------
        depth = plan.pipeline_depth
        multi_window = n_rounds > 1
        img_lens = np.zeros(stripe_count, np.int64)
        segs = []
        for g in range(stripe_count):
            offs, lens, packed, n_cmp = merge_coalesce(ga_inbox[g])
            t.inter_sort = max(t.inter_sort, m.sort_per_cmp * n_cmp)
            segs.append(domain_image(offs, lens, packed, g, stripe_size,
                                     stripe_count))
            img_lens[g] = segs[-1].size
        io_wall = np.zeros(stripe_count)
        for g in range(stripe_count):
            cbw = cb if multi_window and depth > 1 else None
            w0 = time.perf_counter()
            write_segment(f"{path}.seg{g}", segs[g], cbw, depth=depth)
            io_wall[g] = time.perf_counter() - w0
        # split each segment's measured drain wall across its windows
        # by byte share, for the session's per-round feedback arrays
        lo = np.arange(n_rounds, dtype=np.int64) * cb
        share = np.clip(img_lens[:, None] - lo[None, :], 0, cb) \
            .astype(np.float64)
        tot = share.sum(axis=1, keepdims=True)
        share = np.divide(share, np.where(tot == 0, 1, tot))
        io_rounds = (share * io_wall[:, None]).sum(axis=0)
        t.io = float(io_wall.sum())
        t.io_rounds = tuple(float(x) for x in io_rounds)
        if depth_request == "auto" and multi_window:
            depth, _ = optimal_depth(
                round_times=(np.asarray(comm_rounds), io_rounds))
        t.pipeline_depth = max(1, min(depth, n_rounds))
        return t
    finally:
        stop.set()
        for lst in listeners.values():
            try:
                lst.close()
            except OSError:
                pass
        for p in procs.values():
            if p.is_alive():
                p.terminate()
                p.join(1.0)
        # drop every parent-side view of the arenas so close() can
        # release the exported buffer (otherwise __del__ whines)
        src = None
        arenas.clear()
        for shm in shms.values():
            try:
                shm.close()
            except BufferError:
                pass       # a view survived anyway; unlink suffices
            try:
                shm.unlink()
            except (OSError, FileNotFoundError):
                pass


def _expected_blocks(sched, senders):
    for s in senders:
        for g, _, _, _, rounds in sched[s]:
            for r in rounds:
                yield (s, g, r)


def execute_read(plan, machine, rank_requests, path, t, *, n_nodes,
                 ranks_per_node, depth_request=None, node_cache=True,
                 serve_map=None, faults=None):
    """Run a read plan on real reader processes (the write's mirror).

    Same signature and byte contract as
    :func:`repro.checkpoint.host_exec.execute_read`. The parent does
    the ranged window reads (it owns the segment files), ships each
    needed window ONCE per (window, node) to that node's elected
    fetcher over a socket (``node_cache=True``; codec-encoded when the
    node is off the serving slot's node), the fetcher stages it in the
    node arena and fans it out to co-located readers through their
    queues, and each reader assembles its spans into a result arena.
    ``node_cache=False`` ships every window to every needing rank.
    """
    m = machine
    stripe_count, cb = plan.n_aggregators, plan.cb
    stripe_size = plan.layout.stripe_size
    n_rounds = plan.n_rounds
    codec = get_codec(plan.slow_hop_codec) if plan.slow_hop_codec else None
    if faults is not None:
        raise ValueError("mp transport: fault injection is write-side "
                         "only (worker kill); reads take faults=None")
    serve, serve_nodes = _serve_of(plan, serve_map, stripe_count, n_nodes)

    # ---- demand map (host_exec.execute_read, verbatim semantics) -----
    win_need: dict = {}
    win_spans: dict = {}
    rank_spans = []
    node_bytes = np.zeros((stripe_count, n_nodes), np.int64)
    for rank, (offs, lens) in enumerate(rank_requests):
        nd = rank // ranks_per_node
        spans = []
        out_pos = 0
        for o, ln in zip(np.asarray(offs, np.int64),
                         np.asarray(lens, np.int64)):
            g = int((o // stripe_size) % stripe_count)
            dl = int(to_domain_local(o, stripe_size, stripe_count))
            node_bytes[g, nd] += int(ln)
            pos = 0
            while pos < ln:
                r = (dl + pos) // cb
                take = int(min(ln - pos, (r + 1) * cb - (dl + pos)))
                wo = int(dl + pos - r * cb)
                spans.append((g, int(r), wo, take, out_pos + pos))
                win_spans.setdefault((g, int(r)), []).append((wo, take))
                per_rank = (win_need.setdefault((g, int(r)), {})
                            .setdefault(nd, {}))
                per_rank[rank] = per_rank.get(rank, 0) + take
                pos += take
            out_pos += int(ln)
        rank_spans.append((spans, out_pos))

    # ---- ranged reads: the parent owns the disk ----------------------
    needed_gs = sorted({g for g, _ in win_need})
    for g in needed_gs:
        if os.path.exists(partial_marker(f"{path}.seg{g}")):
            raise TornWriteError(f"{path}.seg{g}", -1, -1)
    seg_len = {g: (os.path.getsize(f"{path}.seg{g}")
                   if os.path.exists(f"{path}.seg{g}") else 0)
               for g in needed_gs}
    windows: dict = {}
    io_arrival: dict = {}
    t_io0 = time.perf_counter()
    handles = {g: (open(f"{path}.seg{g}", "rb") if seg_len[g] else None)
               for g in needed_gs}
    try:
        for (g, r) in sorted(win_need):
            base = r * cb
            buf = np.zeros(cb, np.uint8)
            runs = []
            for wo, take in sorted(win_spans[(g, r)]):
                if runs and wo <= runs[-1][1]:
                    runs[-1][1] = max(runs[-1][1], wo + take)
                else:
                    runs.append([wo, wo + take])
            for lo_, hi in runs:
                hi_f = min(base + hi, seg_len[g])
                take = hi_f - (base + lo_)
                if take > 0:
                    handles[g].seek(base + lo_)
                    buf[lo_:lo_ + take] = np.frombuffer(
                        handles[g].read(take), np.uint8)
                    t.read_bytes += int(take)
            windows[(g, r)] = buf
            io_arrival[r] = time.perf_counter()
    finally:
        for f in handles.values():
            if f is not None:
                f.close()
    io_rounds = _round_walls(io_arrival, n_rounds, t_io0)

    # ---- codec: encode once at the serving side; every consumer sees
    # the round-tripped window (host oracle identity) ------------------
    enc_wire: dict = {}
    raw_total = wire_total = 0
    for (g, r), per_node in sorted(win_need.items()):
        if codec is not None and any(serve_nodes[g] != nd
                                     for nd in per_node):
            wire = np.asarray(codec.encode_bytes(windows[(g, r)]),
                              np.uint8)
            windows[(g, r)] = np.asarray(
                codec.decode_bytes(wire), np.uint8)
            enc_wire[(g, r)] = wire
            raw_total += int(windows[(g, r)].size)
            wire_total += int(wire.size)

    # ---- fetch plan: one elected fetcher per (window, node) ----------
    fetch_of: dict = {}
    readers_of: dict = {}
    stage_bytes = np.zeros(n_nodes, np.int64)
    for (g, r), per_node in sorted(win_need.items()):
        for nd, readers in sorted(per_node.items()):
            readers_of[(g, r, nd)] = sorted(readers)
            if node_cache:
                fetch_of[(g, r, nd)] = min(readers)
                t.cache_misses += 1
                t.cache_hits += len(readers) - 1
                stage_bytes[nd] += cb
            else:
                t.cache_misses += len(readers)
    slot_of: dict = {}
    slots_per_node = {nd: 0 for nd in range(n_nodes)}
    if node_cache:
        for (g, r, nd) in sorted(fetch_of):
            slot_of[(g, r, nd)] = slots_per_node[nd]
            slots_per_node[nd] += 1

    worker_ranks = [rank for rank, (spans, total) in
                    enumerate(rank_spans) if spans]
    needed: dict = {}
    spans_by_win: dict = {}
    for rank in worker_ranks:
        spans, _ = rank_spans[rank]
        wins = sorted({(g, r) for g, r, _, _, _ in spans})
        needed[rank] = wins
        for g, r, wo, ln, op in spans:
            spans_by_win.setdefault((rank, g, r), []).append(
                (wo, ln, op))

    # frames each rank receives over its socket, in global window order
    to_rank: dict = {rank: [] for rank in worker_ranks}
    for (g, r) in sorted(win_need):
        for nd in sorted(win_need[(g, r)]):
            if node_cache:
                to_rank[fetch_of[(g, r, nd)]].append((g, r, nd))
            else:
                for rank in readers_of[(g, r, nd)]:
                    to_rank[rank].append((g, r, nd))

    ctx = _ctx()
    res_off = {}
    res_total = 0
    for rank, (spans, total) in enumerate(rank_spans):
        res_off[rank] = res_total
        res_total += total
    res_shm = shared_memory.SharedMemory(create=True,
                                         size=max(res_total, 1))
    res_arena = np.frombuffer(res_shm.buf, np.uint8)
    cache_shms = {nd: shared_memory.SharedMemory(
        create=True, size=max(slots_per_node.get(nd, 0) * cb, 1))
        for nd in range(n_nodes)} if node_cache else {}
    cache_arenas = {nd: np.frombuffer(shm.buf, np.uint8)
                    for nd, shm in cache_shms.items()}
    rank_qs = {rank: ctx.Queue() for rank in worker_ranks}
    ctrl = ctx.Queue()

    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(len(worker_ranks) + 1)
    lst.settimeout(WAIT_S)
    port = lst.getsockname()[1]

    def _reader(rank):
        nd = rank // ranks_per_node
        sk = socket.create_connection(("127.0.0.1", port),
                                      timeout=WAIT_S)
        try:
            sk.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sk.settimeout(WAIT_S)
            sk.sendall(struct.pack("!I", rank))
            _, total = rank_spans[rank]
            buf = np.zeros(total, np.uint8)
            stash: set = set()
            for (g, r) in needed[rank]:
                mine = (not node_cache) or fetch_of[(g, r, nd)] == rank
                if mine:
                    body = tx.recv_msg(sk)
                    kind, _, g2, r2, _, _, wire, _ = \
                        tx.unpack_block(body)
                    if (g2, r2) != (g, r):
                        raise ConnectionError(
                            f"rank {rank}: window ({g2},{r2}) arrived, "
                            f"({g},{r}) expected")
                    warr = np.frombuffer(wire, np.uint8)
                    win = (np.asarray(codec.decode_bytes(warr), np.uint8)
                           if kind & tx.FLAG_ENCODED else warr)
                    if node_cache:
                        slot = slot_of[(g, r, nd)]
                        cache_arenas[nd][slot * cb:slot * cb + cb] = win
                        for rk in readers_of[(g, r, nd)]:
                            if rk != rank:
                                rank_qs[rk].put((g, r))
                        src = cache_arenas[nd][slot * cb:slot * cb + cb]
                    else:
                        src = win
                else:
                    while (g, r) not in stash:
                        stash.add(rank_qs[rank].get(timeout=WAIT_S))
                    slot = slot_of[(g, r, nd)]
                    src = cache_arenas[nd][slot * cb:slot * cb + cb]
                for wo, ln, op in spans_by_win[(rank, g, r)]:
                    buf[op:op + ln] = src[wo:wo + ln]
            off = res_off[rank]
            res_arena[off:off + total] = buf
            ctrl.put(("done", rank))
        finally:
            sk.close()

    conns: dict = {}
    send_errors: list = []
    arrival: dict = {}
    wire_slow = [0]
    wire_fast = [0]
    lock = threading.Lock()

    def _send_to(rank, conn):
        try:
            conn.settimeout(WAIT_S)
            for (g, r, nd) in to_rank[rank]:
                enc = (g, r) in enc_wire and nd != serve_nodes[g]
                payload = (enc_wire[(g, r)] if enc
                           else windows[(g, r)])
                kind = tx.KIND_WINDOW | (tx.FLAG_ENCODED if enc else 0)
                body = tx.pack_block(
                    kind, rank, g, r, np.zeros(0, np.int64),
                    np.zeros(0, np.int64), payload.tobytes(), cb)
                n = tx.send_msg(conn, body)
                with lock:
                    (wire_slow if nd != serve_nodes[g]
                     else wire_fast)[0] += n
                    if arrival.get(r, 0.0) < time.perf_counter():
                        arrival[r] = time.perf_counter()
        except (OSError, ConnectionError) as e:
            send_errors.append((rank, e))

    procs = {}
    t0 = time.perf_counter()
    try:
        for rank in worker_ranks:
            p = ctx.Process(target=_reader, args=(rank,), daemon=True)
            p.start()
            procs[rank] = p
        senders_th = []
        for _ in worker_ranks:
            conn, _ = lst.accept()
            (rank,) = struct.unpack("!I", tx.recv_exact(conn, 4))
            conns[rank] = conn
            th = threading.Thread(target=_send_to, args=(rank, conn))
            th.start()
            senders_th.append(th)
        for th in senders_th:
            th.join(WAIT_S)
        pending = set(worker_ranks)
        deadline = time.monotonic() + WAIT_S
        while pending:
            try:
                msg = ctrl.get(timeout=0.05)
            except queue_mod.Empty:
                for rank in list(pending):
                    p = procs[rank]
                    if not p.is_alive() and p.exitcode not in (0, None):
                        raise _Failed(f"mp transport: reader {rank} "
                                      f"died (exit {p.exitcode})")
                if time.monotonic() > deadline:
                    raise _Failed(
                        f"mp transport: readers hung: {sorted(pending)}")
                continue
            pending.discard(msg[1])
        for p in procs.values():
            p.join(WAIT_S)
        if send_errors:
            raise _Failed(f"mp transport: window send failed: "
                          f"{send_errors}")
        if arrival:
            arrival[max(arrival)] = max(arrival[max(arrival)],
                                        time.perf_counter())

        outs = []
        for rank, (spans, total) in enumerate(rank_spans):
            off = res_off[rank]
            outs.append(np.array(res_arena[off:off + total]))

        # ---- measured + counted timings -----------------------------
        t.transport = "mp"
        t.rounds_executed = n_rounds
        comm_rounds = _round_walls(arrival, n_rounds, t0)
        t.comm_rounds = tuple(comm_rounds)
        t.inter_comm = float(sum(comm_rounds))
        t.io_rounds = tuple(io_rounds)
        t.io = float(sum(io_rounds))
        ga_msgs = np.zeros((stripe_count, n_rounds), np.int64)
        ga_msgs_fast = np.zeros((stripe_count, n_rounds), np.int64)
        for (g, r), per_node in win_need.items():
            for nd, readers in per_node.items():
                n_f = 1 if node_cache else len(readers)
                if nd == serve_nodes[g]:
                    ga_msgs_fast[g, r] += n_f
                else:
                    ga_msgs[g, r] += n_f
        t.messages_at_ga = int((ga_msgs + ga_msgs_fast).max(initial=0))
        t.placement = plan.placement
        t.slow_hop_slow_bytes = int(wire_slow[0])
        t.slow_hop_fast_bytes = int(wire_fast[0])
        t.node_bytes = tuple(tuple(int(b) for b in row)
                             for row in node_bytes)
        t.intra_memcpy = float(stage_bytes.max(initial=0)) / m.memcpy_bw
        if codec is not None:
            t.slow_hop_codec = codec.name
            t.slow_hop_raw_bytes = int(raw_total)
            t.slow_hop_wire_bytes = int(wire_total)
        t.serve_map = serve if serve_map is not None else None
        depth = plan.pipeline_depth
        if depth_request == "auto" and n_rounds > 1:
            depth, _ = optimal_depth(round_times=(
                np.asarray(comm_rounds), np.asarray(io_rounds)))
        t.pipeline_depth = max(1, min(depth, n_rounds))
        return outs
    finally:
        try:
            lst.close()
        except OSError:
            pass
        for conn in conns.values():
            try:
                conn.close()
            except OSError:
                pass
        for p in procs.values():
            if p.is_alive():
                p.terminate()
                p.join(1.0)
        # drop every parent-side view so close() can release the buffer
        res_arena = None
        cache_arenas.clear()
        for shm in list(cache_shms.values()) + [res_shm]:
            try:
                shm.close()
            except BufferError:
                pass       # a view survived anyway; unlink suffices
            try:
                shm.unlink()
            except (OSError, FileNotFoundError):
                pass
