"""Host executor: runs a compiled :class:`repro.core.plan.IOPlan` with
real numpy data movement and modeled alpha-beta timing.

One of the two interchangeable backends of the plan/executor split
(ARCHITECTURE.md); the other is ``repro.core.spmd_exec``. The plan is
compiled by the SAME planner (``HostCollectiveIO.plan_for`` routes
through ``repro.core.plan.compile_plan``, byte units), so the window
schedule the host drains is the one the SPMD ring would run.

What is real vs modeled here: bytes are REAL — requests are merged,
coalesced, and packed with numpy and every segment file on disk is
byte-identical whatever the schedule (single shot, rounds, any ring
depth). TIME is modeled — the per-round incast latency
``alpha_eff(senders)``, the beta byte costs, and the depth-k pipeline
makespan (``cost_model.pipeline_span``, the exact bounded-buffer
recurrence over the MEASURED per-round comm/drain arrays). The drain
itself is physical too: with a multi-round plan each segment is written
through a background writer thread fed one cb window at a time through
a ring of ``depth - 1`` queue slots.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core import placement as placement_mod
from repro.core.codec import get_codec
from repro.core.cost_model import Machine, optimal_depth, pipeline_span
from repro.core.plan import IOPlan

PAIR_BYTES = 8  # offset + length metadata per request


def to_domain_local(offs, stripe_size: int, stripe_count: int):
    """Byte position inside the owning GA's domain image (its stripes
    concatenated in round order) — mirrors ``domains.to_domain_local``."""
    return ((offs // stripe_size) // stripe_count) * stripe_size \
        + offs % stripe_size


def merge_coalesce(reqs: list[tuple[np.ndarray, np.ndarray, np.ndarray]]):
    """Merge per-sender (offsets, lengths, payload), sort, coalesce.

    Returns (offsets, lengths, payload) with payload packed in sorted
    offset order (contiguous per coalesced run). Comparisons counted for
    the sort-time model.
    """
    offs = np.concatenate([r[0] for r in reqs]) if reqs else np.zeros(0, np.int64)
    lens = np.concatenate([r[1] for r in reqs]) if reqs else np.zeros(0, np.int64)
    data = np.concatenate([r[2] for r in reqs]) if reqs else np.zeros(0, np.uint8)
    if offs.size == 0:
        return offs, lens, data, 0
    order = np.argsort(offs, kind="stable")
    offs, lens = offs[order], lens[order]
    starts = np.concatenate([[0], np.cumsum(
        np.concatenate([r[1] for r in reqs]))[:-1]])
    packed = np.concatenate([
        data[starts[i]:starts[i] + lens_orig]
        for i, lens_orig in zip(order, lens)]) if data.size else data
    # coalesce adjacent contiguous runs
    boundary = np.ones(offs.size, bool)
    boundary[1:] = offs[1:] != offs[:-1] + lens[:-1]
    run = np.cumsum(boundary) - 1
    out_offs = offs[boundary]
    out_lens = np.bincount(run, weights=lens).astype(np.int64)
    n_cmp = int(offs.size * max(np.log2(max(len(reqs), 2)), 1))
    return out_offs, out_lens, packed, n_cmp


def domain_image(offs, lens, packed, g, stripe_size, stripe_count):
    """Dense image of aggregator g's file domain (its stripes, in round
    order), mirroring core.domains.to_domain_local."""
    if offs.size == 0:
        return np.zeros(0, np.uint8)
    rounds = (offs // stripe_size) // stripe_count
    n_rounds = int(rounds.max()) + 1
    img = np.zeros(n_rounds * stripe_size, np.uint8)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    locals_ = to_domain_local(offs, stripe_size, stripe_count)
    for o, l, s in zip(locals_, lens, starts):
        img[o:o + l] = packed[s:s + l]
    return img


def write_segment(path: str, seg: np.ndarray, cb_bytes: int | None,
                  depth: int = 2) -> None:
    """Write one segment file; with ``cb_bytes`` smaller than the
    segment, drain it through a background writer thread fed one cb
    window at a time through ``depth - 1`` queue slots (mirroring the
    SPMD ring's ``depth`` in-flight window buffers: the producer can
    run up to depth-1 windows ahead of the writer). A single consumer
    writes the windows in order, so the bytes on disk are identical to
    the direct write for every depth."""
    if cb_bytes is None or seg.size <= cb_bytes or depth <= 1:
        with open(path, "wb") as f:
            f.write(seg.tobytes())
        return
    q: queue.Queue = queue.Queue(maxsize=max(depth - 1, 1))
    error: list[BaseException] = []

    def drain(f):
        # on a write error, keep consuming (and discarding) so the
        # producer's q.put never blocks on a dead consumer; the error
        # re-raises in the producer after join
        while True:
            chunk = q.get()
            if chunk is None:
                return
            if not error:
                try:
                    f.write(chunk)
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    error.append(e)

    with open(path, "wb") as f:
        th = threading.Thread(target=drain, args=(f,))
        th.start()
        try:
            for lo in range(0, int(seg.size), cb_bytes):
                q.put(seg[lo:lo + cb_bytes].tobytes())
        finally:
            q.put(None)
            th.join()
    if error:
        raise error[0]


def execute_write(plan: IOPlan, machine: Machine, per_la, path: str, t,
                  depth_request=None, sender_nodes=None,
                  n_nodes: int | None = None):
    """Run the inter-node exchange + I/O step of a write plan.

    per_la: the stage-1 output — per local aggregator (per rank for
    two-phase) ``(offsets, lengths, packed)`` in BYTE units, already
    split at stripe boundaries. ``t`` is the :class:`IOTimings` being
    filled (stage-1 fields already set by the caller).

    The round partition comes from the plan: round r covers
    domain-local bytes ``[r*cb, (r+1)*cb)`` of every GA (the 1-round
    plan with ``cb == domain_len`` IS the single shot). Padding rounds
    past the occupied extent receive zero messages and cost nothing —
    the makespan is invariant to them.

    depth_request: ``None`` executes the plan's resolved depth;
    ``"auto"`` re-resolves against the MEASURED per-round comm/drain
    arrays via ``cost_model.optimal_depth`` (the planner's uniform
    model cannot distinguish depths > 2 — the measurement can).

    With ``plan.slow_hop_codec`` set (lossless byte codecs only — the
    payloads here are raw bytes), every slow-hop payload passes through
    a REAL ``encode_bytes``/``decode_bytes`` round trip, the per-round
    incast charges the ENCODED sizes against ``alpha_eff``/beta, the
    encode+decode scan is charged at ``machine.codec_bw``, and the
    achieved raw/wire ratio is reported
    (``IOTimings.slow_hop_compression_ratio``).

    sender_nodes: per ``per_la`` entry, the compute node the sender
    lives on. When given (the placement-aware path — the caller
    requested a ``placement``), the per-round incast is charged against
    the PLACEMENT-INDUCED sender sets: a message whose sender shares
    the serving aggregator's node (``plan.placement`` through the
    canonical slot->node map, ``core.placement.node_of_slot``) moves at
    the fast intra rates (``alpha_intra``/``beta_intra``, no incast
    knee); the rest pay ``alpha_eff``/``beta_inter`` as before. The
    measured per-(domain, sender-node) byte matrix is reported
    (``IOTimings.node_bytes``) so a session can re-resolve
    ``placement="auto"`` exactly. ``None`` keeps the legacy all-inter
    accounting (bit-identical timings to the pre-placement executor).
    """
    m = machine
    stripe_count, cb = plan.n_aggregators, plan.cb
    stripe_size = plan.layout.stripe_size
    n_rounds = plan.n_rounds
    codec = get_codec(plan.slow_hop_codec) if plan.slow_hop_codec else None
    raw_total = wire_total = 0
    ga_nodes = None
    if sender_nodes is not None:
        if n_nodes is None:
            n_nodes = int(max(sender_nodes, default=0)) + 1
        perm = (plan.placement if plan.placement is not None
                else tuple(range(stripe_count)))
        ga_nodes = [placement_mod.node_of_slot(perm[g], stripe_count,
                                               n_nodes)
                    for g in range(stripe_count)]
        node_bytes = np.zeros((stripe_count, n_nodes), np.int64)

    # ---- inter-node: local aggregators -> global aggregators ---------
    ga_inbox: list[list] = [[] for _ in range(stripe_count)]
    ga_msgs = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes = np.zeros((stripe_count, n_rounds), np.int64)
    ga_msgs_fast = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes_fast = np.zeros((stripe_count, n_rounds), np.int64)
    for sender, (offs, lens, packed) in enumerate(per_la):
        if offs.size == 0:
            continue
        s_node = sender_nodes[sender] if sender_nodes is not None else None
        owner = (offs // stripe_size) % stripe_count
        rnd = to_domain_local(offs, stripe_size, stripe_count) // cb
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        for g in range(stripe_count):
            sel = owner == g
            if not sel.any():
                continue
            fast = s_node is not None and ga_nodes[g] == s_node
            po = offs[sel]
            pl = lens[sel]
            pd = np.concatenate([packed[s:s + l] for s, l in
                                 zip(starts[sel], pl)])
            seg_starts = np.concatenate([[0], np.cumsum(pl)[:-1]])
            if s_node is not None:
                node_bytes[g, s_node] += int(pl.sum())
            for r in np.unique(rnd[sel]):
                in_r = rnd[sel] == r
                (ga_msgs_fast if fast else ga_msgs)[g, r] += 1
                payload = int(pl[in_r].sum())
                if codec is not None:
                    # one encode per byte: round r's slice is encoded
                    # for the wire accounting AND its decode is
                    # scattered back in place, so the bytes the GA
                    # sees are the ones that survived the round trip
                    # (byte-identical for the lossless codecs this
                    # path admits)
                    raw = (np.concatenate(
                        [pd[s:s + l] for s, l in zip(seg_starts[in_r],
                                                     pl[in_r])])
                        if payload else np.zeros(0, np.uint8))
                    wire = codec.encode_bytes(raw)
                    dec = codec.decode_bytes(wire)
                    pos = 0
                    for s, l in zip(seg_starts[in_r], pl[in_r]):
                        pd[s:s + l] = dec[pos:pos + l]
                        pos += l
                    raw_total += raw.size
                    wire_total += wire.size
                    payload = wire.size        # the wire moves encoded
                (ga_bytes_fast if fast else ga_bytes)[g, r] += \
                    payload + int(in_r.sum()) * PAIR_BYTES
            ga_inbox[g].append((po, pl, pd))
    t.rounds_executed = n_rounds
    if codec is not None:
        t.slow_hop_codec = codec.name
        t.slow_hop_raw_bytes = int(raw_total)
        t.slow_hop_wire_bytes = int(wire_total)
        t.codec = float(raw_total + wire_total) / m.codec_bw
    t.messages_at_ga = int((ga_msgs + ga_msgs_fast).max(initial=0))
    if ga_nodes is not None:
        t.placement = plan.placement
        t.slow_hop_fast_bytes = int(ga_bytes_fast.sum())
        t.slow_hop_slow_bytes = int(ga_bytes.sum())
        t.node_bytes = tuple(tuple(int(b) for b in row)
                             for row in node_bytes)
    # per-round incast: a receiver with S concurrent SLOW senders pays
    # alpha_eff(S) each (cost_model refinement 2, applied to the
    # single-shot exchange too so the timings are comparable); the
    # placement-induced FAST senders (same node as the serving
    # aggregator) pay alpha_intra/beta_intra instead — no incast knee
    # inside a node. Rounds serialize unless pipelined (below).
    alpha = np.vectorize(m.alpha_eff)(ga_msgs) * ga_msgs \
        + m.alpha_intra * ga_msgs_fast
    comm_rounds = (alpha + m.beta_inter * ga_bytes
                   + m.beta_intra * ga_bytes_fast).max(axis=0, initial=0)
    t.inter_comm = float(comm_rounds.sum())

    # ---- pipeline depth: the plan's pick, or re-resolved against the
    # measured rounds ---------------------------------------------------
    depth = plan.pipeline_depth
    multi_window = n_rounds > 1

    # ---- I/O step: sort + write segments ------------------------------
    img_lens = np.zeros(stripe_count, np.int64)
    segs = []
    for g in range(stripe_count):
        offs, lens, packed, n_cmp = merge_coalesce(ga_inbox[g])
        t.inter_sort = max(t.inter_sort, m.sort_per_cmp * n_cmp)
        segs.append(domain_image(offs, lens, packed, g, stripe_size,
                                 stripe_count))
        img_lens[g] = segs[-1].size
    t.io = float(img_lens.sum()) / m.io_bw

    # bytes GA g drains in round r: its image's overlap with the
    # window [r*cb, (r+1)*cb)
    lo = np.arange(n_rounds, dtype=np.int64) * cb
    io_rounds = (np.clip(img_lens[:, None] - lo[None, :], 0, cb)
                 .sum(axis=0) / m.io_bw)
    if depth_request == "auto" and multi_window:
        depth, _ = optimal_depth(round_times=(comm_rounds, io_rounds))
    t.pipeline_depth = max(1, min(depth, n_rounds))  # executed in-flight
    # measured per-round arrays: what a session feeds back into the
    # next write's "auto" resolutions (cost_model.optimal_depth runs
    # on exactly these)
    t.comm_rounds = tuple(float(c) for c in comm_rounds)
    t.io_rounds = tuple(float(i) for i in io_rounds)

    for g in range(stripe_count):
        write_segment(f"{path}.seg{g}", segs[g],
                      cb if multi_window and depth > 1 else None,
                      depth=depth)

    # ---- pipelined makespan: the depth-k bounded-buffer recurrence
    # over the measured per-round arrays; the prologue (first exchange)
    # and epilogue (last drain) stay exposed ----------------------------
    if depth > 1 and n_rounds > 0:
        serial = float(comm_rounds.sum() + io_rounds.sum())
        span = pipeline_span(comm_rounds, io_rounds, depth)
        t.overlap_saved = max(serial - span, 0.0)
        hideable = (float(min(comm_rounds[1:].sum(),
                              io_rounds[:-1].sum()))
                    if n_rounds > 1 else 0.0)
        t.overlap_fraction = (min(t.overlap_saved / hideable, 1.0)
                              if hideable > 0 else 0.0)
    return t
