"""Host executor: runs a compiled :class:`repro.core.plan.IOPlan` with
real numpy data movement and modeled alpha-beta timing.

One of the two interchangeable backends of the plan/executor split
(ARCHITECTURE.md); the other is ``repro.core.spmd_exec``. The plan is
compiled by the SAME planner (``HostCollectiveIO.plan_for`` routes
through ``repro.core.plan.compile_plan``, byte units), so the window
schedule the host drains is the one the SPMD ring would run.

What is real vs modeled here: bytes are REAL — requests are merged,
coalesced, and packed with numpy and every segment file on disk is
byte-identical whatever the schedule (single shot, rounds, any ring
depth). TIME is modeled — the per-round incast latency
``alpha_eff(senders)``, the beta byte costs, and the depth-k pipeline
makespan (``cost_model.pipeline_span``, the exact bounded-buffer
recurrence over the MEASURED per-round comm/drain arrays). The drain
itself is physical too: with a multi-round plan each segment is written
through a background writer thread fed one cb window at a time through
a ring of ``depth - 1`` queue slots.
"""
from __future__ import annotations

import os
import queue
import threading

import numpy as np

from repro.core import placement as placement_mod
from repro.core.codec import get_codec
from repro.core.cost_model import Machine, optimal_depth, pipeline_span
from repro.core.faults import (TornWriteError, UnrecoverableFaultError,
                               measure_node_slowdown, partial_marker,
                               repair_map)
from repro.core.plan import IOPlan

PAIR_BYTES = 8  # offset + length metadata per request


def to_domain_local(offs, stripe_size: int, stripe_count: int):
    """Byte position inside the owning GA's domain image (its stripes
    concatenated in round order) — mirrors ``domains.to_domain_local``."""
    return ((offs // stripe_size) // stripe_count) * stripe_size \
        + offs % stripe_size


def merge_coalesce(reqs: list[tuple[np.ndarray, np.ndarray, np.ndarray]]):
    """Merge per-sender (offsets, lengths, payload), sort, coalesce.

    Returns (offsets, lengths, payload) with payload packed in sorted
    offset order (contiguous per coalesced run). Comparisons counted for
    the sort-time model.
    """
    offs = np.concatenate([r[0] for r in reqs]) if reqs else np.zeros(0, np.int64)
    lens = np.concatenate([r[1] for r in reqs]) if reqs else np.zeros(0, np.int64)
    data = np.concatenate([r[2] for r in reqs]) if reqs else np.zeros(0, np.uint8)
    if offs.size == 0:
        return offs, lens, data, 0
    order = np.argsort(offs, kind="stable")
    offs, lens = offs[order], lens[order]
    starts = np.concatenate([[0], np.cumsum(
        np.concatenate([r[1] for r in reqs]))[:-1]])
    packed = np.concatenate([
        data[starts[i]:starts[i] + lens_orig]
        for i, lens_orig in zip(order, lens)]) if data.size else data
    # coalesce adjacent contiguous runs
    boundary = np.ones(offs.size, bool)
    boundary[1:] = offs[1:] != offs[:-1] + lens[:-1]
    run = np.cumsum(boundary) - 1
    out_offs = offs[boundary]
    out_lens = np.bincount(run, weights=lens).astype(np.int64)
    n_cmp = int(offs.size * max(np.log2(max(len(reqs), 2)), 1))
    return out_offs, out_lens, packed, n_cmp


def domain_image(offs, lens, packed, g, stripe_size, stripe_count):
    """Dense image of aggregator g's file domain (its stripes, in round
    order), mirroring core.domains.to_domain_local."""
    if offs.size == 0:
        return np.zeros(0, np.uint8)
    rounds = (offs // stripe_size) // stripe_count
    n_rounds = int(rounds.max()) + 1
    img = np.zeros(n_rounds * stripe_size, np.uint8)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    locals_ = to_domain_local(offs, stripe_size, stripe_count)
    for o, l, s in zip(locals_, lens, starts):
        img[o:o + l] = packed[s:s + l]
    return img


def write_segment(path: str, seg: np.ndarray, cb_bytes: int | None,
                  depth: int = 2, fail_after_windows: int | None = None
                  ) -> None:
    """Write one segment file; with ``cb_bytes`` smaller than the
    segment, drain it through a background writer thread fed one cb
    window at a time through ``depth - 1`` queue slots (mirroring the
    SPMD ring's ``depth`` in-flight window buffers: the producer can
    run up to depth-1 windows ahead of the writer). A single consumer
    writes the windows in order, so the bytes on disk are identical to
    the direct write for every depth.

    Failure semantics (fail fast): the producer checks the drain
    thread's error flag before EVERY enqueue and stops producing the
    moment the drain dies — it no longer pushes the remaining rounds
    into a dead consumer only to learn of the error after the final
    join. A failed write leaves the file truncated at the last complete
    window plus a ``<path>.partial`` marker (``faults.partial_marker``)
    so a reader/restart can DETECT the torn write instead of consuming
    a silently short segment, then raises :class:`TornWriteError`
    (original error as ``__cause__``).

    ``fail_after_windows`` is the fault-injection hook: the drain
    thread dies after writing that many windows (forcing the threaded
    path even for single-window segments), exercising exactly the
    fail-fast + marker path above.
    """
    inject = fail_after_windows is not None
    if not inject and (cb_bytes is None or seg.size <= cb_bytes
                       or depth <= 1):
        with open(path, "wb") as f:
            f.write(seg.tobytes())
        return
    if cb_bytes is None or cb_bytes <= 0:
        cb_bytes = max(int(seg.size), 1)
    q: queue.Queue = queue.Queue(maxsize=max(depth - 1, 1))
    error: list[BaseException] = []
    written = [0]

    def drain(f):
        # after an error, keep consuming (and discarding) so a
        # producer enqueue racing the error flag never blocks on a
        # dead consumer; the producer stops at its next check
        while True:
            chunk = q.get()
            if chunk is None:
                return
            if error:
                continue
            if inject and written[0] >= fail_after_windows:
                error.append(IOError(
                    f"injected drain fault after {written[0]} windows"))
                continue
            try:
                f.write(chunk)
                written[0] += 1
            except BaseException as e:  # noqa: BLE001 - re-raised below
                error.append(e)

    enqueued = 0
    with open(path, "wb") as f:
        th = threading.Thread(target=drain, args=(f,))
        th.start()
        try:
            for lo in range(0, int(seg.size), cb_bytes):
                if error:
                    break          # fail fast: drain died, stop feeding it
                q.put(seg[lo:lo + cb_bytes].tobytes())
                enqueued += 1
        finally:
            q.put(None)
            th.join()
    if error:
        with open(partial_marker(path), "w") as mf:
            mf.write(f"windows_written={written[0]}\n")
        raise TornWriteError(path, enqueued, written[0]) from error[0]


def execute_write(plan: IOPlan, machine: Machine, per_la, path: str, t,
                  depth_request=None, sender_nodes=None,
                  n_nodes: int | None = None, faults=None,
                  heartbeat=None, serve_map=None):
    """Run the inter-node exchange + I/O step of a write plan.

    per_la: the stage-1 output — per local aggregator (per rank for
    two-phase) ``(offsets, lengths, packed)`` in BYTE units, already
    split at stripe boundaries. ``t`` is the :class:`IOTimings` being
    filled (stage-1 fields already set by the caller).

    The round partition comes from the plan: round r covers
    domain-local bytes ``[r*cb, (r+1)*cb)`` of every GA (the 1-round
    plan with ``cb == domain_len`` IS the single shot). Padding rounds
    past the occupied extent receive zero messages and cost nothing —
    the makespan is invariant to them.

    depth_request: ``None`` executes the plan's resolved depth;
    ``"auto"`` re-resolves against the MEASURED per-round comm/drain
    arrays via ``cost_model.optimal_depth`` (the planner's uniform
    model cannot distinguish depths > 2 — the measurement can).

    With ``plan.slow_hop_codec`` set (lossless byte codecs only — the
    payloads here are raw bytes), every slow-hop payload passes through
    a REAL ``encode_bytes``/``decode_bytes`` round trip, the per-round
    incast charges the ENCODED sizes against ``alpha_eff``/beta, the
    encode+decode scan is charged at ``machine.codec_bw``, and the
    achieved raw/wire ratio is reported
    (``IOTimings.slow_hop_compression_ratio``).

    sender_nodes: per ``per_la`` entry, the compute node the sender
    lives on. When given (the placement-aware path — the caller
    requested a ``placement``), the per-round incast is charged against
    the PLACEMENT-INDUCED sender sets: a message whose sender shares
    the serving aggregator's node (``plan.placement`` through the
    canonical slot->node map, ``core.placement.node_of_slot``) moves at
    the fast intra rates (``alpha_intra``/``beta_intra``, no incast
    knee); the rest pay ``alpha_eff``/``beta_inter`` as before. The
    measured per-(domain, sender-node) byte matrix is reported
    (``IOTimings.node_bytes``) so a session can re-resolve
    ``placement="auto"`` exactly. ``None`` keeps the legacy all-inter
    accounting (bit-identical timings to the pre-placement executor).

    faults: a ``core.faults.FaultSpec`` — the injection hook. Injected
    node slowdowns scale everything the node serves (comm AND its drain
    share) and land in the measured ``IOTimings.node_slowdown``; lost
    messages charge a bounded-retry backoff (``IOTimings.retries``, or
    :class:`UnrecoverableFaultError` past ``max_retries``); a dead
    aggregator is detected through ``heartbeat.dead_hosts()`` (or
    ``faults.detection_s`` without a monitor), its domains re-route
    through ``faults.repair_map`` and replay their unfinished rounds
    (``IOTimings.recovery_seconds``, ``IOTimings.repair_map``), and the
    segment its drain tore is left partial + marked, then detected and
    rewritten (``IOTimings.torn_writes_detected``) — the bytes on disk
    stay byte-identical to the healthy run.

    serve_map: an execution-level domain->slot override (NOT required
    to be a bijection — ``core.faults.evacuation_map``): domains
    sharing a slot SERIALIZE on it, so per-round comm is the max over
    slots of the sum of their domains' times (reduces to the old
    max-over-domains for any bijection). The plan's placement stays
    bijective; this is how the session evacuates a straggler without
    perturbing the plan cache or the SPMD executors.
    """
    m = machine
    stripe_count, cb = plan.n_aggregators, plan.cb
    stripe_size = plan.layout.stripe_size
    n_rounds = plan.n_rounds
    codec = get_codec(plan.slow_hop_codec) if plan.slow_hop_codec else None
    raw_total = wire_total = 0
    if n_nodes is None and sender_nodes is not None:
        n_nodes = int(max(sender_nodes, default=0)) + 1
    if n_nodes is None and faults is not None and faults.any_node_faults:
        raise ValueError("node-level faults need n_nodes (or "
                         "sender_nodes) to locate the victims")
    perm = (plan.placement if plan.placement is not None
            else tuple(range(stripe_count)))
    if serve_map is not None:
        serve = tuple(int(s) for s in serve_map)
        if len(serve) != stripe_count or not all(
                0 <= s < stripe_count for s in serve):
            raise ValueError(f"serve_map {serve!r} must map each of "
                             f"{stripe_count} domains to a valid slot")
    else:
        serve = tuple(perm)
    serve_nodes = None
    if n_nodes is not None:
        serve_nodes = [placement_mod.node_of_slot(serve[g], stripe_count,
                                                  n_nodes)
                       for g in range(stripe_count)]
    slow_of = (lambda node: faults.slowdown(node)) if faults is not None \
        else (lambda node: 1.0)
    ga_nodes = None
    if sender_nodes is not None:
        ga_nodes = serve_nodes
        node_bytes = np.zeros((stripe_count, n_nodes), np.int64)

    # ---- inter-node: local aggregators -> global aggregators ---------
    ga_inbox: list[list] = [[] for _ in range(stripe_count)]
    ga_msgs = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes = np.zeros((stripe_count, n_rounds), np.int64)
    ga_msgs_fast = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes_fast = np.zeros((stripe_count, n_rounds), np.int64)
    # injected message faults: extra seconds charged to (domain, round)
    penalty = np.zeros((stripe_count, n_rounds))
    matched_lost: set[tuple[int, int]] = set()
    for sender, (offs, lens, packed) in enumerate(per_la):
        if offs.size == 0:
            continue
        s_node = sender_nodes[sender] if sender_nodes is not None else None
        owner = (offs // stripe_size) % stripe_count
        rnd = to_domain_local(offs, stripe_size, stripe_count) // cb
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        for g in range(stripe_count):
            sel = owner == g
            if not sel.any():
                continue
            fast = s_node is not None and ga_nodes[g] == s_node
            po = offs[sel]
            pl = lens[sel]
            pd = np.concatenate([packed[s:s + l] for s, l in
                                 zip(starts[sel], pl)])
            seg_starts = np.concatenate([[0], np.cumsum(pl)[:-1]])
            if s_node is not None:
                node_bytes[g, s_node] += int(pl.sum())
            for r in np.unique(rnd[sel]):
                in_r = rnd[sel] == r
                (ga_msgs_fast if fast else ga_msgs)[g, r] += 1
                payload = int(pl[in_r].sum())
                if faults is not None:
                    key = (sender, int(r))
                    lost_n = int(faults.lost.get(key, 0))
                    if lost_n:
                        if lost_n > faults.max_retries:
                            raise UnrecoverableFaultError(
                                f"message from sender {sender} in round "
                                f"{int(r)} lost {lost_n} times "
                                f"(max_retries={faults.max_retries})")
                        matched_lost.add(key)
                        # each loss times out (exponential backoff) and
                        # re-sends the round's slice
                        penalty[g, r] += faults.retry_penalty(lost_n) \
                            + lost_n * (m.alpha_inter + m.beta_inter
                                        * (payload + int(in_r.sum())
                                           * PAIR_BYTES))
                    penalty[g, r] += float(faults.delayed.get(key, 0.0))
                if codec is not None:
                    # one encode per byte: round r's slice is encoded
                    # for the wire accounting AND its decode is
                    # scattered back in place, so the bytes the GA
                    # sees are the ones that survived the round trip
                    # (byte-identical for the lossless codecs this
                    # path admits)
                    raw = (np.concatenate(
                        [pd[s:s + l] for s, l in zip(seg_starts[in_r],
                                                     pl[in_r])])
                        if payload else np.zeros(0, np.uint8))
                    wire = codec.encode_bytes(raw)
                    dec = codec.decode_bytes(wire)
                    pos = 0
                    for s, l in zip(seg_starts[in_r], pl[in_r]):
                        pd[s:s + l] = dec[pos:pos + l]
                        pos += l
                    raw_total += raw.size
                    wire_total += wire.size
                    payload = wire.size        # the wire moves encoded
                (ga_bytes_fast if fast else ga_bytes)[g, r] += \
                    payload + int(in_r.sum()) * PAIR_BYTES
            ga_inbox[g].append((po, pl, pd))
    t.rounds_executed = n_rounds
    if codec is not None:
        t.slow_hop_codec = codec.name
        t.slow_hop_raw_bytes = int(raw_total)
        t.slow_hop_wire_bytes = int(wire_total)
        t.codec = float(raw_total + wire_total) / m.codec_bw
    t.messages_at_ga = int((ga_msgs + ga_msgs_fast).max(initial=0))
    if ga_nodes is not None:
        t.placement = plan.placement
        t.slow_hop_fast_bytes = int(ga_bytes_fast.sum())
        t.slow_hop_slow_bytes = int(ga_bytes.sum())
        t.node_bytes = tuple(tuple(int(b) for b in row)
                             for row in node_bytes)
    t.retries = sum(int(faults.lost[k]) for k in matched_lost) \
        if faults is not None else 0
    # per-round incast: a receiver with S concurrent SLOW senders pays
    # alpha_eff(S) each (cost_model refinement 2, applied to the
    # single-shot exchange too so the timings are comparable); the
    # placement-induced FAST senders (same node as the serving
    # aggregator) pay alpha_intra/beta_intra instead — no incast knee
    # inside a node. ``t_dom[g, r]`` is domain g's round-r receive time
    # on a HEALTHY node; the serving node's slowdown scales it, and
    # domains sharing a serving slot (a degraded serve map) SERIALIZE:
    # the round's comm is the max over slots of the sum of their
    # domains' times — which reduces to the old max-over-domains for
    # any bijection, keeping healthy timings bit-identical.
    alpha = np.vectorize(m.alpha_eff)(ga_msgs) * ga_msgs \
        + m.alpha_intra * ga_msgs_fast
    t_dom = (alpha + m.beta_inter * ga_bytes
             + m.beta_intra * ga_bytes_fast + penalty)
    dom_factor = np.ones(stripe_count)
    if serve_nodes is not None:
        dom_factor = np.asarray([slow_of(n) for n in serve_nodes])
    t_dom_served = t_dom * dom_factor[:, None]
    slot_rounds = np.zeros((stripe_count, n_rounds))
    for g in range(stripe_count):
        slot_rounds[serve[g]] += t_dom_served[g]
    comm_rounds = slot_rounds.max(axis=0, initial=0)
    t.inter_comm = float(comm_rounds.sum())

    # ---- pipeline depth: the plan's pick, or re-resolved against the
    # measured rounds ---------------------------------------------------
    depth = plan.pipeline_depth
    multi_window = n_rounds > 1

    # ---- I/O step: sort + write segments ------------------------------
    img_lens = np.zeros(stripe_count, np.int64)
    segs = []
    for g in range(stripe_count):
        offs, lens, packed, n_cmp = merge_coalesce(ga_inbox[g])
        t.inter_sort = max(t.inter_sort, m.sort_per_cmp * n_cmp)
        segs.append(domain_image(offs, lens, packed, g, stripe_size,
                                 stripe_count))
        img_lens[g] = segs[-1].size

    # bytes GA g drains in round r: its image's overlap with the
    # window [r*cb, (r+1)*cb); the serving node's slowdown scales its
    # drain share (a straggler's file-system client is slow too)
    lo = np.arange(n_rounds, dtype=np.int64) * cb
    io_share = (np.clip(img_lens[:, None] - lo[None, :], 0, cb)
                / m.io_bw) * dom_factor[:, None]
    io_rounds = io_share.sum(axis=0)
    t.io = float(io_share.sum())
    if depth_request == "auto" and multi_window:
        depth, _ = optimal_depth(round_times=(comm_rounds, io_rounds))
    t.pipeline_depth = max(1, min(depth, n_rounds))  # executed in-flight
    # measured per-round arrays: what a session feeds back into the
    # next write's "auto" resolutions (cost_model.optimal_depth runs
    # on exactly these)
    t.comm_rounds = tuple(float(c) for c in comm_rounds)
    t.io_rounds = tuple(float(i) for i in io_rounds)

    # ---- measured per-node service rates: seconds-per-byte of what
    # each node actually served, normalized by the fastest busy node —
    # the feedback placement="auto" consumes to evacuate a straggler
    if serve_nodes is not None:
        served_t = [0.0] * n_nodes
        served_b = [0.0] * n_nodes
        for g in range(stripe_count):
            node = serve_nodes[g]
            served_t[node] += float(t_dom_served[g].sum()
                                    + io_share[g].sum())
            served_b[node] += float(img_lens[g]
                                    + (ga_bytes[g] + ga_bytes_fast[g])
                                    .sum())
        t.node_slowdown = measure_node_slowdown(served_t, served_b)
        t.serve_map = serve if serve_map is not None else None

    # ---- dead aggregator: the serving node dies entering round rd.
    # Detection is the heartbeat monitor's job (inject -> dead_hosts()
    # latches it; latency = its timeout) — faults.detection_s stands in
    # without a monitor. Recovery re-routes the victim slot's domains
    # to the least-loaded healthy slot (faults.repair_map) and REPLAYS
    # their unfinished rounds there; the victim's torn segment is
    # marked on disk and rewritten below. All recovery time is reported
    # separately (recovery_seconds), never hidden in the round arrays.
    torn_victim, torn_trunc = None, 0
    if faults is not None and faults.dead_aggregator is not None:
        dead_slot, rd = faults.dead_aggregator
        dead_slot = int(dead_slot)
        rd = max(0, min(int(rd), n_rounds - 1))
        victim_node = placement_mod.node_of_slot(dead_slot, stripe_count,
                                                 n_nodes)
        if heartbeat is not None:
            heartbeat.inject_failure(victim_node)
            assert victim_node in heartbeat.dead_hosts()
            detect_s = float(heartbeat.timeout_s)
        else:
            detect_s = float(faults.detection_s)
        slot_load = [0.0] * stripe_count
        for g in range(stripe_count):
            slot_load[serve[g]] += float(t_dom_served[g].sum()
                                         + io_share[g].sum())
        new_serve, repair_slot, victims = repair_map(
            serve, dead_slot, slot_load, stripe_count, n_nodes)
        repair_factor = slow_of(placement_mod.node_of_slot(
            repair_slot, stripe_count, n_nodes))
        replay = 0.0
        for g in victims:
            replay += float(t_dom[g, rd:].sum()) * repair_factor
            replay += float(io_share[g, rd:].sum() / dom_factor[g]) \
                * repair_factor
        t.recovery_seconds += detect_s + replay
        t.repair_map = new_serve
        t.serve_map = new_serve
        serve = new_serve
        if victims:
            # the victim's drain died mid-segment: rd complete windows
            # are on disk, marked partial; detected + rewritten below
            torn_victim = victims[0]
            torn_trunc = int(min(rd * cb, img_lens[torn_victim])) \
                if multi_window else 0

    for g in range(stripe_count):
        seg_path = f"{path}.seg{g}"
        cbw = cb if multi_window and depth > 1 else None
        if g == torn_victim:
            with open(seg_path, "wb") as f:
                f.write(segs[g][:torn_trunc].tobytes())
            with open(partial_marker(seg_path), "w") as mf:
                mf.write(f"windows_written={torn_trunc // max(cb, 1)}\n")
        else:
            inject = None
            if faults is not None and faults.torn_window is not None \
                    and g == faults.torn_window[0]:
                inject = int(faults.torn_window[1])
            try:
                write_segment(seg_path, segs[g], cbw, depth=depth,
                              fail_after_windows=inject)
            except TornWriteError:
                if inject is None:
                    raise      # a REAL drain failure is not recoverable
        if os.path.exists(partial_marker(seg_path)):
            # torn-write repair: the marker is the detection; rewrite
            # the full segment and clear it, charging the re-drain
            write_segment(seg_path, segs[g], cbw, depth=depth)
            os.remove(partial_marker(seg_path))
            t.torn_writes_detected += 1
            t.recovery_seconds += float(img_lens[g]) / m.io_bw

    # ---- pipelined makespan: the depth-k bounded-buffer recurrence
    # over the measured per-round arrays; the prologue (first exchange)
    # and epilogue (last drain) stay exposed ----------------------------
    if depth > 1 and n_rounds > 0:
        serial = float(comm_rounds.sum() + io_rounds.sum())
        span = pipeline_span(comm_rounds, io_rounds, depth)
        t.overlap_saved = max(serial - span, 0.0)
        hideable = (float(min(comm_rounds[1:].sum(),
                              io_rounds[:-1].sum()))
                    if n_rounds > 1 else 0.0)
        t.overlap_fraction = (min(t.overlap_saved / hideable, 1.0)
                              if hideable > 0 else 0.0)
    return t


def execute_read(plan: IOPlan, machine: Machine, rank_requests, path: str,
                 t, *, n_nodes: int, ranks_per_node: int,
                 depth_request=None, node_cache: bool = True,
                 serve_map=None, faults=None):
    """Run the I/O + fan-out step of a read plan (the write's mirror).

    rank_requests: per READER rank ``(offsets, lengths)`` in byte
    units, already split at stripe boundaries (each request lives in
    one stripe, hence one file domain). Rank i lives on node
    ``i // ranks_per_node``. Returns the per-rank payloads (one uint8
    array per rank, request order) with ``t`` (:class:`IOTimings`)
    filled; bytes are REAL — every window any rank needs is read from
    its segment file with a RANGED read (``t.read_bytes`` counts disk
    bytes once per window, the subset-restore economy), zeros past the
    segment's written extent — and TIME is modeled, same split as
    :func:`execute_write`.

    The round partition is the plan's: window ``(g, r)`` is domain g's
    bytes ``[r*cb, (r+1)*cb)``, served by slot ``serve[g]`` (the
    plan's placement, or an execution-level ``serve_map`` override with
    the same serialization semantics as the write path). Only windows
    somebody asked for are read, shipped, or charged.

    ``node_cache=True`` is the intra-node request aggregation of the
    paper, read direction: per (window, needing node) the node's
    ELECTED fetcher (its lowest needing rank) pulls the window over
    the slow hop ONCE — ``t.cache_misses`` — and every co-located
    reader after it is served from the node's window cache at the fast
    intra rates (``t.cache_hits``; alpha_intra per delivery,
    beta_intra on the reader's requested bytes, the staging copy at
    ``memcpy_bw``). The slow-hop bytes per (window, node) are ONE
    window regardless of how many ranks on the node want it — the
    flat-replica-curve acceptance of BENCH_restore. A fetcher on the
    serving slot's own node pulls intra (no slow hop at all), same
    placement affinity as the write's fast senders.

    ``node_cache=False`` is the pre-cache baseline: every needing RANK
    pulls the whole window itself (window-granular transfer, so q
    co-located readers pay the slow hop q times — exactly the
    duplicated broadcast traffic the cache deletes). All fetches count
    as misses; no intra fan-out, no staging.

    With ``plan.slow_hop_codec`` set, each window crossing the slow
    hop passes a REAL ``encode_bytes``/``decode_bytes`` round trip —
    encoded once at the serving aggregator, wire bytes charged per
    slow transmission, the decoded bytes being what readers consume —
    and intra-node deliveries move raw bytes (the codec is the slow
    hop's, not the cache's).

    depth_request: as in :func:`execute_write` — ``"auto"``
    re-resolves the ring depth against the measured per-round arrays.
    A read round is disk-then-wire, the write's phases reversed; the
    bounded-buffer makespan is symmetric under phase reversal, so the
    same ``pipeline_span(comm, io, depth)`` recurrence applies and the
    session feedback keeps the write's ``(comm, io)`` convention.

    faults: node slowdowns scale what the node serves, as in the write
    path. A ``<seg>.partial`` marker on ANY needed segment raises
    :class:`TornWriteError` — a torn write must be repaired (rewritten
    or restored from an older step) before a restore may consume it.
    """
    m = machine
    stripe_count, cb = plan.n_aggregators, plan.cb
    stripe_size = plan.layout.stripe_size
    n_rounds = plan.n_rounds
    codec = get_codec(plan.slow_hop_codec) if plan.slow_hop_codec else None
    perm = (plan.placement if plan.placement is not None
            else tuple(range(stripe_count)))
    if serve_map is not None:
        serve = tuple(int(s) for s in serve_map)
        if len(serve) != stripe_count or not all(
                0 <= s < stripe_count for s in serve):
            raise ValueError(f"serve_map {serve!r} must map each of "
                             f"{stripe_count} domains to a valid slot")
    else:
        serve = tuple(perm)
    serve_nodes = [placement_mod.node_of_slot(serve[g], stripe_count,
                                              n_nodes)
                   for g in range(stripe_count)]
    slow_of = (lambda node: faults.slowdown(node)) if faults is not None \
        else (lambda node: 1.0)

    # ---- demand map: which (domain, window) does each rank/node need --
    # win_need[(g, r)] = {node: {rank: requested bytes}}
    win_need: dict = {}
    win_spans: dict = {}       # (g, r) -> [(win_off, len)] requested
    rank_spans = []            # per rank: ([(g, r, win_off, len, out_pos)],
    #                             total_out_bytes)
    node_bytes = np.zeros((stripe_count, n_nodes), np.int64)
    for rank, (offs, lens) in enumerate(rank_requests):
        nd = rank // ranks_per_node
        spans = []
        out_pos = 0
        for o, ln in zip(np.asarray(offs, np.int64),
                         np.asarray(lens, np.int64)):
            g = int((o // stripe_size) % stripe_count)
            dl = int(to_domain_local(o, stripe_size, stripe_count))
            node_bytes[g, nd] += int(ln)
            pos = 0
            while pos < ln:
                r = (dl + pos) // cb
                take = int(min(ln - pos, (r + 1) * cb - (dl + pos)))
                wo = int(dl + pos - r * cb)
                spans.append((g, int(r), wo, take, out_pos + pos))
                win_spans.setdefault((g, int(r)), []).append((wo, take))
                per_rank = (win_need.setdefault((g, int(r)), {})
                            .setdefault(nd, {}))
                per_rank[rank] = per_rank.get(rank, 0) + take
                pos += take
            out_pos += int(ln)
        rank_spans.append((spans, out_pos))

    # ---- ranged segment reads: within each needed window, only the
    # REQUESTED byte runs hit disk (coalesced — overlapping readers
    # share one run), once per window whatever the reader count. This
    # is the subset-restore economy: a half-tree subset's windows read
    # roughly half the file's bytes (t.read_bytes), never whole
    # segments. ----------------------------------------------------------
    needed_gs = sorted({g for g, _ in win_need})
    for g in needed_gs:
        if os.path.exists(partial_marker(f"{path}.seg{g}")):
            raise TornWriteError(f"{path}.seg{g}", -1, -1)
    seg_len = {g: (os.path.getsize(f"{path}.seg{g}")
                   if os.path.exists(f"{path}.seg{g}") else 0)
               for g in needed_gs}
    windows: dict = {}
    raw_total = wire_total = 0
    io_share = np.zeros((stripe_count, n_rounds))
    handles = {g: (open(f"{path}.seg{g}", "rb") if seg_len[g] else None)
               for g in needed_gs}
    try:
        for (g, r) in sorted(win_need):
            base = r * cb
            buf = np.zeros(cb, np.uint8)
            # coalesce the requested runs inside this window
            runs = []
            for wo, take in sorted(win_spans[(g, r)]):
                if runs and wo <= runs[-1][1]:
                    runs[-1][1] = max(runs[-1][1], wo + take)
                else:
                    runs.append([wo, wo + take])
            got = 0
            for lo, hi in runs:
                hi_f = min(base + hi, seg_len[g])
                take = hi_f - (base + lo)
                if take > 0:
                    handles[g].seek(base + lo)
                    buf[lo:lo + take] = np.frombuffer(
                        handles[g].read(take), np.uint8)
                    got += take
            t.read_bytes += int(got)
            io_share[g, r] = got / m.io_bw * slow_of(serve_nodes[g])
            windows[(g, r)] = buf
    finally:
        for f in handles.values():
            if f is not None:
                f.close()

    # ---- slow-hop fetches + intra fan-out -----------------------------
    ga_msgs = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes = np.zeros((stripe_count, n_rounds), np.int64)
    ga_msgs_fast = np.zeros((stripe_count, n_rounds), np.int64)
    ga_bytes_fast = np.zeros((stripe_count, n_rounds), np.int64)
    fan_msgs = np.zeros((n_nodes, n_rounds), np.int64)
    fan_bytes = np.zeros((n_nodes, n_rounds), np.int64)
    stage_bytes = np.zeros(n_nodes, np.int64)
    for (g, r), per_node in sorted(win_need.items()):
        raw_b = cb + PAIR_BYTES
        wire_b = raw_b
        if codec is not None and any(serve_nodes[g] != nd
                                     for nd in per_node):
            # encoded ONCE at the serving aggregator; every slow
            # receiver decodes the same wire bytes — and consumes the
            # round-tripped payload (byte-identical: lossless only)
            wire = codec.encode_bytes(windows[(g, r)])
            dec = codec.decode_bytes(wire)
            windows[(g, r)] = np.asarray(dec, np.uint8)
            raw_total += int(windows[(g, r)].size)
            wire_total += int(wire.size)
            wire_b = int(wire.size) + PAIR_BYTES
        for nd, readers in sorted(per_node.items()):
            fast = nd == serve_nodes[g]
            if node_cache:
                # one fetch per (window, node) by the elected fetcher;
                # the rest of the node reads from the cache
                if fast:
                    ga_msgs_fast[g, r] += 1
                    ga_bytes_fast[g, r] += raw_b
                else:
                    ga_msgs[g, r] += 1
                    ga_bytes[g, r] += wire_b
                t.cache_misses += 1
                t.cache_hits += len(readers) - 1
                stage_bytes[nd] += cb
                fetcher = min(readers)
                fan_msgs[nd, r] += len(readers) - 1
                fan_bytes[nd, r] += sum(b for rk, b in readers.items()
                                        if rk != fetcher)
            else:
                # every rank pulls the whole window itself
                n_read = len(readers)
                if fast:
                    ga_msgs_fast[g, r] += n_read
                    ga_bytes_fast[g, r] += raw_b * n_read
                else:
                    ga_msgs[g, r] += n_read
                    ga_bytes[g, r] += wire_b * n_read
                t.cache_misses += n_read

    t.rounds_executed = n_rounds
    if codec is not None:
        t.slow_hop_codec = codec.name
        t.slow_hop_raw_bytes = int(raw_total)
        t.slow_hop_wire_bytes = int(wire_total)
        t.codec = float(raw_total + wire_total) / m.codec_bw
    t.messages_at_ga = int((ga_msgs + ga_msgs_fast).max(initial=0))
    t.placement = plan.placement
    t.slow_hop_fast_bytes = int(ga_bytes_fast.sum())
    t.slow_hop_slow_bytes = int(ga_bytes.sum())
    t.node_bytes = tuple(tuple(int(b) for b in row) for row in node_bytes)

    # per-round outcast at the serving aggregator: S concurrent slow
    # receivers pay alpha_eff(S) each (the incast knee is symmetric —
    # it models NIC/agent saturation, not direction); same-node
    # deliveries move at intra rates. Domains sharing a serving slot
    # serialize exactly as in the write path.
    alpha = np.vectorize(m.alpha_eff)(ga_msgs) * ga_msgs \
        + m.alpha_intra * ga_msgs_fast
    t_dom = (alpha + m.beta_inter * ga_bytes
             + m.beta_intra * ga_bytes_fast)
    dom_factor = np.asarray([slow_of(n) for n in serve_nodes])
    t_dom_served = t_dom * dom_factor[:, None]
    slot_rounds = np.zeros((stripe_count, n_rounds))
    for g in range(stripe_count):
        slot_rounds[serve[g]] += t_dom_served[g]
    fetch_rounds = slot_rounds.max(axis=0, initial=0)
    # the fan-out runs per node in parallel; round r's comm closes when
    # the slowest node has delivered its cached windows
    fan_rounds = (m.alpha_intra * fan_msgs
                  + m.beta_intra * fan_bytes).max(axis=0, initial=0)
    comm_rounds = fetch_rounds + fan_rounds
    t.inter_comm = float(fetch_rounds.sum())
    t.intra_comm = float(fan_rounds.sum())
    t.intra_memcpy = float(stage_bytes.max(initial=0)) / m.memcpy_bw
    io_rounds = io_share.sum(axis=0)
    t.io = float(io_share.sum())

    depth = plan.pipeline_depth
    multi_window = n_rounds > 1
    if depth_request == "auto" and multi_window:
        depth, _ = optimal_depth(round_times=(comm_rounds, io_rounds))
    t.pipeline_depth = max(1, min(depth, n_rounds))
    t.comm_rounds = tuple(float(c) for c in comm_rounds)
    t.io_rounds = tuple(float(i) for i in io_rounds)

    served_t = [0.0] * n_nodes
    served_b = [0.0] * n_nodes
    for g in range(stripe_count):
        node = serve_nodes[g]
        served_t[node] += float(t_dom_served[g].sum() + io_share[g].sum())
        served_b[node] += float((ga_bytes[g] + ga_bytes_fast[g]).sum())
    t.node_slowdown = measure_node_slowdown(served_t, served_b)
    t.serve_map = serve if serve_map is not None else None

    if depth > 1 and n_rounds > 0:
        serial = float(comm_rounds.sum() + io_rounds.sum())
        span = pipeline_span(comm_rounds, io_rounds, depth)
        t.overlap_saved = max(serial - span, 0.0)
        hideable = (float(min(comm_rounds[1:].sum(),
                              io_rounds[:-1].sum()))
                    if n_rounds > 1 else 0.0)
        t.overlap_fraction = (min(t.overlap_saved / hideable, 1.0)
                              if hideable > 0 else 0.0)

    # ---- assemble per-rank payloads from the fetched windows ----------
    outs = []
    for spans, total in rank_spans:
        buf = np.zeros(total, np.uint8)
        for g, r, wo, ln, op in spans:
            buf[op:op + ln] = windows[(g, r)][wo:wo + ln]
        outs.append(buf)
    return outs
