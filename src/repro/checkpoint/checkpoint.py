"""Checkpoint save/restore through TAM collective I/O.

Layout: the train state pytree is serialized into one contiguous byte
space ("the file"): leaves in deterministic tree order, each leaf padded
to 256-B alignment. A manifest (JSON) records leaf paths, dtypes,
shapes, offsets. Each simulated host contributes its shards of every
leaf as (offset, length, payload) requests — exactly an MPI collective
write with an MPI file view — and ``HostCollectiveIO`` executes it with
the TAM or two-phase schedule.

Restore is the write's mirror: the reader topology's per-rank read
requests route through the SAME planner (``compile_plan`` with
``direction="read"``) and the host read executor — node-level window
cache, ranged segment reads, read-side :class:`IOTimings` — then each
leaf is device_put with the target sharding, which may belong to a
DIFFERENT mesh (elastic restart; see runtime.elastic). ``subset=``
restores part of the tree from exactly its byte ranges; the legacy
single-reader reassembly (``planned=False``) remains as the
byte-identity oracle.

Async saves (``save_checkpoint(..., async_=True)`` /
:meth:`CheckpointManager.save_async`) decouple the application from
the collective write: the tree is SNAPSHOT to host buffers
synchronously (so a training step mutating the params afterwards can
never change the written bytes), a :class:`PendingCheckpoint` future
returns immediately, and a daemon thread drains the write through the
same :class:`HostCollectiveIO` / ``IOSession`` path as a sync save.
Crash consistency is commit-last: any stale manifest for the target
path is unlinked BEFORE the segments are touched and the new manifest
is written only after every segment landed, so a torn async write is
never restorable — restart discovery (``CheckpointManager.latest_step``,
``runtime.elastic.find_restart_step``) sees committed manifests only,
and a mid-drain death leaves ``.partial`` markers (core.faults) on the
torn segments exactly like a sync write's.
"""
from __future__ import annotations

import json
import math
import threading
import time
import zlib
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.host_io import _UNSET, HostCollectiveIO, IOTimings
from repro.core.plan import IOConfig

ALIGN = 256


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def build_manifest(tree, step: int = 0) -> dict:
    entries = []
    offset = 0
    for path, leaf in _leaf_paths(tree):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize \
            if leaf.shape else leaf.dtype.itemsize
        entries.append({"path": path, "shape": list(leaf.shape),
                        "dtype": str(leaf.dtype), "offset": offset,
                        "nbytes": int(nbytes)})
        offset += -(-nbytes // ALIGN) * ALIGN
    return {"step": step, "file_len": offset, "leaves": entries}


def _leaf_spans(nbytes: int, n_ranks: int):
    """Contiguous per-rank byte spans of one leaf — the SAME sharding
    for save and restore, so a restore's read requests mirror the
    write's exactly (yields (rank, lo, hi), empty spans skipped)."""
    chunk = max(nbytes // n_ranks, 1)
    for r in range(n_ranks):
        lo = min(r * chunk, nbytes)
        hi = nbytes if r == n_ranks - 1 else min((r + 1) * chunk, nbytes)
        if hi > lo:
            yield r, lo, hi


def _rank_requests(tree, manifest, n_ranks: int):
    """Shard every leaf round-robin by rows across ranks -> per-rank
    (offsets, lengths, payload) request lists, offset-sorted."""
    reqs = [([], [], []) for _ in range(n_ranks)]
    for entry, (path, leaf) in zip(manifest["leaves"], _leaf_paths(tree)):
        arr = np.asarray(leaf)
        flat = arr.reshape(-1).view(np.uint8)
        # each rank owns a contiguous span of the leaf's bytes
        for r, lo, hi in _leaf_spans(len(flat), n_ranks):
            reqs[r][0].append(entry["offset"] + lo)
            reqs[r][1].append(hi - lo)
            reqs[r][2].append(flat[lo:hi])
    out = []
    for o, l, d in reqs:
        if o:
            oo = np.asarray(o, np.int64)
            ll = np.asarray(l, np.int64)
            dd = np.concatenate(d)
            order = np.argsort(oo, kind="stable")
            starts = np.concatenate([[0], np.cumsum(ll)[:-1]])
            dd = np.concatenate([dd[starts[i]:starts[i] + ll[i]]
                                 for i in order])
            out.append((oo[order], ll[order], dd))
        else:
            out.append((np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.uint8)))
    return out


def snapshot_tree(tree):
    """Copy every leaf of ``tree`` into fresh host (numpy) buffers —
    the snapshot an async save isolates itself with. The copy is what
    guarantees snapshot isolation: a training step mutating (or
    donating) the live buffers after ``save_checkpoint(async_=True)``
    returns can never change the bytes the background drain writes
    (asserted by tests/test_async_ckpt.py)."""
    return jax.tree_util.tree_map(
        lambda leaf: np.array(np.asarray(leaf), copy=True), tree)


class PendingCheckpoint:
    """Future for an in-flight async checkpoint write.

    Returned immediately by ``save_checkpoint(..., async_=True)`` /
    :meth:`CheckpointManager.save_async` after the tree snapshot; the
    collective write drains on a daemon thread. At most one checkpoint
    is in flight per :class:`CheckpointManager` (``save_async`` blocks
    on the previous future first — a bounded queue of depth one, so a
    slow filesystem backpressures the training loop instead of
    accumulating unbounded host copies).

    * :meth:`wait` / :meth:`result` block until the drain finishes and
      return ``(manifest, timings)``; a failed drain re-raises the
      background exception (every call — like ``concurrent.futures``).
    * :meth:`block_until_done` is :meth:`wait` for callers that only
      need the barrier (returns ``None``).
    * :meth:`done` polls without blocking.

    The returned ``timings`` carry the async accounting on top of the
    modeled write fields: ``snapshot_seconds`` (real wall time of the
    host copy — the only part the caller's step blocked on),
    ``drain_wall_seconds`` (real wall time of the background write) and
    ``overlap_hidden_seconds`` / ``hidden_fraction`` (the part of the
    drain that ran before the caller first blocked on this future —
    what checkpoint-every-N overlap actually hid behind compute).
    """

    def __init__(self, path: Path, step: int, snapshot_seconds: float):
        self.path = Path(path)
        self.step = step
        self.snapshot_seconds = snapshot_seconds
        self._started = time.perf_counter()
        self._finished = None          # perf_counter at drain completion
        self._event = threading.Event()
        self._result = None            # (manifest, timings) on success
        self._exc = None
        self.exception_observed = False  # a wait() already re-raised it

    # -- worker side ---------------------------------------------------
    def _finish(self, manifest: dict, timings: IOTimings) -> None:
        self._finished = time.perf_counter()
        timings.snapshot_seconds = self.snapshot_seconds
        timings.drain_wall_seconds = self._finished - self._started
        self._result = (manifest, timings)
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._finished = time.perf_counter()
        self._exc = exc
        self._event.set()

    # -- caller side ---------------------------------------------------
    def done(self) -> bool:
        """True once the background drain finished (committed OR
        failed) — never blocks."""
        return self._event.is_set()

    def wait(self, timeout: float | None = None):
        """Block until the drain finishes; return ``(manifest,
        timings)``. Raises the background exception if the write
        failed (the checkpoint was NOT committed — no manifest exists)
        and :class:`TimeoutError` if ``timeout`` expires first.

        The FIRST wait fixes the overlap accounting: everything the
        drain did before this call ran concurrently with the caller
        (``timings.overlap_hidden_seconds``)."""
        blocked_at = time.perf_counter()
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"checkpoint {self.path} still draining after {timeout}s")
        if self._exc is not None:
            self.exception_observed = True
            raise self._exc
        manifest, timings = self._result
        if timings.overlap_hidden_seconds == 0.0:
            hidden = min(self._finished, blocked_at) - self._started
            timings.overlap_hidden_seconds = max(
                min(hidden, timings.drain_wall_seconds), 0.0)
        return manifest, timings

    def result(self, timeout: float | None = None):
        """Alias of :meth:`wait` (``concurrent.futures`` spelling)."""
        return self.wait(timeout)

    def block_until_done(self, timeout: float | None = None) -> None:
        """:meth:`wait`, discarding the result — the bare barrier."""
        self.wait(timeout)


def _commit_write(tree, path: Path, io: HostCollectiveIO, step: int,
                  write_kwargs: dict) -> tuple[dict, IOTimings]:
    """The commit-last write body shared by the sync and async paths:
    un-commit first (a stale manifest for this path is unlinked before
    any segment byte moves, so a torn write is never restorable under
    the OLD layout), drain the segments, then write the manifest as
    the atomic commit point."""
    manifest = build_manifest(tree, step)
    mpath = path.parent / (path.name + ".manifest.json")
    if mpath.exists():
        mpath.unlink()
    reqs = _rank_requests(tree, manifest, io.n_ranks)
    timings = io.write(reqs, str(path), **write_kwargs)
    manifest["stripe_size"] = io.stripe_size
    manifest["stripe_count"] = io.stripe_count
    mpath.write_text(json.dumps(manifest))
    return manifest, timings


def save_checkpoint(tree, path: str | Path, *, step: int = 0,
                    io: HostCollectiveIO | None = None,
                    method: str = "tam",
                    local_aggregators: int | None = None,
                    cb_bytes: int | str | None = _UNSET,
                    pipeline: bool = _UNSET,
                    pipeline_depth: int | str | None = _UNSET,
                    slow_hop_codec: str | None = _UNSET,
                    placement=_UNSET,
                    session=None,
                    config: IOConfig | None = None,
                    kernel_fusion: str | None = _UNSET,
                    faults=None, heartbeat=None,
                    async_: bool = False, on_commit=None):
    """Serialize ``tree`` to ``<path>.seg*`` through the collective
    writer, manifest committed LAST.

    Args:
        tree: the pytree to serialize (leaves: array-likes).
        path: checkpoint stem; segments land at ``<path>.seg<g>`` and
            the manifest at ``<path>.manifest.json``.
        step: recorded in the manifest (returned by restore).
        io: the :class:`HostCollectiveIO` writer topology (a default
            8-rank / 2-node writer is built when omitted).
        method: ``"tam"`` | ``"twophase"`` | ``"auto"``.
        local_aggregators: TAM stage-1 P_L (default ``4 * n_nodes``).
        config: ONE :class:`IOConfig` — the unified knob surface
            (``cb_buffer_size`` is byte units here). Explicit per-knob
            kwargs on top of a config are sparse overrides; the bare
            per-knob kwargs (``cb_bytes`` / ``pipeline`` /
            ``pipeline_depth`` / ``slow_hop_codec`` / ``placement`` /
            ``kernel_fusion``) WITHOUT a config are a deprecated shim
            (one ``DeprecationWarning``, identical plan — asserted by
            tests/test_plan.py).
        session: an :class:`~repro.core.session.IOSession` — repeated
            saves reuse the compiled plan and feed measured timings
            back into every ``"auto"`` knob. Async drains feed the
            same session (it is thread-safe; the manager serializes
            writes so a background drain never races a foreground
            trial).
        faults / heartbeat: fault injection + failure detection,
            passed straight to :meth:`HostCollectiveIO.write`
            (core.faults); recovered saves stay byte-identical to
            healthy ones.
        async_: snapshot the tree to host buffers NOW (snapshot
            isolation — later mutation of the live tree cannot change
            the written bytes), return a :class:`PendingCheckpoint`
            immediately, and drain the collective write on a daemon
            thread. Commit stays last: a drain that dies leaves NO
            manifest (plus ``.partial`` markers on torn segments), so
            restart lands on the previous committed step.
        on_commit: optional zero-arg callable run right after the
            manifest commit (the manager's rolling GC hook); on the
            async path it runs on the drain thread.

    Returns:
        ``(manifest, timings)`` — or a :class:`PendingCheckpoint` when
        ``async_=True`` (its :meth:`~PendingCheckpoint.result` yields
        the same pair).

    Raises:
        Whatever the collective write raises (e.g.
        :class:`~repro.core.faults.UnrecoverableFaultError` under
        injected faults) — from this call when sync, from the future's
        ``wait()``/``result()`` when async.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    io = io or HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1 << 20,
                                stripe_count=4)
    write_kwargs = dict(
        method=method, local_aggregators=local_aggregators,
        config=config, cb_bytes=cb_bytes, pipeline=pipeline,
        pipeline_depth=pipeline_depth, slow_hop_codec=slow_hop_codec,
        placement=placement, kernel_fusion=kernel_fusion,
        session=session, faults=faults, heartbeat=heartbeat)
    if not async_:
        manifest, timings = _commit_write(tree, path, io, step,
                                          write_kwargs)
        if on_commit is not None:
            on_commit()
        return manifest, timings
    t0 = time.perf_counter()
    snap = snapshot_tree(tree)
    pending = PendingCheckpoint(path, step,
                                snapshot_seconds=time.perf_counter() - t0)

    def _drain():
        try:
            manifest, timings = _commit_write(snap, path, io, step,
                                              write_kwargs)
            if on_commit is not None:
                on_commit()
            pending._finish(manifest, timings)
        except BaseException as exc:  # surfaced via wait()/result()
            pending._fail(exc)

    threading.Thread(target=_drain, daemon=True,
                     name=f"ckpt-drain-{step}").start()
    return pending


def manifest_fingerprint(manifest: dict) -> int:
    """Deterministic content key of a manifest (CRC of its canonical
    JSON) — what keys a read session entry to THIS checkpoint's layout,
    so a re-striped or re-written file never reuses a stale plan.
    (Not Python ``hash()``: that is salted per process, and a session
    may outlive several manifests.)"""
    return zlib.crc32(json.dumps(manifest, sort_keys=True).encode())


def _select_leaves(manifest: dict, subset):
    """Indices of the manifest leaves a ``subset`` keeps: ``None`` =
    all, an iterable of leaf-path strings, or a predicate on the path.
    Unknown paths in an iterable subset are an error (a silent miss
    would restore garbage-by-omission)."""
    if subset is None:
        return list(range(len(manifest["leaves"])))
    if callable(subset):
        return [i for i, e in enumerate(manifest["leaves"])
                if subset(e["path"])]
    want = set(subset)
    known = {e["path"] for e in manifest["leaves"]}
    missing = want - known
    if missing:
        raise KeyError(f"subset names unknown leaves: {sorted(missing)}; "
                       f"manifest has {sorted(known)}")
    return [i for i, e in enumerate(manifest["leaves"])
            if e["path"] in want]


def restore_checkpoint(path: str | Path, like_tree, shardings=None, *,
                       subset=None, io: HostCollectiveIO | None = None,
                       method: str = "twophase",
                       cb_bytes: int | str | None = _UNSET,
                       pipeline: bool = _UNSET,
                       pipeline_depth: int | str | None = _UNSET,
                       slow_hop_codec: str | None = _UNSET,
                       placement=_UNSET,
                       kernel_fusion: str | None = _UNSET,
                       session=None, config: IOConfig | None = None,
                       node_cache: bool = True, planned: bool | None = None,
                       with_timings: bool = False):
    """Rebuild the pytree (optionally device_put with ``shardings`` —
    which may target a different mesh than the one that saved it).

    ``subset`` slices the restore to part of the tree — an iterable of
    leaf-path strings (``jax.tree_util.keystr`` form, as recorded in
    the manifest) or a predicate on the path. Selected leaves are
    restored from RANGED segment reads of exactly their byte spans;
    every other leaf passes through from ``like_tree`` untouched. Disk
    bytes scale with the subset, not the file
    (``IOTimings.read_bytes``).

    ``planned`` routes the read through the full planner
    (:meth:`HostCollectiveIO.read`: ``compile_plan(direction="read")``,
    placement/codec/cb/depth passes, the node-level window cache when
    ``node_cache``, session reuse under the manifest's fingerprint) —
    the restore-side mirror of the collective write. Default: planned
    when an ``io`` is supplied (its ranks/nodes are the reader
    topology), legacy single-reader reassembly otherwise — the
    byte-identity oracle the planned path is fuzzed against. Returns
    ``(tree, step)``, or ``(tree, step, timings)`` with
    ``with_timings=True`` (timings is ``None`` on the legacy path —
    nothing collective ran).
    """
    path = Path(path)
    manifest = json.loads(
        (path.parent / (path.name + ".manifest.json")).read_text())
    selected = set(_select_leaves(manifest, subset))
    if planned is None:
        planned = io is not None
    flat, treedef = jax.tree_util.tree_flatten(like_tree)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"like_tree has {len(flat)} leaves but the manifest has "
            f"{len(manifest['leaves'])} — restore needs the saved shape")
    io = io or HostCollectiveIO(n_ranks=1, n_nodes=1,
                                stripe_size=manifest["stripe_size"],
                                stripe_count=manifest["stripe_count"])
    timings = None
    bufs: dict[int, np.ndarray] = {}
    if planned:
        reqs = [([], []) for _ in range(io.n_ranks)]
        fills = []                 # (rank, pos in rank payload, leaf, lo)
        cursor = [0] * io.n_ranks
        for li in sorted(selected):
            entry = manifest["leaves"][li]
            for r, lo, hi in _leaf_spans(entry["nbytes"], io.n_ranks):
                reqs[r][0].append(entry["offset"] + lo)
                reqs[r][1].append(hi - lo)
                fills.append((r, cursor[r], li, lo, hi))
                cursor[r] += hi - lo
        rank_requests = [(np.asarray(o, np.int64), np.asarray(ln, np.int64))
                         for o, ln in reqs]
        outs, timings = io.read(
            rank_requests, str(path), method=method, config=config,
            cb_bytes=cb_bytes, pipeline=pipeline,
            pipeline_depth=pipeline_depth, slow_hop_codec=slow_hop_codec,
            placement=placement, kernel_fusion=kernel_fusion,
            session=session, node_cache=node_cache,
            fingerprint=manifest_fingerprint(manifest))
        for li in sorted(selected):
            bufs[li] = np.zeros(manifest["leaves"][li]["nbytes"], np.uint8)
        for r, pos, li, lo, hi in fills:
            bufs[li][lo:hi] = outs[r][pos:pos + hi - lo]
    else:
        for li in sorted(selected):
            entry = manifest["leaves"][li]
            bufs[li] = io.read_file(str(path), manifest["file_len"],
                                    offset=entry["offset"],
                                    nbytes=entry["nbytes"])
    leaves = []
    for li, (entry, like) in enumerate(zip(manifest["leaves"], flat)):
        if li not in selected:
            leaves.append(like)
            continue
        arr = bufs[li].view(np.dtype(entry["dtype"])) \
            .reshape(entry["shape"])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    if with_timings:
        return tree, manifest["step"], timings
    return tree, manifest["step"]


@dataclass
class CheckpointManager:
    """Rolling checkpoints + restart discovery.

    Holds the cross-save state a production checkpoint loop needs: the
    writer topology (``io``), the unified knob surface (``config``),
    the persistent ``session`` (plan reuse + measured feedback), the
    ``heartbeat`` failure detector, and the rolling-GC window
    (``keep``). :meth:`save` blocks the caller on the collective
    write; :meth:`save_async` snapshots and returns a
    :class:`PendingCheckpoint` immediately, with at most ONE write in
    flight (the next ``save_async``/``save`` first drains the previous
    future — backpressure, and it also means the shared session never
    sees two concurrent writes, so background feedback cannot race a
    foreground trial). :meth:`latest_step` sees committed manifests
    only, so a killed async drain is invisible to restart discovery.
    """

    directory: str | Path
    io: HostCollectiveIO
    method: str = "tam"
    local_aggregators: int | None = None
    config: IOConfig | None = None  # the unified knob surface: ONE
    # IOConfig carrying cb/pipeline/codec/placement/kernel_fusion
    # (byte units); any per-knob field set below is a sparse override
    cb_bytes: int | str | None = _UNSET   # DEPRECATED shim (rounds:
    # None = single shot, "auto" = cost-model autotuned) — use config
    pipeline: bool = _UNSET        # DEPRECATED shim — use config
    pipeline_depth: int | str | None = _UNSET  # DEPRECATED shim (the
    # depth-k ring; None = 2 when pipeline, "auto" = measured pick)
    slow_hop_codec: str | None = _UNSET  # DEPRECATED shim (lossless
    # wire codec on the LA -> GA hop; "auto" = modeled pick)
    placement: str | tuple | None = _UNSET  # DEPRECATED shim
    # (aggregator placement policy / permutation / "auto")
    kernel_fusion: str | None = _UNSET  # DEPRECATED shim (plan field
    # only — the host executor has no Pallas hot path)
    session: object | None = None  # IOSession (core.session): repeated
    # saves of the same state shape reuse the compiled plan and feed
    # measured timings back into the "auto" knobs — the manager holds
    # it so the cross-write loop survives across save() calls
    heartbeat: object | None = None  # HeartbeatMonitor
    # (runtime.heartbeat): the failure detector every save consults
    # when a fault spec injects a dead aggregator — the manager holds
    # it so detection latches across saves (kill-and-resume scenarios)
    keep: int = 3
    #: the in-flight async save (at most one; see :meth:`save_async`)
    pending: PendingCheckpoint | None = field(default=None, repr=False)

    def _save_kwargs(self, faults) -> dict:
        return dict(
            io=self.io, method=self.method,
            local_aggregators=self.local_aggregators,
            config=self.config, cb_bytes=self.cb_bytes,
            pipeline=self.pipeline, pipeline_depth=self.pipeline_depth,
            slow_hop_codec=self.slow_hop_codec,
            placement=self.placement, kernel_fusion=self.kernel_fusion,
            session=self.session, faults=faults,
            heartbeat=self.heartbeat)

    def save(self, tree, step: int, faults=None) -> IOTimings:
        """One rolling save, blocking until committed; ``faults``
        (core.faults.FaultSpec) injects this save's degraded scenario
        through the write path. Any in-flight async save drains first
        (write ordering: steps commit in save order)."""
        self.block_until_done()
        d = Path(self.directory)
        d.mkdir(parents=True, exist_ok=True)
        _, t = save_checkpoint(
            tree, d / f"ckpt_{step:08d}", step=step,
            **self._save_kwargs(faults))
        self._gc()
        return t

    def save_async(self, tree, step: int, faults=None
                   ) -> PendingCheckpoint:
        """Start an async rolling save and return its
        :class:`PendingCheckpoint` without blocking on the collective
        write (only on the tree snapshot). At most one checkpoint is
        in flight: if a previous async save is still draining, this
        call blocks until it commits — a bounded queue of depth one —
        and re-raises its failure if it died unobserved (a silently
        lost checkpoint would defeat the crash-consistency story).
        Rolling GC runs on the drain thread after the manifest
        commits."""
        self.block_until_done()
        d = Path(self.directory)
        d.mkdir(parents=True, exist_ok=True)
        self.pending = save_checkpoint(
            tree, d / f"ckpt_{step:08d}", step=step, async_=True,
            on_commit=self._gc, **self._save_kwargs(faults))
        return self.pending

    def block_until_done(self) -> None:
        """Barrier on the in-flight async save (no-op when none). A
        drain that failed re-raises here UNLESS the caller already
        observed the exception through the future itself — the error
        surfaces exactly once, and the manager stays usable for the
        next save either way.

        The slot clears exactly when the future is FINISHED (committed
        or failed): clearing it eagerly before the wait meant an
        interrupt mid-drain (timeout, KeyboardInterrupt) silently
        orphaned a still-running write, and the next ``save_async``
        would start a second concurrent drain against the shared
        session — the one-in-flight invariant this method exists to
        hold. A dead future never wedges the slot either: once
        ``done()``, it is dropped even on the re-raise path."""
        p = self.pending
        if p is None:
            return
        observed_before = p.exception_observed
        try:
            p.wait()
        except BaseException:
            if not p.done():
                raise      # interrupted mid-drain: keep the live future
            if self.pending is p:
                self.pending = None
            if not observed_before:
                raise
        else:
            if self.pending is p:
                self.pending = None

    def latest_step(self) -> int | None:
        d = Path(self.directory)
        steps = sorted(int(p.name[5:13]) for p in
                       d.glob("ckpt_*.manifest.json"))
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None, shardings=None,
                *, subset=None, node_cache: bool = True,
                planned: bool | None = None, with_timings: bool = False):
        """Restore the latest (or a given) step through the planned
        collective read, using the manager's io/config/session — so
        repeated restores of the same manifest hit the read-plan cache
        exactly like repeated saves hit the write's. ``subset`` /
        ``node_cache`` / ``with_timings`` pass straight to
        :func:`restore_checkpoint`."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_checkpoint(
            Path(self.directory) / f"ckpt_{step:08d}", like_tree,
            shardings, subset=subset, io=self.io, config=self.config,
            session=self.session, node_cache=node_cache, planned=planned,
            with_timings=with_timings)

    def _gc(self):
        d = Path(self.directory)
        manifests = sorted(d.glob("ckpt_*.manifest.json"))
        for old in manifests[:-self.keep]:
            stem = old.name.replace(".manifest.json", "")
            for seg in d.glob(stem + ".seg*"):
                seg.unlink()
            old.unlink()
