"""Checkpoint save/restore through TAM collective I/O.

Layout: the train state pytree is serialized into one contiguous byte
space ("the file"): leaves in deterministic tree order, each leaf padded
to 256-B alignment. A manifest (JSON) records leaf paths, dtypes,
shapes, offsets. Each simulated host contributes its shards of every
leaf as (offset, length, payload) requests — exactly an MPI collective
write with an MPI file view — and ``HostCollectiveIO`` executes it with
the TAM or two-phase schedule.

Restore reads the striped segments back, reassembles the byte space,
and device_puts each leaf with the target sharding — which may belong
to a DIFFERENT mesh (elastic restart; see runtime.elastic).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.host_io import _UNSET, HostCollectiveIO, IOTimings
from repro.core.plan import IOConfig

ALIGN = 256


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def build_manifest(tree, step: int = 0) -> dict:
    entries = []
    offset = 0
    for path, leaf in _leaf_paths(tree):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize \
            if leaf.shape else leaf.dtype.itemsize
        entries.append({"path": path, "shape": list(leaf.shape),
                        "dtype": str(leaf.dtype), "offset": offset,
                        "nbytes": int(nbytes)})
        offset += -(-nbytes // ALIGN) * ALIGN
    return {"step": step, "file_len": offset, "leaves": entries}


def _rank_requests(tree, manifest, n_ranks: int):
    """Shard every leaf round-robin by rows across ranks -> per-rank
    (offsets, lengths, payload) request lists, offset-sorted."""
    reqs = [([], [], []) for _ in range(n_ranks)]
    for entry, (path, leaf) in zip(manifest["leaves"], _leaf_paths(tree)):
        arr = np.asarray(leaf)
        flat = arr.reshape(-1).view(np.uint8)
        chunk = max(len(flat) // n_ranks, 1)
        # each rank owns a contiguous span of the leaf's bytes
        for r in range(n_ranks):
            lo = min(r * chunk, len(flat))
            hi = len(flat) if r == n_ranks - 1 else min((r + 1) * chunk,
                                                        len(flat))
            if hi <= lo:
                continue
            reqs[r][0].append(entry["offset"] + lo)
            reqs[r][1].append(hi - lo)
            reqs[r][2].append(flat[lo:hi])
    out = []
    for o, l, d in reqs:
        if o:
            oo = np.asarray(o, np.int64)
            ll = np.asarray(l, np.int64)
            dd = np.concatenate(d)
            order = np.argsort(oo, kind="stable")
            starts = np.concatenate([[0], np.cumsum(ll)[:-1]])
            dd = np.concatenate([dd[starts[i]:starts[i] + ll[i]]
                                 for i in order])
            out.append((oo[order], ll[order], dd))
        else:
            out.append((np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.uint8)))
    return out


def save_checkpoint(tree, path: str | Path, *, step: int = 0,
                    io: HostCollectiveIO | None = None,
                    method: str = "tam",
                    local_aggregators: int | None = None,
                    cb_bytes: int | str | None = _UNSET,
                    pipeline: bool = _UNSET,
                    pipeline_depth: int | str | None = _UNSET,
                    slow_hop_codec: str | None = _UNSET,
                    placement=_UNSET,
                    session=None,
                    config: IOConfig | None = None,
                    kernel_fusion: str | None = _UNSET,
                    faults=None, heartbeat=None
                    ) -> tuple[dict, IOTimings]:
    """Serialize ``tree`` to ``<path>.seg*`` through the collective
    writer. Knobs: pass ONE ``config=IOConfig(...)`` (the unified
    surface — ``cb_buffer_size`` is byte units here; explicit per-knob
    kwargs are sparse overrides); the bare per-knob kwargs remain as a
    deprecated shim (one ``DeprecationWarning``, identical plan —
    asserted by tests/test_plan.py). ``faults`` / ``heartbeat`` pass
    straight to :meth:`HostCollectiveIO.write` — fault injection and
    failure detection for the degraded-mode scenarios (core.faults);
    recovered saves stay byte-identical to healthy ones."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    io = io or HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1 << 20,
                                stripe_count=4)
    manifest = build_manifest(tree, step)
    reqs = _rank_requests(tree, manifest, io.n_ranks)
    timings = io.write(reqs, str(path), method=method,
                       local_aggregators=local_aggregators,
                       config=config, cb_bytes=cb_bytes,
                       pipeline=pipeline,
                       pipeline_depth=pipeline_depth,
                       slow_hop_codec=slow_hop_codec,
                       placement=placement,
                       kernel_fusion=kernel_fusion, session=session,
                       faults=faults, heartbeat=heartbeat)
    manifest["stripe_size"] = io.stripe_size
    manifest["stripe_count"] = io.stripe_count
    (path.parent / (path.name + ".manifest.json")).write_text(
        json.dumps(manifest))
    return manifest, timings


def restore_checkpoint(path: str | Path, like_tree,
                       shardings=None):
    """Rebuild the pytree (optionally device_put with ``shardings`` —
    which may target a different mesh than the one that saved it)."""
    path = Path(path)
    manifest = json.loads(
        (path.parent / (path.name + ".manifest.json")).read_text())
    io = HostCollectiveIO(n_ranks=1, n_nodes=1,
                          stripe_size=manifest["stripe_size"],
                          stripe_count=manifest["stripe_count"])
    blob = io.read_file(str(path), manifest["file_len"])
    flat, treedef = jax.tree_util.tree_flatten(like_tree)
    leaves = []
    for entry, like in zip(manifest["leaves"], flat):
        raw = blob[entry["offset"]:entry["offset"] + entry["nbytes"]]
        arr = raw.view(np.dtype(entry["dtype"])).reshape(entry["shape"])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]


@dataclass
class CheckpointManager:
    """Rolling checkpoints + restart discovery."""

    directory: str | Path
    io: HostCollectiveIO
    method: str = "tam"
    local_aggregators: int | None = None
    config: IOConfig | None = None  # the unified knob surface: ONE
    # IOConfig carrying cb/pipeline/codec/placement/kernel_fusion
    # (byte units); any per-knob field set below is a sparse override
    cb_bytes: int | str | None = _UNSET   # DEPRECATED shim (rounds:
    # None = single shot, "auto" = cost-model autotuned) — use config
    pipeline: bool = _UNSET        # DEPRECATED shim — use config
    pipeline_depth: int | str | None = _UNSET  # DEPRECATED shim (the
    # depth-k ring; None = 2 when pipeline, "auto" = measured pick)
    slow_hop_codec: str | None = _UNSET  # DEPRECATED shim (lossless
    # wire codec on the LA -> GA hop; "auto" = modeled pick)
    placement: str | tuple | None = _UNSET  # DEPRECATED shim
    # (aggregator placement policy / permutation / "auto")
    kernel_fusion: str | None = _UNSET  # DEPRECATED shim (plan field
    # only — the host executor has no Pallas hot path)
    session: object | None = None  # IOSession (core.session): repeated
    # saves of the same state shape reuse the compiled plan and feed
    # measured timings back into the "auto" knobs — the manager holds
    # it so the cross-write loop survives across save() calls
    heartbeat: object | None = None  # HeartbeatMonitor
    # (runtime.heartbeat): the failure detector every save consults
    # when a fault spec injects a dead aggregator — the manager holds
    # it so detection latches across saves (kill-and-resume scenarios)
    keep: int = 3

    def save(self, tree, step: int, faults=None) -> IOTimings:
        """One rolling save; ``faults`` (core.faults.FaultSpec) injects
        this save's degraded scenario through the write path."""
        d = Path(self.directory)
        d.mkdir(parents=True, exist_ok=True)
        _, t = save_checkpoint(
            tree, d / f"ckpt_{step:08d}", step=step, io=self.io,
            method=self.method, local_aggregators=self.local_aggregators,
            config=self.config, cb_bytes=self.cb_bytes,
            pipeline=self.pipeline, pipeline_depth=self.pipeline_depth,
            slow_hop_codec=self.slow_hop_codec,
            placement=self.placement, kernel_fusion=self.kernel_fusion,
            session=self.session, faults=faults,
            heartbeat=self.heartbeat)
        self._gc()
        return t

    def latest_step(self) -> int | None:
        d = Path(self.directory)
        steps = sorted(int(p.name[5:13]) for p in
                       d.glob("ckpt_*.manifest.json"))
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_checkpoint(
            Path(self.directory) / f"ckpt_{step:08d}", like_tree,
            shardings)

    def _gc(self):
        d = Path(self.directory)
        manifests = sorted(d.glob("ckpt_*.manifest.json"))
        for old in manifests[:-self.keep]:
            stem = old.name.replace(".manifest.json", "")
            for seg in d.glob(stem + ".seg*"):
                seg.unlink()
            old.unlink()
