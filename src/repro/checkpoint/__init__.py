from repro.checkpoint.host_io import (  # noqa: F401
    HostCollectiveIO, IOTimings,
)
from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager, PendingCheckpoint, restore_checkpoint,
    save_checkpoint, snapshot_tree,
)
