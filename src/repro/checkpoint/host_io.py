"""Host-level collective I/O: the literal TAM reproduction.

On a real TPU fleet, checkpoint bytes leave through the hosts. This
module implements BOTH collective-write schedules over a set of
simulated "ranks" placed on "nodes":

* two-phase: every rank's (offset, length, payload) requests go straight
  to the global aggregator owning the stripe (all-to-many);
* TAM: ranks aggregate to P_L local aggregators inside their node
  (merge-sort + coalesce, numpy), then only local aggregators talk to
  the global aggregators.

Data movement is real (numpy), producing byte-identical files for both
schedules; *time* is modeled with the alpha-beta congestion machine from
``core.cost_model`` applied to the actual per-phase message sizes and
counts — receivers serialize incoming messages, which is exactly the
contention TAM removes (paper Fig. 2). This gives the Fig. 3-7
reproductions their x-axes without a 16k-core Cray.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.cost_model import Machine, Workload, optimal_cb


@dataclass
class IOTimings:
    intra_comm: float = 0.0
    intra_sort: float = 0.0
    intra_memcpy: float = 0.0
    inter_comm: float = 0.0
    inter_sort: float = 0.0
    io: float = 0.0
    messages_at_ga: int = 0        # max receives at one GA (per round)
    requests_before: int = 0
    requests_after: int = 0
    rounds_executed: int = 1       # exchange rounds (1 == single shot)
    overlap_saved: float = 0.0     # time hidden by the pipelined drain:
    # each steady-state round is charged max(comm, io) instead of their
    # sum, so total == serial total - overlap_saved
    overlap_fraction: float = 0.0  # overlap_saved / the hideable time
    # (the smaller of steady-state comm and io); 0 when serial or when
    # there is no steady state (single round)

    @property
    def comm(self) -> float:
        return self.intra_comm + self.inter_comm

    @property
    def total(self) -> float:
        return (self.intra_comm + self.intra_sort + self.intra_memcpy
                + self.inter_comm + self.inter_sort + self.io
                - self.overlap_saved)

    @property
    def coalesce_ratio(self) -> float:
        return self.requests_after / max(self.requests_before, 1)


PAIR_BYTES = 8  # offset + length metadata per request


def _to_domain_local(offs, stripe_size: int, stripe_count: int):
    """Byte position inside the owning GA's domain image (its stripes
    concatenated in round order) — mirrors ``domains.to_domain_local``."""
    return ((offs // stripe_size) // stripe_count) * stripe_size \
        + offs % stripe_size


def _merge_coalesce(reqs: list[tuple[np.ndarray, np.ndarray, np.ndarray]]):
    """Merge per-sender (offsets, lengths, payload), sort, coalesce.

    Returns (offsets, lengths, payload) with payload packed in sorted
    offset order (contiguous per coalesced run). Comparisons counted for
    the sort-time model.
    """
    offs = np.concatenate([r[0] for r in reqs]) if reqs else np.zeros(0, np.int64)
    lens = np.concatenate([r[1] for r in reqs]) if reqs else np.zeros(0, np.int64)
    data = np.concatenate([r[2] for r in reqs]) if reqs else np.zeros(0, np.uint8)
    if offs.size == 0:
        return offs, lens, data, 0
    order = np.argsort(offs, kind="stable")
    offs, lens = offs[order], lens[order]
    starts = np.concatenate([[0], np.cumsum(
        np.concatenate([r[1] for r in reqs]))[:-1]])
    packed = np.concatenate([
        data[starts[i]:starts[i] + lens_orig]
        for i, lens_orig in zip(order, lens)]) if data.size else data
    # coalesce adjacent contiguous runs
    boundary = np.ones(offs.size, bool)
    boundary[1:] = offs[1:] != offs[:-1] + lens[:-1]
    run = np.cumsum(boundary) - 1
    out_offs = offs[boundary]
    out_lens = np.bincount(run, weights=lens).astype(np.int64)
    n_cmp = int(offs.size * max(np.log2(max(len(reqs), 2)), 1))
    return out_offs, out_lens, packed, n_cmp


class HostCollectiveIO:
    """Collective write/read over simulated ranks -> striped file segments.

    ranks are grouped into ``n_nodes`` nodes; ``stripe_count`` global
    aggregators each own stripes ``s % stripe_count`` and write one file
    segment (``<path>.seg<g>``); a manifest maps stripes back.
    """

    def __init__(self, n_ranks: int, n_nodes: int, stripe_size: int,
                 stripe_count: int, machine: Machine | None = None):
        assert n_ranks % n_nodes == 0
        self.n_ranks, self.n_nodes = n_ranks, n_nodes
        self.stripe_size, self.stripe_count = stripe_size, stripe_count
        self.machine = machine or Machine()

    # ------------------------------------------------------------------
    def _split_stripes(self, offs, lens, data):
        """Split requests at stripe boundaries (ROMIO file-domain split)."""
        out_o, out_l = [], []
        for o, l in zip(offs, lens):
            while l > 0:
                within = o % self.stripe_size
                take = min(l, self.stripe_size - within)
                out_o.append(o)
                out_l.append(take)
                o += take
                l -= take
        return (np.asarray(out_o, np.int64), np.asarray(out_l, np.int64),
                data)

    def _owner(self, offs):
        return (offs // self.stripe_size) % self.stripe_count

    def _domain_local(self, offs):
        return _to_domain_local(offs, self.stripe_size, self.stripe_count)

    # ------------------------------------------------------------------
    def write(self, rank_requests, path: str, method: str = "tam",
              local_aggregators: int | None = None,
              failed_aggregators: set[int] | None = None,
              cb_bytes: int | str | None = None,
              pipeline: bool = False) -> IOTimings:
        """rank_requests: list of (offsets[int64], lengths[int64],
        payload[uint8]) per rank, offsets element=byte units here.
        method: "tam" | "twophase". Returns IOTimings; writes
        ``<path>.seg<g>`` files.

        failed_aggregators: ranks that must not serve as local
        aggregators (straggler/failure mitigation): each group falls
        back to its next healthy member — output is unchanged, the
        reassignment only costs one extra intra-node hop in the model.

        cb_bytes: aggregator collective-buffer bytes per round
        (stripe-aligned, mirroring ``rounds.RoundScheduler``). ``None``
        keeps the single-shot exchange; ``"auto"`` lets
        :meth:`auto_cb_bytes` pick the size minimizing the modeled
        total for this request set. Bytes written are identical either
        way; what changes is the TIMING: each round re-pays the incast
        latency ``alpha_eff(senders)`` per receive, exactly the cost
        model's round refinement.

        pipeline: double-buffer the rounds — round t+1's exchange
        overlaps round t's drain, so each steady-state round is charged
        ``max(comm, io)`` instead of their sum (``overlap_saved`` /
        ``overlap_fraction`` report the hidden time), and each segment
        is physically drained through a double-buffered background
        writer thread, one cb window at a time. Output bytes are
        identical to the serial path.
        """
        failed_aggregators = failed_aggregators or set()
        if cb_bytes == "auto":
            cb_bytes = self.auto_cb_bytes(
                rank_requests, method=method,
                local_aggregators=local_aggregators, pipeline=pipeline)
        if cb_bytes is not None and cb_bytes % self.stripe_size:
            raise ValueError("cb_bytes must be a stripe_size multiple")
        m = self.machine
        t = IOTimings()
        P, nodes = self.n_ranks, self.n_nodes
        q = P // nodes
        split = [self._split_stripes(*r) for r in rank_requests]
        t.requests_before = sum(s[0].size for s in split)

        if method == "twophase":
            per_la = split                      # every rank speaks for itself
            la_of_rank = list(range(P))
            P_L = P
        else:
            P_L = local_aggregators or nodes * 4
            assert P_L % nodes == 0
            c = P_L // nodes                    # local aggs per node
            per_la = []
            for node in range(nodes):
                node_ranks = range(node * q, (node + 1) * q)
                groups = np.array_split(np.array(list(node_ranks)), c)
                for g in groups:
                    # backup-aggregator selection: default LA = first
                    # rank of the group (paper's policy); skip failed
                    la = next((r for r in g
                               if r not in failed_aggregators), None)
                    if la is None and len(g):
                        raise RuntimeError(
                            f"no healthy aggregator in group {list(g)}")
                    reassigned = bool(len(g)) and \
                        int(g[0]) in failed_aggregators
                    merged = _merge_coalesce([split[r] for r in g])
                    offs, lens, packed, n_cmp = merged
                    # coalescing may fuse runs ACROSS stripe boundaries;
                    # re-split so each request has exactly one owner
                    # (ROMIO splits at file-domain boundaries the same way)
                    offs, lens, packed = self._split_stripes(
                        offs, lens, packed)
                    per_la.append((offs, lens, packed))
                    # intra-node timing: many-to-one receives + sort + copy
                    bytes_in = sum(int(split[r][1].sum()) +
                                   split[r][0].size * PAIR_BYTES for r in g)
                    reassign_penalty = m.alpha_intra if reassigned else 0.0
                    t.intra_comm = max(
                        t.intra_comm,
                        m.alpha_intra * len(g) + m.beta_intra * bytes_in
                        + reassign_penalty)
                    t.intra_sort = max(t.intra_sort, m.sort_per_cmp * n_cmp)
                    t.intra_memcpy = max(t.intra_memcpy,
                                         bytes_in / m.memcpy_bw)
        t.requests_after = sum(la[0].size for la in per_la)

        # ---- inter-node: local aggregators -> global aggregators -------
        # Round partition (mirrors core.rounds.RoundScheduler): round r
        # covers domain-local bytes [r*cb, (r+1)*cb) of every GA; with
        # cb_bytes=None everything lands in round 0 (single shot).
        n_rounds = 1
        if cb_bytes is not None:
            dom_ends = [int((self._domain_local(o) + l).max())
                        for o, l, _ in per_la if o.size]
            n_rounds = max(-(-max(dom_ends, default=1) // cb_bytes), 1)
        ga_inbox: list[list] = [[] for _ in range(self.stripe_count)]
        ga_msgs = np.zeros((self.stripe_count, n_rounds), np.int64)
        ga_bytes = np.zeros((self.stripe_count, n_rounds), np.int64)
        for offs, lens, packed in per_la:
            if offs.size == 0:
                continue
            owner = self._owner(offs)
            rnd = (self._domain_local(offs) // cb_bytes
                   if cb_bytes is not None
                   else np.zeros(offs.size, np.int64))
            starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
            for g in range(self.stripe_count):
                sel = owner == g
                if not sel.any():
                    continue
                po = offs[sel]
                pl = lens[sel]
                pd = np.concatenate([packed[s:s + l] for s, l in
                                     zip(starts[sel], pl)])
                ga_inbox[g].append((po, pl, pd))
                for r in np.unique(rnd[sel]):
                    in_r = rnd[sel] == r
                    ga_msgs[g, r] += 1       # one (re)send per round
                    ga_bytes[g, r] += (int(pl[in_r].sum())
                                       + int(in_r.sum()) * PAIR_BYTES)
        t.rounds_executed = n_rounds
        t.messages_at_ga = int(ga_msgs.max(initial=0))
        # per-round incast: a receiver with S concurrent senders pays
        # alpha_eff(S) each (cost_model refinement 2, applied to the
        # single-shot exchange too so the timings are comparable);
        # rounds serialize unless pipelined (accounted below).
        alpha = np.vectorize(m.alpha_eff)(ga_msgs) * ga_msgs
        comm_rounds = (alpha + m.beta_inter * ga_bytes).max(axis=0,
                                                           initial=0)
        t.inter_comm = float(comm_rounds.sum())

        # ---- I/O step: sort + write segments ---------------------------
        # pipelined: each segment drains through a double-buffered
        # background writer, one cb window at a time (byte-identical:
        # a single consumer writes the windows in order)
        img_lens = np.zeros(self.stripe_count, np.int64)
        for g in range(self.stripe_count):
            offs, lens, packed, n_cmp = _merge_coalesce(ga_inbox[g])
            t.inter_sort = max(t.inter_sort, m.sort_per_cmp * n_cmp)
            seg = _domain_image(offs, lens, packed, g, self.stripe_size,
                                self.stripe_count)
            _write_segment(f"{path}.seg{g}", seg,
                           cb_bytes if pipeline else None)
            img_lens[g] = seg.size
        t.io = float(img_lens.sum()) / m.io_bw

        # ---- pipelined overlap: round t+1's exchange runs while round
        # t's window drains, so the steady state pays max(comm, io) per
        # round; the prologue (first exchange) and epilogue (last
        # drain) stay exposed -------------------------------------------
        if pipeline and n_rounds > 0:
            cb = (cb_bytes if cb_bytes is not None
                  else max(int(img_lens.max(initial=1)), 1))
            lo = np.arange(n_rounds, dtype=np.int64) * cb
            # bytes GA g drains in round r: its image's overlap with
            # the window [r*cb, (r+1)*cb)
            io_rounds = (np.clip(img_lens[:, None] - lo[None, :], 0, cb)
                         .sum(axis=0) / m.io_bw)
            serial = float(comm_rounds.sum() + io_rounds.sum())
            span = float(comm_rounds[0]
                         + np.maximum(comm_rounds[1:], io_rounds[:-1]).sum()
                         + io_rounds[-1])
            t.overlap_saved = max(serial - span, 0.0)
            hideable = (float(min(comm_rounds[1:].sum(),
                                  io_rounds[:-1].sum()))
                        if n_rounds > 1 else 0.0)
            t.overlap_fraction = (min(t.overlap_saved / hideable, 1.0)
                                  if hideable > 0 else 0.0)
        return t

    # ------------------------------------------------------------------
    def auto_cb_bytes(self, rank_requests, method: str = "tam",
                      local_aggregators: int | None = None,
                      pipeline: bool = True) -> int:
        """Autotuned collective-buffer size for THIS request set: the
        stripe-aligned cb minimizing ``cost_model.optimal_cb``'s modeled
        total (pipelined when ``pipeline``) for the measured workload
        shape (P, nodes, P_G = stripe_count, request count, bytes)."""
        P = self.n_ranks
        total = float(sum(int(ln.sum()) for _, ln, _ in rank_requests))
        n_req = float(sum(o.size for o, _, _ in rank_requests))
        ext = max((int((o + ln).max()) for o, ln, _ in rank_requests
                   if o.size), default=self.stripe_size)
        n_str = -(-ext // self.stripe_size)
        dom_bytes = -(-n_str // self.stripe_count) * self.stripe_size
        cands, c = [], self.stripe_size
        while c < dom_bytes:
            cands.append(c)
            c *= 2
        cands.append(dom_bytes)
        w = Workload(P=P, nodes=self.n_nodes, P_G=self.stripe_count,
                     k=max(n_req, 1.0) / P, total_bytes=max(total, 1.0),
                     stripe_size=float(self.stripe_size),
                     overlap=1.0 if pipeline else 0.0)
        P_L = ((local_aggregators or self.n_nodes * 4)
               if method == "tam" else None)
        cb, _ = optimal_cb(w, self.machine, P_L=P_L,
                           candidates=tuple(cands))
        return cb

    # ------------------------------------------------------------------
    def read_file(self, path: str, file_len: int) -> np.ndarray:
        """Reassemble the full byte-space from the striped segments."""
        out = np.zeros(file_len, np.uint8)
        for g in range(self.stripe_count):
            with open(f"{path}.seg{g}", "rb") as f:
                seg = np.frombuffer(f.read(), np.uint8)
            # segment g holds stripes g, g+SC, g+2SC, ... concatenated
            n_str = seg.size // self.stripe_size
            for r in range(n_str):
                fo = (r * self.stripe_count + g) * self.stripe_size
                if fo >= file_len:
                    break
                take = min(self.stripe_size, file_len - fo)
                out[fo:fo + take] = seg[r * self.stripe_size:
                                        r * self.stripe_size + take]
        return out


def _write_segment(path: str, seg: np.ndarray,
                   cb_bytes: int | None) -> None:
    """Write one segment file; with ``cb_bytes`` set, drain it through
    a double-buffered background writer thread — one cb window is being
    written while the producer stages the next (mirroring the SPMD
    pipeline's two in-flight window buffers). A single consumer writes
    the windows in order, so the bytes on disk are identical to the
    direct write."""
    if cb_bytes is None or seg.size <= cb_bytes:
        with open(path, "wb") as f:
            f.write(seg.tobytes())
        return
    q: queue.Queue = queue.Queue(maxsize=1)
    error: list[BaseException] = []

    def drain(f):
        # on a write error, keep consuming (and discarding) so the
        # producer's q.put never blocks on a dead consumer; the error
        # re-raises in the producer after join
        while True:
            chunk = q.get()
            if chunk is None:
                return
            if not error:
                try:
                    f.write(chunk)
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    error.append(e)

    with open(path, "wb") as f:
        th = threading.Thread(target=drain, args=(f,))
        th.start()
        try:
            for lo in range(0, int(seg.size), cb_bytes):
                q.put(seg[lo:lo + cb_bytes].tobytes())
        finally:
            q.put(None)
            th.join()
    if error:
        raise error[0]


def _domain_image(offs, lens, packed, g, stripe_size, stripe_count):
    """Dense image of aggregator g's file domain (its stripes, in round
    order), mirroring core.domains.to_domain_local."""
    if offs.size == 0:
        return np.zeros(0, np.uint8)
    rounds = (offs // stripe_size) // stripe_count
    n_rounds = int(rounds.max()) + 1
    img = np.zeros(n_rounds * stripe_size, np.uint8)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    locals_ = _to_domain_local(offs, stripe_size, stripe_count)
    for o, l, s in zip(locals_, lens, starts):
        img[o:o + l] = packed[s:s + l]
    return img
