"""Host-level collective I/O: the literal TAM reproduction.

On a real TPU fleet, checkpoint bytes leave through the hosts. This
module implements BOTH collective-write schedules over a set of
simulated "ranks" placed on "nodes":

* two-phase: every rank's (offset, length, payload) requests go straight
  to the global aggregator owning the stripe (all-to-many);
* TAM: ranks aggregate to P_L local aggregators inside their node
  (merge-sort + coalesce, numpy), then only local aggregators talk to
  the global aggregators.

Since the plan/executor split (ARCHITECTURE.md), :class:`HostCollectiveIO`
is a thin wrapper: :meth:`HostCollectiveIO.plan_for` compiles the
schedule through the SAME planner the SPMD entry points use
(``repro.core.plan.compile_plan``, byte units), and
``repro.checkpoint.host_exec.execute_write`` runs it — round partition,
per-round incast timing, depth-k pipelined drain. Stage 1 (the
intra-node aggregation, which the SPMD executor expresses as mesh-axis
gathers) stays here because it is where ranks map onto nodes and
failed-aggregator fallback lives.

Data movement is real (numpy), producing byte-identical files for both
schedules at every ring depth; *time* is modeled with the alpha-beta
congestion machine from ``core.cost_model`` applied to the actual
per-phase message sizes and counts — receivers serialize incoming
messages, which is exactly the contention TAM removes (paper Fig. 2).
This gives the Fig. 3-7 reproductions their x-axes without a 16k-core
Cray.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.checkpoint import host_exec
from repro.checkpoint.host_exec import PAIR_BYTES  # noqa: F401 (compat)
from repro.core import codec as codec_mod
from repro.core.cost_model import Machine, Workload, optimal_cb, with_codec
from repro.core.domains import FileLayout
from repro.core.plan import (IOConfig, IOPlan, compile_plan,
                             resolve_method, resolve_slow_hop_codec)


@dataclass
class IOTimings:
    intra_comm: float = 0.0
    intra_sort: float = 0.0
    intra_memcpy: float = 0.0
    inter_comm: float = 0.0
    inter_sort: float = 0.0
    io: float = 0.0
    messages_at_ga: int = 0        # max receives at one GA (per round)
    requests_before: int = 0
    requests_after: int = 0
    rounds_executed: int = 1       # exchange rounds (1 == single shot)
    pipeline_depth: int = 1        # executed in-flight windows (1=serial)
    overlap_saved: float = 0.0     # time hidden by the pipelined drain:
    # the depth-k ring's makespan (cost_model.pipeline_span over the
    # measured per-round arrays) replaces the serial comm+io sum, so
    # total == serial total - overlap_saved
    overlap_fraction: float = 0.0  # overlap_saved / the hideable time
    # (the smaller of steady-state comm and io); 0 when serial or when
    # there is no steady state (single round)
    slow_hop_codec: str | None = None  # executed wire codec (None = off)
    slow_hop_raw_bytes: int = 0    # payload bytes offered to the codec
    slow_hop_wire_bytes: int = 0   # payload bytes after encoding (what
    # the per-round incast beta actually charged)
    codec: float = 0.0             # encode+decode scan time (codec_bw)

    @property
    def comm(self) -> float:
        return self.intra_comm + self.inter_comm

    @property
    def total(self) -> float:
        return (self.intra_comm + self.intra_sort + self.intra_memcpy
                + self.inter_comm + self.inter_sort + self.io
                + self.codec - self.overlap_saved)

    @property
    def coalesce_ratio(self) -> float:
        return self.requests_after / max(self.requests_before, 1)

    @property
    def slow_hop_compression_ratio(self) -> float:
        """Achieved raw/wire ratio on the slow hop (1.0 = codec off or
        nothing moved; > 1 means the wire moved fewer bytes)."""
        if self.slow_hop_wire_bytes <= 0:
            return 1.0
        return self.slow_hop_raw_bytes / self.slow_hop_wire_bytes


class HostCollectiveIO:
    """Collective write/read over simulated ranks -> striped file segments.

    ranks are grouped into ``n_nodes`` nodes; ``stripe_count`` global
    aggregators each own stripes ``s % stripe_count`` and write one file
    segment (``<path>.seg<g>``); a manifest maps stripes back.
    """

    def __init__(self, n_ranks: int, n_nodes: int, stripe_size: int,
                 stripe_count: int, machine: Machine | None = None):
        assert n_ranks % n_nodes == 0
        self.n_ranks, self.n_nodes = n_ranks, n_nodes
        self.stripe_size, self.stripe_count = stripe_size, stripe_count
        self.machine = machine or Machine()

    # ------------------------------------------------------------------
    def _split_stripes(self, offs, lens, data):
        """Split requests at stripe boundaries (ROMIO file-domain split)."""
        out_o, out_l = [], []
        for o, l in zip(offs, lens):
            while l > 0:
                within = o % self.stripe_size
                take = min(l, self.stripe_size - within)
                out_o.append(o)
                out_l.append(take)
                o += take
                l -= take
        return (np.asarray(out_o, np.int64), np.asarray(out_l, np.int64),
                data)

    def _owner(self, offs):
        return (offs // self.stripe_size) % self.stripe_count

    def _domain_local(self, offs):
        return host_exec.to_domain_local(offs, self.stripe_size,
                                         self.stripe_count)

    def _measured_workload(self, rank_requests, pipeline: bool = True,
                           slow_hop_codec: str | None = None) -> Workload:
        """Cost-model Workload for THIS request set (byte units).

        With a codec requested (a name, or ``"auto"`` which weighs the
        lossless byte codec), ``slow_hop_ratio`` is ESTIMATED from the
        payload's measured zero fraction through THAT codec's model
        (``codec.zero_fraction`` -> ``Codec.modeled_ratio``) — what
        ``slow_hop_codec="auto"`` weighs against the encode cost and
        what the CI gate compares to the achieved ratio. With no codec
        requested the O(total_bytes) zero scan is skipped entirely and
        the ratio stays 1.0 (codec-off model)."""
        P = self.n_ranks
        total = float(sum(int(ln.sum()) for _, ln, _ in rank_requests))
        n_req = float(sum(o.size for o, _, _ in rank_requests))
        ratio = 1.0
        if slow_hop_codec is not None:
            name = "rle" if slow_hop_codec == "auto" else slow_hop_codec
            zf = codec_mod.zero_fraction(d for _, _, d in rank_requests)
            ratio = codec_mod.get_codec(name).modeled_ratio(zf, total)
        return Workload(P=P, nodes=self.n_nodes, P_G=self.stripe_count,
                        k=max(n_req, 1.0) / P, total_bytes=max(total, 1.0),
                        stripe_size=float(self.stripe_size),
                        overlap=1.0 if pipeline else 0.0,
                        slow_hop_ratio=ratio)

    # ------------------------------------------------------------------
    def plan_for(self, *, method: str = "twophase",
                 cb_bytes: int | str | None = None,
                 pipeline: bool = False,
                 pipeline_depth: int | str | None = None,
                 file_len: int | None = None, rank_requests=None,
                 local_aggregators: int | None = None,
                 req_cap: int = 0, data_cap: int = 0,
                 coalesce_cap: int | None = None,
                 slow_hop_codec: str | None = None) -> IOPlan:
        """Compile this writer's schedule — the host side of the
        plan-identity contract: given the same layout/config, this and
        the SPMD ``twophase.plan_for`` produce the SAME
        :class:`IOPlan` (asserted by tests/test_plan.py). Units here
        are bytes. This is THE auto-resolution point for the host path
        (``write`` delegates): method resolves first (measured
        workload, shared ``plan.resolve_method``), then
        ``cb_bytes="auto"`` tunes for that method at the
        ``local_aggregators`` P_L the write will actually use.

        file_len defaults to the request set's extent padded so every
        aggregator domain is a whole number of cb windows (padding
        rounds are empty — they receive no messages and the makespan
        is invariant to them). req_cap/data_cap are the SPMD backend's
        static capacities; numpy is dynamic, so they default to 0 and
        are advisory here.
        """
        pipe = pipeline or pipeline_depth is not None
        # the ratio estimate costs an O(total_bytes) zero scan — only
        # pay it when something consumes it: the codec's own "auto"
        # resolution, or a named codec whose discount must feed another
        # auto knob (method / cb / depth)
        any_auto = (method == "auto" or cb_bytes == "auto"
                    or pipeline_depth == "auto")
        ratio_codec = (slow_hop_codec
                       if slow_hop_codec == "auto"
                       or (slow_hop_codec is not None and any_auto)
                       else None)
        workload = (self._measured_workload(rank_requests, pipe,
                                            ratio_codec)
                    if rank_requests is not None else None)
        # codec resolves before any other auto: its beta discount /
        # encode cost must be visible to the method and cb tuners, and
        # a codec-off plan must not keep the measured ratio estimate
        if workload is not None:
            if slow_hop_codec == "auto":
                slow_hop_codec = resolve_slow_hop_codec(workload,
                                                        self.machine)
            if slow_hop_codec is None and workload.slow_hop_ratio != 1.0:
                workload = with_codec(workload, 1.0)
        if method == "auto" and workload is not None:
            method = resolve_method(workload, self.machine)
        if cb_bytes == "auto":
            if rank_requests is None:
                raise ValueError(
                    'cb_bytes="auto" needs rank_requests to measure')
            cb_bytes = self.auto_cb_bytes(
                rank_requests, method=method,
                local_aggregators=local_aggregators, pipeline=pipe,
                workload=workload)
        if cb_bytes is not None and cb_bytes % self.stripe_size:
            raise ValueError("cb_bytes must be a stripe_size multiple")
        if file_len is None:
            ext = self.stripe_size
            if rank_requests is not None:
                ext = max((int((o + ln).max()) for o, ln, _ in rank_requests
                           if o.size), default=self.stripe_size)
            n_str = -(-ext // self.stripe_size)
            dom = -(-n_str // self.stripe_count) * self.stripe_size
            if cb_bytes is not None:       # whole number of windows
                dom = -(-dom // cb_bytes) * cb_bytes
            file_len = dom * self.stripe_count
        cfg = IOConfig(
            req_cap=req_cap, data_cap=data_cap, coalesce_cap=coalesce_cap,
            cb_buffer_size=cb_bytes, pipeline=pipe,
            pipeline_depth=(pipeline_depth if pipeline_depth is not None
                            else 2),
            slow_hop_codec=slow_hop_codec)
        return compile_plan(
            FileLayout(stripe_size=self.stripe_size,
                       stripe_count=self.stripe_count, file_len=file_len),
            cfg, n_aggregators=self.stripe_count, n_nodes=self.n_nodes,
            n_ranks=self.n_ranks, method=method, direction="write",
            machine=self.machine, workload=workload, unit_bytes=1)

    # ------------------------------------------------------------------
    def write(self, rank_requests, path: str, method: str = "tam",
              local_aggregators: int | None = None,
              failed_aggregators: set[int] | None = None,
              cb_bytes: int | str | None = None,
              pipeline: bool = False,
              pipeline_depth: int | str | None = None,
              slow_hop_codec: str | None = None) -> IOTimings:
        """rank_requests: list of (offsets[int64], lengths[int64],
        payload[uint8]) per rank, offsets element=byte units here.
        method: "tam" | "twophase" | "auto" (cost-model pick at plan
        time). Returns IOTimings; writes ``<path>.seg<g>`` files.

        failed_aggregators: ranks that must not serve as local
        aggregators (straggler/failure mitigation): each group falls
        back to its next healthy member — output is unchanged, the
        reassignment only costs one extra intra-node hop in the model.

        cb_bytes: aggregator collective-buffer bytes per round
        (stripe-aligned). ``None`` = the 1-round plan (single shot);
        ``"auto"`` lets :meth:`auto_cb_bytes` pick the size minimizing
        the modeled total for this request set. Bytes written are
        identical either way; what changes is the TIMING: each round
        re-pays the incast latency ``alpha_eff(senders)`` per receive,
        exactly the cost model's round refinement.

        pipeline / pipeline_depth: run the depth-k window ring — the
        exchange runs up to k-1 rounds ahead of the drain, each round
        is charged by the exact bounded-buffer makespan
        (``cost_model.pipeline_span``), and each segment is physically
        drained through a background writer thread fed one cb window
        at a time through k-1 queue slots. ``pipeline=True`` alone is
        the classic double buffer (k=2); ``pipeline_depth="auto"``
        re-resolves k against the MEASURED per-round arrays. Output
        bytes are identical to the serial path for every k.

        slow_hop_codec: per-round wire codec on the LA -> GA hop
        (``core.codec``). Only LOSSLESS byte codecs are admitted here —
        the payloads are raw bytes, so a lossy codec would corrupt the
        file. ``"auto"`` enables the codec when the modeled saving
        (from the payload's measured zero fraction) beats the encode
        cost. Encoded sizes are what the per-round incast charges, and
        the achieved ratio is reported
        (``IOTimings.slow_hop_compression_ratio``).
        """
        failed_aggregators = failed_aggregators or set()
        plan = self.plan_for(
            method=method, cb_bytes=cb_bytes, pipeline=pipeline,
            pipeline_depth=(2 if pipeline_depth == "auto"
                            else pipeline_depth),
            rank_requests=rank_requests,
            local_aggregators=local_aggregators,
            slow_hop_codec=slow_hop_codec)
        if plan.slow_hop_codec is not None and \
                not codec_mod.get_codec(plan.slow_hop_codec).lossless:
            raise ValueError(
                f"slow_hop_codec={plan.slow_hop_codec!r} is lossy; the "
                "host write path moves raw bytes — use a lossless codec "
                f"({codec_mod.lossless_codecs()})")
        m = self.machine
        t = IOTimings()
        P, nodes = self.n_ranks, self.n_nodes
        q = P // nodes
        split = [self._split_stripes(*r) for r in rank_requests]
        t.requests_before = sum(s[0].size for s in split)

        # ---- stage 1: intra-node aggregation (plan.method) -----------
        if plan.method == "twophase":
            per_la = split                  # every rank speaks for itself
        else:
            P_L = local_aggregators or nodes * 4
            assert P_L % nodes == 0
            c = P_L // nodes                # local aggs per node
            per_la = []
            for node in range(nodes):
                node_ranks = range(node * q, (node + 1) * q)
                groups = np.array_split(np.array(list(node_ranks)), c)
                for g in groups:
                    # backup-aggregator selection: default LA = first
                    # rank of the group (paper's policy); skip failed
                    la = next((r for r in g
                               if r not in failed_aggregators), None)
                    if la is None and len(g):
                        raise RuntimeError(
                            f"no healthy aggregator in group {list(g)}")
                    reassigned = bool(len(g)) and \
                        int(g[0]) in failed_aggregators
                    merged = host_exec.merge_coalesce(
                        [split[r] for r in g])
                    offs, lens, packed, n_cmp = merged
                    # coalescing may fuse runs ACROSS stripe boundaries;
                    # re-split so each request has exactly one owner
                    # (ROMIO splits at file-domain boundaries the same way)
                    offs, lens, packed = self._split_stripes(
                        offs, lens, packed)
                    per_la.append((offs, lens, packed))
                    # intra-node timing: many-to-one receives + sort + copy
                    bytes_in = sum(int(split[r][1].sum()) +
                                   split[r][0].size * PAIR_BYTES for r in g)
                    reassign_penalty = m.alpha_intra if reassigned else 0.0
                    t.intra_comm = max(
                        t.intra_comm,
                        m.alpha_intra * len(g) + m.beta_intra * bytes_in
                        + reassign_penalty)
                    t.intra_sort = max(t.intra_sort, m.sort_per_cmp * n_cmp)
                    t.intra_memcpy = max(t.intra_memcpy,
                                         bytes_in / m.memcpy_bw)
        t.requests_after = sum(la[0].size for la in per_la)

        # ---- inter-node exchange + I/O: the host executor ------------
        return host_exec.execute_write(
            plan, m, per_la, path, t,
            depth_request="auto" if pipeline_depth == "auto" else None)

    # ------------------------------------------------------------------
    def auto_cb_bytes(self, rank_requests, method: str = "tam",
                      local_aggregators: int | None = None,
                      pipeline: bool = True, workload=None) -> int:
        """Autotuned collective-buffer size for THIS request set: the
        stripe-aligned cb minimizing ``cost_model.optimal_cb``'s modeled
        total (pipelined when ``pipeline``) for the measured workload
        shape (P, nodes, P_G = stripe_count, request count, bytes).
        Pass ``workload`` to reuse an already-measured one."""
        ext = max((int((o + ln).max()) for o, ln, _ in rank_requests
                   if o.size), default=self.stripe_size)
        n_str = -(-ext // self.stripe_size)
        dom_bytes = -(-n_str // self.stripe_count) * self.stripe_size
        cands, c = [], self.stripe_size
        while c < dom_bytes:
            cands.append(c)
            c *= 2
        cands.append(dom_bytes)
        w = workload if workload is not None else \
            self._measured_workload(rank_requests, pipeline)
        P_L = ((local_aggregators or self.n_nodes * 4)
               if method == "tam" else None)
        cb, _ = optimal_cb(w, self.machine, P_L=P_L,
                           candidates=tuple(cands))
        return cb

    # ------------------------------------------------------------------
    def read_file(self, path: str, file_len: int) -> np.ndarray:
        """Reassemble the full byte-space from the striped segments."""
        out = np.zeros(file_len, np.uint8)
        for g in range(self.stripe_count):
            with open(f"{path}.seg{g}", "rb") as f:
                seg = np.frombuffer(f.read(), np.uint8)
            # segment g holds stripes g, g+SC, g+2SC, ... concatenated
            n_str = seg.size // self.stripe_size
            for r in range(n_str):
                fo = (r * self.stripe_count + g) * self.stripe_size
                if fo >= file_len:
                    break
                take = min(self.stripe_size, file_len - fo)
                out[fo:fo + take] = seg[r * self.stripe_size:
                                        r * self.stripe_size + take]
        return out


# Backwards-compatible aliases: the executor bodies moved to host_exec.
_merge_coalesce = host_exec.merge_coalesce
_write_segment = host_exec.write_segment
_domain_image = host_exec.domain_image
_to_domain_local = host_exec.to_domain_local
