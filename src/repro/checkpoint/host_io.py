"""Host-level collective I/O: the literal TAM reproduction.

On a real TPU fleet, checkpoint bytes leave through the hosts. This
module implements BOTH collective-write schedules over a set of
simulated "ranks" placed on "nodes":

* two-phase: every rank's (offset, length, payload) requests go straight
  to the global aggregator owning the stripe (all-to-many);
* TAM: ranks aggregate to P_L local aggregators inside their node
  (merge-sort + coalesce, numpy), then only local aggregators talk to
  the global aggregators.

Since the plan/executor split (ARCHITECTURE.md), :class:`HostCollectiveIO`
is a thin wrapper: :meth:`HostCollectiveIO.plan_for` compiles the
schedule through the SAME planner the SPMD entry points use
(``repro.core.plan.compile_plan``, byte units), and
``repro.checkpoint.host_exec.execute_write`` runs it — round partition,
per-round incast timing, depth-k pipelined drain. Stage 1 (the
intra-node aggregation, which the SPMD executor expresses as mesh-axis
gathers) stays here because it is where ranks map onto nodes and
failed-aggregator fallback lives.

Data movement is real (numpy), producing byte-identical files for both
schedules at every ring depth; *time* is modeled with the alpha-beta
congestion machine from ``core.cost_model`` applied to the actual
per-phase message sizes and counts — receivers serialize incoming
messages, which is exactly the contention TAM removes (paper Fig. 2).
This gives the Fig. 3-7 reproductions their x-axes without a 16k-core
Cray.
"""
from __future__ import annotations

import os
import time
import warnings
from dataclasses import dataclass

import numpy as np

from repro.checkpoint import host_exec, mp_exec
from repro.checkpoint.host_exec import PAIR_BYTES  # noqa: F401 (compat)
from repro.core import codec as codec_mod
from repro.core.cost_model import (Machine, Workload, optimal_cb,
                                   optimal_read_cb, with_codec)
from repro.core.domains import FileLayout
from repro.core.faults import TornWriteError, partial_marker
from repro.core.plan import (IOConfig, IOPlan, compile_plan,
                             resolve_method, resolve_slow_hop_codec)
from repro.core.session import IOSession  # noqa: F401 (re-export)

# sentinel distinguishing "caller never passed this legacy kwarg" from
# an explicit None (None is a meaningful knob value: codec off,
# placement off, single-shot cb)
_UNSET: object = object()

_KNOB_FIELDS = ("cb_bytes", "pipeline", "pipeline_depth",
                "slow_hop_codec", "placement", "kernel_fusion",
                "transport")


def resolve_knobs(config: IOConfig | None, *, warn: bool = False,
                  stacklevel: int = 3, **legacy) -> dict:
    """The unified knob surface: fold a single :class:`IOConfig` and/or
    per-knob legacy kwargs into concrete knob values.

    ``config=None`` + legacy kwargs is the pre-config calling
    convention — it still works, but the user-facing entry points
    (``HostCollectiveIO.write``, ``save_checkpoint``,
    ``CheckpointManager``) pass ``warn=True`` so it raises ONE
    :class:`DeprecationWarning` per call site. With a config, explicit
    legacy kwargs act as sparse overrides of the config's fields (no
    warning — that is the supported way to vary one knob off a shared
    config). Knob names map 1:1 onto IOConfig fields except
    ``cb_bytes`` ↔ ``cb_buffer_size`` (host units are bytes) and the
    pipeline pair: a non-pipelined config yields
    ``pipeline_depth=None`` (the host convention for "serial"), so a
    config round-trips to the identical plan the legacy kwargs built.
    """
    legacy = {k: v for k, v in legacy.items() if v is not _UNSET}
    unknown = set(legacy) - set(_KNOB_FIELDS)
    if unknown:
        raise TypeError(f"unknown knob(s): {sorted(unknown)}")
    if config is None:
        if legacy and warn:
            warnings.warn(
                "per-knob kwargs (cb_bytes / pipeline / pipeline_depth /"
                " slow_hop_codec / placement / kernel_fusion /"
                " transport) are deprecated; pass config=IOConfig(...) —"
                " legacy kwargs on top of a config act as sparse"
                " overrides",
                DeprecationWarning, stacklevel=stacklevel)
        out = dict(cb_bytes=None, pipeline=False, pipeline_depth=None,
                   slow_hop_codec=None, placement=None,
                   kernel_fusion=None, transport=None)
    else:
        out = dict(
            cb_bytes=config.cb_buffer_size,
            pipeline=config.pipeline,
            pipeline_depth=(config.pipeline_depth if config.pipeline
                            else None),
            slow_hop_codec=config.slow_hop_codec,
            placement=config.placement,
            kernel_fusion=config.kernel_fusion,
            transport=getattr(config, "transport", None))
    out.update(legacy)
    return out


@dataclass
class IOTimings:
    intra_comm: float = 0.0
    intra_sort: float = 0.0
    intra_memcpy: float = 0.0
    inter_comm: float = 0.0
    inter_sort: float = 0.0
    io: float = 0.0
    messages_at_ga: int = 0        # max receives at one GA (per round)
    requests_before: int = 0
    requests_after: int = 0
    rounds_executed: int = 1       # exchange rounds (1 == single shot)
    pipeline_depth: int = 1        # executed in-flight windows (1=serial)
    overlap_saved: float = 0.0     # time hidden by the pipelined drain:
    # the depth-k ring's makespan (cost_model.pipeline_span over the
    # measured per-round arrays) replaces the serial comm+io sum, so
    # total == serial total - overlap_saved
    overlap_fraction: float = 0.0  # overlap_saved / the hideable time
    # (the smaller of steady-state comm and io); 0 when serial or when
    # there is no steady state (single round)
    slow_hop_codec: str | None = None  # executed wire codec (None = off)
    slow_hop_raw_bytes: int = 0    # payload bytes offered to the codec
    slow_hop_wire_bytes: int = 0   # payload bytes after encoding (what
    # the per-round incast beta actually charged)
    codec: float = 0.0             # encode+decode scan time (codec_bw)
    placement: tuple | None = None  # executed aggregator placement
    # (plan.placement; None = placement-off legacy accounting)
    slow_hop_fast_bytes: int = 0   # slow-hop bytes that stayed on the
    # serving aggregator's node under the placement (charged intra)
    slow_hop_slow_bytes: int = 0   # slow-hop bytes that crossed nodes
    node_bytes: tuple = ()         # measured per-(domain, sender-node)
    # payload matrix — what a session feeds resolve_placement("auto")
    comm_rounds: tuple = ()        # measured per-round exchange times
    io_rounds: tuple = ()          # measured per-round drain times
    plan_seconds: float = 0.0      # REAL wall-clock planning time (the
    # cost a session amortizes; every other field is modeled seconds)
    plan_source: str = "compiled"  # "compiled" | "session-hit" |
    # "session-trial" (a measured-feedback replan being tried out)
    node_slowdown: tuple = ()      # measured per-node service slowdown
    # (seconds-per-byte served, normalized by the fastest busy node;
    # 1.0 = healthy) — the straggler signal placement="auto" and the
    # session's evacuation map consume (core.faults)
    serve_map: tuple | None = None  # executed degraded serve map
    # (domain -> serving slot, possibly non-bijective; None = the
    # plan's bijective placement served every domain)
    retries: int = 0               # lost slow-hop messages re-sent
    # (bounded by FaultSpec.max_retries; each charged timeout+backoff)
    recovery_seconds: float = 0.0  # total fault-recovery time: dead-
    # aggregator detection + round replay + torn-segment rewrites —
    # reported separately, and added to .total (recovery is real time)
    repair_map: tuple | None = None  # post-repair serve map after a
    # dead aggregator (None = no repair happened)
    torn_writes_detected: int = 0  # partial-write markers detected and
    # repaired by rewrite (drain faults + dead-aggregator tears)
    transport: str | None = None   # which byte-moving backend produced
    # this measurement ("mp" = real processes + wall-clock rounds;
    # None = in-process executor, modeled time) — sessions key on it so
    # feedback never crosses executors
    direction: str = "write"       # which executor filled this
    node_cache: bool | None = None  # read path: node-level window cache
    # on/off (None = a write; the knob does not exist there)
    cache_hits: int = 0            # read deliveries served from a node's
    # window cache (co-located readers after the elected fetch)
    cache_misses: int = 0          # window fetches that left the serving
    # aggregator: one per (window, node) with the cache on, one per
    # (window, rank) without — the q-fold duplication the cache deletes
    read_bytes: int = 0            # bytes read from disk, once per
    # needed window (the subset-restore economy measure)
    snapshot_seconds: float = 0.0  # REAL wall time an async save spent
    # copying the tree to host buffers (checkpoint.snapshot_tree) —
    # the only part of an async checkpoint the caller's step blocks on
    drain_wall_seconds: float = 0.0  # REAL wall time of the async
    # background drain (snapshot -> manifest commit); 0 on sync writes
    overlap_hidden_seconds: float = 0.0  # the part of the async drain
    # that ran before the caller first blocked on the future — real
    # write time hidden behind the application's compute
    # (checkpoint.PendingCheckpoint fixes it at the first wait())

    @property
    def hidden_fraction(self) -> float:
        """Fraction of the async drain's wall time hidden behind the
        caller's compute (0.0 = sync write, or the caller blocked
        immediately; 1.0 = the drain finished before anyone waited)."""
        if self.drain_wall_seconds <= 0.0:
            return 0.0
        return self.overlap_hidden_seconds / self.drain_wall_seconds

    @property
    def cache_hit_ratio(self) -> float:
        """Fraction of read deliveries served intra-node from a window
        cache (0.0 = every delivery paid a fetch; a write reports 0)."""
        return self.cache_hits / max(self.cache_hits
                                     + self.cache_misses, 1)

    @property
    def comm(self) -> float:
        return self.intra_comm + self.inter_comm

    @property
    def total(self) -> float:
        return (self.intra_comm + self.intra_sort + self.intra_memcpy
                + self.inter_comm + self.inter_sort + self.io
                + self.codec - self.overlap_saved
                + self.recovery_seconds)

    @property
    def coalesce_ratio(self) -> float:
        return self.requests_after / max(self.requests_before, 1)

    @property
    def slow_hop_compression_ratio(self) -> float:
        """Achieved raw/wire ratio on the slow hop (1.0 = codec off or
        nothing moved; > 1 means the wire moved fewer bytes)."""
        if self.slow_hop_wire_bytes <= 0:
            return 1.0
        return self.slow_hop_raw_bytes / self.slow_hop_wire_bytes


class HostCollectiveIO:
    """Collective write/read over simulated ranks -> striped file segments.

    ranks are grouped into ``n_nodes`` nodes; ``stripe_count`` global
    aggregators each own stripes ``s % stripe_count`` and write one file
    segment (``<path>.seg<g>``); a manifest maps stripes back.
    """

    def __init__(self, n_ranks: int, n_nodes: int, stripe_size: int,
                 stripe_count: int, machine: Machine | None = None,
                 session: "IOSession | None" = None):
        assert n_ranks % n_nodes == 0
        self.n_ranks, self.n_nodes = n_ranks, n_nodes
        self.stripe_size, self.stripe_count = stripe_size, stripe_count
        self.machine = machine or Machine()
        # cross-write plan cache + measured-feedback tuner; every write
        # may also pass its own (write(session=...) overrides)
        self.session = session

    # ------------------------------------------------------------------
    def _split_stripes(self, offs, lens, data):
        """Split requests at stripe boundaries (ROMIO file-domain split)."""
        out_o, out_l = [], []
        for o, l in zip(offs, lens):
            while l > 0:
                within = o % self.stripe_size
                take = min(l, self.stripe_size - within)
                out_o.append(o)
                out_l.append(take)
                o += take
                l -= take
        return (np.asarray(out_o, np.int64), np.asarray(out_l, np.int64),
                data)

    def _owner(self, offs):
        return (offs // self.stripe_size) % self.stripe_count

    def _domain_local(self, offs):
        return host_exec.to_domain_local(offs, self.stripe_size,
                                         self.stripe_count)

    def _measured_workload(self, rank_requests, pipeline: bool = True,
                           slow_hop_codec: str | None = None) -> Workload:
        """Cost-model Workload for THIS request set (byte units).

        With a codec requested (a name, or ``"auto"`` which weighs the
        lossless byte codec), ``slow_hop_ratio`` is ESTIMATED from the
        payload's measured zero fraction through THAT codec's model
        (``codec.zero_fraction`` -> ``Codec.modeled_ratio``) — what
        ``slow_hop_codec="auto"`` weighs against the encode cost and
        what the CI gate compares to the achieved ratio. With no codec
        requested the O(total_bytes) zero scan is skipped entirely and
        the ratio stays 1.0 (codec-off model)."""
        P = self.n_ranks
        total = float(sum(int(ln.sum()) for _, ln, _ in rank_requests))
        n_req = float(sum(o.size for o, _, _ in rank_requests))
        ratio = 1.0
        if slow_hop_codec is not None:
            name = "rle" if slow_hop_codec == "auto" else slow_hop_codec
            zf = codec_mod.zero_fraction(d for _, _, d in rank_requests)
            ratio = codec_mod.get_codec(name).modeled_ratio(zf, total)
        return Workload(P=P, nodes=self.n_nodes, P_G=self.stripe_count,
                        k=max(n_req, 1.0) / P, total_bytes=max(total, 1.0),
                        stripe_size=float(self.stripe_size),
                        overlap=1.0 if pipeline else 0.0,
                        slow_hop_ratio=ratio)

    # ------------------------------------------------------------------
    def _ratio_codec(self, method, cb_bytes, pipeline_depth,
                     slow_hop_codec):
        """Which codec (if any) the measured-ratio zero scan should
        model: the codec's own ``"auto"`` resolution, or a named codec
        whose discount must feed another auto knob — otherwise the
        O(total_bytes) scan is skipped entirely."""
        any_auto = (method == "auto" or cb_bytes == "auto"
                    or pipeline_depth == "auto")
        return (slow_hop_codec
                if slow_hop_codec == "auto"
                or (slow_hop_codec is not None and any_auto)
                else None)

    @staticmethod
    def _extent(rank_requests, default: int = 0) -> int:
        """Last written byte of a request set (the layout fingerprint
        everything extent-derived shares: the session key, the cb
        candidate sweep, and the plan's file_len padding)."""
        return max((int((o + ln).max()) for o, ln, _ in rank_requests
                    if o.size), default=default)

    def _cb_candidates(self, rank_requests) -> tuple[int, ...]:
        """Stripe-aligned cb candidates for THIS request set's extent
        (what ``auto_cb_bytes`` sweeps; a session stores them so a
        measured re-resolution never re-derives the extent)."""
        ext = self._extent(rank_requests, self.stripe_size)
        n_str = -(-ext // self.stripe_size)
        dom_bytes = -(-n_str // self.stripe_count) * self.stripe_size
        cands, c = [], self.stripe_size
        while c < dom_bytes:
            cands.append(c)
            c *= 2
        cands.append(dom_bytes)
        return tuple(cands)

    def workload_for(self, rank_requests, *, method: str = "twophase",
                     cb_bytes=None, pipeline: bool = False,
                     pipeline_depth=None,
                     slow_hop_codec: str | None = None) -> Workload:
        """The measured workload a write with these knobs would resolve
        its autos against (what a session stores alongside the plan)."""
        pipe = pipeline or pipeline_depth is not None
        return self._measured_workload(
            rank_requests, pipe,
            self._ratio_codec(method, cb_bytes, pipeline_depth,
                              slow_hop_codec))

    # ------------------------------------------------------------------
    def plan_for(self, *, method: str = "twophase",
                 cb_bytes: int | str | None = _UNSET,
                 pipeline: bool = _UNSET,
                 pipeline_depth: int | str | None = _UNSET,
                 file_len: int | None = None, rank_requests=None,
                 local_aggregators: int | None = None,
                 req_cap: int = _UNSET, data_cap: int = _UNSET,
                 coalesce_cap: int | None = _UNSET,
                 slow_hop_codec: str | None = _UNSET,
                 placement=_UNSET, workload: Workload | None = None,
                 config: IOConfig | None = None,
                 kernel_fusion: str | None = _UNSET,
                 transport: str | None = _UNSET,
                 direction: str = "write") -> IOPlan:
        """Compile this writer's schedule — the host side of the
        plan-identity contract: given the same layout/config, this and
        the SPMD ``twophase.plan_for`` produce the SAME
        :class:`IOPlan` (asserted by tests/test_plan.py). Units here
        are bytes. This is THE auto-resolution point for the host path
        (``write`` delegates): method resolves first (measured
        workload, shared ``plan.resolve_method``), then
        ``cb_bytes="auto"`` tunes for that method at the
        ``local_aggregators`` P_L the write will actually use.

        file_len defaults to the request set's extent padded so every
        aggregator domain is a whole number of cb windows (padding
        rounds are empty — they receive no messages and the makespan
        is invariant to them). req_cap/data_cap are the SPMD backend's
        static capacities; numpy is dynamic, so they default to 0 and
        are advisory here.

        ``config`` is the unified knob surface (:func:`resolve_knobs`):
        one :class:`IOConfig` carrying cb/pipeline/codec/placement/
        kernel_fusion (and the caps), with any explicit per-knob kwarg
        acting as a sparse override. Given equivalent knobs, the config
        and legacy spellings compile the IDENTICAL plan (asserted by
        tests/test_plan.py).

        ``direction="read"`` compiles a restore schedule through the
        same passes: ``cb_bytes="auto"`` sweeps
        ``cost_model.optimal_read_cb`` (fan-out, not incast) and the
        depth resolves against the read round shape
        (``resolve_cb_and_depth``'s read branch). rank_requests may
        carry EMPTY payloads here — a read has none to fingerprint, so
        ``slow_hop_codec="auto"`` resolves off (ratio 1.0); named
        codecs still execute on the wire.
        """
        k = resolve_knobs(config, cb_bytes=cb_bytes, pipeline=pipeline,
                          pipeline_depth=pipeline_depth,
                          slow_hop_codec=slow_hop_codec,
                          placement=placement, kernel_fusion=kernel_fusion,
                          transport=transport)
        cb_bytes, pipeline = k["cb_bytes"], k["pipeline"]
        pipeline_depth = k["pipeline_depth"]
        slow_hop_codec, placement = k["slow_hop_codec"], k["placement"]
        kernel_fusion = k["kernel_fusion"]
        transport = k["transport"]
        if config is not None:
            caps = (config.req_cap, config.data_cap, config.coalesce_cap)
        else:
            caps = (0, 0, None)
        req_cap = caps[0] if req_cap is _UNSET else req_cap
        data_cap = caps[1] if data_cap is _UNSET else data_cap
        coalesce_cap = caps[2] if coalesce_cap is _UNSET else coalesce_cap
        pipe = pipeline or pipeline_depth is not None
        # the ratio estimate costs an O(total_bytes) zero scan — only
        # pay it when something consumes it (see _ratio_codec); a
        # caller-supplied workload (the session's stored measurement)
        # skips the scan entirely
        if workload is None and rank_requests is not None:
            workload = self._measured_workload(
                rank_requests, pipe,
                self._ratio_codec(method, cb_bytes, pipeline_depth,
                                  slow_hop_codec))
        # codec resolves before any other auto: its beta discount /
        # encode cost must be visible to the method and cb tuners, and
        # a codec-off plan must not keep the measured ratio estimate
        if workload is not None:
            if slow_hop_codec == "auto":
                slow_hop_codec = resolve_slow_hop_codec(workload,
                                                        self.machine)
            if slow_hop_codec is None and workload.slow_hop_ratio != 1.0:
                workload = with_codec(workload, 1.0)
        if method == "auto" and workload is not None:
            method = resolve_method(workload, self.machine)
        if cb_bytes == "auto":
            if rank_requests is None:
                raise ValueError(
                    'cb_bytes="auto" needs rank_requests to measure')
            cb_bytes = self.auto_cb_bytes(
                rank_requests, method=method,
                local_aggregators=local_aggregators, pipeline=pipe,
                workload=workload, direction=direction)
        if cb_bytes is not None and cb_bytes % self.stripe_size \
                and self.stripe_size % cb_bytes:
            # RoundScheduler's alignment rule: whole-stripe multiples
            # or exact sub-stripe divisors (windows never straddle a
            # stripe boundary either way)
            raise ValueError("cb_bytes must align with stripe_size")
        if file_len is None:
            ext = self.stripe_size
            if rank_requests is not None:
                ext = self._extent(rank_requests, self.stripe_size)
            n_str = -(-ext // self.stripe_size)
            dom = -(-n_str // self.stripe_count) * self.stripe_size
            if cb_bytes is not None:       # whole number of windows
                dom = -(-dom // cb_bytes) * cb_bytes
            file_len = dom * self.stripe_count
        cfg = IOConfig(
            req_cap=req_cap, data_cap=data_cap, coalesce_cap=coalesce_cap,
            cb_buffer_size=cb_bytes, pipeline=pipe,
            pipeline_depth=(pipeline_depth if pipeline_depth is not None
                            else 2),
            slow_hop_codec=slow_hop_codec,
            placement=(tuple(placement)
                       if isinstance(placement, (list, tuple))
                       else placement),
            kernel_fusion=kernel_fusion, transport=transport)
        return compile_plan(
            FileLayout(stripe_size=self.stripe_size,
                       stripe_count=self.stripe_count, file_len=file_len),
            cfg, n_aggregators=self.stripe_count, n_nodes=self.n_nodes,
            n_ranks=self.n_ranks, method=method, direction=direction,
            machine=self.machine, workload=workload, unit_bytes=1)

    # ------------------------------------------------------------------
    def write(self, rank_requests, path: str, method: str = "tam",
              local_aggregators: int | None = None,
              failed_aggregators: set[int] | None = None,
              cb_bytes: int | str | None = _UNSET,
              pipeline: bool = _UNSET,
              pipeline_depth: int | str | None = _UNSET,
              slow_hop_codec: str | None = _UNSET,
              placement=_UNSET,
              session: "IOSession | None" = None,
              config: IOConfig | None = None,
              kernel_fusion: str | None = _UNSET,
              transport: str | None = _UNSET,
              faults=None, heartbeat=None) -> IOTimings:
        """rank_requests: list of (offsets[int64], lengths[int64],
        payload[uint8]) per rank, offsets element=byte units here.
        method: "tam" | "twophase" | "auto" (cost-model pick at plan
        time). Returns IOTimings; writes ``<path>.seg<g>`` files.

        failed_aggregators: ranks that must not serve as local
        aggregators (straggler/failure mitigation): each group falls
        back to its next healthy member — output is unchanged, the
        reassignment only costs one extra intra-node hop in the model.

        cb_bytes: aggregator collective-buffer bytes per round
        (stripe-aligned). ``None`` = the 1-round plan (single shot);
        ``"auto"`` lets :meth:`auto_cb_bytes` pick the size minimizing
        the modeled total for this request set. Bytes written are
        identical either way; what changes is the TIMING: each round
        re-pays the incast latency ``alpha_eff(senders)`` per receive,
        exactly the cost model's round refinement.

        pipeline / pipeline_depth: run the depth-k window ring — the
        exchange runs up to k-1 rounds ahead of the drain, each round
        is charged by the exact bounded-buffer makespan
        (``cost_model.pipeline_span``), and each segment is physically
        drained through a background writer thread fed one cb window
        at a time through k-1 queue slots. ``pipeline=True`` alone is
        the classic double buffer (k=2); ``pipeline_depth="auto"``
        re-resolves k against the MEASURED per-round arrays. Output
        bytes are identical to the serial path for every k.

        slow_hop_codec: per-round wire codec on the LA -> GA hop
        (``core.codec``). Only LOSSLESS byte codecs are admitted here —
        the payloads are raw bytes, so a lossy codec would corrupt the
        file. ``"auto"`` enables the codec when the modeled saving
        (from the payload's measured zero fraction) beats the encode
        cost. Encoded sizes are what the per-round incast charges, and
        the achieved ratio is reported
        (``IOTimings.slow_hop_compression_ratio``).

        placement: aggregator placement (``core.placement``): a policy
        name ("packed" / "spread" / "node_balanced"), an explicit
        permutation, or ``"auto"`` (cost-model argmin; a session
        re-resolves it against the MEASURED per-(domain, sender-node)
        byte matrix). With a placement, the per-round incast charges
        the placement-induced sender sets: same-node messages move at
        the intra rates, the rest pay ``alpha_eff``/``beta_inter`` —
        bytes written are identical either way. ``None`` = off (legacy
        all-inter accounting).

        session: an :class:`~repro.core.session.IOSession` (defaults to
        the writer's own). Repeated writes of the same (layout, config)
        reuse the compiled plan (``IOTimings.plan_seconds`` ~ 0,
        ``plan_source="session-hit"``) and every ``"auto"`` knob is
        re-resolved ONCE against the previous write's measurements
        (``plan_source="session-trial"``); thereafter the best plan by
        measured total wins.

        config: the unified knob surface — ONE :class:`IOConfig`
        carrying every knob above (:func:`resolve_knobs`;
        ``cb_buffer_size`` is ``cb_bytes`` here, byte units). Explicit
        per-knob kwargs on top of a config are sparse overrides; the
        per-knob kwargs WITHOUT a config are the deprecated legacy
        spelling and raise one :class:`DeprecationWarning`. The numpy
        executor has no Pallas hot path, so ``kernel_fusion`` is
        accepted (plan field set, shared with the SPMD backend) but is
        a no-op at execution time — bytes are identical either way.

        faults / heartbeat: the fault-injection hook
        (``core.faults.FaultSpec``) and the failure detector
        (``runtime.heartbeat.HeartbeatMonitor``) — threaded straight
        to ``host_exec.execute_write``, NEVER into the plan or the
        session key (a fault is a property of the machine-now, not of
        the schedule; the session sees it only through the MEASURED
        feedback — node_slowdown, degraded round times — which is the
        whole point of the self-healing loop). Injected node slowdowns
        also scale this writer's stage-1 intra timing, so the straggler
        is visible end to end. A write that raises mid-trial reverts
        its session trial (``IOSession.abort``) instead of poisoning
        the entry.

        transport: the byte-moving backend (``core.transport``).
        ``None`` runs the in-process host executor (modeled time);
        ``"mp"`` runs the same plan on real worker processes
        (``checkpoint.mp_exec``) — byte-identical segments, but the
        round timings a session observes are measured wall-clock. Part
        of the plan/session key: switching transports never reuses the
        other executor's measured totals.
        """
        knobs = resolve_knobs(config, warn=True, cb_bytes=cb_bytes,
                              pipeline=pipeline,
                              pipeline_depth=pipeline_depth,
                              slow_hop_codec=slow_hop_codec,
                              placement=placement,
                              kernel_fusion=kernel_fusion,
                              transport=transport)
        cb_bytes, pipeline = knobs["cb_bytes"], knobs["pipeline"]
        pipeline_depth = knobs["pipeline_depth"]
        slow_hop_codec = knobs["slow_hop_codec"]
        placement = knobs["placement"]
        kernel_fusion = knobs["kernel_fusion"]
        transport = knobs["transport"]
        failed_aggregators = failed_aggregators or set()
        plan_t0 = time.perf_counter()
        session = session if session is not None else self.session
        plan, source, skey, serve_map = None, "compiled", None, None
        if session is not None:
            extent = self._extent(rank_requests)
            total = sum(int(ln.sum()) for _, ln, _ in rank_requests)
            n_req = sum(int(o.size) for o, _, _ in rank_requests)
            # sampled payload fingerprint: O(ranks) strided probe of
            # zero-ness + content so same-shape payloads with different
            # sparsity (the dimension slow_hop_codec="auto" tunes on)
            # land in different entries instead of cross-contaminating
            # one entry's measured feedback
            fp = 0
            for _, _, dd in rank_requests:
                if dd.size:
                    probe = dd[::max(1, dd.size // 16)][:17]
                    fp = (fp * 1000003
                          + int((probe == 0).sum()) * 8191
                          + int(probe.astype(np.int64).sum())) \
                        & 0xFFFFFFFFFFFF
            # the Machine is part of the key: a shared session serving
            # writers with different calibrations must not hand one
            # writer a plan whose autos resolved under the other's
            skey = (self.n_ranks, self.n_nodes, self.stripe_size,
                    self.stripe_count, self.machine, extent, total,
                    n_req, fp, method,
                    cb_bytes, pipeline, pipeline_depth, slow_hop_codec,
                    tuple(placement) if isinstance(placement,
                                                   (list, tuple))
                    else placement, local_aggregators, kernel_fusion,
                    transport)
            kind, payload = session.begin_write(skey,
                                                machine=self.machine)
            if kind == "hit":
                plan, serve_map = payload
                source = "session-hit"
            elif kind == "trial":
                plan = self.plan_for(
                    method=payload["method"], cb_bytes=payload["cb_bytes"],
                    pipeline=pipeline or payload["pipeline_depth"] > 1,
                    pipeline_depth=payload["pipeline_depth"],
                    rank_requests=rank_requests,
                    local_aggregators=local_aggregators,
                    slow_hop_codec=payload["slow_hop_codec"],
                    placement=payload["placement"],
                    kernel_fusion=kernel_fusion, transport=transport)
                serve_map = payload.get("serve_map")
                session.register_trial(skey, plan, serve_map)
                source = "session-trial"
        if plan is None:
            workload = (self.workload_for(
                rank_requests, method=method, cb_bytes=cb_bytes,
                pipeline=pipeline, pipeline_depth=pipeline_depth,
                slow_hop_codec=slow_hop_codec)
                if session is not None else None)
            plan = self.plan_for(
                method=method, cb_bytes=cb_bytes, pipeline=pipeline,
                pipeline_depth=(2 if pipeline_depth == "auto"
                                else pipeline_depth),
                rank_requests=rank_requests,
                local_aggregators=local_aggregators,
                slow_hop_codec=slow_hop_codec, placement=placement,
                kernel_fusion=kernel_fusion, transport=transport,
                workload=workload)
            if session is not None:
                session.register(
                    skey, plan,
                    requested={"method": method, "cb_bytes": cb_bytes,
                               "pipeline_depth": pipeline_depth,
                               "slow_hop_codec": slow_hop_codec,
                               "placement": placement},
                    workload=workload,
                    cb_candidates=(self._cb_candidates(rank_requests)
                                   if cb_bytes == "auto" else ()),
                    P_L=((local_aggregators or self.n_nodes * 4)
                         if plan.method == "tam" else None),
                    n_nodes=self.n_nodes,
                    n_aggregators=self.stripe_count)
        if plan.slow_hop_codec is not None and \
                not codec_mod.get_codec(plan.slow_hop_codec).lossless:
            raise ValueError(
                f"slow_hop_codec={plan.slow_hop_codec!r} is lossy; the "
                "host write path moves raw bytes — use a lossless codec "
                f"({codec_mod.lossless_codecs()})")
        m = self.machine
        t = IOTimings()
        t.plan_seconds = time.perf_counter() - plan_t0
        t.plan_source = source
        P, nodes = self.n_ranks, self.n_nodes
        q = P // nodes
        split = [self._split_stripes(*r) for r in rank_requests]
        t.requests_before = sum(s[0].size for s in split)
        placement_on = plan.placement is not None
        # node-level faults and degraded serve maps need the sender->
        # node map even with placement off (the evacuation feedback
        # loop runs on the measured node matrix)
        # the mp transport always needs it: arenas group senders by node
        want_nodes = (placement_on or faults is not None
                      or serve_map is not None
                      or plan.transport is not None)
        sender_nodes = None

        # ---- stage 1: intra-node aggregation (plan.method) -----------
        if plan.method == "twophase":
            per_la = split                  # every rank speaks for itself
            if want_nodes:
                sender_nodes = [r // q for r in range(P)]
        else:
            P_L = local_aggregators or nodes * 4
            assert P_L % nodes == 0
            c = P_L // nodes                # local aggs per node
            per_la = []
            if want_nodes:
                sender_nodes = []
            for node in range(nodes):
                # an injected straggler aggregates slower inside its
                # node too — the slowdown scales every stage-1 charge
                # the node serves
                nf = faults.slowdown(node) if faults is not None else 1.0
                node_ranks = range(node * q, (node + 1) * q)
                groups = np.array_split(np.array(list(node_ranks)), c)
                for g in groups:
                    # backup-aggregator selection: default LA = first
                    # rank of the group (paper's policy); skip failed
                    la = next((r for r in g
                               if r not in failed_aggregators), None)
                    if la is None and len(g):
                        raise RuntimeError(
                            f"no healthy aggregator in group {list(g)}")
                    reassigned = bool(len(g)) and \
                        int(g[0]) in failed_aggregators
                    merged = host_exec.merge_coalesce(
                        [split[r] for r in g])
                    offs, lens, packed, n_cmp = merged
                    # coalescing may fuse runs ACROSS stripe boundaries;
                    # re-split so each request has exactly one owner
                    # (ROMIO splits at file-domain boundaries the same way)
                    offs, lens, packed = self._split_stripes(
                        offs, lens, packed)
                    per_la.append((offs, lens, packed))
                    if want_nodes:
                        sender_nodes.append(node)
                    # intra-node timing: many-to-one receives + sort + copy
                    bytes_in = sum(int(split[r][1].sum()) +
                                   split[r][0].size * PAIR_BYTES for r in g)
                    reassign_penalty = m.alpha_intra if reassigned else 0.0
                    t.intra_comm = max(
                        t.intra_comm,
                        nf * (m.alpha_intra * len(g)
                              + m.beta_intra * bytes_in
                              + reassign_penalty))
                    t.intra_sort = max(t.intra_sort,
                                       nf * m.sort_per_cmp * n_cmp)
                    t.intra_memcpy = max(t.intra_memcpy,
                                         nf * bytes_in / m.memcpy_bw)
        t.requests_after = sum(la[0].size for la in per_la)

        # ---- inter-node exchange + I/O: the chosen executor ----------
        exec_write = (mp_exec.execute_write if plan.transport == "mp"
                      else host_exec.execute_write)
        try:
            t = exec_write(
                plan, m, per_la, path, t,
                depth_request="auto" if pipeline_depth == "auto" else None,
                sender_nodes=sender_nodes, n_nodes=nodes,
                faults=faults, heartbeat=heartbeat, serve_map=serve_map)
        except BaseException:
            # a write that dies mid-trial must not poison the session
            # entry: revert the half-registered trial so the tuner can
            # retry instead of freezing on unmeasured knobs
            if session is not None:
                session.abort(skey, plan)
            raise
        if session is not None:
            session.observe(skey, plan, t, serve_map=serve_map)
        return t

    # ------------------------------------------------------------------
    def auto_cb_bytes(self, rank_requests, method: str = "tam",
                      local_aggregators: int | None = None,
                      pipeline: bool = True, workload=None,
                      direction: str = "write") -> int:
        """Autotuned collective-buffer size for THIS request set: the
        stripe-aligned cb minimizing ``cost_model.optimal_cb``'s modeled
        total (pipelined when ``pipeline``) for the measured workload
        shape (P, nodes, P_G = stripe_count, request count, bytes).
        Pass ``workload`` to reuse an already-measured one.
        ``direction="read"`` sweeps the read model instead
        (``cost_model.optimal_read_cb`` — aggregator fan-out, no
        incast knee, node-cache intra fan-out)."""
        cands = self._cb_candidates(rank_requests)
        w = workload if workload is not None else \
            self._measured_workload(rank_requests, pipeline)
        if direction == "read":
            cb, _ = optimal_read_cb(w, self.machine, candidates=cands)
            return cb
        P_L = ((local_aggregators or self.n_nodes * 4)
               if method == "tam" else None)
        cb, _ = optimal_cb(w, self.machine, P_L=P_L, candidates=cands)
        return cb

    # ------------------------------------------------------------------
    def read_file(self, path: str, file_len: int, *, offset: int = 0,
                  nbytes: int | None = None) -> np.ndarray:
        """Reassemble bytes ``[offset, offset + nbytes)`` of the file
        byte-space from the striped segments (defaults: the whole
        file). The range maps to RANGED per-segment reads — only the
        stripes it touches are seeked and read, never whole segments —
        which is what a partial restore rides: a subset of the manifest
        reads a subset of the disk bytes.

        A touched segment carrying a ``.partial`` marker is a TORN
        write (the drain died mid-segment and nothing repaired it) —
        refuse to reassemble a silently short file and raise
        :class:`~repro.core.faults.TornWriteError` instead."""
        nbytes = file_len - offset if nbytes is None else nbytes
        end = min(offset + nbytes, file_len)
        out = np.zeros(max(end - offset, 0), np.uint8)
        if out.size == 0:
            return out
        handles: dict = {}
        sizes: dict = {}
        try:
            # file stripe s lives at seg (s % SC), stripe (s // SC)
            for s in range(offset // self.stripe_size,
                           (end - 1) // self.stripe_size + 1):
                g, r = s % self.stripe_count, s // self.stripe_count
                if g not in handles:
                    seg_path = f"{path}.seg{g}"
                    if os.path.exists(partial_marker(seg_path)):
                        raise TornWriteError(seg_path, -1, -1)
                    sizes[g] = os.path.getsize(seg_path)
                    handles[g] = open(seg_path, "rb")
                fo = s * self.stripe_size
                lo, hi = max(offset, fo), min(end, fo + self.stripe_size)
                seg_off = r * self.stripe_size + (lo - fo)
                take = min(hi - lo, max(sizes[g] - seg_off, 0))
                if take > 0:
                    handles[g].seek(seg_off)
                    out[lo - offset:lo - offset + take] = np.frombuffer(
                        handles[g].read(take), np.uint8)
        finally:
            for f in handles.values():
                f.close()
        return out

    # ------------------------------------------------------------------
    def read(self, rank_requests, path: str, method: str = "twophase",
             cb_bytes: int | str | None = _UNSET,
             pipeline: bool = _UNSET,
             pipeline_depth: int | str | None = _UNSET,
             slow_hop_codec: str | None = _UNSET,
             placement=_UNSET,
             session: "IOSession | None" = None,
             config: IOConfig | None = None,
             kernel_fusion: str | None = _UNSET,
             transport: str | None = _UNSET,
             node_cache: bool = True, fingerprint=None,
             faults=None) -> tuple[list[np.ndarray], IOTimings]:
        """Collective READ through the full planner — the write's
        mirror and the paper's intra-node aggregation applied to
        restore. rank_requests: list of ``(offsets, lengths)`` per
        READER rank (byte units; no payload — that is what comes
        back). Returns ``(payloads, timings)``: one uint8 array per
        rank in request order, and an :class:`IOTimings` with
        ``direction="read"`` and the cache accounting filled.

        The schedule comes from :meth:`plan_for` with
        ``direction="read"`` — the SAME pass pipeline as a write
        (placement, codec, the read branch of cb/depth resolution), so
        every knob above means what it means on the write side.
        ``node_cache=True`` (default) is the tentpole: each node's
        elected aggregator fetches every window its node needs over
        the slow hop exactly ONCE and fans out intra-node
        (``host_exec.execute_read``; ``timings.cache_hit_ratio``).
        ``node_cache=False`` is the per-rank broadcast baseline the
        benchmark compares against.

        session: the same cross-call protocol as :meth:`write`
        (:meth:`IOSession.begin_read`): repeated restores of the same
        (reader shape, ``fingerprint``, knobs) reuse the compiled plan
        and re-resolve ``"auto"`` knobs against the measured feedback
        once, best-measured-total thereafter. ``fingerprint`` is the
        caller's content key — ``restore_checkpoint`` passes a CRC of
        the manifest, so a re-striped or re-written checkpoint never
        reuses a stale entry. ``node_cache`` is key material too: the
        two settings are different timing regimes, never one entry.
        """
        knobs = resolve_knobs(config, warn=True, cb_bytes=cb_bytes,
                              pipeline=pipeline,
                              pipeline_depth=pipeline_depth,
                              slow_hop_codec=slow_hop_codec,
                              placement=placement,
                              kernel_fusion=kernel_fusion,
                              transport=transport)
        cb_bytes, pipeline = knobs["cb_bytes"], knobs["pipeline"]
        pipeline_depth = knobs["pipeline_depth"]
        slow_hop_codec = knobs["slow_hop_codec"]
        placement = knobs["placement"]
        kernel_fusion = knobs["kernel_fusion"]
        transport = knobs["transport"]
        # reads carry no payload; the planner-facing triples get empty
        # ones (extent/workload measurement are offset/length-only)
        triples = [(np.asarray(o, np.int64), np.asarray(ln, np.int64),
                    np.zeros(0, np.uint8)) for o, ln in rank_requests]
        plan_t0 = time.perf_counter()
        session = session if session is not None else self.session
        plan, source, skey, serve_map = None, "compiled", None, None
        if session is not None:
            extent = self._extent(triples)
            total = sum(int(ln.sum()) for _, ln, _ in triples)
            n_req = sum(int(o.size) for o, _, _ in triples)
            skey = ("read", node_cache, fingerprint, self.n_ranks,
                    self.n_nodes, self.stripe_size, self.stripe_count,
                    self.machine, extent, total, n_req, method,
                    cb_bytes, pipeline, pipeline_depth, slow_hop_codec,
                    tuple(placement) if isinstance(placement,
                                                   (list, tuple))
                    else placement, kernel_fusion, transport)
            kind, payload = session.begin_read(skey,
                                               machine=self.machine)
            if kind == "hit":
                plan, serve_map = payload
                source = "session-hit"
            elif kind == "trial":
                plan = self.plan_for(
                    method=payload["method"], cb_bytes=payload["cb_bytes"],
                    pipeline=pipeline or payload["pipeline_depth"] > 1,
                    pipeline_depth=payload["pipeline_depth"],
                    rank_requests=triples,
                    slow_hop_codec=payload["slow_hop_codec"],
                    placement=payload["placement"],
                    kernel_fusion=kernel_fusion, transport=transport,
                    direction="read")
                serve_map = payload.get("serve_map")
                session.register_trial(skey, plan, serve_map)
                source = "session-trial"
        if plan is None:
            workload = (self._measured_workload(
                triples, pipeline or pipeline_depth is not None, None)
                if session is not None else None)
            plan = self.plan_for(
                method=method, cb_bytes=cb_bytes, pipeline=pipeline,
                pipeline_depth=(2 if pipeline_depth == "auto"
                                else pipeline_depth),
                rank_requests=triples, slow_hop_codec=slow_hop_codec,
                placement=placement, kernel_fusion=kernel_fusion,
                transport=transport, workload=workload,
                direction="read")
            if session is not None:
                session.register(
                    skey, plan,
                    requested={"method": method, "cb_bytes": cb_bytes,
                               "pipeline_depth": pipeline_depth,
                               "slow_hop_codec": slow_hop_codec,
                               "placement": placement},
                    workload=workload,
                    cb_candidates=(self._cb_candidates(triples)
                                   if cb_bytes == "auto" else ()),
                    P_L=None, n_nodes=self.n_nodes,
                    n_aggregators=self.stripe_count)
        if plan.slow_hop_codec is not None and \
                not codec_mod.get_codec(plan.slow_hop_codec).lossless:
            raise ValueError(
                f"slow_hop_codec={plan.slow_hop_codec!r} is lossy; the "
                "host read path moves raw bytes — use a lossless codec "
                f"({codec_mod.lossless_codecs()})")
        t = IOTimings()
        t.direction = "read"
        t.node_cache = node_cache
        t.plan_seconds = time.perf_counter() - plan_t0
        t.plan_source = source
        split = [self._split_stripes(o, ln, None)[:2]
                 for o, ln in rank_requests]
        t.requests_before = sum(np.asarray(o).size
                                for o, _ in rank_requests)
        t.requests_after = sum(o.size for o, _ in split)
        exec_read = (mp_exec.execute_read if plan.transport == "mp"
                     else host_exec.execute_read)
        try:
            outs = exec_read(
                plan, self.machine, split, path, t,
                n_nodes=self.n_nodes,
                ranks_per_node=self.n_ranks // self.n_nodes,
                depth_request=("auto" if pipeline_depth == "auto"
                               else None),
                node_cache=node_cache, serve_map=serve_map,
                faults=faults)
        except BaseException:
            if session is not None:
                session.abort(skey, plan)
            raise
        if session is not None:
            session.observe(skey, plan, t, serve_map=serve_map)
        return outs, t


# Backwards-compatible aliases: the executor bodies moved to host_exec.
_merge_coalesce = host_exec.merge_coalesce
_write_segment = host_exec.write_segment
_domain_image = host_exec.domain_image
_to_domain_local = host_exec.to_domain_local
