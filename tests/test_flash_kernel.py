"""Fused flash-attention Pallas kernel vs the pure-jnp oracle:
shape/dtype/feature sweep in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash import flash_attention_fused
from repro.models.layers import flash_attention

KEY = jax.random.PRNGKey(0)


def mk(b, sq, skv, hq, hkv, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 256, 512, 4, 4, 64),     # MHA
    (2, 256, 512, 8, 2, 64),     # GQA g=4
    (1, 512, 512, 7, 1, 32),     # odd head count (yi-like g=7)
    (1, 256, 1024, 8, 8, 128),   # hd=128
])
def test_fused_matches_oracle(shape):
    b, sq, skv, hq, hkv, hd = shape
    q, k, v = mk(*shape)
    got = flash_attention_fused(q, k, v, causal=True, q_offset=skv - sq,
                                block_q=256, block_kv=256)
    ref = flash_attention(q, k, v, causal=True, window=None,
                          logit_cap=None, q_offset=skv - sq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_fused_window_and_softcap():
    q, k, v = mk(2, 256, 512, 4, 2, 64)
    got = flash_attention_fused(q, k, v, causal=True, window=64,
                                logit_cap=30.0, q_offset=256,
                                block_q=128, block_kv=128)
    ref = flash_attention(q, k, v, causal=True, window=64, logit_cap=30.0,
                          q_offset=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_fused_noncausal():
    q, k, v = mk(1, 256, 256, 4, 4, 64)
    got = flash_attention_fused(q, k, v, causal=False, block_q=128,
                                block_kv=128)
    ref = flash_attention(q, k, v, causal=False, window=None,
                          logit_cap=None, q_offset=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


def test_fused_bf16():
    q, k, v = mk(1, 256, 256, 4, 2, 64, jnp.bfloat16)
    got = flash_attention_fused(q, k, v, causal=True, block_q=128,
                                block_kv=128)
    ref = flash_attention(q, k, v, causal=True, window=None,
                          logit_cap=None, q_offset=0)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_block_divisibility_guard():
    q, k, v = mk(1, 200, 256, 4, 2, 64)
    with pytest.raises(ValueError):
        flash_attention_fused(q, k, v, causal=True, block_q=256,
                              block_kv=256)


def test_ops_fused_attention_padded_shapes():
    """Public wrapper: odd Sq/Skv padded to blocks, padded keys bounded
    by kv_len (never enter the softmax), both causal modes."""
    from repro.kernels import ops
    q, k, v = mk(1, 200, 300, 4, 2, 64)
    q, k, v = q[:, :200], k[:, :300], v[:, :300]
    for causal, off in [(True, 100), (False, 0)]:
        got = ops.fused_attention(q, k, v, causal=causal, q_offset=off)
        ref = flash_attention(q, k, v, causal=causal, window=None,
                              logit_cap=None, q_offset=off)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)
