"""Round-based bounded-buffer exchange engine: scheduler math, peak
buffering, host-path round timing, cost-model wiring, and the SPMD
byte-identity property (subprocess with 8 virtual devices) — including
the pipelined (double-buffered) round loop and the domain-spanning
request patterns. The pipelined overlap accounting and the optimal_cb
autotuner live in tests/test_pipeline_model.py."""
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.cost_model import (Workload, e3sm_g, rounds_for_cb,
                                   twophase_cost, with_measured_rounds)
from repro.core.domains import FileLayout, contiguous_layout
from repro.core.rounds import RoundScheduler, peak_aggregator_buffer_elems
from repro.io_patterns import btio_pattern, e3sm_g_pattern


# ---------------------------------------------------------------------------
# scheduler math
# ---------------------------------------------------------------------------

def test_scheduler_partition():
    s = RoundScheduler(contiguous_layout(320, 2), 2, 32)
    assert s.domain_len == 160 and s.cb == 32 and s.n_rounds == 5
    # None == single shot: one round covering the whole domain
    s1 = RoundScheduler(contiguous_layout(320, 2), 2, None)
    assert s1.n_rounds == 1 and s1.cb == 160


def test_scheduler_window_of():
    s = RoundScheduler(contiguous_layout(320, 2), 2, 40)
    offs = np.array([0, 39, 40, 159, 160, 199, 319])
    # windows are domain-local: offset 160 starts domain 1's window 0
    assert list(np.asarray(s.window_of(offs))) == [0, 0, 1, 3, 0, 0, 3]


def test_scheduler_validation():
    with pytest.raises(ValueError):
        RoundScheduler(contiguous_layout(320, 2), 2, 33)   # 160 % 33 != 0
    with pytest.raises(ValueError):
        RoundScheduler(contiguous_layout(321, 2), 2, 32)   # uneven domains
    with pytest.raises(ValueError):
        # windows must align with stripes
        RoundScheduler(FileLayout(stripe_size=24, stripe_count=2,
                                  file_len=320), 2, 40)


def test_scheduler_max_spans_bounds_split():
    s = RoundScheduler(contiguous_layout(320, 2), 2, 32)
    # a request of length <= data_cap can straddle at most this many windows
    assert s.max_spans(64) == 4
    assert s.max_spans(16) == 2


# ---------------------------------------------------------------------------
# acceptance criterion: aggregator buffering independent of rank count
# ---------------------------------------------------------------------------

def test_peak_buffer_independent_of_rank_count():
    peaks = [peak_aggregator_buffer_elems(
        data_cap=4096, n_nodes=8, ranks_per_node=rpn,
        domain_len=1 << 20, cb_buffer_size=8192)
        for rpn in (1, 16, 256)]
    rounds = {p["rounds"] for p in peaks}
    single = [p["single_shot"] for p in peaks]
    assert len(rounds) == 1              # O(cb): flat in rank count
    assert single[0] < single[1] < single[2]   # O(P * data_cap): grows
    assert peaks[-1]["rounds"] < peaks[-1]["single_shot"]


# ---------------------------------------------------------------------------
# host-level round timing (literal reproduction)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern,method", [
    ("e3sm", "tam"), ("e3sm", "twophase"),
    ("btio", "tam"), ("btio", "twophase"),
])
def test_host_rounds_byte_identical(pattern, method, tmp_path):
    P = 16
    reqs = (e3sm_g_pattern(P) if pattern == "e3sm"
            else btio_pattern(P, n=32))
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=3)
    la = 8 if method == "tam" else None
    t0 = io.write(reqs, str(tmp_path / "ss"), method=method,
                  local_aggregators=la)
    file_len = int(max(o[-1] + l[-1] for o, l, _ in reqs if o.size))
    ref = io.read_file(str(tmp_path / "ss"), file_len)
    assert t0.rounds_executed == 1
    prev_rounds = None
    for cb in (1024, 4096, 16384):
        t = io.write(reqs, str(tmp_path / f"cb{cb}"), method=method,
                     local_aggregators=la, cb_bytes=cb)
        assert np.array_equal(io.read_file(str(tmp_path / f"cb{cb}"),
                                           file_len), ref)
        assert t.rounds_executed >= 1
        if prev_rounds is not None:      # bigger buffer, fewer rounds
            assert t.rounds_executed <= prev_rounds
        prev_rounds = t.rounds_executed
        # rounds serialize the exchange: latency >= the single shot's
        assert t.inter_comm >= t0.inter_comm * 0.99
        # per-round incast at one GA never exceeds the all-at-once storm
        assert t.messages_at_ga <= t0.messages_at_ga


def test_host_rounds_requires_stripe_alignment(tmp_path):
    io = HostCollectiveIO(n_ranks=4, n_nodes=2, stripe_size=1024,
                          stripe_count=2)
    with pytest.raises(ValueError):
        io.write(e3sm_g_pattern(4), str(tmp_path / "x"),
                 method="twophase", cb_bytes=1000)


# ---------------------------------------------------------------------------
# cost-model wiring
# ---------------------------------------------------------------------------

def test_rounds_override_replaces_assumption():
    w = e3sm_g(4096, 64)
    assert w.rounds == w.total_bytes / (w.stripe_size * w.P_G)
    w2 = with_measured_rounds(w, 7)
    assert w2.rounds == 7.0
    # more rounds -> more incast latency paid, total strictly grows
    lo = twophase_cost(with_measured_rounds(w, 1)).total
    hi = twophase_cost(with_measured_rounds(w, 64)).total
    assert hi > lo


def test_rounds_for_cb():
    w = Workload(P=64, nodes=8, P_G=4, k=8, total_bytes=1 << 20)
    assert rounds_for_cb(w, 1 << 18) == 1    # 256 KiB domains fit
    assert rounds_for_cb(w, 1 << 16) == 4
    assert rounds_for_cb(w, 1 << 30) == 1    # never below one round


# ---------------------------------------------------------------------------
# SPMD byte-identity property (8 virtual devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(480)
def test_rounds_spmd_checks(spmd_env):
    # timeout stays under the CI job's 10-minute cap so a hang surfaces
    # this test's captured output, not a generic runner cancellation
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.rounds_checks"],
        env=spmd_env, capture_output=True, text=True, timeout=480)
    print(proc.stdout)
    if proc.returncode != 0:
        print(proc.stderr[-3000:])
    assert proc.returncode == 0, "FAIL lines:\n" + "\n".join(
        ln for ln in proc.stdout.splitlines() if ln.startswith("FAIL"))
    # the pipelined byte-identity, spanning-pattern, and depth-k ring
    # checks must have actually executed (guards against silent skips)
    assert "pipelined_vs_serial" in proc.stdout
    assert "spanning/" in proc.stdout
    assert "read_pipelined" in proc.stdout
    assert "depth3_rounds5_vs_ref" in proc.stdout
    assert "depth4_rounds1_vs_ref" in proc.stdout   # the depth clamp
    assert "tam/depth4_rounds5_vs_ref" in proc.stdout
    assert "read_depth4_rounds5" in proc.stdout
    # placement + cross-executor fuzz must have actually executed
    assert "placement_swap_rounds5_vs_ref" in proc.stdout
    assert "read_placement_swap_rounds5" in proc.stdout
    assert "fuzz3/twophase/pl1_rle_k2_vs_ref" in proc.stdout
    assert "fuzz3/host/swap_rle_k2_vs_spmd" in proc.stdout
    assert "fuzz3/host/tam_swap_rle_k2_vs_spmd" in proc.stdout
