"""End-to-end behaviour tests for the paper's system.

The headline claims, verified at laptop scale + model scale:
1. TAM and two-phase produce byte-identical files (correctness).
2. TAM cuts congestion at global aggregators (messages + modeled time).
3. The full train loop (data -> step -> TAM checkpoint -> restart)
   resumes exactly.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager, HostCollectiveIO
from repro.core import cost_model as cm
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.io_patterns import btio_pattern
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim import adamw
from repro.runtime import HeartbeatMonitor, TrainLoop, TrainLoopConfig


def test_paper_headline_claim():
    """3x-29x end-to-end speedup at 16384 procs (paper abstract)."""
    speedups = [cm.speedup(mk(16384, 256), 256)
                for mk in (cm.e3sm_f, cm.e3sm_g, cm.btio, cm.s3d)]
    assert max(speedups) > 10.0
    assert all(s > 2.0 for s in speedups)


def test_end_to_end_write_and_congestion(tmp_path):
    P = 16
    reqs = btio_pattern(P, n=32)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=2048,
                          stripe_count=4)
    t_tam = io.write(reqs, str(tmp_path / "a"), method="tam",
                     local_aggregators=8)
    t_2ph = io.write(reqs, str(tmp_path / "b"), method="twophase")
    file_len = int(max(o[-1] + l[-1] for o, l, _ in reqs))
    assert np.array_equal(io.read_file(str(tmp_path / "a"), file_len),
                          io.read_file(str(tmp_path / "b"), file_len))
    assert t_tam.messages_at_ga < t_2ph.messages_at_ga
    assert t_tam.requests_after < t_tam.requests_before


def test_train_checkpoint_restart_resumes_exactly(tmp_path):
    cfg = reduced(configs.get("glm4_9b"))
    opt = adamw(weight_decay=0.0)
    data = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq=16,
                                             global_batch=2))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, batch)
        params, opt_state = opt.update(grads, opt_state, params, 1e-3)
        return params, opt_state, loss

    train_step = jax.jit(train_step)
    io = HostCollectiveIO(n_ranks=4, n_nodes=2, stripe_size=1 << 14,
                          stripe_count=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt_state = opt.init(params)

    ckpt = CheckpointManager(tmp_path, io, method="tam",
                             local_aggregators=2)
    loop = TrainLoop(TrainLoopConfig(total_steps=12, checkpoint_every=6),
                     train_step, data, ckpt)
    p_full, o_full, _ = loop.run(params, opt_state)

    # restart from step 6 and re-run 6..12
    state, step0 = ckpt.restore({"params": params, "opt": opt_state},
                                step=6)
    loop2 = TrainLoop(TrainLoopConfig(total_steps=12, checkpoint_every=6),
                      train_step, data, ckpt)
    p_res, o_res, _ = loop2.run(state["params"], state["opt"],
                                start_step=step0)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
