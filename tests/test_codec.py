"""Slow-hop codec subsystem: byte-exact round trips for every lossless
codec (hypothesis property + pinned edge cases), the error-feedback
int8 convergence bound, registry/plan wiring, the cost-model discount,
and the host executor's measured compression ratio on the
sparse-checkpoint workload (the acceptance floor CI also gates)."""
import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import codec as codec_mod
from repro.core import cost_model as cm
from repro.core import twophase
from repro.core.codec import get_codec, lossless_codecs, zero_fraction
from repro.core.domains import FileLayout
from repro.core.plan import (IOConfig, compile_plan,
                             resolve_slow_hop_codec)
from repro.io_patterns import sparse_checkpoint_pattern


# ---------------------------------------------------------------------------
# lossless byte codecs: exact round trip
# ---------------------------------------------------------------------------

EDGE_WINDOWS = (
    b"",                                   # empty
    b"\x00",                               # single zero
    b"\x07",                               # single literal
    b"\x00" * 4096,                        # all-zero page
    bytes(range(1, 256)) * 4,              # no zeros at all
    b"\x00" * 3 + b"abc" + b"\x00" * 100,  # short + long zero runs
    (b"\x00" * codec_mod.RLE_MIN_RUN + b"x") * 7,   # runs at threshold
    (b"\x00" * (codec_mod.RLE_MIN_RUN - 1) + b"x") * 7,  # just below
)


@pytest.mark.parametrize("name", lossless_codecs())
@pytest.mark.parametrize("window", EDGE_WINDOWS, ids=range(len(EDGE_WINDOWS)))
def test_lossless_roundtrip_edges(name, window):
    c = get_codec(name)
    buf = np.frombuffer(window, np.uint8)
    assert np.array_equal(c.decode_bytes(c.encode_bytes(buf)), buf)


@settings(max_examples=120, deadline=None)
@given(st.binary(min_size=0, max_size=4096), st.integers(0, 100))
def test_lossless_roundtrip_property(blob, zero_pct):
    """EVERY lossless codec round-trips arbitrary uint8 windows —
    including hypothesis-found adversarial zero-run placements
    (``zero_pct`` rewrites a prefix of the blob to zeros so all-zero
    and zero-dominated windows are routinely hit)."""
    for name in lossless_codecs():
        c = get_codec(name)
        buf = np.frombuffer(blob, np.uint8).copy()
        buf[:buf.size * zero_pct // 100] = 0
        wire = c.encode_bytes(buf)
        assert np.array_equal(c.decode_bytes(wire), buf), name


def test_rle_compresses_sparse_and_bounds_incompressible():
    rle = get_codec("rle")
    sparse = np.zeros(1 << 16, np.uint8)
    sparse[::997] = 7                       # isolated literals
    assert sparse.size / rle.encode_bytes(sparse).size > 2.0
    dense = np.random.default_rng(0).integers(1, 256, 1 << 16,
                                              dtype=np.uint8)
    overhead = rle.encode_bytes(dense).size - dense.size
    assert overhead <= codec_mod.RLE_HEADER_BYTES + codec_mod.RLE_RECORD_BYTES


def test_rle_jax_roundtrip_exact():
    import jax.numpy as jnp
    rle = get_codec("rle")
    rng = np.random.default_rng(3)
    for dtype in (np.int32, np.float32):
        data = rng.integers(0, 4, size=(6, 37)).astype(dtype)
        parts, st_ = rle.jax_encode(jnp.asarray(data), ())
        out = rle.jax_decode(parts)
        assert st_ == ()
        assert np.array_equal(np.asarray(out), data)
    # 1-D (the read-path window shape)
    w = jnp.asarray(rng.integers(0, 3, size=41).astype(np.float32))
    parts, _ = rle.jax_encode(w, ())
    assert np.array_equal(np.asarray(rle.jax_decode(parts)),
                          np.asarray(w))


# ---------------------------------------------------------------------------
# error-feedback int8: convergence
# ---------------------------------------------------------------------------

def test_ef_int8_accumulated_error_bounded():
    """EF telescopes: sum_t decode_t == sum_t x_t - residual_T, so the
    accumulated decode error over many rounds stays bounded by ONE
    round's quantization error (the 5e-2 relative band spmd_checks uses
    for compressed_psum) instead of growing with the round count."""
    import jax.numpy as jnp
    ef = get_codec("ef-int8")
    rng = np.random.default_rng(11)
    rounds = 64
    xs = rng.normal(size=(rounds, 4, 33)).astype(np.float32)
    res = ef.jax_init_state(xs[0].shape, jnp.float32)
    sent = np.zeros_like(xs[0])
    for t in range(rounds):
        wire, res = ef.jax_encode(jnp.asarray(xs[t]), res)
        sent += np.asarray(ef.jax_decode(wire))
    err = np.abs(sent - xs.sum(0)).max()
    scale = np.abs(xs.sum(0)).max()
    assert err / scale < 5e-2
    # and the bound really is ONE round's worth: the residual equals
    # the missing mass exactly
    assert np.allclose(sent + np.asarray(res), xs.sum(0), atol=1e-4)


def test_ef_int8_requires_float():
    ef = get_codec("ef-int8")
    with pytest.raises(TypeError):
        ef.jax_init_state((4, 8), np.int32)
    with pytest.raises(TypeError):
        ef.encode_bytes(np.zeros(8, np.uint8))


def test_compressed_psum_consumes_the_codec(monkeypatch):
    """hierarchical._int8_encode/_decode are now aliases of the codec's
    arithmetic — one implementation, two consumers."""
    import jax.numpy as jnp
    from repro.core import hierarchical as h
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=17).astype(np.float32))
    q, scale = h._int8_encode(x)
    q2, scale2 = codec_mod.int8_encode(x)
    assert np.array_equal(np.asarray(q), np.asarray(q2))
    assert np.allclose(np.asarray(h._int8_decode(q, scale)),
                       np.asarray(codec_mod.int8_decode(q2, scale2)))


# ---------------------------------------------------------------------------
# registry + plan wiring
# ---------------------------------------------------------------------------

def test_registry_unknown_codec_dies_at_plan_time():
    with pytest.raises(ValueError, match="registered"):
        get_codec("lz77")
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, slow_hop_codec="lz77")
    with pytest.raises(ValueError, match="registered"):
        compile_plan(layout, cfg, n_aggregators=4, n_nodes=4, n_ranks=16)


def test_plan_carries_resolved_codec_and_identity_holds():
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=4096,
                   slow_hop_codec="rle")
    p_spmd = twophase.plan_for(layout, cfg, n_nodes=4, n_ranks=16)
    assert p_spmd.slow_hop_codec == "rle"
    host = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                            stripe_count=4)
    p_host = host.plan_for(method="twophase", cb_bytes=4096,
                           file_len=1 << 16, req_cap=64, data_cap=4096,
                           slow_hop_codec="rle")
    assert p_spmd == p_host          # codec is part of the plan identity
    assert hash(p_spmd) == hash(p_host)


def test_auto_resolution_follows_the_modeled_gain():
    # compressible workload, big file: saving >> encode cost -> on
    w_on = cm.Workload(P=1024, nodes=64, P_G=56, k=100.0,
                       total_bytes=float(64 << 30), slow_hop_ratio=4.0)
    assert resolve_slow_hop_codec(w_on) == "rle"
    assert cm.slow_hop_codec_gain(w_on) > 0
    # incompressible: ratio ~1 -> off, whatever the size
    w_off = cm.with_codec(w_on, 1.0)
    assert resolve_slow_hop_codec(w_off) is None
    # ratio > 1 but the scan costs more than the wire saves -> off
    slow_codec = cm.Machine(codec_bw=1e6)
    assert cm.slow_hop_codec_gain(w_on, slow_codec) < 0
    assert resolve_slow_hop_codec(w_on, slow_codec) is None
    layout = FileLayout(stripe_size=1 << 20, stripe_count=56,
                        file_len=56 << 20)
    cfg = IOConfig(req_cap=64, data_cap=4096, slow_hop_codec="auto")
    plan = compile_plan(layout, cfg, n_aggregators=56, n_nodes=64,
                        n_ranks=1024, workload=w_on)
    assert plan.slow_hop_codec == "rle"
    plan_off = compile_plan(layout, cfg, n_aggregators=56, n_nodes=64,
                            n_ranks=1024, workload=w_off)
    assert plan_off.slow_hop_codec is None


def test_peak_buffer_charges_the_wire_width():
    """The ring memory bound pays the codec's static wire format (XLA
    buffers cannot shrink): rle rings values + int32 positions (2x),
    ef-int8 rings less than raw f32."""
    from repro.core.rounds import peak_aggregator_buffer_elems
    kw = dict(data_cap=4096, n_nodes=8, ranks_per_node=16,
              domain_len=1 << 20, cb_buffer_size=8192, pipeline_depth=3)
    base = peak_aggregator_buffer_elems(**kw)
    rle = peak_aggregator_buffer_elems(**kw, slow_hop_codec="rle")
    ef = peak_aggregator_buffer_elems(**kw, slow_hop_codec="ef-int8")
    window = 8 * 4096 * 3                       # n_nodes * min(dc,cb) * k
    assert rle["rounds"] == base["rounds"] + window          # 2x wire
    assert ef["rounds"] < base["rounds"]                     # int8 wire
    assert rle["tam_stage1_rounds"] == base["tam_stage1_rounds"]  # raw


def test_cost_model_discount_and_charge():
    w = cm.Workload(P=1024, nodes=64, P_G=56, k=100.0,
                    total_bytes=float(8 << 30))
    base = cm.twophase_cost(w)
    on = cm.twophase_cost(cm.with_codec(w, 4.0))
    assert base.codec == 0.0 and on.codec > 0.0
    assert on.inter_comm < base.inter_comm      # beta volume discount
    # the discount reaches the joint cb/depth tuner's totals — on a
    # COMM-bound machine (fast disks): when io dominates the pipelined
    # span hides the comm saving and the model rightly reports no win
    fast_io = cm.Machine(io_bw=1e12)
    _, _, tot_b = cm.optimal_cb_and_depth(w, fast_io)
    _, _, tot_o = cm.optimal_cb_and_depth(cm.with_codec(w, 4.0), fast_io)
    assert tot_o < tot_b


# ---------------------------------------------------------------------------
# host executor: measured ratio + byte identity (the acceptance floor)
# ---------------------------------------------------------------------------

def _sparse_io(P=16):
    return HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                            stripe_count=4)


def test_host_sparse_checkpoint_ratio_above_two(tmp_path):
    P = 16
    reqs = sparse_checkpoint_pattern(P)
    io = _sparse_io(P)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs))
    t_off = io.write(reqs, str(tmp_path / "off"), method="tam",
                     local_aggregators=8, cb_bytes=2048, pipeline_depth=2)
    t_on = io.write(reqs, str(tmp_path / "on"), method="tam",
                    local_aggregators=8, cb_bytes=2048, pipeline_depth=2,
                    slow_hop_codec="rle")
    # byte identity: the codec changes the wire, never the file
    assert np.array_equal(io.read_file(str(tmp_path / "off"), file_len),
                          io.read_file(str(tmp_path / "on"), file_len))
    assert t_on.slow_hop_codec == "rle"
    assert t_on.slow_hop_compression_ratio > 2.0
    assert t_on.slow_hop_wire_bytes < t_on.slow_hop_raw_bytes
    assert t_on.codec > 0.0
    assert t_off.slow_hop_codec is None
    assert t_off.slow_hop_compression_ratio == 1.0
    # modeled vs measured ratio agreement (the CI gate's bound)
    zf = zero_fraction(d for _, _, d in reqs)
    modeled = get_codec("rle").modeled_ratio(
        zf, sum(int(ln.sum()) for _, ln, _ in reqs))
    assert 0.5 <= modeled / t_on.slow_hop_compression_ratio <= 2.0


def test_host_auto_enables_on_sparse_disables_on_dense(tmp_path):
    P = 16
    io = _sparse_io(P)
    t = io.write(sparse_checkpoint_pattern(P), str(tmp_path / "a"),
                 method="tam", local_aggregators=8, cb_bytes=2048,
                 slow_hop_codec="auto")
    assert t.slow_hop_codec == "rle"
    from repro.io_patterns import e3sm_g_pattern
    t2 = io.write(e3sm_g_pattern(P), str(tmp_path / "b"), method="tam",
                  local_aggregators=8, slow_hop_codec="auto")
    assert t2.slow_hop_codec is None


def test_host_rejects_lossy_codec(tmp_path):
    io = _sparse_io()
    with pytest.raises(ValueError, match="lossy"):
        io.write(sparse_checkpoint_pattern(16), str(tmp_path / "x"),
                 slow_hop_codec="ef-int8")
