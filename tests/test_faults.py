"""Fault injection + degraded-mode recovery (core.faults).

Covers the acceptance contract of the fault layer: every recovered
write is byte-identical to the healthy oracle; the drain-thread
fail-fast path leaves a DETECTABLE partial write; a session with
``placement="auto"`` evacuates a measured straggler within one write
of the fault appearing and the steady degraded total stays bounded;
a dead aggregator mid-round recovers (repair map + replay + torn
segment rewrite) instead of wedging; lost slow-hop messages retry
with bounded backoff and fail loudly past the bound; a resize event
mid write-loop replans through runtime.elastic instead of wedging;
and the session tuner survives a write that raises mid-trial.
"""
import os
import warnings

import numpy as np
import pytest

from repro.checkpoint import host_exec
from repro.checkpoint.checkpoint import CheckpointManager
from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.cost_model import Machine
from repro.core.faults import (FaultSpec, TornWriteError,
                               UnrecoverableFaultError, apply_resize,
                               evacuation_map, measure_node_slowdown,
                               partial_marker, repair_map)
from repro.core.placement import node_of_slot
from repro.core.session import IOSession, _arb_key
from repro.io_patterns import btio_pattern, e3sm_f_pattern
from repro.runtime.elastic import plan_remesh
from repro.runtime.heartbeat import HeartbeatMonitor


def _file_len(reqs) -> int:
    return max(int((o + ln).max()) for o, ln, _ in reqs if o.size)


def _reference_file(reqs, file_len: int) -> np.ndarray:
    out = np.zeros(file_len, np.uint8)
    for offs, lens, data in reqs:
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]]) \
            if offs.size else []
        for o, ln, s in zip(offs, lens, starts):
            out[o:o + ln] = data[s:s + ln]
    return out


def _assert_identical(io, reqs, path):
    n = _file_len(reqs)
    np.testing.assert_array_equal(io.read_file(path, n),
                                  _reference_file(reqs, n))


# ---------------------------------------------------------------------
# unit layer: the policy functions
# ---------------------------------------------------------------------

def test_measure_node_slowdown_normalizes_and_ignores_idle():
    sd = measure_node_slowdown([2.0, 8.0, 0.0], [1e6, 1e6, 0.0])
    assert sd == (1.0, 4.0, 1.0)     # idle node: no evidence -> 1.0


def test_evacuation_map_healthy_is_none():
    assert evacuation_map(8, 4, (1.0, 1.2, 1.0, 1.0)) is None


def test_evacuation_map_empties_the_straggler():
    serve = evacuation_map(8, 4, (1.0, 6.0, 1.0, 1.0))
    assert serve is not None and len(serve) == 8
    assert all(node_of_slot(s, 8, 4) != 1 for s in serve)


def test_evacuation_map_excludes_dead_nodes_even_when_healthy():
    serve = evacuation_map(8, 4, (1.0,) * 4, dead_nodes=(0,))
    assert serve is not None
    assert all(node_of_slot(s, 8, 4) != 0 for s in serve)
    with pytest.raises(UnrecoverableFaultError):
        evacuation_map(4, 2, (1.0, 1.0), dead_nodes=(0, 1))


def test_repair_map_routes_to_least_loaded_healthy_slot():
    new_serve, repair, victims = repair_map(
        (0, 1, 2, 3), 2, [1.0, 2.0, 3.0, 4.0], 4, 4)
    assert victims == (2,)
    assert repair == 0                 # lightest healthy slot
    assert new_serve == (0, 1, 0, 3)


def test_retry_penalty_backoff():
    f = FaultSpec(retry_timeout_s=1e-3)
    assert f.retry_penalty(1) == pytest.approx(1e-3)
    assert f.retry_penalty(3) == pytest.approx(7e-3)


# ---------------------------------------------------------------------
# satellite 1: write_segment fail-fast + detectable partial write
# ---------------------------------------------------------------------

def test_write_segment_fails_fast_and_marks_partial(tmp_path):
    path = str(tmp_path / "seg0")
    cb = 1024
    seg = np.arange(64 * cb, dtype=np.int64).astype(np.uint8)
    with pytest.raises(TornWriteError) as ei:
        host_exec.write_segment(path, seg, cb, depth=2,
                                fail_after_windows=2)
    err = ei.value
    assert err.windows_written == 2
    # fail fast: the producer stopped at its next enqueue check instead
    # of pushing all 64 windows into the dead consumer
    assert err.windows_enqueued < 16
    # the torn write is DETECTABLE: truncated at a window boundary with
    # the .partial marker next to it
    assert os.path.exists(partial_marker(path))
    assert os.path.getsize(path) == 2 * cb
    assert "windows_written=2" in open(partial_marker(path)).read()
    # repair = rewrite + clear marker, exactly what the executor does
    os.remove(partial_marker(path))
    host_exec.write_segment(path, seg, cb, depth=2)
    assert np.array_equal(np.fromfile(path, np.uint8), seg)


def test_read_file_refuses_torn_segment(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    path = str(tmp_path / "f")
    io.write(reqs, path, method="tam", cb_bytes=1024)
    _assert_identical(io, reqs, path)
    open(partial_marker(path + ".seg1"), "w").write("windows_written=0\n")
    with pytest.raises(TornWriteError):
        io.read_file(path, _file_len(reqs))


def test_torn_window_injection_detected_and_repaired(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    path = str(tmp_path / "f")
    t = io.write(reqs, path, method="tam", cb_bytes=1024, pipeline=True,
                 faults=FaultSpec(torn_window=(1, 1)))
    assert t.torn_writes_detected == 1
    assert t.recovery_seconds > 0
    assert not os.path.exists(partial_marker(path + ".seg1"))
    _assert_identical(io, reqs, path)


# ---------------------------------------------------------------------
# straggler: measured slowdown + byte identity, then the session's
# self-healing evacuation
# ---------------------------------------------------------------------

def test_slow_node_measured_and_byte_identical(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=8)
    reqs = e3sm_f_pattern(16)
    healthy = io.write(reqs, str(tmp_path / "h"), method="tam",
                       cb_bytes=1024)
    t = io.write(reqs, str(tmp_path / "f"), method="tam", cb_bytes=1024,
                 faults=FaultSpec(slow_nodes={1: 4.0}))
    _assert_identical(io, reqs, str(tmp_path / "f"))
    assert t.node_slowdown[1] > 1.5          # the straggler is visible
    assert all(s < 1.5 for i, s in enumerate(t.node_slowdown) if i != 1)
    assert t.total > healthy.total           # and it costs


def test_session_evacuates_straggler_within_one_write(tmp_path):
    # io-dominant machine so the straggler's service-rate signal is
    # clean and the evacuated steady state is close to healthy
    m = Machine(io_bw=5e7)
    sess = IOSession(machine=m)
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=8, machine=m, session=sess)
    reqs = e3sm_f_pattern(16)
    knobs = dict(method="tam", local_aggregators=8, cb_bytes="auto",
                 pipeline_depth="auto", slow_hop_codec=None,
                 placement="auto")
    ts = [io.write(reqs, str(tmp_path / f"h{i}"), **knobs)
          for i in range(3)]
    healthy = min(t.total for t in ts)
    assert all(t.serve_map is None for t in ts)   # healthy: bijective

    slow = FaultSpec(slow_nodes={1: 6.0})
    faulted = []
    for i in range(7):
        t = io.write(reqs, str(tmp_path / f"d{i}"), **knobs, faults=slow)
        _assert_identical(io, reqs, str(tmp_path / f"d{i}"))
        faulted.append(t)
    # write d0 measures the straggler; d1 — ONE write later — already
    # executes an evacuation serve map with nothing on node 1
    assert faulted[0].node_slowdown[1] > 1.5
    assert faulted[1].serve_map is not None
    assert all(node_of_slot(s, 8, 4) != 1 for s in faulted[1].serve_map)
    # steady state: evacuated, and within 1.5x of the healthy total
    # (the straggler only keeps its un-evictable stage-1 share)
    for t in faulted[-2:]:
        assert t.serve_map is not None
        assert all(node_of_slot(s, 8, 4) != 1 for s in t.serve_map)
        assert t.total <= 1.5 * healthy
    # the straggler sheds its served load: before adaptation node 1
    # looks slow, after evacuation it serves nothing (reads healthy)
    assert faulted[-1].node_slowdown[1] < faulted[0].node_slowdown[1]


# ---------------------------------------------------------------------
# dead aggregator: heartbeat detection, repair re-route, round replay,
# torn-segment rewrite — and the write still lands byte-identical
# ---------------------------------------------------------------------

def test_dead_aggregator_recovers_byte_identical(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    # frozen clock: nobody times out on their own — the only death is
    # the injected one (real time would expire the 5 ms budget for
    # every host before the write even polls)
    hb = HeartbeatMonitor(n_hosts=4, timeout_s=5e-3, clock=lambda: 0.0)
    reqs = btio_pattern(16, n=32)
    path = str(tmp_path / "f")
    t = io.write(reqs, path, method="tam", cb_bytes=1024, pipeline=True,
                 faults=FaultSpec(dead_aggregator=(2, 1)), heartbeat=hb)
    victim_node = node_of_slot(2, 4, 4)
    assert hb.dead_hosts() == [victim_node]       # detection latched
    assert t.repair_map is not None
    assert t.repair_map[2] != 2                   # victim re-routed
    assert t.recovery_seconds >= hb.timeout_s     # detection + replay
    assert t.torn_writes_detected >= 1            # torn segment rewritten
    assert not os.path.exists(partial_marker(path + ".seg2"))
    _assert_identical(io, reqs, path)


def test_dead_aggregator_without_heartbeat_uses_detection_latency(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    t = io.write(reqs, str(tmp_path / "f"), method="tam", cb_bytes=1024,
                 faults=FaultSpec(dead_aggregator=(0, 0),
                                  detection_s=0.25))
    assert t.recovery_seconds >= 0.25
    _assert_identical(io, reqs, str(tmp_path / "f"))


# ---------------------------------------------------------------------
# lost / delayed slow-hop messages: bounded retry, loud failure
# ---------------------------------------------------------------------

def test_lost_message_retries_counted_and_charged(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    healthy = io.write(reqs, str(tmp_path / "h"), method="twophase",
                       cb_bytes=1024)
    t = io.write(reqs, str(tmp_path / "f"), method="twophase",
                 cb_bytes=1024,
                 faults=FaultSpec(lost={(0, 0): 2},
                                  delayed={(1, 0): 0.5}))
    assert t.retries == 2
    assert t.total >= healthy.total + 0.25        # the delay is visible
    _assert_identical(io, reqs, str(tmp_path / "f"))


def test_lost_message_past_max_retries_raises(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    with pytest.raises(UnrecoverableFaultError):
        io.write(reqs, str(tmp_path / "f"), method="twophase",
                 cb_bytes=1024, faults=FaultSpec(lost={(0, 0): 5}))


# ---------------------------------------------------------------------
# satellite 2: a write that raises mid-trial must not poison the session
# ---------------------------------------------------------------------

def test_session_trial_abort_unpoisons_entry(tmp_path):
    sess = IOSession()
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=8, session=sess)
    reqs = e3sm_f_pattern(16)
    knobs = dict(method="tam", local_aggregators=8, cb_bytes="auto",
                 pipeline_depth="auto", slow_hop_codec=None,
                 placement="auto")
    t0 = io.write(reqs, str(tmp_path / "a"), **knobs)
    with pytest.raises(UnrecoverableFaultError):
        io.write(reqs, str(tmp_path / "b"), **knobs,
                 faults=FaultSpec(lost={(0, 0): 99}))
    # no half-registered trial left behind: every surviving plan either
    # measured a total or is the first-compiled plan
    (entry,) = sess._entries.values()
    first = _arb_key(entry.plan, None)
    assert all(ak in entry.totals or ak == first for ak in entry.plans)
    # and the tuner still works: the next writes trial + settle, with
    # the steady state no worse than the first write
    t2 = io.write(reqs, str(tmp_path / "c"), **knobs)
    t3 = io.write(reqs, str(tmp_path / "d"), **knobs)
    assert t3.plan_source == "session-hit"
    assert t3.total <= t0.total + 1e-15
    _assert_identical(io, reqs, str(tmp_path / "d"))
    assert t2 is not None


# ---------------------------------------------------------------------
# satellite 3: heartbeat latch semantics + elastic stranded devices
# ---------------------------------------------------------------------

def test_heartbeat_death_latches_until_revive():
    tm = [0.0]
    hb = HeartbeatMonitor(n_hosts=3, timeout_s=1.0, clock=lambda: tm[0])
    assert hb.healthy()
    tm[0] = 2.0
    hb.beat(0)
    hb.beat(1)
    assert hb.dead_hosts() == [2]        # timed out -> latched
    hb.beat(2)                           # beats are IGNORED once dead
    tm[0] = 2.5
    assert hb.dead_hosts() == [2]
    hb.inject_failure(1)                 # injected: same latch
    hb.beat(1)
    assert hb.dead_hosts() == [1, 2]
    hb.revive(2)                         # the single re-admission path
    hb.revive(1)
    assert hb.healthy()


def test_plan_remesh_reports_stranded_devices():
    with pytest.warns(RuntimeWarning, match="strands 8"):
        plan = plan_remesh(total_devices=24, model_parallel=1,
                           old_data_parallel=32)
    assert plan.mesh_shape[0] == 16
    assert plan.unused_devices == 8
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        exact = plan_remesh(total_devices=16, model_parallel=1,
                            old_data_parallel=16)
    assert exact.unused_devices == 0


# ---------------------------------------------------------------------
# resize event mid write-loop: replan through runtime.elastic, don't
# wedge — and the shrunken writer's file is byte-identical
# ---------------------------------------------------------------------

def test_apply_resize_mid_loop_byte_identical(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    reqs = btio_pattern(16, n=32)
    ref = _reference_file(reqs, _file_len(reqs))
    io.write(reqs, str(tmp_path / "w0"), method="tam", cb_bytes=1024)
    fault = FaultSpec(resize_at_write=1, resize_dead_nodes=(3,))
    with pytest.warns(RuntimeWarning):   # 12 survivors -> data axis 8
        io2, reqs2, plan = apply_resize(io, reqs,
                                        fault.resize_dead_nodes)
    assert io2.n_ranks < io.n_ranks
    assert plan.unused_devices > 0
    # the union of requests survived the re-shard
    assert sum(int(ln.sum()) for _, ln, _ in reqs2) \
        == sum(int(ln.sum()) for _, ln, _ in reqs)
    io2.write(reqs2, str(tmp_path / "w1"), method="tam", cb_bytes=1024)
    got = io2.read_file(str(tmp_path / "w1"), ref.size)
    np.testing.assert_array_equal(got, ref)


def test_apply_resize_consumes_heartbeat_deaths(tmp_path):
    io = HostCollectiveIO(n_ranks=16, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    hb = HeartbeatMonitor(n_hosts=4, timeout_s=10.0)
    hb.inject_failure(2)
    reqs = btio_pattern(16, n=32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        io2, reqs2, _ = apply_resize(io, reqs, (), heartbeat=hb)
    assert io2.n_ranks < io.n_ranks      # the latched death was honored
    with pytest.raises(UnrecoverableFaultError):
        apply_resize(io, reqs, (0, 1, 2, 3))


# ---------------------------------------------------------------------
# satellite 4: kill-and-resume — a checkpoint saved THROUGH a dead
# aggregator restores byte-identical on the shrunken mesh
# ---------------------------------------------------------------------

def _tree():
    rng = np.random.default_rng(11)
    return {"w": rng.standard_normal((64, 16)).astype(np.float32),
            "b": rng.standard_normal(64).astype(np.float32),
            "step_scale": np.float32(0.5) * np.ones(8, np.float32)}


def test_kill_and_resume_restores_byte_identical(tmp_path):
    tree = _tree()
    hb = HeartbeatMonitor(n_hosts=2, timeout_s=1e-3, clock=lambda: 0.0)
    io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1024,
                          stripe_count=4)
    mgr = CheckpointManager(directory=tmp_path / "ck", io=io,
                            cb_bytes=1024, heartbeat=hb)
    mgr.save(tree, step=0)
    # slot 1's node dies mid-save: the save must still COMPLETE (repair
    # + replay + torn-segment rewrite), leaving a valid checkpoint
    t = mgr.save(tree, step=1, faults=FaultSpec(dead_aggregator=(1, 0)))
    assert t.recovery_seconds > 0 and t.repair_map is not None
    dead = hb.dead_hosts()
    assert dead == [node_of_slot(1, 4, 2)]
    # restart: replan the writer onto the survivors via runtime.elastic
    empty = [(np.zeros(0, np.int64), np.zeros(0, np.int64),
              np.zeros(0, np.uint8))] * io.n_ranks
    io2, _, eplan = apply_resize(io, empty, dead)
    assert io2.n_nodes < io.n_nodes
    mgr2 = CheckpointManager(directory=tmp_path / "ck", io=io2,
                             cb_bytes=1024)
    restored, step = mgr2.restore(like_tree=tree)
    assert step == 1
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      np.asarray(tree[k]))
    # and the shrunken mesh keeps checkpointing
    tree2 = {k: np.asarray(v) + 1 for k, v in tree.items()}
    mgr2.save(tree2, step=2)
    restored2, _ = mgr2.restore(like_tree=tree)
    for k in tree2:
        np.testing.assert_array_equal(np.asarray(restored2[k]),
                                      np.asarray(tree2[k]))
