"""The planner pass pipeline (core/passes.py): registry shape and
order, per-pass tracing through ``compile_plan(trace=True)``, per-pass
idempotence on final plans, the ``lower_kernels`` lowering rules, and
the ``describe``/``plan_diff`` introspection surface."""
import dataclasses

import pytest

from _golden_plans import CASES, compile_case

from repro.core import cost_model as cm
from repro.core import passes
from repro.core.domains import contiguous_layout
from repro.core.plan import (IOConfig, _default_workload, compile_plan,
                             plan_diff)

EXPECTED_ORDER = ("normalize_layout", "resolve_codec", "resolve_method",
                  "resolve_placement", "resolve_cb_and_depth",
                  "coalesce_windows", "validate", "lower_kernels",
                  "resolve_transport")


def _ctx(layout, cfg, n_aggregators=2, n_nodes=2, n_ranks=8):
    """The same PlanContext compile_plan builds (default workload)."""
    return passes.PlanContext(
        cfg=cfg,
        workload=_default_workload(layout, cfg, n_aggregators, n_nodes,
                                   n_ranks, 4),
        machine=cm.Machine(), n_nodes=n_nodes, n_ranks=n_ranks,
        unit_bytes=4)


def test_registry_names_and_order():
    assert tuple(p.name for p in passes.PASSES) == EXPECTED_ORDER
    assert set(passes.PASS_REGISTRY) == set(EXPECTED_ORDER)
    for p in passes.PASSES:
        assert passes.PASS_REGISTRY[p.name] is p
        assert p.doc, f"pass {p.name} is undocumented"


def test_trace_exposes_one_snapshot_per_pass():
    plan, snaps = compile_case(
        {"method": "auto", "cb": "auto", "pipeline": True,
         "pipeline_depth": "auto", "codec": "auto", "placement": "auto",
         "direction": "write"}, trace=True)
    assert [name for name, _ in snaps] == list(EXPECTED_ORDER)
    assert snaps[-1][1] == plan                 # last snapshot IS the plan
    # every auto is gone by validate; the snapshots show WHERE each one
    # resolved (trace_report names the pass and the rewritten field)
    by_name = dict(snaps)
    assert by_name["resolve_codec"].slow_hop_codec != "auto"
    assert by_name["resolve_method"].method in ("twophase", "tam")
    assert isinstance(by_name["resolve_placement"].placement, tuple)
    assert isinstance(by_name["resolve_cb_and_depth"].cb, int)
    assert by_name["coalesce_windows"].n_rounds >= 1
    report = passes.trace_report(snaps)
    assert "[resolve_method] " in report
    assert "cb:" in report and "n_rounds:" in report


def test_snapshots_are_immutable_states_not_aliases():
    _, snaps = compile_case(
        {"method": "auto", "cb": None, "pipeline": False,
         "pipeline_depth": 2, "codec": None, "placement": None,
         "direction": "write"}, trace=True)
    # a pass returns a NEW plan; snapshots of different states differ
    assert snaps[0][1].n_rounds == 0            # pre-coalesce marker
    assert dict(snaps)["coalesce_windows"].n_rounds == 1


@pytest.mark.parametrize("case", [CASES[0], CASES[40], CASES[121],
                                  CASES[242], CASES[-2], CASES[-1]],
                         ids=lambda c: c["direction"] + "/" + str(c["method"]))
def test_every_pass_is_idempotent_on_the_final_plan(case):
    """Purity contract: the final plan is a fixed point of every single
    pass (and hence of the whole pipeline) — re-running a rewrite on
    its own output changes nothing."""
    from repro.core.domains import FileLayout
    from _golden_plans import LAYOUT, N_AGGREGATORS, N_NODES, N_RANKS
    plan = compile_case(case)
    cfg = IOConfig(req_cap=8, data_cap=64, coalesce_cap=32,
                   cb_buffer_size=case["cb"], pipeline=case["pipeline"],
                   pipeline_depth=case["pipeline_depth"],
                   slow_hop_codec=case["codec"],
                   placement=case["placement"])
    ctx = _ctx(FileLayout(**LAYOUT), cfg, N_AGGREGATORS, N_NODES, N_RANKS)
    for p in passes.PASSES:
        again = p.fn(plan, ctx)
        assert again == plan, (
            f"pass {p.name} not idempotent:\n{plan_diff(plan, again)}")
    assert passes.run_passes(plan, ctx) == plan


def test_initial_plan_carries_knobs_verbatim():
    layout = contiguous_layout(320, 2)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size="auto",
                   pipeline=True, pipeline_depth="auto",
                   slow_hop_codec="auto", placement="spread",
                   kernel_fusion="fused_round")
    p0 = passes.initial_plan(layout, cfg, n_aggregators=2)
    assert p0.cb == "auto" and p0.pipeline_depth == "auto"
    assert p0.slow_hop_codec == "auto" and p0.placement == "spread"
    assert p0.kernel_fusion == "fused_round"
    assert p0.n_rounds == 0                     # not yet scheduled


def test_validate_rejects_surviving_autos():
    layout = contiguous_layout(320, 2)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=None,
                   slow_hop_codec="auto")
    p0 = passes.initial_plan(layout, cfg, n_aggregators=2)
    ctx = _ctx(layout, cfg)
    # skip resolve_codec: "auto" reaches validate and dies by name
    partial = tuple(p for p in passes.PASSES
                    if p.name in ("normalize_layout", "coalesce_windows"))
    staged = passes.run_passes(p0, ctx, passes=partial)
    with pytest.raises(ValueError, match="slow_hop_codec"):
        passes.PASS_REGISTRY["validate"].fn(staged, ctx)


def test_lower_kernels_rules():
    layout = contiguous_layout(320, 2)
    kw = dict(n_aggregators=2, n_nodes=2, n_ranks=8)
    fused = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=32,
                     kernel_fusion="fused_round")
    assert compile_plan(layout, fused, **kw).kernel_fusion == "fused_round"
    # reads keep the lowering: it swaps the rle decode scatter for the
    # zero_skip_decode kernel in the per-round fetch (PR 8)
    assert compile_plan(layout, fused, direction="read",
                        **kw).kernel_fusion == "fused_round"
    # the default stays unfused
    plain = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=32)
    assert compile_plan(layout, plain, **kw).kernel_fusion is None
    with pytest.raises(ValueError, match="kernel_fusion"):
        compile_plan(layout,
                     dataclasses.replace(fused, kernel_fusion="warp"),
                     **kw)


def test_resolve_transport_rules():
    layout = contiguous_layout(320, 2)
    kw = dict(n_aggregators=2, n_nodes=2, n_ranks=8)
    mp = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=32,
                  transport="mp")
    assert compile_plan(layout, mp, **kw).transport == "mp"
    # the default stays in-process (no transport) in both directions
    plain = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=32)
    assert compile_plan(layout, plain, **kw).transport is None
    assert compile_plan(layout, mp, direction="read",
                        **kw).transport == "mp"
    with pytest.raises(ValueError, match="transport"):
        compile_plan(layout,
                     dataclasses.replace(mp, transport="rdma"), **kw)


def test_plan_diff_and_describe():
    layout = contiguous_layout(320, 2)
    kw = dict(n_aggregators=2, n_nodes=2, n_ranks=8)
    a = compile_plan(layout, IOConfig(req_cap=8, data_cap=64,
                                      cb_buffer_size=32), **kw)
    b = compile_plan(layout, IOConfig(req_cap=8, data_cap=64,
                                      cb_buffer_size=80,
                                      slow_hop_codec="rle"), **kw)
    assert plan_diff(a, a) == ""
    d = plan_diff(a, b)
    assert "cb: 32 -> 80" in d
    assert "n_rounds: 5 -> 2" in d
    assert "slow_hop_codec: None -> 'rle'" in d
    assert "method" not in d                    # unchanged fields silent
    desc = a.describe()
    for f in dataclasses.fields(type(a)):
        assert f.name in desc
    assert "in_flight_windows" in desc          # derived schedule numbers
