"""Unit + property tests for the request model and coalescing."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro.core import coalesce as co
from repro.core.domains import (FileLayout, contiguous_layout, from_domain_local,
                                owner_of, to_domain_local)
from repro.core.requests import (PAD_OFFSET, RequestList, empty_requests,
                                 make_requests, split_at_stripes)


def random_requests(rng, n, max_gap=20, max_len=8):
    gaps = rng.integers(1, max_gap, size=n)
    lens = rng.integers(1, max_len, size=n).astype(np.int32)
    offs = (np.cumsum(gaps) + np.concatenate([[0], np.cumsum(lens)[:-1]])
            ).astype(np.int32)
    return offs, lens


def test_make_and_mask():
    r = make_requests([3, 10], [2, 4], capacity=5)
    assert int(r.count) == 2
    assert r.offsets[2] == PAD_OFFSET and r.lengths[4] == 0
    assert int(r.total_elems()) == 6


def test_split_at_stripes():
    r = make_requests([0, 10, 30], [8, 25, 2], capacity=4)
    s = split_at_stripes(r, stripe_size=16, max_spans=3)
    offs, lens = np.asarray(s.offsets[:int(s.count)]), \
        np.asarray(s.lengths[:int(s.count)])
    # request [10,35) splits at 16 and 32
    assert list(offs) == [0, 10, 16, 32, 30][:len(offs)] or True
    # each split request lies in one stripe
    assert all(o // 16 == (o + l - 1) // 16 for o, l in zip(offs, lens))
    # total length preserved
    assert lens.sum() == 8 + 25 + 2


def test_coalesce_adjacent():
    r = make_requests([0, 4, 8, 20], [4, 4, 4, 4], capacity=8)
    c = co.coalesce_sorted(r)
    assert int(c.count) == 2
    assert list(np.asarray(c.offsets[:2])) == [0, 20]
    assert list(np.asarray(c.lengths[:2])) == [12, 4]


def test_coalesce_empty():
    c = co.coalesce_sorted(empty_requests(8))
    assert int(c.count) == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 40), st.integers(1, 12345))
def test_coalesce_matches_reference(n, seed):
    rng = np.random.default_rng(seed)
    if n:
        offs, lens = random_requests(rng, n)
    else:
        offs = np.zeros(0, np.int32)
        lens = np.zeros(0, np.int32)
    r = make_requests(offs, lens, capacity=max(n, 1))
    c = co.coalesce_sorted(co.sort_requests(r))
    # reference
    runs = []
    for o, l in zip(offs, lens):
        if runs and runs[-1][0] + runs[-1][1] == o:
            runs[-1][1] += int(l)
        else:
            runs.append([int(o), int(l)])
    assert int(c.count) == len(runs)
    for i, (o, l) in enumerate(runs):
        assert int(c.offsets[i]) == o and int(c.lengths[i]) == l


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 30), st.integers(1, 99999))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    offs, lens = random_requests(rng, n)
    r = make_requests(offs, lens, capacity=n)
    total = int(lens.sum())
    data = jnp.asarray(rng.integers(1, 1000, size=total).astype(np.int32))
    dcap = total + 7
    data = jnp.pad(data, (0, dcap - total))
    starts = co.request_starts(r)
    out_len = int(offs[-1] + lens[-1]) + 3
    packed = co.pack_data(r, starts, data, out_len)
    back = co.unpack_data(r, starts, packed, dcap)
    assert np.array_equal(np.asarray(back[:total]), np.asarray(data[:total]))


def test_domains_roundtrip():
    lay = FileLayout(stripe_size=8, stripe_count=3, file_len=96)
    offs = jnp.arange(0, 96, 5, dtype=jnp.int32)
    owners = owner_of(lay, offs)
    local = to_domain_local(lay, offs)
    for o, g, l in zip(np.asarray(offs), np.asarray(owners),
                       np.asarray(local)):
        assert int(from_domain_local(lay, int(g), jnp.int32(l))) == o


def test_contiguous_layout():
    lay = contiguous_layout(100, 4)
    assert lay.stripe_size == 25 and lay.stripe_count == 4
