"""The roofline rests on the loop-aware HLO parser — test it directly
(subprocess with 4 virtual devices)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import HloCostModel
from jax.sharding import NamedSharding, PartitionSpec as P

# 1. while-loop flops multiplied by trip count (XLA counts body once)
def body(c, w):
    return c @ w, ()
W = jnp.ones((10, 128, 128), jnp.float32)
x = jnp.ones((128, 128), jnp.float32)
c = jax.jit(lambda x, W: jax.lax.scan(body, x, W)[0]).lower(x, W).compile()
t = HloCostModel(c.as_text()).total()
expected = 10 * 2 * 128**3
assert abs(t.flops - expected) / expected < 0.01, (t.flops, expected)

# 2. nested scans multiply
def outer(c, w):
    c2, _ = jax.lax.scan(lambda a, _: (a @ w, ()), c, None, length=5)
    return c2, ()
c2 = jax.jit(lambda x, W: jax.lax.scan(outer, x, W)[0]).lower(x, W).compile()
t2 = HloCostModel(c2.as_text()).total()
assert abs(t2.flops - 5 * expected) / (5 * expected) < 0.01

# 3. collective wire bytes: psum of 4KB over a 4-ring = 2*(3/4)*4KB
mesh = jax.make_mesh((4,), ("d",))
f = jax.jit(lambda x: x.sum(0, keepdims=True),
            in_shardings=NamedSharding(mesh, P("d", None)),
            out_shardings=NamedSharding(mesh, P(None, None)))
c3 = f.lower(jax.ShapeDtypeStruct((4, 1024), jnp.float32)).compile()
t3 = HloCostModel(c3.as_text()).total()
ar = t3.coll_bytes.get("all-reduce", 0)
assert abs(ar - 2 * 0.75 * 4096) < 1, ar

# 4. fusion-internal bytes are NOT counted as HBM traffic
def g(x):
    return jnp.sin(x) * 2 + jnp.cos(x)   # one fused kernel
c4 = jax.jit(g).lower(jnp.ones((1024, 1024), jnp.float32)).compile()
t4 = HloCostModel(c4.as_text()).total()
assert t4.bytes <= 3 * 4 * 1024 * 1024, t4.bytes  # ~in+out only

print("hlo_analysis OK")
"""


@pytest.mark.timeout(600)
def test_hlo_analyzer(spmd_env):
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=spmd_env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2500:]
    assert "hlo_analysis OK" in proc.stdout
