"""Property/fuzz tier for the planner (hypothesis via _hyp_compat).

Randomized layouts x cb x depth x placement:

* the round windows PARTITION each aggregator domain exactly —
  coverage (every domain-local offset falls in some window) and
  disjointness (exactly one window), and ``window_of`` agrees with the
  round schedule for every file offset;
* ``compile_plan`` is deterministic — plan equality (and hash
  equality) across recompiles, which is the contract that makes the
  session cache sound (a cached plan IS the recompiled plan);
* every placement permutation is a bijection on the aggregator slots,
  and ``"auto"`` placement is never modeled-worse than any named
  policy.

Runs under the fixed derandomized profile (_hyp_compat registers it:
bounded examples, reproduce_failure blob printed on failure) so both
CI JAX pins explore identical examples.
"""
import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.core import placement as placement_mod
from repro.core.cost_model import Machine, Workload, placement_cost
from repro.core.domains import FileLayout, contiguous_layout
from repro.core.plan import IOConfig, compile_plan


def _layout_and_cb(n_agg, windows, window_elems, striped):
    """A legal (layout, cb) pair: each domain is exactly ``windows``
    cb-sized windows; ``striped`` interleaves stripes (stripe == cb),
    otherwise the domain is one contiguous stripe (cb divides it)."""
    domain = windows * window_elems
    if striped:
        return FileLayout(stripe_size=window_elems, stripe_count=n_agg,
                          file_len=n_agg * domain), window_elems
    return contiguous_layout(n_agg * domain, n_agg), window_elems


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.sampled_from([4, 8, 16]),
       st.booleans(), st.sampled_from([1, 2, 3, 4]))
def test_windows_partition_each_domain(n_agg, windows, window_elems,
                                       striped, depth):
    layout, cb = _layout_and_cb(n_agg, windows, window_elems, striped)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=cb,
                   pipeline=depth > 1, pipeline_depth=depth)
    plan = compile_plan(layout, cfg, n_aggregators=n_agg,
                        n_nodes=max(n_agg // 2, 1), n_ranks=n_agg * 2)
    sched = plan.scheduler()
    # coverage + disjointness: the windows tile the domain exactly
    assert plan.n_rounds * plan.cb == plan.domain_len
    offs = np.arange(layout.file_len)
    # ground truth: the domain-local position (stripes concatenated in
    # round order) of every file offset; round t of every domain covers
    # domain-local span [t*cb, (t+1)*cb)
    from repro.core.domains import to_domain_local
    local = np.asarray(to_domain_local(layout, offs))
    w = local // plan.cb
    assert ((w >= 0) & (w < plan.n_rounds)).all()        # coverage
    counts = np.bincount(w, minlength=plan.n_rounds)
    assert (counts == n_agg * plan.cb).all()   # disjoint exact tiling
    if layout.stripe_size == plan.domain_len:  # contiguous domains
        # window_of agrees with the round schedule (the SPMD executor
        # routes through exactly this)
        np.testing.assert_array_equal(np.asarray(sched.window_of(offs)),
                                      w)
    assert plan.in_flight_windows == max(1, min(depth, plan.n_rounds))


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.sampled_from([4, 8]),
       st.sampled_from([None, "packed", "spread", "node_balanced",
                        "auto"]),
       st.sampled_from([None, "rle"]), st.sampled_from([1, 2, 3]))
def test_compile_plan_is_deterministic(n_agg, windows, window_elems,
                                       placement, codec, depth):
    """The session-cache-key contract: identical (layout, config)
    compile identical (and identically hashed) plans, so a cached plan
    is indistinguishable from a recompile."""
    layout, cb = _layout_and_cb(n_agg, windows, window_elems, False)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=cb,
                   pipeline=depth > 1, pipeline_depth=depth,
                   slow_hop_codec=codec, placement=placement)
    kw = dict(n_aggregators=n_agg, n_nodes=max(n_agg // 2, 1),
              n_ranks=n_agg * 2)
    p1 = compile_plan(layout, cfg, **kw)
    p2 = compile_plan(layout, cfg, **kw)
    assert p1 == p2
    assert hash(p1) == hash(p2)
    if placement is None:
        assert p1.placement is None
    else:
        assert sorted(p1.placement) == list(range(n_agg))


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 16), st.integers(1, 8),
       st.sampled_from(["packed", "spread", "node_balanced", "auto"]),
       st.integers(0, 2**31 - 1))
def test_placement_policies_are_bijections(n_agg, n_nodes, policy, seed):
    rng = np.random.default_rng(seed)
    domain_bytes = rng.integers(0, 1 << 20, size=n_agg).astype(float)
    w = Workload(P=max(n_agg, n_nodes) * 4, nodes=n_nodes, P_G=n_agg,
                 k=8.0, total_bytes=float(max(domain_bytes.sum(), 1.0)),
                 locality=float(rng.random()))
    perm = placement_mod.resolve_placement(
        policy, n_agg, n_nodes, workload=w,
        domain_bytes=list(domain_bytes))
    assert sorted(perm) == list(range(n_agg))
    # explicit permutations round-trip; non-bijections die
    assert placement_mod.resolve_placement(perm, n_agg, n_nodes) == \
        tuple(perm)
    inv = placement_mod.inverse_placement(perm)
    assert all(inv[perm[g]] == g for g in range(n_agg))


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 12), st.integers(2, 6), st.integers(0, 2**31 - 1),
       st.floats(0.0, 1.0))
def test_auto_placement_never_modeled_worse(n_agg, n_nodes, seed,
                                            locality):
    """The invariant check_regression gates at benchmark scale, here
    over random shapes: "auto" is the argmin of placement_cost over the
    named policies, so it can never be modeled-worse than any of them
    (nor than placement-off, which is the packed/identity cost)."""
    rng = np.random.default_rng(seed)
    m = Machine()
    domain_bytes = list(rng.integers(1, 1 << 16, size=n_agg).astype(float))
    w = Workload(P=n_agg * 8, nodes=n_nodes, P_G=n_agg, k=4.0,
                 total_bytes=float(sum(domain_bytes)), locality=locality)
    auto = placement_mod.resolve_placement(
        "auto", n_agg, n_nodes, workload=w, machine=m,
        domain_bytes=domain_bytes)
    c_auto = placement_cost(w, m, auto, n_nodes,
                            domain_bytes=domain_bytes)
    for policy in placement_mod.PLACEMENT_POLICIES:
        perm = placement_mod.resolve_placement(
            policy, n_agg, n_nodes, workload=w,
            domain_bytes=domain_bytes)
        assert c_auto <= placement_cost(w, m, perm, n_nodes,
                                        domain_bytes=domain_bytes) \
            * (1 + 1e-12)
    # placement-off == the identity permutation's cost
    assert c_auto <= placement_cost(w, m, None, n_nodes,
                                    domain_bytes=domain_bytes) \
        * (1 + 1e-12)


def test_non_bijection_dies_at_compile_time():
    layout = contiguous_layout(320, 2)
    with pytest.raises(ValueError):
        compile_plan(layout, IOConfig(req_cap=8, data_cap=64,
                                      placement=(0, 0)),
                     n_aggregators=2, n_nodes=2, n_ranks=8)
    with pytest.raises(ValueError):
        compile_plan(layout, IOConfig(req_cap=8, data_cap=64,
                                      placement=(1, 2)),
                     n_aggregators=2, n_nodes=2, n_ranks=8)
    with pytest.raises(ValueError):
        compile_plan(layout, IOConfig(req_cap=8, data_cap=64,
                                      placement="diagonal"),
                     n_aggregators=2, n_nodes=2, n_ranks=8)
