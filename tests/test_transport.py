"""The mp transport executor (checkpoint/mp_exec.py): byte identity
against the host oracle on real processes, worker-kill repair through
the FaultSpec/heartbeat path, knob plumbing, and the session's
wall-clock observe loop. The heavier placement x codec x depth fuzz
cross lives in repro.testing.rounds_checks (run by test_rounds.py)."""
import numpy as np
import pytest

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.faults import FaultSpec
from repro.core.plan import IOConfig
from repro.core.session import IOSession
from repro.core.transport import (FRAME_OVERHEAD, SUB_OVERHEAD,
                                  resolve_transport)
from repro.runtime.heartbeat import HeartbeatMonitor


def _io(session=None):
    return HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=640,
                            stripe_count=2, session=session)


def _reqs(io, seed=0, n_req=6, max_len=300):
    """Non-overlapping per-rank (offsets, lengths, payload) triples."""
    rng = np.random.default_rng(seed)
    ext = io.stripe_size * io.stripe_count * 4
    out = []
    for _ in range(io.n_ranks):
        offs = np.sort(rng.choice(ext, n_req, replace=False)) \
            .astype(np.int64)
        lens = np.minimum(rng.integers(1, max_len, n_req),
                          np.diff(np.append(offs, ext))).astype(np.int64)
        pay = rng.integers(0, 255, int(lens.sum()), dtype=np.uint8)
        out.append((offs, lens, pay))
    return out


def _cfg(**kw):
    return IOConfig(req_cap=0, data_cap=0, **kw)


def _segs(path, n):
    return [open(f"{path}.seg{g}", "rb").read() for g in range(n)]


# ---------------------------------------------------------------------
# byte identity: the executor contract
# ---------------------------------------------------------------------

@pytest.mark.parametrize("method", ["twophase", "tam"])
def test_write_byte_identical_to_host(tmp_path, method):
    io = _io()
    rr = _reqs(io)
    kw = dict(cb_buffer_size=128, slow_hop_codec="rle", placement=(1, 0),
              pipeline=True, pipeline_depth=2)
    io.write(rr, str(tmp_path / "h"), method=method, config=_cfg(**kw))
    tm = io.write(rr, str(tmp_path / "m"), method=method,
                  config=_cfg(**kw, transport="mp"))
    assert tm.transport == "mp"
    assert _segs(tmp_path / "h", 2) == _segs(tmp_path / "m", 2)
    # the slow hop moved real frames: length prefix + header per frame
    assert tm.slow_hop_slow_bytes > FRAME_OVERHEAD
    # measured wall-clock rounds, not the alpha-beta model
    assert tm.inter_comm >= 0.0 and tm.io > 0.0
    assert len(tm.comm_rounds) == len(tm.io_rounds)


def test_read_byte_identical_to_host(tmp_path):
    io = _io()
    rr = _reqs(io, seed=3)
    kw = dict(cb_buffer_size=128, slow_hop_codec="rle")
    io.write(rr, str(tmp_path / "f"), method="tam", config=_cfg(**kw))
    rd = [(o, ln) for o, ln, _ in rr]
    for cache in (True, False):
        oh, th = io.read(rd, str(tmp_path / "f"), config=_cfg(**kw),
                         node_cache=cache)
        om, tmm = io.read(rd, str(tmp_path / "f"),
                          config=_cfg(**kw, transport="mp"),
                          node_cache=cache)
        assert tmm.transport == "mp"
        for a, b in zip(oh, om):
            np.testing.assert_array_equal(a, b)
        # cache accounting matches the host executor's counters
        assert tmm.cache_hits == th.cache_hits
        assert tmm.cache_misses == th.cache_misses


def _strided(io, chunk=32, repeats=2):
    """Interleaved per-rank chunks (the checkpoint-shard shape): every
    cb window holds several co-located ranks' data, which is exactly
    what intra-node aggregation combines on the wire."""
    P = io.n_ranks
    out = []
    for r in range(P):
        offs = (np.arange(repeats * io.stripe_count * 2, dtype=np.int64)
                * P + r) * chunk
        lens = np.full(offs.size, chunk, np.int64)
        pay = ((offs[:, None] + np.arange(chunk)) % 251) \
            .astype(np.uint8).ravel()
        out.append((offs, lens, pay))
    return out


def test_tam_combines_slow_frames_below_flat(tmp_path):
    """Intra-node aggregation collapses slow-hop messages: with 4
    senders per node sharing windows, TAM's node-combined frames put
    strictly fewer bytes on the wire than flat two-phase's per-sender
    frames (fewer frame overheads AND coalesced pair metadata)."""
    io = _io()
    rr = _strided(io)
    t_flat = io.write(rr, str(tmp_path / "flat"), method="twophase",
                      config=_cfg(cb_buffer_size=128, transport="mp"))
    t_agg = io.write(rr, str(tmp_path / "agg"), method="tam",
                     local_aggregators=2,
                     config=_cfg(cb_buffer_size=128, transport="mp"))
    assert t_agg.slow_hop_slow_bytes < t_flat.slow_hop_slow_bytes
    assert SUB_OVERHEAD < FRAME_OVERHEAD  # where part of the saving is
    # same bytes on disk either way
    assert _segs(tmp_path / "flat", 2) == _segs(tmp_path / "agg", 2)


# ---------------------------------------------------------------------
# worker kill: the repair story on real processes
# ---------------------------------------------------------------------

@pytest.mark.parametrize("method", ["twophase", "tam"])
def test_killed_worker_is_detected_and_repaired(tmp_path, method):
    io = _io()
    rr = _reqs(io, seed=7)
    kw = dict(cb_buffer_size=128)
    io.write(rr, str(tmp_path / "h"), method=method, config=_cfg(**kw))
    # timeout must exceed the run's wall clock or the innocent node
    # latches as timed-out too; detection here comes from the injection
    hb = HeartbeatMonitor(io.n_nodes, timeout_s=30.0)
    t = io.write(rr, str(tmp_path / "m"), method=method,
                 config=_cfg(**kw, transport="mp"),
                 faults=FaultSpec(dead_aggregator=(0, 1)), heartbeat=hb)
    # the victim's node latched on the detector, recovery time charged,
    # and the repaired segments are still byte-identical to the oracle
    assert hb.dead_hosts() == [0]
    assert t.recovery_seconds > 0.0
    assert _segs(tmp_path / "h", 2) == _segs(tmp_path / "m", 2)


def test_mp_rejects_modeled_timing_faults(tmp_path):
    io = _io()
    rr = _reqs(io)
    with pytest.raises(ValueError, match="wall-clock"):
        io.write(rr, str(tmp_path / "x"), method="twophase",
                 config=_cfg(cb_buffer_size=128, transport="mp"),
                 faults=FaultSpec(lost={(0, 0): 1}))
    rd = [(o, ln) for o, ln, _ in rr]
    io.write(rr, str(tmp_path / "f"), config=_cfg(cb_buffer_size=128))
    with pytest.raises(ValueError, match="write-side"):
        io.read(rd, str(tmp_path / "f"),
                config=_cfg(cb_buffer_size=128, transport="mp"),
                faults=FaultSpec(slow_nodes={0: 2.0}))


# ---------------------------------------------------------------------
# knob plumbing + the session loop
# ---------------------------------------------------------------------

def test_resolve_transport_validation():
    assert resolve_transport(None) is None
    assert resolve_transport("mp") == "mp"
    with pytest.raises(ValueError, match="rdma"):
        resolve_transport("rdma")


def test_session_observes_wall_clock_and_keys_on_transport(tmp_path):
    sess = IOSession()
    io = _io(sess)
    rr = _reqs(io)
    kw = dict(method="twophase", cb_bytes=128)
    t1 = io.write(rr, str(tmp_path / "a"), transport="mp",
                  session=sess, **kw)
    t2 = io.write(rr, str(tmp_path / "b"), transport="mp",
                  session=sess, **kw)
    assert t1.plan_source == "compiled"
    assert t2.plan_source in ("session-hit", "session-trial")
    (key,) = list(sess._entries)
    entry = sess.entry(key)
    assert entry.executor == "mp"          # wall-clock totals, marked
    assert all(v > 0.0 for v in entry.totals.values())
    # the same knobs WITHOUT the transport are a different session key
    io.write(rr, str(tmp_path / "c"), session=sess, **kw)
    assert len(sess._entries) == 2
