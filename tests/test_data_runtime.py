"""Data pipeline determinism + fault-tolerance runtime."""
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticTokenPipeline, make_batch_iterator
from repro.runtime import HeartbeatMonitor, plan_remesh


def test_pipeline_deterministic():
    cfg = DataConfig(vocab=1000, seq=16, global_batch=4)
    p1 = SyntheticTokenPipeline(cfg)
    p2 = SyntheticTokenPipeline(cfg)
    for step in (0, 5, 17):
        a, b = p1.batch_at(step), p2.batch_at(step)
        assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_host_sharding_partitions():
    g = DataConfig(vocab=1000, seq=8, global_batch=8, num_hosts=1)
    h0 = DataConfig(vocab=1000, seq=8, global_batch=8, num_hosts=2,
                    host_id=0)
    h1 = DataConfig(vocab=1000, seq=8, global_batch=8, num_hosts=2,
                    host_id=1)
    assert h0.host_batch == 4
    b0 = SyntheticTokenPipeline(h0).batch_at(3)
    b1 = SyntheticTokenPipeline(h1).batch_at(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_iterator_restart_resumes_stream():
    cfg = DataConfig(vocab=500, seq=8, global_batch=2, prefetch=1,
                     deadline_s=5.0)
    it = make_batch_iterator(cfg, start_step=0)
    seq = [next(it)["tokens"] for _ in range(4)]
    it2 = make_batch_iterator(cfg, start_step=2)
    resumed = next(it2)["tokens"]
    assert np.array_equal(resumed, seq[2])


def test_labels_shift():
    cfg = DataConfig(vocab=100, seq=8, global_batch=1)
    b = SyntheticTokenPipeline(cfg).batch_at(0)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_heartbeat_failure_and_revive():
    t = [0.0]
    mon = HeartbeatMonitor(3, timeout_s=5.0, clock=lambda: t[0])
    assert mon.healthy()
    t[0] = 6.0
    mon.beat(0)
    mon.beat(1)
    assert mon.dead_hosts() == [2]
    mon.revive(2)
    assert mon.healthy()
    mon.inject_failure(1)
    assert mon.dead_hosts() == [1]
    mon.beat(1)  # beats from a failed host are ignored
    assert mon.dead_hosts() == [1]


def test_elastic_remesh_keeps_model_axis():
    plan = plan_remesh(total_devices=192, model_parallel=16,
                       old_data_parallel=16)
    assert plan.mesh_shape == (8, 16)
    assert plan.grad_accum == 2
    with pytest.raises(ValueError):
        plan_remesh(total_devices=8, model_parallel=16,
                    old_data_parallel=16)


def test_elastic_remesh_multi_pod():
    plan = plan_remesh(total_devices=480, model_parallel=16,
                       old_data_parallel=16, pods=2)
    assert plan.mesh_shape == (2, 8, 16)
    assert plan.axis_names == ("pod", "data", "model")
