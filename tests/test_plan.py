"""Plan IR: golden identity between the SPMD and host entry points,
auto resolutions (cb / method / depth), depth-k byte identity on the
host executor, and the depth-k pipeline-span model."""
import numpy as np
import pytest

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core import twophase
from repro.core.cost_model import (Workload, optimal_PL, optimal_depth,
                                   pipeline_span, twophase_cost)
from repro.core.domains import FileLayout, contiguous_layout
from repro.core.plan import IOConfig, compile_plan
from repro.core.rounds import peak_aggregator_buffer_elems
from repro.io_patterns import btio_pattern, e3sm_g_pattern


# ---------------------------------------------------------------------------
# golden test: both entry points compile the SAME plan
# ---------------------------------------------------------------------------

def _host(n_ranks=16, n_nodes=4, stripe=1024, count=4):
    return HostCollectiveIO(n_ranks=n_ranks, n_nodes=n_nodes,
                            stripe_size=stripe, stripe_count=count)


def test_plan_identity_spmd_vs_host():
    """The SPMD planner (one GA per node) and the host planner
    (one GA per stripe) must compile identical IOPlans for the same
    workload — the contract that makes the two executors run one
    schedule."""
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    for cb, pipeline, depth in ((4096, True, 3), (1024, True, 2),
                                (None, False, 2), (16384, True, 4)):
        cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=cb,
                       pipeline=pipeline, pipeline_depth=depth)
        p_spmd = twophase.plan_for(layout, cfg, n_nodes=4, n_ranks=16)
        # host convention: an explicit pipeline_depth implies pipelining
        p_host = _host().plan_for(method="twophase", cb_bytes=cb,
                                  pipeline=pipeline,
                                  pipeline_depth=depth if pipeline
                                  else None,
                                  file_len=1 << 16, req_cap=64,
                                  data_cap=4096)
        assert p_spmd == p_host
        assert hash(p_spmd) == hash(p_host)   # frozen + hashable IR


def test_plan_identity_tam():
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, coalesce_cap=32,
                   cb_buffer_size=2048, pipeline=True)
    p_spmd = twophase.plan_for(layout, cfg, n_nodes=4, n_ranks=16,
                               method="tam")
    p_host = _host().plan_for(method="tam", cb_bytes=2048, pipeline=True,
                              file_len=1 << 16, req_cap=64, data_cap=4096,
                              coalesce_cap=32)
    assert p_spmd == p_host
    assert p_spmd.method == "tam" and not p_spmd.tam_read_fallback


# ---------------------------------------------------------------------------
# the unified knob surface: config == legacy shim, plan-identical
# ---------------------------------------------------------------------------

def test_config_and_legacy_shim_compile_identical_plans():
    """``plan_for(config=IOConfig(...))`` and the deprecated per-knob
    kwargs are the SAME knob surface: given equivalent knobs they must
    compile field-identical (and identically hashed) plans — the shim
    is a spelling, not a second planner."""
    host = _host()
    for cb, pipe, depth, codec, pl in (
            (2048, True, 3, "rle", "spread"),
            (1024, False, None, None, None),
            (None, True, 2, None, (1, 0, 3, 2))):
        cfg = IOConfig(req_cap=64, data_cap=4096, coalesce_cap=32,
                       cb_buffer_size=cb, pipeline=pipe,
                       pipeline_depth=depth if depth is not None else 2,
                       slow_hop_codec=codec, placement=pl,
                       kernel_fusion="fused_round")
        p_cfg = host.plan_for(method="twophase", file_len=1 << 16,
                              config=cfg)
        p_legacy = host.plan_for(
            method="twophase", file_len=1 << 16, cb_bytes=cb,
            pipeline=pipe, pipeline_depth=depth if pipe else None,
            slow_hop_codec=codec, placement=pl,
            kernel_fusion="fused_round", req_cap=64, data_cap=4096,
            coalesce_cap=32)
        assert p_cfg == p_legacy
        assert hash(p_cfg) == hash(p_legacy)
        assert p_cfg.kernel_fusion == "fused_round"
    # sparse override: one explicit kwarg on top of a config rewrites
    # exactly that knob
    base_cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=2048,
                        slow_hop_codec="rle")
    p_over = host.plan_for(method="twophase", file_len=1 << 16,
                           config=base_cfg, slow_hop_codec=None)
    assert p_over.slow_hop_codec is None and p_over.cb == 2048


def test_legacy_write_kwargs_deprecation_and_byte_identity(tmp_path):
    """``HostCollectiveIO.write`` with bare per-knob kwargs warns
    (once) and still writes the exact bytes the config spelling
    writes; the config spelling is warning-free."""
    import warnings
    reqs = btio_pattern(16, n=32)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    io = _host()
    cfg = IOConfig(req_cap=0, data_cap=0, cb_buffer_size=2048,
                   pipeline=True, pipeline_depth=2, slow_hop_codec="rle")
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # any warning fails
        io.write(reqs, str(tmp_path / "cfg"), method="twophase",
                 config=cfg)
    with pytest.warns(DeprecationWarning):
        io.write(reqs, str(tmp_path / "legacy"), method="twophase",
                 cb_bytes=2048, pipeline_depth=2, slow_hop_codec="rle")
    a = io.read_file(str(tmp_path / "cfg"), file_len)
    b = io.read_file(str(tmp_path / "legacy"), file_len)
    assert np.array_equal(a, b)


def test_save_checkpoint_config_matches_legacy_shim(tmp_path):
    """The checkpoint layer rides the same surface: manager/save with
    ``config=`` produces the same checkpoint bytes as the deprecated
    kwargs, which warn."""
    from repro.checkpoint.checkpoint import (CheckpointManager,
                                             save_checkpoint)
    tree = {"w": np.arange(2048, dtype=np.float32)}
    io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=1024,
                          stripe_count=4)
    cfg = IOConfig(req_cap=0, data_cap=0, cb_buffer_size=1024,
                   pipeline=True, pipeline_depth=2)
    save_checkpoint(tree, tmp_path / "cfg", io=io, method="twophase",
                    config=cfg)
    with pytest.warns(DeprecationWarning):
        save_checkpoint(tree, tmp_path / "legacy", io=io,
                        method="twophase", cb_bytes=1024,
                        pipeline_depth=2)
    seg_a = (tmp_path / "cfg.seg0").read_bytes()
    seg_b = (tmp_path / "legacy.seg0").read_bytes()
    assert seg_a == seg_b
    mgr = CheckpointManager(directory=tmp_path / "mgr", io=io,
                            method="twophase", config=cfg)
    t = mgr.save(tree, 1)                       # no deprecation path
    assert t.rounds_executed >= 1


# ---------------------------------------------------------------------------
# plan semantics
# ---------------------------------------------------------------------------

def test_single_shot_is_the_one_round_plan():
    """cb_buffer_size=None compiles to cb == domain_len, n_rounds == 1 —
    there is no separate single-shot code path anymore."""
    layout = contiguous_layout(320, 2)
    plan = twophase.plan_for(layout, IOConfig(req_cap=8, data_cap=64),
                             n_nodes=2, n_ranks=8)
    assert plan.cb == plan.domain_len == 160
    assert plan.n_rounds == 1
    assert plan.pipeline_depth == 1            # pipeline off -> serial
    assert plan.in_flight_windows == 1


def test_depth_clamps_to_round_count():
    layout = contiguous_layout(320, 2)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=80,
                   pipeline=True, pipeline_depth=4)
    plan = twophase.plan_for(layout, cfg, n_nodes=2, n_ranks=8)
    assert plan.n_rounds == 2
    assert plan.pipeline_depth == 4            # the configured ring
    assert plan.in_flight_windows == 2         # what can actually fly


def test_plan_validation_happens_at_compile_time():
    with pytest.raises(ValueError):
        twophase.plan_for(contiguous_layout(321, 2),
                          IOConfig(req_cap=8, data_cap=64),
                          n_nodes=2, n_ranks=8)    # uneven domains
    with pytest.raises(ValueError):
        twophase.plan_for(contiguous_layout(320, 2),
                          IOConfig(req_cap=8, data_cap=64,
                                   cb_buffer_size=33),
                          n_nodes=2, n_ranks=8)    # 160 % 33 != 0


def test_tam_read_fallback_is_explicit():
    """make_tam_read's alias of the two-phase read schedule is recorded
    in the plan, and the plans differ ONLY in the method tag."""
    import dataclasses
    layout = contiguous_layout(320, 2)
    cfg = IOConfig(req_cap=8, data_cap=64, cb_buffer_size=32)
    p_tam = twophase.plan_for(layout, cfg, n_nodes=2, n_ranks=8,
                              method="tam", direction="read")
    p_2ph = twophase.plan_for(layout, cfg, n_nodes=2, n_ranks=8,
                              direction="read")
    assert p_tam.tam_read_fallback and not p_2ph.tam_read_fallback
    assert dataclasses.replace(p_tam, method="twophase",
                               tam_read_fallback=False) == p_2ph


def test_method_auto_follows_the_cost_model():
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 20)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=None)
    # btio-like: massive coalescing -> TAM wins by orders of magnitude
    w_tam = Workload(P=16384, nodes=256, P_G=56, k=80000,
                     total_bytes=200 * 2**30, coalesce_ratio=0.0176)
    # singleton: every rank one request, nothing to coalesce, tiny file
    w_2ph = Workload(P=8, nodes=8, P_G=8, k=1.0, total_bytes=1 << 20,
                     coalesce_ratio=1.0)
    for w in (w_tam, w_2ph):
        plan = compile_plan(layout, cfg, n_aggregators=4, n_nodes=4,
                            n_ranks=16, method="auto", workload=w)
        expect = ("tam" if optimal_PL(w)[1].total
                  < twophase_cost(w).total else "twophase")
        assert plan.method == expect
    assert compile_plan(layout, cfg, n_aggregators=4, n_nodes=4,
                        n_ranks=16, method="auto",
                        workload=w_tam).method == "tam"


def test_depth_auto_uniform_model_picks_two():
    """With the model's uniform per-round phases every depth >= 2 ties,
    so 'auto' resolves to the cheapest ring that achieves the overlap."""
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size=1024,
                   pipeline=True, pipeline_depth="auto")
    plan = twophase.plan_for(layout, cfg, n_nodes=4, n_ranks=16)
    assert plan.pipeline_depth == 2


def test_cb_and_depth_auto_jointly():
    layout = FileLayout(stripe_size=1024, stripe_count=4, file_len=1 << 16)
    cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size="auto",
                   pipeline=True, pipeline_depth="auto")
    plan = twophase.plan_for(layout, cfg, n_nodes=4, n_ranks=16)
    assert plan.domain_len % plan.cb == 0      # scheduler invariants
    assert plan.pipeline_depth >= 1
    plan.scheduler()                           # constructing IS the check


# ---------------------------------------------------------------------------
# depth-k pipeline span model
# ---------------------------------------------------------------------------

def test_pipeline_span_depth2_matches_closed_form():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 12))
        c, i = rng.random(n) * 10, rng.random(n) * 10
        closed = (c[0] + sum(max(c[t], i[t - 1]) for t in range(1, n))
                  + i[-1])
        assert pipeline_span(c, i, 2) == pytest.approx(closed)


def test_pipeline_span_monotone_in_depth():
    rng = np.random.default_rng(1)
    for _ in range(50):
        n = int(rng.integers(2, 15))
        c, i = rng.random(n) * 10, rng.random(n) * 10
        spans = [pipeline_span(c, i, d) for d in (1, 2, 3, 4, 5)]
        assert all(s2 <= s1 + 1e-12 for s1, s2 in zip(spans, spans[1:]))
        assert spans[0] == pytest.approx(float(c.sum() + i.sum()))


def test_optimal_depth_absorbs_multi_round_spike():
    """A single slow exchange stalls the double buffer; a depth-3 ring
    rides through it on pre-exchanged windows — the ROADMAP's
    multi-round incast spike, measurable only with non-uniform
    rounds."""
    comm = [1.0, 1.0, 8.0, 1.0, 1.0, 1.0]
    io = [3.0] * 6
    spans = {d: pipeline_span(comm, io, d) for d in (1, 2, 3, 4)}
    assert spans[3] < spans[2] < spans[1]
    d, s = optimal_depth(round_times=(comm, io))
    assert d == 3 and s == pytest.approx(spans[3])   # 4 ties, 3 wins


def test_optimal_depth_uniform_prefers_smallest():
    d, _ = optimal_depth(round_times=([2.0] * 5, [1.0] * 5))
    assert d == 2
    d1, _ = optimal_depth(round_times=([2.0], [1.0]))
    assert d1 == 1                              # single round: serial


# ---------------------------------------------------------------------------
# host executor: depth-k byte identity (k x rounds cross), auto depth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slow_hop_codec", [None, "rle"])
def test_host_depth_k_byte_identity(tmp_path, slow_hop_codec):
    """k in {1, 2, 3, 4} x round counts {1, 2, 5}: the ring is
    byte-identical to serial on the host executor for both schedules —
    with and without the lossless slow-hop codec (a codec changes the
    wire, never the file)."""
    P = 16
    reqs = e3sm_g_pattern(P)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=2)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    plan0 = io.plan_for(rank_requests=reqs, cb_bytes=1024)
    dom = plan0.domain_len
    for method in ("twophase", "tam"):
        la = 8 if method == "tam" else None
        t0 = io.write(reqs, str(tmp_path / f"s_{method}"), method=method,
                      local_aggregators=la)
        ref = io.read_file(str(tmp_path / f"s_{method}"), file_len)
        seen_rounds = set()
        # cb sizes giving exactly 1, 2, and 5 rounds of the padded domain
        for cb in (dom, -(-dom // 2 // 1024) * 1024,
                   -(-dom // 5 // 1024) * 1024):
            for k in (1, 2, 3, 4):
                t = io.write(reqs, str(tmp_path / f"k{k}cb{cb}_{method}"),
                             method=method, local_aggregators=la,
                             cb_bytes=cb, pipeline_depth=k,
                             slow_hop_codec=slow_hop_codec)
                got = io.read_file(str(tmp_path / f"k{k}cb{cb}_{method}"),
                                   file_len)
                assert np.array_equal(got, ref), (method, cb, k)
                assert t.pipeline_depth == min(k, t.rounds_executed)
                assert t.total <= t0.total + t.inter_comm  # sane scale
                assert t.slow_hop_codec == slow_hop_codec
                seen_rounds.add(t.rounds_executed)
        assert seen_rounds == {1, 2, 5}         # the cross was real


def test_host_auto_depth_agrees_with_measured_sweep(tmp_path):
    """pipeline_depth='auto' must land on the depth a brute-force sweep
    of the measured totals picks (ties resolve to the smallest depth on
    both sides)."""
    P = 16
    reqs = btio_pattern(P, n=32)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    totals = []
    for k in (1, 2, 3, 4):
        t = io.write(reqs, str(tmp_path / f"k{k}"), method="tam",
                     local_aggregators=8, cb_bytes=1024, pipeline_depth=k)
        totals.append(t.total)
    best = 1 + int(np.argmin(np.round(totals, 15)))
    ta = io.write(reqs, str(tmp_path / "auto"), method="tam",
                  local_aggregators=8, cb_bytes=1024,
                  pipeline_depth="auto")
    assert ta.pipeline_depth == min(best, ta.rounds_executed)
    assert ta.total == pytest.approx(min(totals))


def test_host_method_auto_writes_identical_bytes(tmp_path):
    P = 16
    reqs = e3sm_g_pattern(P)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=3)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    io.write(reqs, str(tmp_path / "t"), method="tam", local_aggregators=8)
    ref = io.read_file(str(tmp_path / "t"), file_len)
    ta = io.write(reqs, str(tmp_path / "a"), method="auto",
                  local_aggregators=8)
    assert np.array_equal(io.read_file(str(tmp_path / "a"), file_len), ref)
    assert ta.total > 0.0


# ---------------------------------------------------------------------------
# k x window memory accounting
# ---------------------------------------------------------------------------

def test_peak_buffer_scales_linearly_with_depth():
    base = peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192,
                                        pipeline_depth=1)
    window = 8 * 4096                           # n_nodes * min(dc, cb)
    for k in (2, 3, 4):
        pk = peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192,
                                          pipeline_depth=k)
        assert pk["rounds"] == base["rounds"] + (k - 1) * window
        # stage 1 is produced and consumed inside one exchange: no k x
        assert pk["tam_stage1_rounds"] == base["tam_stage1_rounds"]
    # the pipeline bool stays sugar for depth 2
    assert (peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192,
                                         pipeline=True)
            == peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192,
                                            pipeline_depth=2))
