"""Checkpoint save/restore + manager + restart equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (CheckpointManager, HostCollectiveIO,
                              restore_checkpoint, save_checkpoint)


def tree():
    return {"params": {"w": jnp.arange(640, dtype=jnp.float32)
                       .reshape(8, 80),
                       "b": jnp.full((3,), 2.5, jnp.bfloat16)},
            "opt": {"m": jnp.ones((8, 80), jnp.bfloat16),
                    "step": jnp.int32(41)}}


@pytest.mark.parametrize("method", ["tam", "twophase"])
def test_roundtrip(method, tmp_path):
    io = HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=512,
                          stripe_count=4)
    t = tree()
    save_checkpoint(t, tmp_path / "ck", step=41, io=io, method=method,
                    local_aggregators=4)
    got, step = restore_checkpoint(tmp_path / "ck", t)
    assert step == 41
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_restore_across_rank_counts(tmp_path):
    """The byte space is mesh/rank agnostic: write with 8 ranks, read
    with a 1-rank reader (elastic restart)."""
    io8 = HostCollectiveIO(n_ranks=8, n_nodes=4, stripe_size=256,
                           stripe_count=2)
    t = tree()
    save_checkpoint(t, tmp_path / "ck", io=io8, method="tam",
                    local_aggregators=4)
    got, _ = restore_checkpoint(tmp_path / "ck", t)
    assert np.array_equal(np.asarray(got["params"]["w"]),
                          np.asarray(t["params"]["w"]))


def test_manager_rolling_gc(tmp_path):
    io = HostCollectiveIO(n_ranks=4, n_nodes=2, stripe_size=256,
                          stripe_count=2)
    mgr = CheckpointManager(tmp_path, io, keep=2)
    t = tree()
    for step in (10, 20, 30):
        mgr.save(t, step)
    assert mgr.latest_step() == 30
    steps = sorted(int(p.name[5:13]) for p in
                   tmp_path.glob("ckpt_*.manifest.json"))
    assert steps == [20, 30]
    got, step = mgr.restore(t)
    assert step == 30


def test_manager_restore_specific_step(tmp_path):
    io = HostCollectiveIO(n_ranks=4, n_nodes=2, stripe_size=256,
                          stripe_count=2)
    mgr = CheckpointManager(tmp_path, io, keep=3)
    t = tree()
    mgr.save(t, 10)
    t2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t)
    mgr.save(t2, 20)
    got10, _ = mgr.restore(t, step=10)
    assert np.array_equal(np.asarray(got10["params"]["w"]),
                          np.asarray(t["params"]["w"]))
