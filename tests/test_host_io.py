"""Host-level TAM vs two-phase: byte-identical files, congestion and
coalescing behavior on the paper's I/O patterns."""
import numpy as np
import pytest

from repro.checkpoint.host_io import HostCollectiveIO
from repro.io_patterns import (btio_pattern, e3sm_f_pattern, e3sm_g_pattern,
                               s3d_pattern)

PATTERNS = {
    "e3sm_g": lambda P: e3sm_g_pattern(P),
    "e3sm_f": lambda P: e3sm_f_pattern(P),
    "btio": lambda P: btio_pattern(P, n=32),
    "s3d": lambda P: s3d_pattern(P, n=16),
}


def _reference_file(reqs, file_len):
    out = np.zeros(file_len, np.uint8)
    for offs, lens, data in reqs:
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        for o, l, s in zip(offs, lens, starts):
            out[o:o + l] = data[s:s + l]
    return out


def _file_len(reqs):
    return int(max((o[-1] + l[-1]) for o, l, _ in reqs if o.size))


@pytest.mark.parametrize("pattern", sorted(PATTERNS))
def test_tam_equals_twophase_equals_reference(pattern, tmp_path):
    P = 16
    reqs = PATTERNS[pattern](P)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=4096,
                          stripe_count=3)
    t_tam = io.write(reqs, str(tmp_path / "tam"), method="tam",
                     local_aggregators=8)
    t_2ph = io.write(reqs, str(tmp_path / "tp"), method="twophase")
    file_len = _file_len(reqs)
    got_tam = io.read_file(str(tmp_path / "tam"), file_len)
    got_2ph = io.read_file(str(tmp_path / "tp"), file_len)
    ref = _reference_file(reqs, file_len)
    assert np.array_equal(got_tam, ref)
    assert np.array_equal(got_2ph, ref)
    # congestion: TAM's global aggregators hear fewer senders
    assert t_tam.messages_at_ga <= t_2ph.messages_at_ga


def test_btio_coalesces_heavily(tmp_path):
    """Block patterns coalesce at local aggregators (paper SV-B: BTIO
    1.34e9 -> 2.36e7); interleaved E3SM-style patterns barely coalesce."""
    P = 16
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1 << 16,
                          stripe_count=2)
    t_btio = io.write(btio_pattern(P, n=32), str(tmp_path / "b"),
                      method="tam", local_aggregators=4)
    t_e3sm = io.write(e3sm_g_pattern(P), str(tmp_path / "e"),
                      method="tam", local_aggregators=4)
    assert t_btio.coalesce_ratio < 0.2
    assert t_btio.coalesce_ratio < t_e3sm.coalesce_ratio


def test_tam_reduces_modeled_comm_time(tmp_path):
    P = 32
    reqs = e3sm_f_pattern(P, reqs_per_rank=128, req_bytes=16)
    io = HostCollectiveIO(n_ranks=P, n_nodes=8, stripe_size=2048,
                          stripe_count=4)
    t_tam = io.write(reqs, str(tmp_path / "t"), method="tam",
                     local_aggregators=8)
    t_2ph = io.write(reqs, str(tmp_path / "p"), method="twophase")
    assert t_tam.inter_comm < t_2ph.inter_comm
    assert t_tam.total < t_2ph.total


def test_pl_sweep_has_interior_optimum(tmp_path):
    """Sweep P_L (paper Figs. 4-7): intra falls, inter grows."""
    P = 16  # BTIO needs a square process count
    reqs = btio_pattern(P, n=32)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=4096,
                          stripe_count=4)
    totals, intras, inters = [], [], []
    for pl in (4, 8, 16):
        t = io.write(reqs, str(tmp_path / f"x{pl}"), method="tam",
                     local_aggregators=pl)
        totals.append(t.total)
        intras.append(t.intra_comm + t.intra_sort + t.intra_memcpy)
        inters.append(t.inter_comm)
    assert intras[0] >= intras[-1]
    assert inters[0] <= inters[-1]


def test_backup_aggregator_on_failure(tmp_path):
    """A failed local aggregator is replaced by the next healthy group
    member; the written file is unchanged (straggler mitigation)."""
    P = 16
    reqs = e3sm_g_pattern(P)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=2048,
                          stripe_count=2)
    t_ok = io.write(reqs, str(tmp_path / "a"), method="tam",
                    local_aggregators=4)
    t_f = io.write(reqs, str(tmp_path / "b"), method="tam",
                   local_aggregators=4, failed_aggregators={0, 4})
    file_len = _file_len(reqs)
    assert np.array_equal(io.read_file(str(tmp_path / "a"), file_len),
                          io.read_file(str(tmp_path / "b"), file_len))
    assert t_f.intra_comm >= t_ok.intra_comm

    import pytest as _pytest
    with _pytest.raises(RuntimeError):
        io.write(reqs, str(tmp_path / "c"), method="tam",
                 local_aggregators=4,
                 failed_aggregators=set(range(P)))
