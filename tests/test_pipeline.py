"""Pipeline parallelism vs sequential reference (subprocess, 4 devices)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.models.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
S, M, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (M * mb, d))
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ W[s])

fn = pipeline_apply(stage_fn, mesh, microbatches=M)
out = jax.jit(fn)(W, x)
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5), \
    np.abs(np.asarray(out) - np.asarray(ref)).max()
print("pipeline OK")
"""


@pytest.mark.timeout(600)
def test_pipeline_matches_sequential(spmd_env):
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=spmd_env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "pipeline OK" in proc.stdout
