"""Pipelined round engine: overlap refinement in the cost model, the
``optimal_cb`` autotuner invariants (unit sweep + hypothesis property),
and the host path's max(comm, io) steady-state accounting."""
import numpy as np
import pytest

from repro.checkpoint.host_io import HostCollectiveIO
from repro.core.cost_model import (Machine, Workload, btio, cb_candidates,
                                   e3sm_f, optimal_cb, rounds_for_cb,
                                   tam_cost, twophase_cost,
                                   with_measured_rounds, with_overlap)
from repro.io_patterns import btio_pattern, e3sm_g_pattern

from tests._hyp_compat import HAVE_HYPOTHESIS, given, settings, st


# ---------------------------------------------------------------------------
# overlap refinement (cost_model refinement 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen", [btio, e3sm_f])
def test_pipelined_total_beats_serial_at_paper_scale(gen):
    """Acceptance: modeled pipelined < serial on btio and e3sm_f at
    P=16384 / 256 nodes (both schedules, multi-round cb)."""
    w = gen(16384, 256)
    ws = with_measured_rounds(w, rounds_for_cb(w, 4 << 20))
    wp = with_overlap(ws, 1.0)
    assert ws.rounds > 1
    for cost in (twophase_cost, lambda x: tam_cost(x, 256)):
        serial, pipe = cost(ws), cost(wp)
        assert pipe.total < serial.total
        assert pipe.overlap_saved > 0.0
        # only the smaller of (inter_comm, io) can hide, and only the
        # R-1 steady-state rounds of it
        assert pipe.overlap_saved < min(pipe.inter_comm, pipe.io)
        # overlap touches nothing else in the breakdown
        assert pipe.inter_comm == serial.inter_comm
        assert pipe.io == serial.io


def test_overlap_noop_cases():
    w = e3sm_f(16384, 256)
    # single round: no steady state, nothing hides
    w1 = with_overlap(with_measured_rounds(w, 1), 1.0)
    assert twophase_cost(w1).overlap_saved == 0.0
    # overlap=0: serial
    w0 = with_overlap(with_measured_rounds(w, 64), 0.0)
    assert twophase_cost(w0).overlap_saved == 0.0
    # overlap clamps at 1
    w64 = with_measured_rounds(w, 64)
    assert (twophase_cost(with_overlap(w64, 5.0)).overlap_saved
            == twophase_cost(with_overlap(w64, 1.0)).overlap_saved)


# ---------------------------------------------------------------------------
# optimal_cb autotuner
# ---------------------------------------------------------------------------

def _check_cb_invariants(cb, domain_bytes, stripe_bytes):
    assert cb >= 1
    assert cb % stripe_bytes == 0 or stripe_bytes % cb == 0
    if domain_bytes % stripe_bytes == 0:     # exact partition available
        assert domain_bytes % cb == 0


@pytest.mark.parametrize("gen", [btio, e3sm_f])
def test_optimal_cb_paper_workloads(gen):
    w = with_overlap(gen(16384, 256), 1.0)
    cb, cost = optimal_cb(w)
    _check_cb_invariants(cb, int(round(w.total_bytes / w.P_G)),
                         int(w.stripe_size))
    # never worse than the single-shot candidate (the largest one)
    single = max(cb_candidates(w.total_bytes / w.P_G, w.stripe_size))
    ws = with_measured_rounds(w, rounds_for_cb(w, single))
    assert cost.total <= twophase_cost(ws).total + 1e-12


def test_optimal_cb_respects_memory_bound():
    w = with_overlap(e3sm_f(16384, 256), 1.0)
    cap = 4 << 20
    cb, _ = optimal_cb(w, max_cb_bytes=cap)
    assert cb <= cap
    _check_cb_invariants(cb, int(round(w.total_bytes / w.P_G)),
                         int(w.stripe_size))


def test_cb_candidates_alignment_sweep():
    """Deterministic sweep of the property: every candidate satisfies
    the RoundScheduler invariants (stripe alignment always; exact
    domain divisibility whenever the domain is stripe-divisible)."""
    for stripe_pow in (10, 16, 20):
        stripe = 1 << stripe_pow
        for mult in (1, 3, 8, 56, 100):
            domain = stripe * mult
            for c in cb_candidates(domain, stripe):
                _check_cb_invariants(c, domain, stripe)
            # non-divisible domain: alignment still holds
            for c in cb_candidates(domain + 12345, stripe):
                _check_cb_invariants(c, domain + 12345, stripe)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=200, deadline=None)
@given(stripe_pow=st.integers(min_value=0, max_value=22),
       domain_mult=st.integers(min_value=1, max_value=4096),
       P_G=st.integers(min_value=1, max_value=128),
       k=st.floats(min_value=0.1, max_value=1e6),
       overlap=st.floats(min_value=0.0, max_value=1.0))
def test_optimal_cb_never_violates_invariants(stripe_pow, domain_mult,
                                              P_G, k, overlap):
    """Property: optimal_cb never returns a cb violating stripe
    alignment or the domain divisibility invariant."""
    stripe = 1 << stripe_pow
    domain = stripe * domain_mult
    w = Workload(P=1024, nodes=64, P_G=P_G, k=k,
                 total_bytes=float(domain * P_G), stripe_size=float(stripe),
                 overlap=overlap)
    cb, cost = optimal_cb(w)
    _check_cb_invariants(cb, domain, stripe)
    assert cost.total > 0.0


# ---------------------------------------------------------------------------
# host path: steady-state rounds pay max(comm, io), not the sum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["tam", "twophase"])
def test_host_pipeline_overlap_accounting(method, tmp_path):
    P = 16
    reqs = e3sm_g_pattern(P)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=3)
    la = 8 if method == "tam" else None
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    ts = io.write(reqs, str(tmp_path / "s"), method=method,
                  local_aggregators=la, cb_bytes=4096)
    tp = io.write(reqs, str(tmp_path / "p"), method=method,
                  local_aggregators=la, cb_bytes=4096, pipeline=True)
    # bytes identical through the double-buffered drain thread
    assert np.array_equal(io.read_file(str(tmp_path / "s"), file_len),
                          io.read_file(str(tmp_path / "p"), file_len))
    # same exchange, same drain — only the schedule differs
    assert tp.rounds_executed == ts.rounds_executed > 1
    assert tp.inter_comm == ts.inter_comm and tp.io == ts.io
    # steady state charged max(comm, io): the serial sum minus the
    # hidden (smaller) phase of the R-1 steady-state rounds
    assert 0.0 < tp.overlap_saved < min(tp.inter_comm, tp.io)
    assert tp.total == pytest.approx(ts.total - tp.overlap_saved)
    assert 0.0 < tp.overlap_fraction <= 1.0
    # serial path reports no overlap
    assert ts.overlap_saved == 0.0 and ts.overlap_fraction == 0.0


def test_host_pipeline_single_round_no_overlap(tmp_path):
    reqs = e3sm_g_pattern(4)
    io = HostCollectiveIO(n_ranks=4, n_nodes=2, stripe_size=1024,
                          stripe_count=2)
    t = io.write(reqs, str(tmp_path / "x"), method="twophase",
                 pipeline=True)   # cb=None: single shot, no steady state
    assert t.rounds_executed == 1
    assert t.overlap_saved == 0.0 and t.overlap_fraction == 0.0


def test_host_auto_cb(tmp_path):
    P = 16
    reqs = btio_pattern(P, n=32)
    io = HostCollectiveIO(n_ranks=P, n_nodes=4, stripe_size=1024,
                          stripe_count=4)
    file_len = int(max((o + ln).max() for o, ln, _ in reqs if o.size))
    t0 = io.write(reqs, str(tmp_path / "s"), method="tam",
                  local_aggregators=8)
    ta = io.write(reqs, str(tmp_path / "a"), method="tam",
                  local_aggregators=8, cb_bytes="auto", pipeline=True)
    assert np.array_equal(io.read_file(str(tmp_path / "s"), file_len),
                          io.read_file(str(tmp_path / "a"), file_len))
    cb = io.auto_cb_bytes(reqs, method="tam", local_aggregators=8)
    assert cb % io.stripe_size == 0 and cb >= io.stripe_size
    assert ta.rounds_executed >= 1


# ---------------------------------------------------------------------------
# SPMD "auto" resolution obeys the RoundScheduler invariants
# ---------------------------------------------------------------------------

def test_spmd_auto_cb_resolution():
    from repro.core.domains import FileLayout, contiguous_layout
    from repro.core.rounds import RoundScheduler
    from repro.core.twophase import IOConfig, resolve_cb_buffer_size

    for layout, n_nodes in ((contiguous_layout(1 << 20, 8), 8),
                            (FileLayout(stripe_size=1024, stripe_count=4,
                                        file_len=1 << 20), 4)):
        cfg = IOConfig(req_cap=64, data_cap=4096, cb_buffer_size="auto",
                       pipeline=True)
        resolved = resolve_cb_buffer_size(layout, n_nodes, 64, cfg)
        assert isinstance(resolved.cb_buffer_size, int)
        # constructing the scheduler IS the invariant check
        RoundScheduler(layout, n_nodes, resolved.cb_buffer_size)
        # non-auto configs pass through untouched
        assert resolve_cb_buffer_size(layout, n_nodes, 64,
                                      IOConfig(8, 8)) == IOConfig(8, 8)


def test_peak_buffer_tam_stage1_bounded():
    from repro.core.rounds import peak_aggregator_buffer_elems

    # stage-1 gather is O(cb) per rank once data_cap exceeds cb
    peaks = [peak_aggregator_buffer_elems(
        data_cap=dc, n_nodes=8, ranks_per_node=16,
        domain_len=1 << 20, cb_buffer_size=8192) for dc in
        (8192, 65536, 1 << 20)]
    assert len({p["tam_stage1_rounds"] for p in peaks}) == 1
    singles = [p["tam_stage1_single_shot"] for p in peaks]
    assert singles[0] < singles[1] < singles[2]
    # the pipeline's price: exactly two in-flight a2a window buffers;
    # stage 1 is produced and consumed inside one exchange step, so it
    # does NOT double
    serial = peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192)
    piped = peak_aggregator_buffer_elems(4096, 8, 16, 1 << 20, 8192,
                                         pipeline=True)
    extra = 8 * 4096   # one more n_nodes * min(data_cap, cb) image
    assert piped["rounds"] == serial["rounds"] + extra
    assert piped["tam_stage1_rounds"] == serial["tam_stage1_rounds"]
