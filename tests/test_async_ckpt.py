"""Async checkpointing: snapshot isolation, commit-last crash
consistency, one-in-flight backpressure, session feedback from the
drain thread, and the TrainLoop overlap hook."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import (CheckpointManager, HostCollectiveIO,
                              PendingCheckpoint, restore_checkpoint,
                              save_checkpoint, snapshot_tree)
from repro.core.faults import FaultSpec, UnrecoverableFaultError
from repro.core.session import IOSession
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.runtime import TrainLoop, TrainLoopConfig
from repro.runtime.elastic import find_restart_step


def tree():
    return {"params": {"w": np.arange(640, dtype=np.float32)
                       .reshape(8, 80),
                       "b": np.full((3,), 2.5, np.float32)},
            "opt": {"m": np.ones((8, 80), np.float32),
                    "step": np.int32(41)}}


def small_io(session=None):
    return HostCollectiveIO(n_ranks=8, n_nodes=2, stripe_size=512,
                            stripe_count=4, session=session)


def seg_bytes(directory, step):
    return [p.read_bytes() for p in
            sorted(directory.glob(f"ckpt_{step:08d}.seg*"))]


# ---------------------------------------------------------------------
# byte identity + future semantics
# ---------------------------------------------------------------------

def test_async_write_byte_identical_to_sync(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    pending = mgr.save_async(t, 10)
    assert isinstance(pending, PendingCheckpoint)
    manifest, timings = pending.result()
    assert pending.done()
    assert manifest["step"] == 10
    assert timings.snapshot_seconds >= 0.0
    assert timings.drain_wall_seconds > 0.0
    assert 0.0 <= timings.hidden_fraction <= 1.0
    mgr.save(t, 20)
    assert seg_bytes(tmp_path, 10) == seg_bytes(tmp_path, 20)
    got, step = mgr.restore(t, step=10)
    assert step == 10
    for a, b in zip(np.asarray(t["params"]["w"]),
                    np.asarray(got["params"]["w"])):
        np.testing.assert_array_equal(a, b)


def test_wait_timeout_and_result_alias(tmp_path):
    pending = save_checkpoint(tree(), tmp_path / "ck", step=1,
                              io=small_io(), async_=True)
    with pytest.raises(TimeoutError):
        # a zero timeout may legitimately succeed if the tiny drain
        # already finished; force the losing race with a fresh future
        # that can never complete
        stuck = PendingCheckpoint(tmp_path / "never", 0, 0.0)
        stuck.wait(timeout=0.01)
    m1, t1 = pending.result()
    m2, t2 = pending.wait()
    assert m1 is m2 and t1 is t2   # idempotent after completion


# ---------------------------------------------------------------------
# snapshot isolation: the race test
# ---------------------------------------------------------------------

def test_mutation_after_save_async_does_not_change_bytes(tmp_path):
    t = tree()
    expected = snapshot_tree(t)
    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    mgr.save_async(t, 10)
    # the training step "runs" immediately after the future returns,
    # clobbering the live buffers in place while the drain is (maybe
    # still) writing
    t["params"]["w"][:] = -1.0
    t["opt"]["m"][:] = 999.0
    mgr.block_until_done()
    got, _ = mgr.restore(expected, step=10)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  expected["params"]["w"])
    np.testing.assert_array_equal(np.asarray(got["opt"]["m"]),
                                  expected["opt"]["m"])


def test_snapshot_tree_copies_leaves():
    t = tree()
    snap = snapshot_tree(t)
    t["params"]["w"][0, 0] = -123.0
    assert snap["params"]["w"][0, 0] == 0.0
    # jax arrays snapshot to host numpy
    snap2 = snapshot_tree({"x": jnp.ones(4)})
    assert isinstance(snap2["x"], np.ndarray)


# ---------------------------------------------------------------------
# crash consistency: failed/killed drains are never restorable
# ---------------------------------------------------------------------

def test_failed_async_write_leaves_previous_step_restorable(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase")
    mgr.save(t, 10)
    good = seg_bytes(tmp_path, 10)
    pending = mgr.save_async(t, 20,
                             faults=FaultSpec(lost={(0, 0): 99}))
    with pytest.raises(UnrecoverableFaultError):
        pending.wait()
    # the failure was observed through the future, so the manager
    # surfaces it exactly once: block_until_done stays quiet and the
    # manager is usable for the next save
    mgr.block_until_done()
    # commit-last: the dead drain left no manifest for step 20
    assert mgr.latest_step() == 10
    assert find_restart_step(tmp_path) == 10
    assert not (tmp_path / "ckpt_00000020.manifest.json").exists()
    assert seg_bytes(tmp_path, 10) == good
    got, step = mgr.restore(t)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  t["params"]["w"])


def test_unobserved_async_failure_raises_at_next_save(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase")
    mgr.save_async(t, 10, faults=FaultSpec(lost={(0, 0): 99}))
    # nobody waited on the future: the next save's barrier re-raises
    # so the failure is never silently swallowed
    with pytest.raises(UnrecoverableFaultError):
        mgr.save(t, 20)
    # the manager recovered: pending is cleared and saves work again
    mgr.save(t, 30)
    assert mgr.latest_step() == 30


def test_find_restart_step_skips_uncommitted_and_torn(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    mgr.save(t, 10)
    mgr.save(t, 20)
    # fabricate a kill mid-async-drain of step 30: segments (some of
    # them) landed, the manifest commit never ran
    (tmp_path / "ckpt_00000030.seg0").write_bytes(b"\x00" * 64)
    (tmp_path / "ckpt_00000030.seg1").write_bytes(b"\x00" * 16)
    assert find_restart_step(tmp_path) == 20
    # fabricate a torn segment of step 20 (drain died mid-segment,
    # .partial marker from core.faults still present)
    (tmp_path / "ckpt_00000020.seg0.partial").write_text("torn")
    assert find_restart_step(tmp_path) == 10
    (tmp_path / "ckpt_00000020.seg0.partial").unlink()
    assert find_restart_step(tmp_path) == 20
    # a manifest that outlived its segments is skipped too
    for seg in tmp_path.glob("ckpt_00000020.seg*"):
        seg.unlink()
    assert find_restart_step(tmp_path) == 10


def test_failed_drain_then_save_async_succeeds(tmp_path):
    """The one-in-flight slot must not wedge on a dead future: a drain
    that failed (and was observed through the future) is dropped by the
    next save_async's barrier, which then launches normally."""
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase")
    mgr.save(t, 10)
    pending = mgr.save_async(t, 20, faults=FaultSpec(lost={(0, 0): 99}))
    with pytest.raises(UnrecoverableFaultError):
        pending.wait()
    # observed-once: the next save_async must NOT re-raise, must clear
    # the dead future from the slot, and must commit its own step
    p2 = mgr.save_async(t, 30)
    assert p2 is not pending
    assert mgr.pending is p2
    p2.result()
    assert mgr.latest_step() == 30
    # and the unobserved flavor surfaces exactly once before recovering
    mgr.save_async(t, 40, faults=FaultSpec(lost={(0, 0): 99}))
    with pytest.raises(UnrecoverableFaultError):
        mgr.save_async(t, 50)
    mgr.save_async(t, 60).result()
    assert mgr.latest_step() == 60


def test_interrupted_barrier_keeps_live_future(tmp_path):
    """An interrupt while WAITING on a live drain must not clear the
    slot: the drain is still running, and dropping the future would let
    the next save_async start a second concurrent write."""
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase")
    stuck = PendingCheckpoint(tmp_path / "never", 0, 0.0)
    stuck.wait = lambda timeout=None: (_ for _ in ()).throw(
        KeyboardInterrupt())
    mgr.pending = stuck
    with pytest.raises(KeyboardInterrupt):
        mgr.block_until_done()
    assert mgr.pending is stuck     # live drain not orphaned
    # once the drain actually finishes, the barrier clears the slot
    del stuck.wait                  # restore the real method
    from repro.checkpoint.host_io import IOTimings
    stuck._finish({"step": 0}, IOTimings())
    mgr.block_until_done()
    assert mgr.pending is None


def test_find_restart_step_skips_all_zero_length_segments(tmp_path):
    """Created-but-never-written segments hold none of the manifest's
    bytes — a step whose segment files are all empty is as dead as one
    with no segment files at all."""
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    mgr.save(t, 10)
    mgr.save(t, 20)
    for seg in tmp_path.glob("ckpt_00000020.seg*"):
        seg.write_bytes(b"")
    assert find_restart_step(tmp_path) == 10
    # one segment holding bytes again re-qualifies the step (the
    # all-zero disqualifier is all-or-nothing, like the no-segments one)
    (tmp_path / "ckpt_00000020.seg0").write_bytes(b"\x01" * 8)
    assert find_restart_step(tmp_path) == 20


def test_find_restart_step_empty_dir(tmp_path):
    assert find_restart_step(tmp_path) is None
    (tmp_path / "ckpt_00000010.seg0").write_bytes(b"orphan")
    assert find_restart_step(tmp_path) is None


def test_kill_and_resume_mid_async_write(tmp_path):
    """The acceptance-criteria scenario: a process dies mid-async-write;
    the restart discovers the last committed step and restores it
    byte-identically."""
    t = tree()
    sess = IOSession()
    mgr = CheckpointManager(tmp_path, small_io(sess), method="tam",
                            local_aggregators=4, session=sess)
    mgr.save(t, 10)
    # the "kill": an async drain of step 20 that dies before its
    # commit point (unrecoverable fault on the collective write) — the
    # process never gets to wait() on it
    mgr.save_async(t, 20, faults=FaultSpec(lost={(0, 0): 99}))
    mgr.pending._event.wait(30)   # let the drain thread die
    # --- restart: a NEW manager on the same directory ---
    mgr2 = CheckpointManager(tmp_path, small_io(), method="tam",
                             local_aggregators=4)
    step = find_restart_step(tmp_path)
    assert step == 10
    got, got_step = mgr2.restore(t, step=step)
    assert got_step == 10
    for a, b in zip(np.asarray(t["params"]["w"]),
                    np.asarray(got["params"]["w"])):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------
# bounded queue + session feedback
# ---------------------------------------------------------------------

def test_at_most_one_in_flight(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    p1 = mgr.save_async(t, 10)
    p2 = mgr.save_async(t, 20)
    # save_async blocked on p1 before launching p2
    assert p1.done()
    assert p2 is mgr.pending
    mgr.block_until_done()
    assert mgr.pending is None
    assert mgr.latest_step() == 20


def test_async_saves_feed_session_plan_cache(tmp_path):
    t = tree()
    sess = IOSession()
    mgr = CheckpointManager(tmp_path, small_io(sess), method="tam",
                            local_aggregators=4, session=sess)
    _, t1 = mgr.save_async(t, 10).result()
    _, t2 = mgr.save_async(t, 20).result()
    _, t3 = mgr.save_async(t, 30).result()
    # the drain thread drove the full session protocol: the steady
    # state reuses the measured-best plan
    assert t1.plan_source == "compiled"
    assert t3.plan_source == "session-hit"
    assert sess.hits >= 1


def test_sync_save_after_async_drains_first(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase")
    mgr.save_async(t, 10)
    mgr.save(t, 20)   # barrier first: steps commit in save order
    assert mgr.pending is None
    steps = sorted(int(p.name[5:13]) for p in
                   tmp_path.glob("ckpt_*.manifest.json"))
    assert steps == [10, 20]


def test_rolling_gc_runs_on_drain_thread(tmp_path):
    t = tree()
    mgr = CheckpointManager(tmp_path, small_io(), method="twophase",
                            keep=2)
    for step in (10, 20, 30, 40):
        mgr.save_async(t, step)
    mgr.block_until_done()
    steps = sorted(int(p.name[5:13]) for p in
                   tmp_path.glob("ckpt_*.manifest.json"))
    assert steps == [30, 40]
    # GC'd steps left no orphan segments behind
    assert not list(tmp_path.glob("ckpt_00000010.seg*"))
    assert not list(tmp_path.glob("ckpt_00000020.seg*"))


# ---------------------------------------------------------------------
# the TrainLoop overlap hook
# ---------------------------------------------------------------------

def test_trainloop_async_checkpoint_end_to_end(tmp_path):
    data = SyntheticTokenPipeline(DataConfig(vocab=64, seq=8,
                                             global_batch=2))

    def train_step(params, opt_state, batch):
        params = {"w": params["w"] + 1.0}
        return params, opt_state, np.float32(0.5)

    mgr = CheckpointManager(tmp_path, small_io(), method="tam",
                            local_aggregators=4)
    loop = TrainLoop(
        TrainLoopConfig(total_steps=9, checkpoint_every=3,
                        async_checkpoint=True),
        train_step, data, mgr)
    params = {"w": np.zeros((8, 80), np.float32)}
    p_out, _, last = loop.run(params, {"s": np.int32(0)})
    assert last == 9
    # run() drained the trailing async save before returning
    assert mgr.pending is None
    assert mgr.latest_step() == 9
    state = {"params": {"w": params["w"]}, "opt": {"s": np.int32(0)}}
    got, step = mgr.restore(
        {"params": {"w": np.zeros((8, 80), np.float32)},
         "opt": {"s": np.int32(0)}})
    assert step == 9
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(p_out["w"]))
    assert state is not None
