"""Validate the analytical model against the paper's own claims."""
import math

import pytest

from repro.core.cost_model import (Machine, Workload, btio, e3sm_f, e3sm_g,
                                   optimal_PL, receives_per_global_aggregator,
                                   s3d, sort_complexity, speedup, tam_cost,
                                   twophase_cost)


def test_twophase_is_tam_with_PL_equal_P():
    w = e3sm_f(P=4096, nodes=64)
    assert tam_cost(w, w.P).total == twophase_cost(w).total


def test_congestion_metric():
    w = e3sm_g(P=16384, nodes=256)
    assert receives_per_global_aggregator(w, None) == 16384 / 56
    assert receives_per_global_aggregator(w, 256) == 256 / 56


def test_sort_complexity_paper_section_IV_D():
    w = e3sm_f(P=16384, nodes=256)
    # TAM sorting is cheaper whenever P_L >= P_G (paper claim)
    assert sort_complexity(w, 256) < sort_complexity(w, None)


def test_paper_speedup_range_at_scale():
    """Paper: 3x-29x end-to-end at 16384 procs / 256 nodes."""
    for mk in (e3sm_f, e3sm_g, btio, s3d):
        w = mk(16384, 256)
        s = speedup(w, 256)
        assert 2.0 < s < 60.0, (mk.__name__, s)
    # the most communication-bound case should sit in the upper range
    assert speedup(e3sm_f(16384, 256), 256) > 5.0


def test_btio_absolute_anchor():
    """Paper SV-B: TAM BTIO at 16384 procs finishes in ~40 s at
    >4-5 GiB/s — the strongest absolute-number anchor we have."""
    w = btio(16384, 256)
    t = tam_cost(w, 256).total
    assert 20 < t < 80
    assert w.total_bytes / t / 2**30 > 3.5


def test_optimal_PL_is_moderate():
    """Paper SV-A: P_L = 256 best on Theta among {nodes * 2^i}."""
    w = e3sm_f(16384, 256)
    best, _ = optimal_PL(w)
    assert 256 <= best <= 2048  # optimum is far from both extremes
    assert tam_cost(w, best).total < twophase_cost(w).total


def test_intra_inter_tradeoff_monotonic():
    """f(P_L) falls with P_L; g(P_L) grows (paper SIV-D)."""
    w = btio(4096, 64)
    pls = [64, 128, 256, 512, 1024]
    intra = [tam_cost(w, pl).intra_comm + tam_cost(w, pl).intra_sort
             for pl in pls]
    inter = [tam_cost(w, pl).inter_comm for pl in pls]
    assert all(a >= b for a, b in zip(intra, intra[1:]))
    assert all(a <= b for a, b in zip(inter, inter[1:]))


def test_strong_scaling_twophase_degrades():
    """Two-phase comm grows with P (paper Fig. 3 a/b shape); TAM at
    fixed P_L does not."""
    t2 = [twophase_cost(e3sm_f(p, max(p // 64, 1))).comm
          for p in (1024, 4096, 16384)]
    assert t2[0] < t2[1] < t2[2]
    tt = [tam_cost(e3sm_f(p, max(p // 64, 1)), 256).inter_comm
          for p in (1024, 4096, 16384)]
    assert max(tt) / min(tt) < 2.5  # flat-ish in P


def test_tpu_preset():
    m = Machine.tpu_v5e()
    w = Workload(P=512, nodes=2, P_G=16, k=1000, total_bytes=1 << 30,
                 coalesce_ratio=0.1)
    assert tam_cost(w, 32, m).total < twophase_cost(w, m).total
